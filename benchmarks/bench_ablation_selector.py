"""Table 2 / Fig. 6: device-selector ablation (FLUDE w/o selector).

The ablated variant is a registered policy — exactly the plug-in path a
new scenario policy takes; no runner monkey-patching.
"""
import numpy as np

from benchmarks.common import emit, standard_setup, timed_run
from repro.fl import RoundPlan, register_policy
from repro.fl.policies import FludePolicy


@register_policy("flude_no_selector")
class FludeNoSelector(FludePolicy):
    """FLUDE with the device selector disabled: random selection, but
    caching + staleness-aware distribution still on."""

    def plan(self, state, obs, rng):
        state, plan = super().plan(state, obs, rng)
        N = self.fl_cfg.num_clients
        rs = np.random.RandomState(1000 + obs.rnd)
        sel = np.zeros(N, bool)
        idx = np.flatnonzero(obs.online)
        take = min(self.fl_cfg.clients_per_round, idx.size)
        sel[rs.choice(idx, take, replace=False)] = True
        # rebuild distribution decision for the random selection
        stamp = np.asarray(obs.caches.round_stamp)
        has = stamp >= 0
        stale = np.where(has, obs.rnd - stamp, 1 << 20)
        resume = sel & has & (stale <= float(
            state.core.distributor.w_threshold))
        # SAME quorum rule as native FLUDE (floor(|S|·R̄), R̄ straight from
        # the FludePlan) so the ablation isolates the selector, not the
        # round-termination rule
        r_bar = float(state.last.avg_dependability)
        quorum = max(np.floor(sel.sum() * r_bar), 1.0) if take else 0.0
        return state, RoundPlan.create(sel, sel & ~resume, resume,
                                       min(quorum, float(sel.sum())))


def run():
    sim, fl, data = standard_setup()
    h_full, w1 = timed_run("flude", data, sim, fl)
    h_abl, w2 = timed_run("flude_no_selector", data, sim, fl)

    # near-asymptote target: early rounds are policy-agnostic
    target = min(h_full.acc[-1], h_abl.acc[-1]) * 0.995
    out = {
        "flude": {"acc": h_full.acc[-1],
                  "tta": h_full.time_to_accuracy(target)},
        "no_selector": {"acc": h_abl.acc[-1],
                        "tta": h_abl.time_to_accuracy(target)},
    }
    emit("ablation_selector", (w1 + w2) * 1e6 / (2 * sim.rounds),
         f"acc_full={out['flude']['acc']:.4f};"
         f"acc_ablated={out['no_selector']['acc']:.4f};"
         f"tta_full={out['flude']['tta']:.0f};"
         f"tta_ablated={out['no_selector']['tta']:.0f}",
         record=out)
    return out


if __name__ == "__main__":
    run()
