"""Table 2 / Fig. 6: device-selector ablation (FLUDE w/o selector)."""
import dataclasses

from benchmarks.common import emit, standard_setup, timed_run
from repro.fl import runner as R


class FludeNoSelector(R.FludePolicy):
    """FLUDE with the device selector disabled: random selection, but
    caching + staleness-aware distribution still on."""
    name = "flude_no_selector"

    def plan(self, rnd, online, caches, rng):
        import numpy as np
        plan = super().plan(rnd, online, caches, rng)
        N = self.fl_cfg.num_clients
        rs = np.random.RandomState(1000 + rnd)
        sel = np.zeros(N, bool)
        idx = np.flatnonzero(online)
        take = min(self.fl_cfg.clients_per_round, idx.size)
        sel[rs.choice(idx, take, replace=False)] = True
        # rebuild distribution decision for the random selection
        stamp = caches.round_stamp
        has = np.asarray(stamp) >= 0
        stale = np.where(has, rnd - np.asarray(stamp), 1 << 20)
        resume = sel & has & (stale <= float(
            self.state.distributor.w_threshold))
        # SAME quorum rule as native FLUDE (floor(|S|·R̄)) so the ablation
        # isolates the selector, not the round-termination rule
        r_bar = float(plan["quorum"]) / max(plan["selected"].sum(), 1)
        return {"selected": sel, "distribute": sel & ~resume,
                "resume": resume,
                "quorum": max(np.floor(sel.sum() * r_bar), 1.0)}


def run():
    sim, fl, data = standard_setup()
    h_full, w1 = timed_run("flude", data, sim, fl)

    # monkey-register the ablated policy
    orig = R.make_policy

    def patched(name, sim_cfg, fl_cfg, fleet):
        if name == "flude_no_selector":
            return FludeNoSelector(sim_cfg, fl_cfg)
        return orig(name, sim_cfg, fl_cfg, fleet)

    R.make_policy = patched
    try:
        h_abl, w2 = timed_run("flude_no_selector", data, sim, fl)
    finally:
        R.make_policy = orig

    # near-asymptote target: early rounds are policy-agnostic
    target = min(h_full.acc[-1], h_abl.acc[-1]) * 0.995
    out = {
        "flude": {"acc": h_full.acc[-1],
                  "tta": h_full.time_to_accuracy(target)},
        "no_selector": {"acc": h_abl.acc[-1],
                        "tta": h_abl.time_to_accuracy(target)},
    }
    emit("ablation_selector", (w1 + w2) * 1e6 / (2 * sim.rounds),
         f"acc_full={out['flude']['acc']:.4f};"
         f"acc_ablated={out['no_selector']['acc']:.4f};"
         f"tta_full={out['flude']['tta']:.0f};"
         f"tta_ablated={out['no_selector']['tta']:.0f}",
         record=out)
    return out


if __name__ == "__main__":
    run()
