"""Table 2 / Fig. 6: device-selector ablation (FLUDE w/o selector).

The ablated variant is a registered policy — exactly the plug-in path a
new scenario policy takes; no runner monkey-patching.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, standard_setup, timed_run
from repro import core
from repro.fl import RoundPlan, register_policy
from repro.fl.policies import FludePolicy, FludePolicyState


@functools.lru_cache(maxsize=4)
def _ablated_plan_jit(fl_cfg):
    """Native FLUDE distribution (Eq. 4) + quorum rule, but over an
    externally chosen selection — only Algorithm 1 is ablated."""

    def fn(st, caches, sel):
        stale = core.staleness(caches, st.round)
        dist = core.plan_distribution(
            st.distributor, sel, st.in_v, core.has_cache(caches), stale,
            lam=fl_cfg.lam, mu=fl_cfg.mu, w_min=fl_cfg.w_min,
            w_max=fl_cfg.w_max, mode=fl_cfg.distribution_mode)
        r_sel = jnp.where(sel, core.dependability(st.belief), 0.0)
        n_sel = jnp.maximum(sel.sum(), 1)
        r_bar = r_sel.sum() / n_sel
        cost = core.predicted_comm_cost(dist.distribute, sel, r_bar)
        quorum = jnp.where(sel.sum() > 0,
                           jnp.maximum(jnp.floor(sel.sum() * r_bar), 1.0),
                           0.0)
        return core.FludePlan(sel, dist.distribute, dist.resume, cost,
                              quorum, r_bar, r_sel, dist.state)

    return jax.jit(fn)


@register_policy("flude_no_selector")
class FludeNoSelector(FludePolicy):
    """FLUDE with the device selector disabled: random selection, but
    caching + staleness-aware distribution still on."""

    def __init__(self, sim_cfg, fl_cfg, fleet=None):
        super().__init__(sim_cfg, fl_cfg, fleet)
        self._abl_plan_jit = _ablated_plan_jit(fl_cfg)

    def plan(self, state, obs, rng):
        # the inherited observe() parks the previous round's receipts for
        # the next plan to fold in — apply them before planning, like the
        # base policy's fused update+plan dispatch does
        st = self._flush(state)
        N = self.fl_cfg.num_clients
        rs = np.random.RandomState(1000 + obs.rnd)
        sel = np.zeros(N, bool)
        idx = np.flatnonzero(obs.online)
        take = min(self.fl_cfg.clients_per_round, idx.size)
        if take:
            sel[rs.choice(idx, take, replace=False)] = True
        # the FludePlan stored in state.last must describe THIS selection —
        # the inherited observe() books Beta-belief successes/failures
        # against state.last.selected, so it has to match the executed plan
        p = self._abl_plan_jit(st, obs.caches, jnp.asarray(sel))
        quorum = min(float(p.quorum), float(sel.sum()))
        plan = RoundPlan.create(sel, np.asarray(p.distribute),
                                np.asarray(p.resume), quorum)
        return FludePolicyState(st, p, None), plan


def run():
    sim, fl, data = standard_setup()
    h_full, w1 = timed_run("flude", data, sim, fl)
    h_abl, w2 = timed_run("flude_no_selector", data, sim, fl)

    # near-asymptote target: early rounds are policy-agnostic
    target = min(h_full.acc[-1], h_abl.acc[-1]) * 0.995
    out = {
        "flude": {"acc": h_full.acc[-1],
                  "tta": h_full.time_to_accuracy(target)},
        "no_selector": {"acc": h_abl.acc[-1],
                        "tta": h_abl.time_to_accuracy(target)},
    }
    emit("ablation_selector", (w1 + w2) * 1e6 / (2 * sim.rounds),
         f"acc_full={out['flude']['acc']:.4f};"
         f"acc_ablated={out['no_selector']['acc']:.4f};"
         f"tta_full={out['flude']['tta']:.0f};"
         f"tta_ablated={out['no_selector']['tta']:.0f}",
         record=out)
    return out


if __name__ == "__main__":
    run()
