"""Beyond-paper selection variants: Thompson sampling vs posterior mean.

Thompson keeps probing uncertain devices after ε decays — hypothesis: it
recovers stragglers' data better in class-correlated fleets at equal time.
"""
import dataclasses

from benchmarks.common import emit, standard_setup, timed_run


def run():
    sim, fl, data = standard_setup(group_mode="class")
    out = {}
    for mode in ("mean", "thompson"):
        cfg = dataclasses.replace(fl, selection_mode=mode)
        h, w = timed_run("flude", data, sim, cfg)
        out[mode] = {"acc": h.acc[-1], "rounds": len(h.acc),
                     "comm_mb": h.comm_mb[-1],
                     "worst_class": float(sorted(h.per_class_acc)[0])}
        emit(f"beyond_selection_{mode}", w * 1e6 / max(len(h.acc), 1),
             f"acc={h.acc[-1]:.4f};worst_class={out[mode]['worst_class']:.3f};"
             f"rounds={len(h.acc)}")
    emit("beyond_selection_summary", 0.0,
         f"thompson_minus_mean_acc="
         f"{out['thompson']['acc'] - out['mean']['acc']:+.4f};"
         f"worst_class_delta="
         f"{out['thompson']['worst_class'] - out['mean']['worst_class']:+.3f}",
         record=out)
    return out


if __name__ == "__main__":
    run()
