"""Old host-side server loop vs device-resident FleetEngine, rounds/sec.

The baseline reconstructs the pre-fusion runner: every round it pulls the
stacked trainer outputs to host, runs the server step in numpy (weights
incl. staleness discount, leaf-wise weighted aggregation, C3 cache
bookkeeping), pushes the new global model + caches back to device, and
evaluates test accuracy — the host-side loop the typed FleetEngine
replaced.  The engine keeps params and caches device-resident across
rounds and syncs to host only at eval boundaries.

Each loop runs with its own default eval cadence (host loop: every
round, like the old runner; engine: eval boundaries only) — the cadence
difference is part of what the device-resident design buys and is
included in the measured speedup deliberately.  Numerical equivalence of
the two paths is NOT asserted here (the two runs train for different
cumulative rounds); that is covered by the golden-file tests in
tests/test_policy_api.py.

Fleet sizes N ∈ {256, 1024, 4096}; records results/benchmarks/
BENCH_engine.json.

``--mesh`` instead sweeps the client-mesh round path: forced host device
counts 1/2/4/8 (each in a fresh subprocess so
``--xla_force_host_platform_device_count`` lands before the jax import),
recording sharded rounds/sec and the fused server step's peak live bytes
with buffer donation on vs off, merged into the same JSON under "mesh".
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import QUICK, RESULTS, emit
from repro import core
from repro.configs.base import FLConfig
from repro.data.synthetic import federated_classification
from repro.fl import Fleet, FleetEngine, SimConfig, make_trainer
from repro.fl import classifier as CLF
from repro.obs import Tracer

# benchmark clock: every timed section is a tracer span, so one run's
# measurement timeline can be dumped as a Perfetto trace if needed
TRACER = Tracer()

BIG = 1 << 20
SIZES = (64, 256) if QUICK else (256, 1024, 4096)
ROUNDS = 3 if QUICK else 5
WARMUP = 1
POLICY = "flude"
MESH_DEVICES = (1, 2, 4, 8)
N_MESH = 256 if QUICK else 4096


def _setup(n):
    sim = SimConfig(num_clients=n, rounds=WARMUP + ROUNDS, seed=7,
                    local_steps=2, batch_size=16)
    fl = FLConfig(num_clients=n, clients_per_round=max(n // 8, 8))
    data = federated_classification(n, seed=8, n_per_client=16)
    return sim, fl, data


def host_loop(data, sim, fl, n_rounds, fleet):
    """Per-round host round-trip of the server step (the old loop).

    FLUDE planning/bookkeeping run eagerly (op-by-op, as the dict-era
    runner did) rather than through the policy's jitted plan path.
    ``fleet`` is constructed by the caller so every variant at a sweep
    point runs on the same identically-seeded draw stream."""
    N = fl.num_clients
    hints = jnp.asarray(fleet.battery * fleet.stability, jnp.float32)
    fstate = core.init_state(fl)
    trainer = make_trainer(sim, data)
    acc_fn = jax.jit(CLF.clf_accuracy)
    params = CLF.init_classifier(jax.random.key(sim.seed + 1),
                                 dim=data.x.shape[-1],
                                 num_classes=data.num_classes)
    caches = core.init_caches(params, N)
    cache_every = jnp.asarray(np.clip(np.round(core.adaptive_cache_interval(
        2.0, fleet.battery, fleet.stability)), 1, 4).astype(np.int32))
    n_samples = np.full(N, data.x.shape[1], np.float32)
    test_x = jnp.asarray(data.test_x)
    test_y = jnp.asarray(data.test_y)
    rng = jax.random.key(sim.seed)
    acc = float("nan")

    def _round(rnd):
        nonlocal rng, fstate, caches, params, acc
        rng, k_sel = jax.random.split(rng)
        online = fleet.online_mask()
        p = core.plan_round(fstate, caches, jnp.asarray(online), fl, k_sel,
                            explore_hints=hints)
        selected = np.asarray(p.selected)
        distribute = np.asarray(p.distribute)
        resume = np.asarray(p.resume)

        progress_h = np.asarray(caches.progress)
        stamp_h = np.asarray(caches.round_stamp)
        prior_steps = np.round(progress_h * sim.local_steps).astype(np.int32)
        steps_needed = np.where(resume,
                                np.maximum(sim.local_steps - prior_steps, 1),
                                sim.local_steps).astype(np.int32)
        steps_needed = np.where(selected, steps_needed, 0)
        fail = fleet.failure_draw(steps_needed / max(sim.local_steps, 1))
        fail &= selected
        stop = np.where(fail, fleet.failure_step(steps_needed), BIG)

        final, cache_p, cached_steps, _ = trainer(
            params, caches, jnp.asarray(resume), jnp.asarray(steps_needed),
            jnp.asarray(stop), cache_every)

        success = selected & ~fail & (steps_needed > 0)
        completed = np.minimum(steps_needed, stop)
        times = fleet.round_times(steps_needed, distribute, completed,
                                  success)
        quorum = int(np.ceil(min(float(p.quorum), float(selected.sum()))))
        finite = np.sort(times[np.isfinite(times)])
        if finite.size >= quorum and quorum > 0:
            t_cut = min(finite[quorum - 1], sim.round_deadline)
        else:
            t_cut = sim.round_deadline
        received = success & (times <= t_cut)
        fstate = core.update_after_round(fstate, p, jnp.asarray(received),
                                         fl)

        # --- host-side server step: pull, numpy aggregate, push --------
        final_h = jax.device_get(final)
        cache_h = jax.device_get(cache_p)
        cached_h = np.asarray(cached_steps)
        base_stale = np.where(resume & (stamp_h >= 0),
                              np.maximum(rnd - stamp_h, 0), 0)
        w = received * n_samples / (1.0 + base_stale)
        total = max(w.sum(), 1e-30)
        params_h = jax.device_get(params)
        if w.sum() > 0:
            wv = (w / total).astype(np.float32)
            params_h = jax.tree.map(
                lambda c, g: (c.astype(np.float32)
                              * wv.reshape((-1,) + (1,) * (c.ndim - 1))
                              ).sum(0).astype(g.dtype), final_h, params_h)
        total_cached = np.where(resume, prior_steps, 0) + cached_h
        write = selected & fail & (total_cached > 0)
        base_round = np.where(resume & (stamp_h >= 0), stamp_h, rnd)
        cache_leaves = jax.tree.map(
            lambda old, new: np.where(
                write.reshape((-1,) + (1,) * (old.ndim - 1)), new, old),
            jax.device_get(caches.params), cache_h)
        progress_h = np.where(write, total_cached / max(sim.local_steps, 1),
                              progress_h)
        stamp_h = np.where(write, base_round, stamp_h).astype(np.int32)
        progress_h = np.where(received, 0.0, progress_h).astype(np.float32)
        stamp_h = np.where(received, -1, stamp_h).astype(np.int32)
        params = jax.device_put(params_h)
        caches = core.ClientCaches(
            jax.tree.map(jnp.asarray, cache_leaves),
            jnp.asarray(progress_h), jnp.asarray(stamp_h))
        # per-round eval (the old loop's default)
        acc = float(acc_fn(params, test_x, test_y))

    for rnd in range(WARMUP):
        _round(rnd)
    with TRACER.span("bench_host_loop", n=N) as sp:
        for rnd in range(WARMUP, n_rounds):
            _round(rnd)
    return acc, sp.seconds


def engine_loop(data, sim, fl, n_rounds, fleet):
    # one shared fleet per sweep point: warmup advances the same stream
    # the measured rounds continue, exactly like the host loop — the A/B
    # variants see identical draws
    engine = FleetEngine(data, sim, fl, fleet=fleet)
    engine.run(POLICY, rounds=WARMUP, diagnostics=False)    # jit warmup
    with TRACER.span("bench_engine_loop", n=fl.num_clients) as sp:
        h = engine.run(POLICY, rounds=n_rounds - WARMUP,
                       eval_every=n_rounds, diagnostics=False)
    return h.acc[-1], sp.seconds


def run():
    # read-merge so a previously recorded --mesh sweep survives a plain
    # engine re-run (run_mesh() merges the other way for the same reason)
    path = os.path.join(RESULTS, "BENCH_engine.json")
    record = {}
    if os.path.exists(path):
        with open(path) as f:
            record = json.load(f)
    record.update(
        {"policy": POLICY, "rounds": ROUNDS,
         "note": "host loop evals every round (old default), engine "
                 "evals at boundaries; accs are sanity values, not "
                 "an equivalence check (see tests/test_policy_api.py)",
         "sizes": {}})
    for n in SIZES:
        sim, fl, data = _setup(n)
        # identically-seeded fleet per variant: both loops consume the
        # same warmup+measured draw sequence (A/B on one stream)
        acc_e, dt_e = engine_loop(data, sim, fl, WARMUP + ROUNDS,
                                  Fleet(sim))
        acc_h, dt_h = host_loop(data, sim, fl, WARMUP + ROUNDS,
                                Fleet(sim))
        rps_e = ROUNDS / dt_e
        rps_h = ROUNDS / dt_h
        record["sizes"][str(n)] = {
            "engine_rounds_per_sec": rps_e,
            "host_rounds_per_sec": rps_h,
            "speedup": rps_e / rps_h,
            "engine_final_acc": acc_e, "host_final_acc": acc_h,
        }
        emit(f"engine_n{n}", dt_e * 1e6 / ROUNDS,
             f"engine_rps={rps_e:.2f};host_rps={rps_h:.2f};"
             f"speedup={rps_e / rps_h:.2f}x")
    os.makedirs(RESULTS, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    emit("engine_summary", 0.0,
         f"max_speedup={max(v['speedup'] for v in record['sizes'].values()):.2f}x",
         record=None)
    return record


def mesh_child(k: int):
    """One forced-host-device-count measurement (runs in a subprocess).

    The parent sets ``--xla_force_host_platform_device_count=k`` through
    ``repro.launch.mesh.force_host_platform_device_count`` *before* this
    module (and therefore jax) is imported.
    """
    sim, fl, data = _setup(N_MESH)
    out = {"devices": k, "n": N_MESH, "policy": POLICY,
           "rounds": ROUNDS, "donate": {}}
    for donate in (False, True):
        fl2 = dataclasses.replace(fl,
                                  mesh_shape=(k,) if k > 1 else None,
                                  donate_buffers=donate)
        # one identically-seeded fleet per variant: donate on/off compare
        # on the same draw stream
        engine = FleetEngine(data, sim, fl2, fleet=Fleet(sim))
        engine.run(POLICY, rounds=WARMUP, diagnostics=False)   # jit warmup
        with TRACER.span("bench_mesh", devices=k, donate=donate) as sp:
            engine.run(POLICY, rounds=ROUNDS, eval_every=ROUNDS,
                       diagnostics=False)
        out["donate"]["on" if donate else "off"] = {
            "rounds_per_sec": ROUNDS / sp.seconds,
            **engine.server_step_memory(uses_cache=True)}
    print(json.dumps(out))


def run_mesh():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    sweep = []
    for k in MESH_DEVICES:
        code = ("from repro.launch.mesh import "
                "force_host_platform_device_count as F; "
                f"F({k}); "
                "from benchmarks.bench_engine import mesh_child; "
                f"mesh_child({k})")
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             cwd=root, capture_output=True, text=True,
                             timeout=3600)
        if out.returncode != 0:
            raise RuntimeError(f"mesh child k={k} failed:\n"
                               + out.stderr[-3000:])
        rec = json.loads(out.stdout.strip().splitlines()[-1])
        sweep.append(rec)
        on, off = rec["donate"]["on"], rec["donate"]["off"]
        emit(f"engine_mesh{k}", 1e6 / max(on["rounds_per_sec"], 1e-9),
             f"rps_on={on['rounds_per_sec']:.2f};"
             f"rps_off={off['rounds_per_sec']:.2f};"
             f"peak_on={on['peak_live_bytes']};"
             f"peak_off={off['peak_live_bytes']}")
    path = os.path.join(RESULTS, "BENCH_engine.json")
    record = {}
    if os.path.exists(path):
        with open(path) as f:
            record = json.load(f)
    record["mesh"] = {
        "policy": POLICY, "n": N_MESH, "rounds": ROUNDS,
        "note": "forced host devices; donate on/off compared per device "
                "count.  peak_live_bytes = argument+output+temp-alias of "
                "the compiled fused server step (donation aliases the "
                "previous global model + caches into the outputs)",
        "sweep": sweep}
    os.makedirs(RESULTS, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


PIPE_DEPTHS = (1, 2, 4)
PIPE_ROUNDS = 4 if QUICK else 10
PIPE_EVAL_EVERY = 10
PIPE_REPS = 1 if QUICK else 3


def run_pipeline():
    """Pipelined device round loop: rounds/sec at pipeline_depth 1/2/4.

    Depth 1 is the PR-4 device loop's scheduling (every round's
    bookkeeping resolves before the next round is planned); depth d
    keeps d-1 rounds of bookkeeping in flight, so round k+1's fused
    trainer + server step dispatch while round k executes.  Same policy,
    fleet, dynamics and eval cadence per depth — trajectories are
    bit-identical (tier-1 parity tests); only host/device overlap
    changes.  The measurement interleaves PIPE_REPS repetitions of every
    depth on pre-compiled engines and keeps each depth's best rep, so
    slow machine-load drift cannot masquerade as (or hide) a speedup.
    Merged into BENCH_engine.json under "pipeline"."""
    n = N_MESH
    sim, fl, data = _setup(n)
    sim = dataclasses.replace(sim, rounds=WARMUP + PIPE_ROUNDS * PIPE_REPS)
    engines = {}
    for depth in PIPE_DEPTHS:
        fl2 = dataclasses.replace(fl, dynamics="bernoulli",
                                  pipeline_depth=depth)
        engine = FleetEngine(data, sim, fl2, fleet=Fleet(sim))
        engine.run(POLICY, rounds=WARMUP, diagnostics=False)  # jit warmup
        engines[depth] = engine
    reps = {depth: [] for depth in PIPE_DEPTHS}
    acc = {}
    for _ in range(PIPE_REPS):
        for depth in PIPE_DEPTHS:
            with TRACER.span("bench_pipeline", depth=depth) as sp:
                h = engines[depth].run(POLICY, rounds=PIPE_ROUNDS,
                                       eval_every=PIPE_EVAL_EVERY,
                                       diagnostics=False)
            reps[depth].append(PIPE_ROUNDS / sp.seconds)
            acc[depth] = h.acc[-1]
    depths = {}
    for depth in PIPE_DEPTHS:
        best = max(reps[depth])
        depths[str(depth)] = {"rounds_per_sec": best,
                              "reps_rounds_per_sec": reps[depth],
                              "final_acc": acc[depth]}
        emit(f"engine_pipe_d{depth}", 1e6 / best,
             f"n={n};rps={best:.3f}")
    speedup = depths["2"]["rounds_per_sec"] / depths["1"]["rounds_per_sec"]
    path = os.path.join(RESULTS, "BENCH_engine.json")
    record = {}
    if os.path.exists(path):
        with open(path) as f:
            record = json.load(f)
    record["pipeline"] = {
        "policy": POLICY, "n": n, "rounds": PIPE_ROUNDS,
        "reps": PIPE_REPS, "eval_every": PIPE_EVAL_EVERY,
        "dynamics": "bernoulli",
        "depth2_over_depth1_speedup": speedup,
        "note": "depth 1 = the PR-4 device loop's per-round host sync; "
                "depth d defers History readback so up to d-1 rounds "
                "stay in flight.  Trajectories are depth-invariant "
                "(tests/test_round_close.py, tests/test_fleet_dynamics"
                ".py).  The speedup is pure host/device overlap: it is "
                "bounded by the host-side gap pipelining removes, which "
                "on the 2-core CPU recording container is ~5% of a "
                "round (fully-async dispatch upper bound measured "
                "1.06x) and within that machine's load noise — "
                "accelerator-backed hosts, where a round's host gap is "
                "a much larger fraction, are where depth > 1 pays",
        "depths": depths}
    os.makedirs(RESULTS, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    emit("engine_pipe_summary", 0.0,
         f"depth2_over_depth1={speedup:.3f}x", record=None)
    return record


COHORT_XS = (128, 512, 1024, None)            # None = full scan
# 30-round reps amortize the per-rep run boundary (fresh policy state +
# fleet cache reset, which is O(N) and so asymmetric across fleet sizes)
# down to noise; shorter reps understate the compact path's steady state
COHORT_ROUNDS = 4 if QUICK else 30
# 5 reps: the recording container shares cores, and per-rep throughput
# swings ~10% with co-tenant load — best-of-5 pins each engine's
# quiet-machine rate where best-of-3 still carries rep lottery
COHORT_REPS = 1 if QUICK else 5
PAIR_EXTRA_REPS = 0 if QUICK else 10
N_SMOKE = 20_000 if QUICK else 1_000_000
X_SMOKE = 512
SMOKE_ROUNDS = 3


def _vec_classification(n, *, num_classes=2, dim=4, n_per_client=2,
                        n_test=256, seed=0):
    """Vectorized tiny-task synthesis for the million-client smoke —
    ``federated_classification``'s per-client python loop is O(N) host
    work that would dwarf the measurement at N=1M."""
    from repro.data.synthetic import FederatedClassification
    rng = np.random.RandomState(seed)
    centers = (rng.randn(num_classes, dim) * 2.2).astype(np.float32)
    y = rng.randint(0, num_classes, (n, n_per_client))
    x = centers[y] + rng.randn(n, n_per_client, dim).astype(np.float32)
    ty = rng.randint(0, num_classes, n_test)
    tx = centers[ty] + rng.randn(n_test, dim).astype(np.float32)
    return FederatedClassification(
        x, y.astype(np.int32), tx, ty.astype(np.int32),
        y[:, :1].astype(np.int32), num_classes)


def run_cohort():
    """Compact-cohort round path: rounds/sec vs cohort width X at
    N=4096, plus the N=1M fleet-state smoke.

    The sweep holds the fleet fixed and varies ``FLConfig.cohort_size``
    (None = the full (N, ...) scan); ``clients_per_round`` is capped at
    X so every compact point satisfies the static selection bound.  The
    acceptance reference is a *full-scan* N=512 run: compact N=4096,
    X=512 vmaps the same 512 trainer rows, so its rate should meet or
    beat the small fleet's — that is what "round cost tracks the cohort,
    not the fleet" means.  Reps are interleaved on pre-compiled engines
    and each point keeps its best rep (machine-load drift cannot
    masquerade as a speedup).  Merged into BENCH_engine.json under
    "cohort"."""
    n = N_MESH
    sim, fl, data = _setup(n)
    sim = dataclasses.replace(
        sim, rounds=WARMUP + COHORT_ROUNDS * COHORT_REPS)
    sim512, fl512, data512 = _setup(512)
    sim512 = dataclasses.replace(
        sim512, rounds=WARMUP + COHORT_ROUNDS * COHORT_REPS)

    engines = {}
    # quick mode shrinks the fleet below the larger sweep points
    xs = tuple(x for x in COHORT_XS if x is None or x <= n)
    for x in xs:
        cpr = fl.clients_per_round if x is None \
            else min(x, fl.clients_per_round)
        # donation is the steady-state config the compact path is built
        # for: the cohort cache scatter updates the donated (N, D) buffer
        # in place (undonated, XLA copies the whole fleet cache per
        # round, which is O(N) work the cohort exists to avoid)
        fl2 = dataclasses.replace(fl, dynamics="bernoulli",
                                  cohort_size=x, clients_per_round=cpr,
                                  donate_buffers=True)
        engine = FleetEngine(data, sim, fl2, fleet=Fleet(sim))
        engine.run(POLICY, rounds=WARMUP, diagnostics=False)  # jit warmup
        engines["full" if x is None else str(x)] = (engine, cpr)
    ref_fl = dataclasses.replace(fl512, dynamics="bernoulli",
                                 donate_buffers=True)
    ref_engine = FleetEngine(data512, sim512, ref_fl, fleet=Fleet(sim512))
    ref_engine.run(POLICY, rounds=WARMUP, diagnostics=False)
    engines["full_n512"] = (ref_engine, ref_fl.clients_per_round)
    # run the acceptance-critical pair (compact X=512 vs the full-scan
    # N=512 reference — the "round cost tracks the cohort" comparison)
    # back-to-back within each rep: the slow full-fleet points otherwise
    # sit between them and transient machine load decorrelates exactly
    # the two rates being compared
    order = [k for k in ("128", "512", "full_n512", "1024", "full")
             if k in engines] + [k for k in engines
                                 if k not in ("128", "512", "full_n512",
                                              "1024", "full")]

    reps = {k: [] for k in engines}
    for _ in range(COHORT_REPS):
        for k in order:
            engine, _cpr = engines[k]
            with TRACER.span("bench_cohort", point=k) as sp:
                engine.run(POLICY, rounds=COHORT_ROUNDS,
                           eval_every=10 * COHORT_ROUNDS,
                           diagnostics=False)
            reps[k].append(COHORT_ROUNDS / sp.seconds)
    # the pair is ~1% of the sweep's wall-clock, so oversample it: the
    # two rates sit within a few percent of each other and a handful of
    # paired samples still leaves their median at the mercy of one bad
    # weather window
    for _ in range(PAIR_EXTRA_REPS if "512" in engines else 0):
        for k in ("512", "full_n512"):
            engine, _cpr = engines[k]
            with TRACER.span("bench_cohort_pair", point=k) as sp:
                engine.run(POLICY, rounds=COHORT_ROUNDS,
                           eval_every=10 * COHORT_ROUNDS,
                           diagnostics=False)
            reps[k].append(COHORT_ROUNDS / sp.seconds)
    sweep = {}
    for k, (engine, cpr) in engines.items():
        best = max(reps[k])
        sweep[k] = {"n": engine.fl_cfg.num_clients,
                    "cohort_size": engine.fl_cfg.cohort_size,
                    "clients_per_round": cpr,
                    "rounds_per_sec": best,
                    "reps_rounds_per_sec": reps[k],
                    "packed_rows":
                        engine.server_step_memory()["packed_rows"]}
        emit(f"engine_cohort_{k}", 1e6 / best,
             f"n={sweep[k]['n']};rps={best:.3f}")
    del engines, ref_engine

    # ---- N=1M fleet-state smoke: (N,) state is the only N-proportional
    # memory; the trainer, cut and aggregation all run on (X, ...) blocks
    smoke_sim = SimConfig(num_clients=N_SMOKE, rounds=WARMUP + SMOKE_ROUNDS,
                          local_steps=2, batch_size=2, seed=7,
                          model_hidden=4, model_depth=1)
    smoke_fl = FLConfig(num_clients=N_SMOKE, clients_per_round=X_SMOKE,
                        cohort_size=X_SMOKE, dynamics="bernoulli",
                        donate_buffers=True)
    smoke_data = _vec_classification(N_SMOKE, seed=8)
    engine = FleetEngine(smoke_data, smoke_sim, smoke_fl,
                         fleet=Fleet(smoke_sim))
    engine.run(POLICY, rounds=WARMUP, diagnostics=False)      # jit warmup
    with TRACER.span("bench_cohort_smoke", n=N_SMOKE) as sp:
        engine.run(POLICY, rounds=SMOKE_ROUNDS,
                   eval_every=10 * SMOKE_ROUNDS, diagnostics=False)
    dt = sp.seconds
    mem = engine.server_step_memory()
    live = int(sum(a.nbytes for a in jax.live_arrays()))
    smoke = {"n": N_SMOKE, "cohort_size": X_SMOKE,
             "rounds_run": SMOKE_ROUNDS,
             "rounds_per_sec": SMOKE_ROUNDS / dt,
             "model_hidden": smoke_sim.model_hidden,
             "model_depth": smoke_sim.model_depth,
             "server_step_peak_live_bytes": mem["peak_live_bytes"],
             "packed_rows": mem["packed_rows"],
             "packed_buffer_bytes": mem["packed_buffer_bytes"],
             "live_device_bytes": live}
    emit("engine_cohort_smoke", dt * 1e6 / SMOKE_ROUNDS,
         f"n={N_SMOKE};x={X_SMOKE};rps={SMOKE_ROUNDS / dt:.3f};"
         f"live_bytes={live}")

    path = os.path.join(RESULTS, "BENCH_engine.json")
    record = {}
    if os.path.exists(path):
        with open(path) as f:
            record = json.load(f)
    record["cohort"] = {
        "policy": POLICY, "rounds": COHORT_ROUNDS, "reps": COHORT_REPS,
        "pair_extra_reps": PAIR_EXTRA_REPS,
        "dynamics": "bernoulli", "donate_buffers": True,
        "note": "cohort_size=X gathers the selected cohort into dense "
                "(X, ...) blocks for train/cut/aggregate and scatters "
                "back to (N,) fleet state; full_n512 is the full-scan "
                "acceptance reference (same 512 trainer rows as the "
                "N=4096, X=512 compact point).  smoke: only the (N,) "
                "fleet state scales with N (tiny model via "
                "SimConfig.model_hidden/model_depth, vectorized data)",
        "sweep": sweep, "smoke": smoke}
    if "512" in sweep and "full_n512" in sweep:
        # the controlled acceptance contrast: rep i runs the two engines
        # back-to-back (see the order comment above), so the per-rep
        # ratio differences out the co-tenant load swing of that weather
        # window; the median over reps is the noise-robust "compact
        # round meets the same-cohort full-scan rate" statistic, where
        # a ratio of two independently-taken maxima still carries the
        # per-engine rep lottery (~+-8% swings on the shared container)
        paired = sorted(a / b for a, b in
                        zip(reps["512"], reps["full_n512"]))
        record["cohort"]["pair"] = {
            "paired_ratios": paired,
            "x512_over_full_n512_paired_median":
                paired[len(paired) // 2],
            "x512_over_full_n512_best_rates":
                sweep["512"]["rounds_per_sec"]
                / sweep["full_n512"]["rounds_per_sec"]}
    os.makedirs(RESULTS, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    if "512" in sweep:
        pair = record["cohort"]["pair"]
        emit("engine_cohort_summary", 0.0,
             f"x512_over_full_n512_paired_median="
             f"{pair['x512_over_full_n512_paired_median']:.3f}x;"
             f"best_rates="
             f"{pair['x512_over_full_n512_best_rates']:.3f}x",
             record=None)
    return record


OFFLOAD_XS = (128, 512)
OFFLOAD_MODES = (None, "host", "discard")
OFFLOAD_ROUNDS = COHORT_ROUNDS
OFFLOAD_REPS = COHORT_REPS
OFFLOAD_STALENESS = 8          # discard bound, in rounds
STATS_ROUNDS = 3               # transfer-counter probe after timing


def run_offload():
    """C3 cache residency: resident (N, D) pytree vs the host-offloaded
    store ("host") vs the staleness-bounded store ("discard"),
    rounds/sec at N=4096, X in {128, 512}.

    The three residency modes of one cohort width run back-to-back
    within each rep, so the host/resident ratio is paired against the
    same machine-load window; each point keeps its best rep.  After
    timing, each offload engine reruns a short probe with the transfer
    counters reset to record the per-round async-copy footprint (the
    streaming contract: zero synchronous round-blocking copies).  The
    N=1M smoke reruns the fleet-state scaling check with the *default*
    full-size model (hidden=128, depth=2) — resident C3 state for that
    model is ~70 GB at N=1M, so the host store is what makes the run
    fit; the recorded residency split shows device cache bytes tracking
    X, not N.  Merged into BENCH_engine.json under "offload"."""
    n = N_MESH
    sim, fl, data = _setup(n)
    sim = dataclasses.replace(
        sim, rounds=WARMUP + OFFLOAD_ROUNDS * OFFLOAD_REPS)

    engines = {}
    for x in (x for x in OFFLOAD_XS if x <= n):
        for mode in OFFLOAD_MODES:
            fl2 = dataclasses.replace(
                fl, dynamics="bernoulli", cohort_size=x,
                clients_per_round=min(x, fl.clients_per_round),
                donate_buffers=True, cache_offload=mode,
                cache_staleness_bound=(
                    OFFLOAD_STALENESS if mode == "discard"
                    else fl.cache_staleness_bound))
            engine = FleetEngine(data, sim, fl2, fleet=Fleet(sim))
            engine.run(POLICY, rounds=WARMUP, diagnostics=False)  # warmup
            engines[f"x{x}_{mode or 'resident'}"] = engine

    reps = {k: [] for k in engines}
    for _ in range(OFFLOAD_REPS):
        for k, engine in engines.items():   # modes of one X stay paired
            with TRACER.span("bench_offload", point=k) as sp:
                engine.run(POLICY, rounds=OFFLOAD_ROUNDS,
                           eval_every=10 * OFFLOAD_ROUNDS,
                           diagnostics=False)
            reps[k].append(OFFLOAD_ROUNDS / sp.seconds)
    # oversample the acceptance-critical X=512 trio: the resident point
    # is compared against the prior cohort record's best-of-15 rate (5
    # reps + 10 pair-extra), so a best-of-5 here would understate it by
    # pure rep lottery on the shared container
    pair_keys = tuple(k for k in ("x512_resident", "x512_host",
                                  "x512_discard") if k in engines)
    for _ in range(PAIR_EXTRA_REPS if pair_keys else 0):
        for k in pair_keys:
            engine = engines[k]
            with TRACER.span("bench_offload_pair", point=k) as sp:
                engine.run(POLICY, rounds=OFFLOAD_ROUNDS,
                           eval_every=10 * OFFLOAD_ROUNDS,
                           diagnostics=False)
            reps[k].append(OFFLOAD_ROUNDS / sp.seconds)

    sweep = {}
    for k, engine in engines.items():
        point = {"n": n, "cohort_size": engine.fl_cfg.cohort_size,
                 "cache_offload": engine.fl_cfg.cache_offload,
                 "rounds_per_sec": max(reps[k]),
                 "reps_rounds_per_sec": reps[k]}
        if engine.fl_cfg.cache_offload is not None:
            engine.transfer_stats.reset()
            engine.run(POLICY, rounds=STATS_ROUNDS,
                       eval_every=10 * STATS_ROUNDS, diagnostics=False)
            point["transfer_stats_rounds"] = STATS_ROUNDS
            point["transfer_stats"] = engine.transfer_stats.snapshot()
        mem = engine.server_step_memory()
        point["cache_device_bytes"] = mem["cache_device_bytes"]
        point["cache_host_bytes"] = mem["cache_host_bytes"]
        sweep[k] = point
        emit(f"engine_offload_{k}", 1e6 / point["rounds_per_sec"],
             f"n={n};rps={point['rounds_per_sec']:.3f};"
             f"cache_dev={mem['cache_device_bytes']}")
    del engines

    # paired host/resident + discard/resident ratios per cohort width
    # (rep i of each mode ran back-to-back, so the per-rep ratio
    # differences out that weather window's co-tenant load)
    ratios = {}
    for x in OFFLOAD_XS:
        if f"x{x}_resident" not in sweep:
            continue
        for mode in ("host", "discard"):
            paired = sorted(a / b for a, b in
                            zip(reps[f"x{x}_{mode}"],
                                reps[f"x{x}_resident"]))
            ratios[f"x{x}_{mode}_over_resident"] = {
                "paired_median": paired[len(paired) // 2],
                "paired_ratios": paired,
                "best_rates": sweep[f"x{x}_{mode}"]["rounds_per_sec"]
                / sweep[f"x{x}_resident"]["rounds_per_sec"]}

    # ---- N=1M smoke, full-size default model: the host store carries
    # the fleet's C3 params, the device holds (X, D) blocks + (N,)
    # metadata only
    smoke_sim = SimConfig(num_clients=N_SMOKE,
                          rounds=WARMUP + SMOKE_ROUNDS,
                          local_steps=2, batch_size=2, seed=7)
    smoke_fl = FLConfig(num_clients=N_SMOKE, clients_per_round=X_SMOKE,
                        cohort_size=X_SMOKE, dynamics="bernoulli",
                        donate_buffers=True, cache_offload="host")
    engine = FleetEngine(_vec_classification(N_SMOKE, seed=8), smoke_sim,
                         smoke_fl, fleet=Fleet(smoke_sim))
    engine.run(POLICY, rounds=WARMUP, diagnostics=False)      # jit warmup
    engine.transfer_stats.reset()
    with TRACER.span("bench_offload_smoke", n=N_SMOKE) as sp:
        engine.run(POLICY, rounds=SMOKE_ROUNDS,
                   eval_every=10 * SMOKE_ROUNDS, diagnostics=False)
    dt = sp.seconds
    mem = engine.server_step_memory()
    live = int(sum(a.nbytes for a in jax.live_arrays()))
    row = engine.cache_store.row_bytes
    smoke = {"n": N_SMOKE, "cohort_size": X_SMOKE,
             "rounds_run": SMOKE_ROUNDS,
             "rounds_per_sec": SMOKE_ROUNDS / dt,
             "model_hidden": smoke_sim.model_hidden,
             "model_depth": smoke_sim.model_depth,
             "cache_offload": "host", "cache_row_bytes": row,
             "resident_equivalent_cache_bytes": N_SMOKE * row,
             "cache_device_bytes": mem["cache_device_bytes"],
             "cache_host_bytes": mem["cache_host_bytes"],
             "server_step_peak_live_bytes": mem["peak_live_bytes"],
             "live_device_bytes": live,
             "transfer_stats": engine.transfer_stats.snapshot()}
    emit("engine_offload_smoke", dt * 1e6 / SMOKE_ROUNDS,
         f"n={N_SMOKE};x={X_SMOKE};rps={SMOKE_ROUNDS / dt:.3f};"
         f"cache_dev={mem['cache_device_bytes']};"
         f"cache_host={mem['cache_host_bytes']};live_bytes={live}")

    path = os.path.join(RESULTS, "BENCH_engine.json")
    record = {}
    if os.path.exists(path):
        with open(path) as f:
            record = json.load(f)
    record["offload"] = {
        "policy": POLICY, "n": n, "rounds": OFFLOAD_ROUNDS,
        "reps": OFFLOAD_REPS, "dynamics": "bernoulli",
        "donate_buffers": True, "discard_staleness_bound":
            OFFLOAD_STALENESS,
        "note": "cache_offload='host' keeps only the (X, D) cohort "
                "cache slots on device and streams written slots to a "
                "sparse host store (async dispatch, double-buffered "
                "drain — transfer_stats.sync_copies counts the "
                "round-blocking copies the protocol never makes); "
                "'discard' additionally drops caches older than the "
                "staleness bound.  smoke: N=1M with the default "
                "full-size model — the resident-equivalent (N, D) "
                "cache pytree would be resident_equivalent_cache_bytes "
                "(~70 GB), the device footprint stays O(X*D)",
        "sweep": sweep, "ratios": ratios, "smoke_full_model": smoke}
    prior = record.get("cohort", {}).get("sweep", {}).get("512")
    if prior and "x512_resident" in sweep:
        # resident-path regression guard: same config as the cohort
        # sweep's X=512 point, recorded before the offload seam existed
        record["offload"]["resident_x512_over_prior_cohort_x512"] = \
            sweep["x512_resident"]["rounds_per_sec"] \
            / prior["rounds_per_sec"]
    os.makedirs(RESULTS, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    if ratios:
        emit("engine_offload_summary", 0.0,
             ";".join(f"{k}={v['paired_median']:.3f}x"
                      for k, v in ratios.items()), record=None)
    return record


TEL_ROUNDS = 4 if QUICK else 10
TEL_REPS = 2 if QUICK else 3
TEL_JSONL = "telemetry_run.jsonl"
TEL_TRACE = "telemetry_trace.json"


def run_telemetry():
    """Telemetry overhead: rounds/sec with telemetry off vs "full".

    One pre-compiled engine (N=N_MESH full-scan, device dynamics); each
    rep runs the off and full variants back-to-back so the per-rep
    ratio differences out that window's machine load — the paired
    median is the overhead statistic, best-of rates are recorded too.
    The fused metrics dispatch rides the round ledger's readback (zero
    added host syncs), so the expected overhead is one extra small
    dispatch per round.  Also records a *real* run's artifacts —
    telemetry JSONL + Perfetto trace under results/benchmarks/ — and
    renders the report CLI against them.  Merged into BENCH_engine.json
    under "telemetry"."""
    from repro import obs
    from repro.obs import report as obs_report
    n = N_MESH
    sim, fl, data = _setup(n)
    sim = dataclasses.replace(
        sim, rounds=WARMUP + TEL_ROUNDS * (2 * TEL_REPS + 2))
    fl2 = dataclasses.replace(fl, dynamics="bernoulli")
    engine = FleetEngine(data, sim, fl2, fleet=Fleet(sim))
    engine.run(POLICY, rounds=WARMUP, diagnostics=False)  # round-path jit
    engine.run(POLICY, rounds=WARMUP, diagnostics=False,
               telemetry="full")                          # metrics jit

    reps_off, reps_full = [], []
    for _ in range(TEL_REPS):
        with TRACER.span("bench_tel_off") as sp:
            engine.run(POLICY, rounds=TEL_ROUNDS,
                       eval_every=10 * TEL_ROUNDS, diagnostics=False,
                       telemetry=False)
        reps_off.append(TEL_ROUNDS / sp.seconds)
        with TRACER.span("bench_tel_full") as sp:
            engine.run(POLICY, rounds=TEL_ROUNDS,
                       eval_every=10 * TEL_ROUNDS, diagnostics=False,
                       telemetry="full")
        reps_full.append(TEL_ROUNDS / sp.seconds)
    paired = sorted(off / full for off, full in zip(reps_off, reps_full))
    overhead_pct = (paired[len(paired) // 2] - 1.0) * 100.0

    # real-run artifacts: JSONL + Perfetto trace + report render
    os.makedirs(RESULTS, exist_ok=True)
    jsonl = os.path.join(RESULTS, TEL_JSONL)
    trace = os.path.join(RESULTS, TEL_TRACE)
    if os.path.exists(jsonl):
        os.remove(jsonl)
    tel = obs.Telemetry(level="full", jsonl=jsonl, trace=trace)
    engine.run(POLICY, rounds=TEL_ROUNDS, eval_every=2,
               diagnostics=False, telemetry=tel)
    tel.close()
    assert obs_report.main([jsonl]) == 0

    path = os.path.join(RESULTS, "BENCH_engine.json")
    record = {}
    if os.path.exists(path):
        with open(path) as f:
            record = json.load(f)
    record["telemetry"] = {
        "policy": POLICY, "n": n, "rounds": TEL_ROUNDS,
        "reps": TEL_REPS, "dynamics": "bernoulli",
        "rps_off": max(reps_off), "rps_full": max(reps_full),
        "reps_off": reps_off, "reps_full": reps_full,
        "paired_off_over_full": paired,
        "overhead_pct": overhead_pct,
        "jsonl": TEL_JSONL, "trace": TEL_TRACE,
        "note": "telemetry='full' fuses every registered metric into "
                "one extra jitted dispatch per round whose handles ride "
                "the pipelined round ledger readback (zero added host "
                "syncs); overhead_pct is the paired per-rep median of "
                "off/full - 1.  The JSONL/trace artifacts are a real "
                "instrumented run (report CLI renders the JSONL)",
    }
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    emit("engine_telemetry", 1e6 / max(reps_full),
         f"n={n};rps_off={max(reps_off):.3f};"
         f"rps_full={max(reps_full):.3f};"
         f"overhead_pct={overhead_pct:.2f}")
    return record


DYN_PATHS = (("host_rng", "bernoulli_host"),
             ("device_bernoulli", "bernoulli"),
             ("device_markov", "markov"))


def run_dynamics():
    """Host-RNG vs device-resident fleet-draw round paths, rounds/sec.

    ``bernoulli_host`` draws every round on the host (numpy RNG + three
    place_per_client uploads per round); the device processes produce the
    draw, workload, failure and timing model in jitted dispatches with no
    per-round host→device hand-off.  Same policy, same fleet size —
    merged into BENCH_engine.json under "dynamics"."""
    n = N_MESH
    sim, fl, data = _setup(n)
    paths = {}
    for label, dyn in DYN_PATHS:
        fl2 = dataclasses.replace(fl, dynamics=dyn)
        engine = FleetEngine(data, sim, fl2, fleet=Fleet(sim))
        engine.run(POLICY, rounds=WARMUP, diagnostics=False)  # jit warmup
        with TRACER.span("bench_dynamics", path=label) as sp:
            h = engine.run(POLICY, rounds=ROUNDS, eval_every=ROUNDS,
                           diagnostics=False)
        dt = sp.seconds
        paths[label] = {"dynamics": dyn, "rounds_per_sec": ROUNDS / dt,
                        "final_acc": h.acc[-1]}
        emit(f"engine_dyn_{label}", dt * 1e6 / ROUNDS,
             f"n={n};rps={ROUNDS / dt:.2f}")
    speedup = paths["device_bernoulli"]["rounds_per_sec"] \
        / paths["host_rng"]["rounds_per_sec"]
    path = os.path.join(RESULTS, "BENCH_engine.json")
    record = {}
    if os.path.exists(path):
        with open(path) as f:
            record = json.load(f)
    record["dynamics"] = {
        "policy": POLICY, "n": n, "rounds": ROUNDS,
        "device_over_host_speedup": speedup,
        "note": "host_rng draws availability/failures on host numpy and "
                "uploads (N,) masks per round; device paths produce the "
                "draw + workload + timing on device (repro.fleet), no "
                "per-round place_per_client",
        "paths": paths}
    os.makedirs(RESULTS, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    emit("engine_dyn_summary", 0.0,
         f"device_over_host={speedup:.2f}x", record=None)
    return record


if __name__ == "__main__":
    if "--mesh" in sys.argv[1:]:
        run_mesh()
    elif "--dynamics" in sys.argv[1:]:
        run_dynamics()
    elif "--pipeline" in sys.argv[1:]:
        run_pipeline()
    elif "--cohort" in sys.argv[1:]:
        run_cohort()
    elif "--offload" in sys.argv[1:]:
        run_offload()
    elif "--telemetry" in sys.argv[1:]:
        run_telemetry()
    else:
        run()
