"""Old host-side server loop vs device-resident FleetEngine, rounds/sec.

The baseline reconstructs the pre-fusion runner: every round it pulls the
stacked trainer outputs to host, runs the server step in numpy (weights
incl. staleness discount, leaf-wise weighted aggregation, C3 cache
bookkeeping), pushes the new global model + caches back to device, and
evaluates test accuracy — the host-side loop the typed FleetEngine
replaced.  The engine keeps params and caches device-resident across
rounds and syncs to host only at eval boundaries.

Each loop runs with its own default eval cadence (host loop: every
round, like the old runner; engine: eval boundaries only) — the cadence
difference is part of what the device-resident design buys and is
included in the measured speedup deliberately.  Numerical equivalence of
the two paths is NOT asserted here (the two runs train for different
cumulative rounds); that is covered by the golden-file tests in
tests/test_policy_api.py.

Fleet sizes N ∈ {256, 1024, 4096}; records results/benchmarks/
BENCH_engine.json.

``--mesh`` instead sweeps the client-mesh round path: forced host device
counts 1/2/4/8 (each in a fresh subprocess so
``--xla_force_host_platform_device_count`` lands before the jax import),
recording sharded rounds/sec and the fused server step's peak live bytes
with buffer donation on vs off, merged into the same JSON under "mesh".
"""
import dataclasses
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import QUICK, RESULTS, emit
from repro import core
from repro.configs.base import FLConfig
from repro.data.synthetic import federated_classification
from repro.fl import Fleet, FleetEngine, SimConfig, make_trainer
from repro.fl import classifier as CLF

BIG = 1 << 20
SIZES = (64, 256) if QUICK else (256, 1024, 4096)
ROUNDS = 3 if QUICK else 5
WARMUP = 1
POLICY = "flude"
MESH_DEVICES = (1, 2, 4, 8)
N_MESH = 256 if QUICK else 4096


def _setup(n):
    sim = SimConfig(num_clients=n, rounds=WARMUP + ROUNDS, seed=7,
                    local_steps=2, batch_size=16)
    fl = FLConfig(num_clients=n, clients_per_round=max(n // 8, 8))
    data = federated_classification(n, seed=8, n_per_client=16)
    return sim, fl, data


def host_loop(data, sim, fl, n_rounds, fleet):
    """Per-round host round-trip of the server step (the old loop).

    FLUDE planning/bookkeeping run eagerly (op-by-op, as the dict-era
    runner did) rather than through the policy's jitted plan path.
    ``fleet`` is constructed by the caller so every variant at a sweep
    point runs on the same identically-seeded draw stream."""
    N = fl.num_clients
    hints = jnp.asarray(fleet.battery * fleet.stability, jnp.float32)
    fstate = core.init_state(fl)
    trainer = make_trainer(sim, data)
    acc_fn = jax.jit(CLF.clf_accuracy)
    params = CLF.init_classifier(jax.random.key(sim.seed + 1),
                                 dim=data.x.shape[-1],
                                 num_classes=data.num_classes)
    caches = core.init_caches(params, N)
    cache_every = jnp.asarray(np.clip(np.round(core.adaptive_cache_interval(
        2.0, fleet.battery, fleet.stability)), 1, 4).astype(np.int32))
    n_samples = np.full(N, data.x.shape[1], np.float32)
    test_x = jnp.asarray(data.test_x)
    test_y = jnp.asarray(data.test_y)
    rng = jax.random.key(sim.seed)
    acc = float("nan")
    t_after_warmup = None
    for rnd in range(n_rounds):
        if rnd == WARMUP:
            t_after_warmup = time.time()
        rng, k_sel = jax.random.split(rng)
        online = fleet.online_mask()
        p = core.plan_round(fstate, caches, jnp.asarray(online), fl, k_sel,
                            explore_hints=hints)
        selected = np.asarray(p.selected)
        distribute = np.asarray(p.distribute)
        resume = np.asarray(p.resume)

        progress_h = np.asarray(caches.progress)
        stamp_h = np.asarray(caches.round_stamp)
        prior_steps = np.round(progress_h * sim.local_steps).astype(np.int32)
        steps_needed = np.where(resume,
                                np.maximum(sim.local_steps - prior_steps, 1),
                                sim.local_steps).astype(np.int32)
        steps_needed = np.where(selected, steps_needed, 0)
        fail = fleet.failure_draw(steps_needed / max(sim.local_steps, 1))
        fail &= selected
        stop = np.where(fail, fleet.failure_step(steps_needed), BIG)

        final, cache_p, cached_steps, _ = trainer(
            params, caches, jnp.asarray(resume), jnp.asarray(steps_needed),
            jnp.asarray(stop), cache_every)

        success = selected & ~fail & (steps_needed > 0)
        completed = np.minimum(steps_needed, stop)
        times = fleet.round_times(steps_needed, distribute, completed,
                                  success)
        quorum = int(np.ceil(min(float(p.quorum), float(selected.sum()))))
        finite = np.sort(times[np.isfinite(times)])
        if finite.size >= quorum and quorum > 0:
            t_cut = min(finite[quorum - 1], sim.round_deadline)
        else:
            t_cut = sim.round_deadline
        received = success & (times <= t_cut)
        fstate = core.update_after_round(fstate, p, jnp.asarray(received),
                                         fl)

        # --- host-side server step: pull, numpy aggregate, push --------
        final_h = jax.device_get(final)
        cache_h = jax.device_get(cache_p)
        cached_h = np.asarray(cached_steps)
        base_stale = np.where(resume & (stamp_h >= 0),
                              np.maximum(rnd - stamp_h, 0), 0)
        w = received * n_samples / (1.0 + base_stale)
        total = max(w.sum(), 1e-30)
        params_h = jax.device_get(params)
        if w.sum() > 0:
            wv = (w / total).astype(np.float32)
            params_h = jax.tree.map(
                lambda c, g: (c.astype(np.float32)
                              * wv.reshape((-1,) + (1,) * (c.ndim - 1))
                              ).sum(0).astype(g.dtype), final_h, params_h)
        total_cached = np.where(resume, prior_steps, 0) + cached_h
        write = selected & fail & (total_cached > 0)
        base_round = np.where(resume & (stamp_h >= 0), stamp_h, rnd)
        cache_leaves = jax.tree.map(
            lambda old, new: np.where(
                write.reshape((-1,) + (1,) * (old.ndim - 1)), new, old),
            jax.device_get(caches.params), cache_h)
        progress_h = np.where(write, total_cached / max(sim.local_steps, 1),
                              progress_h)
        stamp_h = np.where(write, base_round, stamp_h).astype(np.int32)
        progress_h = np.where(received, 0.0, progress_h).astype(np.float32)
        stamp_h = np.where(received, -1, stamp_h).astype(np.int32)
        params = jax.device_put(params_h)
        caches = core.ClientCaches(
            jax.tree.map(jnp.asarray, cache_leaves),
            jnp.asarray(progress_h), jnp.asarray(stamp_h))
        # per-round eval (the old loop's default)
        acc = float(acc_fn(params, test_x, test_y))
    return acc, time.time() - t_after_warmup


def engine_loop(data, sim, fl, n_rounds, fleet):
    # one shared fleet per sweep point: warmup advances the same stream
    # the measured rounds continue, exactly like the host loop — the A/B
    # variants see identical draws
    engine = FleetEngine(data, sim, fl, fleet=fleet)
    engine.run(POLICY, rounds=WARMUP, diagnostics=False)    # jit warmup
    t0 = time.time()
    h = engine.run(POLICY, rounds=n_rounds - WARMUP,
                   eval_every=n_rounds, diagnostics=False)
    return h.acc[-1], time.time() - t0


def run():
    # read-merge so a previously recorded --mesh sweep survives a plain
    # engine re-run (run_mesh() merges the other way for the same reason)
    path = os.path.join(RESULTS, "BENCH_engine.json")
    record = {}
    if os.path.exists(path):
        with open(path) as f:
            record = json.load(f)
    record.update(
        {"policy": POLICY, "rounds": ROUNDS,
         "note": "host loop evals every round (old default), engine "
                 "evals at boundaries; accs are sanity values, not "
                 "an equivalence check (see tests/test_policy_api.py)",
         "sizes": {}})
    for n in SIZES:
        sim, fl, data = _setup(n)
        # identically-seeded fleet per variant: both loops consume the
        # same warmup+measured draw sequence (A/B on one stream)
        acc_e, dt_e = engine_loop(data, sim, fl, WARMUP + ROUNDS,
                                  Fleet(sim))
        acc_h, dt_h = host_loop(data, sim, fl, WARMUP + ROUNDS,
                                Fleet(sim))
        rps_e = ROUNDS / dt_e
        rps_h = ROUNDS / dt_h
        record["sizes"][str(n)] = {
            "engine_rounds_per_sec": rps_e,
            "host_rounds_per_sec": rps_h,
            "speedup": rps_e / rps_h,
            "engine_final_acc": acc_e, "host_final_acc": acc_h,
        }
        emit(f"engine_n{n}", dt_e * 1e6 / ROUNDS,
             f"engine_rps={rps_e:.2f};host_rps={rps_h:.2f};"
             f"speedup={rps_e / rps_h:.2f}x")
    os.makedirs(RESULTS, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    emit("engine_summary", 0.0,
         f"max_speedup={max(v['speedup'] for v in record['sizes'].values()):.2f}x",
         record=None)
    return record


def mesh_child(k: int):
    """One forced-host-device-count measurement (runs in a subprocess).

    The parent sets ``--xla_force_host_platform_device_count=k`` through
    ``repro.launch.mesh.force_host_platform_device_count`` *before* this
    module (and therefore jax) is imported.
    """
    sim, fl, data = _setup(N_MESH)
    out = {"devices": k, "n": N_MESH, "policy": POLICY,
           "rounds": ROUNDS, "donate": {}}
    for donate in (False, True):
        fl2 = dataclasses.replace(fl,
                                  mesh_shape=(k,) if k > 1 else None,
                                  donate_buffers=donate)
        # one identically-seeded fleet per variant: donate on/off compare
        # on the same draw stream
        engine = FleetEngine(data, sim, fl2, fleet=Fleet(sim))
        engine.run(POLICY, rounds=WARMUP, diagnostics=False)   # jit warmup
        t0 = time.time()
        engine.run(POLICY, rounds=ROUNDS, eval_every=ROUNDS,
                   diagnostics=False)
        dt = time.time() - t0
        out["donate"]["on" if donate else "off"] = {
            "rounds_per_sec": ROUNDS / dt,
            **engine.server_step_memory(uses_cache=True)}
    print(json.dumps(out))


def run_mesh():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    sweep = []
    for k in MESH_DEVICES:
        code = ("from repro.launch.mesh import "
                "force_host_platform_device_count as F; "
                f"F({k}); "
                "from benchmarks.bench_engine import mesh_child; "
                f"mesh_child({k})")
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             cwd=root, capture_output=True, text=True,
                             timeout=3600)
        if out.returncode != 0:
            raise RuntimeError(f"mesh child k={k} failed:\n"
                               + out.stderr[-3000:])
        rec = json.loads(out.stdout.strip().splitlines()[-1])
        sweep.append(rec)
        on, off = rec["donate"]["on"], rec["donate"]["off"]
        emit(f"engine_mesh{k}", 1e6 / max(on["rounds_per_sec"], 1e-9),
             f"rps_on={on['rounds_per_sec']:.2f};"
             f"rps_off={off['rounds_per_sec']:.2f};"
             f"peak_on={on['peak_live_bytes']};"
             f"peak_off={off['peak_live_bytes']}")
    path = os.path.join(RESULTS, "BENCH_engine.json")
    record = {}
    if os.path.exists(path):
        with open(path) as f:
            record = json.load(f)
    record["mesh"] = {
        "policy": POLICY, "n": N_MESH, "rounds": ROUNDS,
        "note": "forced host devices; donate on/off compared per device "
                "count.  peak_live_bytes = argument+output+temp-alias of "
                "the compiled fused server step (donation aliases the "
                "previous global model + caches into the outputs)",
        "sweep": sweep}
    os.makedirs(RESULTS, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


PIPE_DEPTHS = (1, 2, 4)
PIPE_ROUNDS = 4 if QUICK else 10
PIPE_EVAL_EVERY = 10
PIPE_REPS = 1 if QUICK else 3


def run_pipeline():
    """Pipelined device round loop: rounds/sec at pipeline_depth 1/2/4.

    Depth 1 is the PR-4 device loop's scheduling (every round's
    bookkeeping resolves before the next round is planned); depth d
    keeps d-1 rounds of bookkeeping in flight, so round k+1's fused
    trainer + server step dispatch while round k executes.  Same policy,
    fleet, dynamics and eval cadence per depth — trajectories are
    bit-identical (tier-1 parity tests); only host/device overlap
    changes.  The measurement interleaves PIPE_REPS repetitions of every
    depth on pre-compiled engines and keeps each depth's best rep, so
    slow machine-load drift cannot masquerade as (or hide) a speedup.
    Merged into BENCH_engine.json under "pipeline"."""
    n = N_MESH
    sim, fl, data = _setup(n)
    sim = dataclasses.replace(sim, rounds=WARMUP + PIPE_ROUNDS * PIPE_REPS)
    engines = {}
    for depth in PIPE_DEPTHS:
        fl2 = dataclasses.replace(fl, dynamics="bernoulli",
                                  pipeline_depth=depth)
        engine = FleetEngine(data, sim, fl2, fleet=Fleet(sim))
        engine.run(POLICY, rounds=WARMUP, diagnostics=False)  # jit warmup
        engines[depth] = engine
    reps = {depth: [] for depth in PIPE_DEPTHS}
    acc = {}
    for _ in range(PIPE_REPS):
        for depth in PIPE_DEPTHS:
            t0 = time.time()
            h = engines[depth].run(POLICY, rounds=PIPE_ROUNDS,
                                   eval_every=PIPE_EVAL_EVERY,
                                   diagnostics=False)
            reps[depth].append(PIPE_ROUNDS / (time.time() - t0))
            acc[depth] = h.acc[-1]
    depths = {}
    for depth in PIPE_DEPTHS:
        best = max(reps[depth])
        depths[str(depth)] = {"rounds_per_sec": best,
                              "reps_rounds_per_sec": reps[depth],
                              "final_acc": acc[depth]}
        emit(f"engine_pipe_d{depth}", 1e6 / best,
             f"n={n};rps={best:.3f}")
    speedup = depths["2"]["rounds_per_sec"] / depths["1"]["rounds_per_sec"]
    path = os.path.join(RESULTS, "BENCH_engine.json")
    record = {}
    if os.path.exists(path):
        with open(path) as f:
            record = json.load(f)
    record["pipeline"] = {
        "policy": POLICY, "n": n, "rounds": PIPE_ROUNDS,
        "reps": PIPE_REPS, "eval_every": PIPE_EVAL_EVERY,
        "dynamics": "bernoulli",
        "depth2_over_depth1_speedup": speedup,
        "note": "depth 1 = the PR-4 device loop's per-round host sync; "
                "depth d defers History readback so up to d-1 rounds "
                "stay in flight.  Trajectories are depth-invariant "
                "(tests/test_round_close.py, tests/test_fleet_dynamics"
                ".py).  The speedup is pure host/device overlap: it is "
                "bounded by the host-side gap pipelining removes, which "
                "on the 2-core CPU recording container is ~5% of a "
                "round (fully-async dispatch upper bound measured "
                "1.06x) and within that machine's load noise — "
                "accelerator-backed hosts, where a round's host gap is "
                "a much larger fraction, are where depth > 1 pays",
        "depths": depths}
    os.makedirs(RESULTS, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    emit("engine_pipe_summary", 0.0,
         f"depth2_over_depth1={speedup:.3f}x", record=None)
    return record


DYN_PATHS = (("host_rng", "bernoulli_host"),
             ("device_bernoulli", "bernoulli"),
             ("device_markov", "markov"))


def run_dynamics():
    """Host-RNG vs device-resident fleet-draw round paths, rounds/sec.

    ``bernoulli_host`` draws every round on the host (numpy RNG + three
    place_per_client uploads per round); the device processes produce the
    draw, workload, failure and timing model in jitted dispatches with no
    per-round host→device hand-off.  Same policy, same fleet size —
    merged into BENCH_engine.json under "dynamics"."""
    n = N_MESH
    sim, fl, data = _setup(n)
    paths = {}
    for label, dyn in DYN_PATHS:
        fl2 = dataclasses.replace(fl, dynamics=dyn)
        engine = FleetEngine(data, sim, fl2, fleet=Fleet(sim))
        engine.run(POLICY, rounds=WARMUP, diagnostics=False)  # jit warmup
        t0 = time.time()
        h = engine.run(POLICY, rounds=ROUNDS, eval_every=ROUNDS,
                       diagnostics=False)
        dt = time.time() - t0
        paths[label] = {"dynamics": dyn, "rounds_per_sec": ROUNDS / dt,
                        "final_acc": h.acc[-1]}
        emit(f"engine_dyn_{label}", dt * 1e6 / ROUNDS,
             f"n={n};rps={ROUNDS / dt:.2f}")
    speedup = paths["device_bernoulli"]["rounds_per_sec"] \
        / paths["host_rng"]["rounds_per_sec"]
    path = os.path.join(RESULTS, "BENCH_engine.json")
    record = {}
    if os.path.exists(path):
        with open(path) as f:
            record = json.load(f)
    record["dynamics"] = {
        "policy": POLICY, "n": n, "rounds": ROUNDS,
        "device_over_host_speedup": speedup,
        "note": "host_rng draws availability/failures on host numpy and "
                "uploads (N,) masks per round; device paths produce the "
                "draw + workload + timing on device (repro.fleet), no "
                "per-round place_per_client",
        "paths": paths}
    os.makedirs(RESULTS, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    emit("engine_dyn_summary", 0.0,
         f"device_over_host={speedup:.2f}x", record=None)
    return record


if __name__ == "__main__":
    if "--mesh" in sys.argv[1:]:
        run_mesh()
    elif "--dynamics" in sys.argv[1:]:
        run_dynamics()
    elif "--pipeline" in sys.argv[1:]:
        run_pipeline()
    else:
        run()
