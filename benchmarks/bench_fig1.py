"""Fig. 1: model-performance damage from undependability.

(a) accuracy vs undependability rate (10%..60%), normal + uniform
    heterogeneity, vs a fully dependable fleet;
(b, c) per-class and per-device accuracy bias at 40% undependability.
"""
import numpy as np

from benchmarks.common import QUICK, emit, standard_setup, timed_run


def run():
    rates = [0.1, 0.3, 0.5] if QUICK else [0.1, 0.2, 0.3, 0.4, 0.5, 0.6]
    out = {"rates": rates, "normal": [], "uniform": [], "dependable": None}
    # dependable reference (undependability ~ 0)
    sim, fl, data = standard_setup(undep_means=(0.02, 0.02, 0.02), group_mode="class")
    h, _ = timed_run("random", data, sim, fl)
    out["dependable"] = h.acc[-1]
    for r in rates:
        sim, fl, data = standard_setup(undep_means=(r, r, r), group_mode="class")
        h, w = timed_run("random", data, sim, fl)
        out["normal"].append(h.acc[-1])
        # uniform heterogeneity: spread rates around the mean
        lo, hi = max(r - 0.2, 0.02), min(r + 0.2, 0.98)
        sim2, fl2, data2 = standard_setup(
            undep_means=tuple(np.linspace(lo, hi, 3)), group_mode="class")
        h2, _ = timed_run("random", data2, sim2, fl2)
        out["uniform"].append(h2.acc[-1])
        emit(f"fig1a_rate{int(r * 100)}", w * 1e6 / sim.rounds,
             f"normal={h.acc[-1]:.4f};uniform={h2.acc[-1]:.4f};"
             f"depend={out['dependable']:.4f}")
    # (b)(c): bias at 40%
    sim, fl, data = standard_setup(undep_means=(0.4, 0.4, 0.4), group_mode="class")
    h, _ = timed_run("random", data, sim, fl)
    out["per_class_acc"] = list(map(float, np.sort(h.per_class_acc)))
    out["per_client_acc"] = list(map(float, np.sort(h.per_client_acc)))
    emit("fig1bc_bias", 0.0,
         f"class_spread={out['per_class_acc'][-1] - out['per_class_acc'][0]:.3f};"
         f"client_spread={out['per_client_acc'][-1] - out['per_client_acc'][0]:.3f}",
         record=out)
    return out


if __name__ == "__main__":
    run()
