"""Fig. 2: communication cost to reach target accuracy vs undependability."""
from benchmarks.common import QUICK, emit, standard_setup, timed_run


def run():
    rates = [0.1, 0.3, 0.6] if QUICK else [0.1, 0.2, 0.3, 0.4, 0.5, 0.6]
    # dependable baseline target
    sim, fl, data = standard_setup(undep_means=(0.02, 0.02, 0.02), group_mode="class")
    h0, _ = timed_run("random", data, sim, fl)
    target = min(0.9 * h0.acc[-1], 0.9)
    base_comm = h0.comm_to_accuracy(target)
    out = {"target": target, "dependable_comm": base_comm, "rates": rates,
           "comm": []}
    for r in rates:
        sim, fl, data = standard_setup(undep_means=(r, r, r), group_mode="class")
        h, w = timed_run("random", data, sim, fl)
        c = h.comm_to_accuracy(target)
        out["comm"].append(c)
        rel = c / base_comm if base_comm > 0 else float("inf")
        emit(f"fig2_rate{int(r * 100)}", w * 1e6 / sim.rounds,
             f"comm_mb={c:.0f};vs_dependable={rel:.2f}x")
    emit("fig2_summary", 0.0,
         f"comm_inflation_at_60pct="
         f"{(out['comm'][-1] / base_comm if base_comm else 0):.2f}x",
         record=out)
    return out


if __name__ == "__main__":
    run()
