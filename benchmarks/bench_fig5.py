"""Fig. 5: communication cost to target accuracy, all five methods."""
from benchmarks.common import emit, standard_setup, timed_run

METHODS = ["asyncfeded", "safa", "fedsea", "oort", "flude"]


def run():
    sim, fl, data = standard_setup()
    hs = {m: timed_run(m, data, sim, fl)[0] for m in METHODS}
    target = min(h.acc[-1] for h in hs.values()) * 0.97
    out = {}
    for m in METHODS:
        c = hs[m].comm_to_accuracy(target)
        out[m] = c
        emit(f"fig5_{m}", 0.0, f"comm_mb={c:.0f}")
    best_base = min(v for k, v in out.items() if k != "flude")
    emit("fig5_summary", 0.0,
         f"flude_comm_reduction="
         f"{(1 - out['flude'] / best_base) * 100:.1f}pct",
         record=out)
    return out


if __name__ == "__main__":
    run()
