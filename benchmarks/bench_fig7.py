"""Fig. 7: model distributor ablation (full / adaptive / least)."""
from benchmarks.common import emit, replace, standard_setup, timed_run


def run():
    sim, fl, data = standard_setup()
    out = {}
    for mode in ("full", "adaptive", "least"):
        h, w = timed_run("flude", data, sim,
                         replace(fl, distribution_mode=mode))
        out[mode] = {"acc": h.acc[-1], "comm_mb": h.comm_mb[-1]}
        emit(f"fig7_{mode}", w * 1e6 / sim.rounds,
             f"acc={h.acc[-1]:.4f};comm_mb={h.comm_mb[-1]:.0f}")
    emit("fig7_summary", 0.0,
         f"adaptive_saves_vs_full="
         f"{(1 - out['adaptive']['comm_mb'] / max(out['full']['comm_mb'], 1e-9)) * 100:.1f}pct;"
         f"acc_drop_vs_full={out['full']['acc'] - out['adaptive']['acc']:.4f}",
         record=out)
    return out


if __name__ == "__main__":
    run()
