"""Fig. 8: robustness to device offline rates (online rate 0.5/0.3/0.1)."""
import dataclasses

from benchmarks.common import emit, standard_setup, timed_run
from repro.fl import Fleet


def run():
    out = {}
    for level, rate in (("low", 0.5), ("medium", 0.3), ("high", 0.1)):
        sim, fl, data = standard_setup()
        sim = dataclasses.replace(sim, online_low=rate * 0.8,
                                  online_high=rate * 1.2)
        accs = {}
        for m in ("flude", "oort"):
            h, w = timed_run(m, data, sim, fl)
            accs[m] = h.acc[-1]
        out[level] = accs
        emit(f"fig8_{level}", w * 1e6 / sim.rounds,
             f"flude={accs['flude']:.4f};oort={accs['oort']:.4f}")
    degr_f = out["low"]["flude"] - out["high"]["flude"]
    degr_o = out["low"]["oort"] - out["high"]["oort"]
    emit("fig8_summary", 0.0,
         f"flude_degradation={degr_f:.4f};oort_degradation={degr_o:.4f}",
         record=out)
    return out


if __name__ == "__main__":
    run()
