"""Fig. 9: robustness to undependability level (0.2/0.4/0.6) vs Oort."""
from benchmarks.common import emit, standard_setup, timed_run


def run():
    out = {}
    for level, mean in (("low", 0.2), ("medium", 0.4), ("high", 0.6)):
        sim, fl, data = standard_setup(undep_means=(mean, mean, mean))
        accs = {}
        for m in ("flude", "oort"):
            h, w = timed_run(m, data, sim, fl)
            accs[m] = h.acc[-1]
        out[level] = accs
        emit(f"fig9_{level}", w * 1e6 / sim.rounds,
             f"flude={accs['flude']:.4f};oort={accs['oort']:.4f}")
    emit("fig9_summary", 0.0,
         f"flude_drop={out['low']['flude'] - out['high']['flude']:.4f};"
         f"oort_drop={out['low']['oort'] - out['high']['oort']:.4f}",
         record=out)
    return out


if __name__ == "__main__":
    run()
