"""Kernel micro-benchmarks: XLA-path wall time on CPU + correctness gap.

(True TPU timings are out of reach in this container; interpret-mode Pallas
timing is NOT representative and is excluded from the perf narrative — the
roofline analysis covers the hardware story.  This bench times the XLA
reference path and records kernel-vs-oracle max error.)
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels.fed_agg.ops import fed_agg
from repro.kernels.fed_agg.ref import fed_agg_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rwkv6_scan.ops import rwkv6_scan
from repro.kernels.ssm_scan.ops import ssm_scan


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def run():
    rng = np.random.RandomState(0)

    # flash attention (XLA ref timing + kernel error)
    B, Hq, Hkv, S, D = 1, 8, 2, 512, 64
    q = jnp.asarray(rng.randn(B, Hq, S, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, Hkv, S, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, Hkv, S, D), jnp.float32)
    ref = jax.jit(lambda *a: attention_ref(*a, causal=True))
    us = _time(ref, q, k, v)
    got = flash_attention(q, k, v, causal=True, impl="pallas_interpret")
    err = float(jnp.abs(got - ref(q, k, v)).max())
    emit("kernel_flash_attention", us, f"maxerr={err:.2e};shape=B1H8S512D64")

    # ssm scan
    B, S, H, P, N = 1, 512, 4, 64, 64
    x = jnp.asarray(rng.randn(B, S, H, P), jnp.float32)
    dt = jnp.asarray(rng.rand(B, S, H) * 0.5, jnp.float32)
    A = jnp.asarray(-rng.rand(H) - 0.1, jnp.float32)
    Bm = jnp.asarray(rng.randn(B, S, 1, N), jnp.float32)
    Cm = jnp.asarray(rng.randn(B, S, 1, N), jnp.float32)
    ref_fn = jax.jit(lambda *a: ssm_scan(*a, impl="xla"))
    us = _time(ref_fn, x, dt, A, Bm, Cm)
    y1, h1 = ssm_scan(x, dt, A, Bm, Cm, impl="pallas_interpret", chunk=128)
    y2, h2 = ref_fn(x, dt, A, Bm, Cm)
    emit("kernel_ssm_scan", us,
         f"maxerr={float(jnp.abs(y1 - y2).max()):.2e};shape=S512H4P64N64")

    # rwkv6
    B, H, S, D = 1, 4, 256, 64
    r = jnp.asarray(rng.randn(B, H, S, D) * .5, jnp.float32)
    kk = jnp.asarray(rng.randn(B, H, S, D) * .5, jnp.float32)
    vv = jnp.asarray(rng.randn(B, H, S, D) * .5, jnp.float32)
    lw = jnp.asarray(-np.exp(rng.randn(B, H, S, D) * .5), jnp.float32)
    u = jnp.asarray(rng.randn(H, D) * .3, jnp.float32)
    ref_fn = jax.jit(lambda *a: rwkv6_scan(*a, impl="xla"))
    us = _time(ref_fn, r, kk, vv, lw, u)
    y1, s1 = rwkv6_scan(r, kk, vv, lw, u, impl="pallas_interpret", chunk=64)
    y2, s2 = ref_fn(r, kk, vv, lw, u)
    emit("kernel_rwkv6_scan", us,
         f"maxerr={float(jnp.abs(y1 - y2).max()):.2e};shape=S256H4D64")

    # fed_agg
    C, Dm = 64, 1 << 16
    up = jnp.asarray(rng.randn(C, Dm), jnp.float32)
    w = jnp.asarray(rng.rand(C), jnp.float32)
    ref_fn = jax.jit(fed_agg_ref)
    us = _time(ref_fn, up, w)
    got = fed_agg(up, w, impl="pallas_interpret")
    emit("kernel_fed_agg", us,
         f"maxerr={float(jnp.abs(got - ref_fn(up, w)).max()):.2e};"
         f"shape=C64D65536")


if __name__ == "__main__":
    run()
