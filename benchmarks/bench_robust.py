"""Robust aggregation under Byzantine attack: accuracy-vs-malicious-%
curves per agg_rule.

Every registered aggregation rule trains against the same scaled
sign-flip fleet (``u' = g - 4(u - g)``) at 0 / 10 / 20% malicious
clients.  Selection is unbiased (``random`` policy) so the curve
isolates the *aggregation* effect: the FLUDE selector would re-pick
dependable malicious clients round after round and inflate the cohort's
malicious fraction past the nominal rate (that interaction is a selection
problem, not an aggregation one — see the README's robust-aggregation
notes).

The headline derived metric is each rule's *retention* at 20% —
``acc(20%) / acc(0%)`` against its own clean accuracy, i.e. the drop
along its own curve.  Acceptance regime: ``geometric_median`` and
``trimmed_mean`` retain >= 90% at 20% malicious while the weighted mean
visibly degrades.

Records results/benchmarks/BENCH_robust.json.
"""
import dataclasses
import time

from benchmarks.common import QUICK, emit
from repro.configs.base import FLConfig
from repro.data.synthetic import federated_classification
from repro.fl import FleetEngine, SimConfig

N = 32 if QUICK else 60
ROUNDS = 20 if QUICK else 60
FRACS = (0.0, 0.2) if QUICK else (0.0, 0.1, 0.2)
POLICY = "random"
# trimmed_mean at the default trim=0.2 leaks coordinates in rounds where
# the cohort draw lands above the nominal malicious rate; trim=0.3 covers
# the hypergeometric spread at 20% malicious
RULES = (("mean", ()),
         ("geometric_median", ()),
         ("trimmed_mean", (("trim", 0.3),)),
         ("trust", ()))


def run():
    data = federated_classification(N, seed=1, classes_per_client=4)
    sim = SimConfig(num_clients=N, rounds=ROUNDS, seed=0,
                    undep_means=(0.4,) * 3)
    base = FLConfig(num_clients=N, clients_per_round=max(N // 4, 8),
                    dynamics="bernoulli")

    curves = {}
    t0 = time.time()
    for rule, params in RULES:
        accs = {}
        for frac in FRACS:
            fl = dataclasses.replace(
                base, agg_rule=rule, agg_rule_params=params,
                adversary=None if frac == 0.0 else "sign_flip",
                adversary_params=() if frac == 0.0
                else (("malicious_frac", frac),))
            h = FleetEngine(data, sim, fl).run(POLICY,
                                               diagnostics=False)
            accs[f"{frac:.2f}"] = float(h.acc[-1])
        clean = max(accs["0.00"], 1e-9)
        worst = f"{max(FRACS):.2f}"
        curves[rule] = {
            "params": dict(params),
            "acc": accs,
            "retention_at_worst": accs[worst] / clean,
        }
        emit(f"robust_{rule}", 0.0,
             f"acc@0%={accs['0.00']:.4f} acc@{worst}="
             f"{accs[worst]:.4f} retention="
             f"{curves[rule]['retention_at_worst']:.3f}")

    record = {
        "setup": {"num_clients": N, "rounds": ROUNDS, "policy": POLICY,
                  "attack": "sign_flip", "attack_scale": 4.0,
                  "malicious_fracs": list(FRACS),
                  "classes_per_client": 4, "quick": QUICK},
        "curves": curves,
        "elapsed_s": time.time() - t0,
    }
    emit("BENCH_robust", record["elapsed_s"] * 1e6,
         f"mean_retention={curves['mean']['retention_at_worst']:.3f} "
         f"gm_retention="
         f"{curves['geometric_median']['retention_at_worst']:.3f}",
         record=record)


if __name__ == "__main__":
    run()
