"""Deliverable (g): collate dry-run JSONs into the roofline table."""
import glob
import json
import os

from benchmarks.common import emit
from repro.roofline.analysis import format_table

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_rows():
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if "roofline" in rec:
            r = dict(rec["roofline"])
            r["peak_gb"] = rec["memory"]["peak_gb"]
            r["compile_s"] = rec["compile_s"]
            rows.append(r)
        elif "skipped" in rec:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": "-", "skipped": rec["skipped"]})
    return rows


def run():
    rows = load_rows()
    ok = [r for r in rows if "skipped" not in r and "compute_s" in r]
    skipped = [r for r in rows if "skipped" in r]
    for r in ok:
        emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
             r["compile_s"] * 1e6,
             f"dominant={r['dominant']};c={r['compute_s']:.3g};"
             f"m={r['memory_s']:.3g};coll={r['collective_s']:.3g};"
             f"useful={r['useful_flops_fraction']:.3f};"
             f"peak_gb={r['peak_gb']:.1f}")
    emit("roofline_matrix", 0.0,
         f"lowered={len(ok)};skipped={len(skipped)}",
         record={"rows": rows, "table": format_table(ok)})
    return rows


if __name__ == "__main__":
    run()
