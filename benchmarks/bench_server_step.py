"""Server round-step benchmark: leaf-wise vs packed aggregation, and the
old host-driven round tail vs the fused jitted ``server_round_step``.

Two comparisons across fleet size C (paper §4.3 hot spot):

  * ``agg``: per-leaf ``fed_aggregate`` (one XLA op chain per leaf) vs the
    packed single-buffer path (one aggregation over the whole model).
  * ``round_tail``: the pre-fusion sequence (host staleness math + leaf-wise
    aggregate + cache write/clear, each a separate dispatch) vs one
    ``server_round_step`` call.

CPU timings measure dispatch/fusion overhead, not TPU kernel speed — the
Pallas path is exercised for parity in tests and on TPU via
``FLConfig.agg_impl="pallas"``.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import QUICK, emit
from repro import core
from repro.fl import classifier as CLF

FLEETS = (32, 256) if QUICK else (32, 256, 1024)
LOCAL_STEPS = 4


def _time(fn, *args, reps=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def _fleet(C, rng):
    g = CLF.init_classifier(jax.random.key(0), dim=32)
    final = jax.tree.map(
        lambda a: jnp.asarray(rng.randn(C, *a.shape), a.dtype), g)
    w = jnp.asarray(rng.rand(C), jnp.float32)
    return g, final, w


def _old_round_tail(g, caches, final, w_inputs, local_steps):
    """Pre-fusion server tail, verbatim host-driven sequence."""
    selected, fail, received, resume, n_samples, rnd = w_inputs
    stamp0 = np.asarray(caches.round_stamp)
    base_stale = np.where(resume & (stamp0 >= 0),
                          np.maximum(rnd - stamp0, 0), 0)
    w = core.aggregation_weights(jnp.asarray(received), n_samples=n_samples,
                                 staleness=jnp.asarray(base_stale,
                                                       jnp.float32),
                                 staleness_discount=1.0)
    g = core.fed_aggregate(g, final, w)
    prior = np.round(np.asarray(caches.progress)
                     * local_steps).astype(np.int32)
    total = np.where(resume, prior, 0) + local_steps
    write = selected & fail & (total > 0)
    base_round = np.where(resume & (stamp0 >= 0), stamp0, rnd)
    caches = core.write_cache(
        caches, jnp.asarray(write), final,
        jnp.asarray(total / max(local_steps, 1)).astype(jnp.float32),
        jnp.asarray(base_round, jnp.int32))
    caches = core.clear_cache(caches, jnp.asarray(received))
    return g, caches


def run():
    rng = np.random.RandomState(0)
    for C in FLEETS:
        g, final, w = _fleet(C, rng)
        D = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(g))

        # -- aggregation only: leaf-wise tree.map vs packed single buffer
        leafwise = jax.jit(lambda gp, cp, ww: core.fed_aggregate(gp, cp, ww))
        packed = jax.jit(lambda gp, cp, ww: core.fed_aggregate_packed(
            gp, cp, ww, impl="xla"))
        us_leaf = _time(leafwise, g, final, w)
        us_pack = _time(packed, g, final, w)
        emit(f"server_agg_leafwise_C{C}", us_leaf, f"D={D}")
        emit(f"server_agg_packed_C{C}", us_pack,
             f"D={D};speedup={us_leaf / max(us_pack, 1e-9):.2f}x")

        # -- full round tail: old host-driven sequence vs fused jitted step
        caches = core.init_caches(g, C)
        selected = rng.rand(C) < 0.8
        fail = selected & (rng.rand(C) < 0.3)
        received = selected & ~fail
        resume = selected & (rng.rand(C) < 0.5)
        n_samples = jnp.full((C,), 48.0)
        step = core.make_server_round_step(g, local_steps=LOCAL_STEPS,
                                           agg_impl="xla")
        cached_steps = jnp.full((C,), LOCAL_STEPS, jnp.int32)
        args = (g, caches, final, final, cached_steps,
                jnp.asarray(selected), jnp.asarray(fail),
                jnp.asarray(received), jnp.asarray(resume), n_samples,
                jnp.ones((C,), jnp.float32), 3)
        us_fused = _time(lambda *a: step(*a), *args)
        w_inputs = (selected, fail, received, resume, n_samples, 3)
        us_old = _time(
            lambda: _old_round_tail(g, caches, final, w_inputs, LOCAL_STEPS))
        emit(f"server_round_old_C{C}", us_old, f"D={D}")
        emit(f"server_round_fused_C{C}", us_fused,
             f"D={D};speedup={us_old / max(us_fused, 1e-9):.2f}x")


if __name__ == "__main__":
    run()
