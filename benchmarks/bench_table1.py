"""Table 1: final accuracy/AUC + time-to-accuracy + comm, all five methods.

Two task rows mirror the paper's spread: classification (CIFAR/Speech
analogue, accuracy) and CTR recommendation (Avazu analogue, AUC).
"""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import TIME_BUDGET, emit, standard_setup, timed_run
from repro.configs.base import FLConfig
from repro.data.synthetic import auc, ctr_dataset
from repro.fl import SimConfig
from repro.fl import classifier as CLF

METHODS = ["asyncfeded", "safa", "fedsea", "oort", "flude"]


def run_ctr():
    n = 48
    data = ctr_dataset(n, seed=11)
    sim = SimConfig(num_clients=n, rounds=250, seed=11, local_steps=6)
    fl = FLConfig(num_clients=n, clients_per_round=10)
    out = {}
    for m in METHODS:
        h, _ = timed_run(m, data, sim, fl)
        scores = np.asarray(CLF.clf_logits(
            h.final_params, jnp.asarray(data.test_x)))[:, 1]
        out[m] = {"auc": auc(scores, data.test_y),
                  "comm_mb": h.comm_mb[-1], "rounds": len(h.acc)}
        emit(f"table1_ctr_{m}", 0.0,
             f"auc={out[m]['auc']:.4f};comm_mb={out[m]['comm_mb']:.0f}")
    emit("table1_ctr_summary", 0.0,
         f"flude_auc_rank="
         f"{sorted(out, key=lambda k: -out[k]['auc']).index('flude') + 1}"
         f"/5", record=out)
    return out


def run():
    sim, fl, data = standard_setup()
    results = {}
    for m in METHODS:
        h, wall = timed_run(m, data, sim, fl)
        results[m] = {"acc": h.acc[-1], "wall_clock": h.wall_clock[-1],
                      "comm_mb": h.comm_mb[-1], "acc_curve": h.acc,
                      "time_curve": h.wall_clock,
                      "comm_curve": h.comm_mb, "bench_s": wall}
    # target = weakest final accuracy (paper's fair-comparison rule)
    target = min(r["acc"] for r in results.values())
    for m in METHODS:
        h_t = next((t for t, a in zip(results[m]["time_curve"],
                                      results[m]["acc_curve"])
                    if a >= target), float("inf"))
        results[m]["time_to_target"] = h_t
        emit(f"table1_{m}",
             results[m]["bench_s"] * 1e6 / sim.rounds,
             f"acc={results[m]['acc']:.4f};tta_s={h_t:.0f};"
             f"comm_mb={results[m]['comm_mb']:.0f}")
    results["ctr"] = run_ctr()
    results["target_acc"] = target
    emit("table1_summary", 0.0,
         f"flude_speedup_vs_best_baseline="
         f"{min(results[m]['time_to_target'] for m in METHODS[:-1]) / max(results['flude']['time_to_target'], 1e-9):.2f}x",
         record=results)
    return results


if __name__ == "__main__":
    run()
