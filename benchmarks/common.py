"""Shared benchmark scaffolding: standard fleet/task setups + CSV output.

Every bench mirrors one paper artifact (Table 1, Figs. 1/2/5/6/7/8/9) on the
synthetic classification task (the paper's CIFAR/Speech stand-in, see
DESIGN.md §3).  Results print as ``name,us_per_call,derived`` CSV rows and
are archived under results/benchmarks/.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

from repro.configs.base import FLConfig
from repro.data.synthetic import federated_classification
from repro.fl import FleetEngine, SimConfig

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "benchmarks")

QUICK = bool(int(os.environ.get("BENCH_QUICK", "0")))


# simulated-time budget: the paper's comparison regime — every method gets
# the same wall clock; faster policies fit more rounds (Table 1 "Time").
TIME_BUDGET = 4000.0 if QUICK else 10800.0


def standard_setup(num_clients=60, rounds=None, seed=7,
                   undep_means=(0.2, 0.4, 0.6), group_mode="random",
                   **data_kw):
    rounds = rounds or (60 if QUICK else 250)
    sim = SimConfig(num_clients=num_clients, rounds=rounds, seed=seed,
                    undep_means=undep_means, local_steps=6,
                    group_mode=group_mode)
    fl = FLConfig(num_clients=num_clients,
                  clients_per_round=max(num_clients // 5, 8))
    kw = dict(seed=seed + 1, margin=1.0, noise=1.6, n_per_client=48)
    kw.update(data_kw)
    data = federated_classification(num_clients, **kw)
    return sim, fl, data


_ENGINES = {}
_ENGINE_SLOTS = 4     # bounded: a full bench sweep must not pin every
                      # dataset + compiled trainer for the process lifetime


def get_engine(data, sim, fl) -> FleetEngine:
    """One FleetEngine per (task, sim, fl) setup — policies compared on
    the same setup share the compiled trainer/server round path."""
    key = (id(data), sim, fl)
    if key not in _ENGINES:
        while len(_ENGINES) >= _ENGINE_SLOTS:
            _ENGINES.pop(next(iter(_ENGINES)))
        _ENGINES[key] = FleetEngine(data, sim, fl)
    return _ENGINES[key]


def timed_run(policy, data, sim, fl, time_budget=None):
    engine = get_engine(data, sim, fl)
    t0 = time.time()
    h = engine.run(policy, time_budget=time_budget or TIME_BUDGET)
    return h, time.time() - t0


def emit(name: str, us_per_call: float, derived, record=None):
    print(f"{name},{us_per_call:.1f},{derived}")
    if record is not None:
        os.makedirs(RESULTS, exist_ok=True)
        with open(os.path.join(RESULTS, f"{name}.json"), "w") as f:
            json.dump(record, f, indent=1, default=float)


def replace(obj, **kw):
    return dataclasses.replace(obj, **kw)
