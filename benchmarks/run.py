"""Benchmark harness: one bench per paper table/figure + kernels + roofline.

Usage:  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
Output: ``name,us_per_call,derived`` CSV rows (also archived under
results/benchmarks/).
"""
import argparse
import os
import sys
import traceback
import types


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer rounds/settings per bench")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    if args.quick:
        os.environ["BENCH_QUICK"] = "1"

    from benchmarks import (bench_ablation_selector, bench_beyond,
                            bench_engine, bench_fig1, bench_fig2,
                            bench_fig5, bench_fig7, bench_fig8, bench_fig9,
                            bench_kernels, bench_robust, bench_roofline,
                            bench_server_step, bench_table1)
    benches = {
        "table1": bench_table1,
        "fig1": bench_fig1,
        "fig2": bench_fig2,
        "fig5": bench_fig5,
        "ablation_selector": bench_ablation_selector,
        "fig7": bench_fig7,
        "fig8": bench_fig8,
        "fig9": bench_fig9,
        "beyond_selection": bench_beyond,
        "kernels": bench_kernels,
        # robust aggregation rules vs Byzantine attack fractions
        "robust": bench_robust,
        "roofline": bench_roofline,
        "server_step": bench_server_step,
        "engine": bench_engine,
        # client-mesh sweep (forced-host-device subprocesses, so it works
        # from this single-device parent process)
        "engine_mesh": types.SimpleNamespace(run=bench_engine.run_mesh),
        # host-RNG vs device-resident fleet-draw paths (repro.fleet)
        "engine_dynamics": types.SimpleNamespace(
            run=bench_engine.run_dynamics),
        # pipelined device round loop (pipeline_depth 1/2/4)
        "engine_pipeline": types.SimpleNamespace(
            run=bench_engine.run_pipeline),
        # compact-cohort round path (X sweep + N=1M fleet-state smoke)
        "engine_cohort": types.SimpleNamespace(
            run=bench_engine.run_cohort),
        # C3 cache residency (resident vs host vs discard + full-model
        # N=1M smoke)
        "engine_offload": types.SimpleNamespace(
            run=bench_engine.run_offload),
        # telemetry="full" overhead (paired off/full) + real JSONL/trace
        # artifacts rendered by the report CLI
        "engine_telemetry": types.SimpleNamespace(
            run=bench_engine.run_telemetry),
    }
    print("name,us_per_call,derived")
    failed = []
    for name, mod in benches.items():
        if args.only and name != args.only:
            continue
        try:
            mod.run()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
