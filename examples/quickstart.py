"""Quickstart: FLUDE vs random FedAvg on a 60-device undependable fleet.

Builds one FleetEngine (trainer + fused server step jit once) and runs
two registered policies through it — the paper's comparison loop.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.base import FLConfig
from repro.data.synthetic import federated_classification
from repro.fl import FleetEngine, SimConfig


def main():
    n = 60
    sim = SimConfig(num_clients=n, rounds=30, seed=0,
                    undep_means=(0.2, 0.4, 0.6))   # paper §5.2 groups
    fl = FLConfig(num_clients=n, clients_per_round=15)
    data = federated_classification(n, seed=1, margin=1.4, noise=1.3)
    engine = FleetEngine(data, sim, fl)

    print("policy    final-acc   wall-clock   comm")
    for policy in ("flude", "random"):
        h = engine.run(policy,
                       progress=lambda r, a, c, t:
                       print(f"  [{policy}] round {r:3d} acc {a:.3f}"))
        print(f"{policy:8s}  {h.acc[-1]:.4f}     "
              f"{h.wall_clock[-1]:8.0f}s   {h.comm_mb[-1]:7.0f} MB")


if __name__ == "__main__":
    main()
