"""Batched serving example: prefill + decode with any assigned arch.

    PYTHONPATH=src python examples/serve_batch.py [arch]
"""
import sys

from repro.launch import serve


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "qwen2-7b"
    sys.argv = [sys.argv[0], "--arch", arch, "--reduced", "--batch", "4",
                "--prompt-len", "64", "--decode-tokens", "16"]
    serve.main()


if __name__ == "__main__":
    main()
