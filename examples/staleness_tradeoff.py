"""The staleness-aware distributor's trade-off (paper §4.3 / Fig. 7).

Compares full / adaptive / least model distribution and prints the
accuracy-vs-communication frontier; also shows the adaptive threshold W
reacting to fleet staleness (Eq. 4).

    PYTHONPATH=src python examples/staleness_tradeoff.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro import core
from repro.configs.base import FLConfig
from repro.data.synthetic import federated_classification
from repro.fl import FleetEngine, SimConfig


def main():
    n = 60
    sim = SimConfig(num_clients=n, rounds=30, seed=0,
                    undep_means=(0.3, 0.5, 0.7))
    data = federated_classification(n, seed=1, margin=1.4, noise=1.3)

    print("mode       final-acc   comm (MB)")
    for mode in ("full", "adaptive", "least"):
        fl = FLConfig(num_clients=n, clients_per_round=15,
                      distribution_mode=mode)
        h = FleetEngine(data, sim, fl).run("flude")
        print(f"{mode:9s}  {h.acc[-1]:.4f}     {h.comm_mb[-1]:7.0f}")

    print("\n== Eq. 4 threshold dynamics (isolated) ==")
    st = core.init_distributor(3.0)
    rng = jax.random.key(0)
    for rnd, avg_stale in enumerate([1.0, 2.0, 6.0, 12.0, 4.0, 2.0]):
        sel = jnp.ones((16,), bool)
        stale = jnp.full((16,), avg_stale)
        plan = core.plan_distribution(
            st, sel, jnp.ones((16,), bool), jnp.ones((16,), bool), stale,
            lam=1.0, mu=0.5, w_min=1.0, w_max=50.0)
        st = plan.state
        print(f"  round {rnd}: avg staleness {avg_stale:4.1f}  ->  "
              f"W = {float(st.w_threshold):5.2f}  "
              f"(refresh {int(plan.distribute.sum())}/16)")


if __name__ == "__main__":
    main()
