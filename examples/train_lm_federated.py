"""End-to-end driver: federated-train a causal LM with FLUDE (paper kind:
training).  Defaults to a quick 5M-param run; use --scale 100m for the
~100M-parameter configuration.

    PYTHONPATH=src python examples/train_lm_federated.py --rounds 200
    PYTHONPATH=src python examples/train_lm_federated.py --scale 100m \
        --rounds 300     # full driver (slower on CPU)
"""
import sys

from repro.launch import train


def main():
    if "--rounds" not in " ".join(sys.argv):
        sys.argv += ["--rounds", "100"]
    train.main()


if __name__ == "__main__":
    main()
