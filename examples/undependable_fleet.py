"""Reproduce the paper's §2.2 motivation: how undependability hurts FL.

Sweeps the undependability rate and reports final accuracy + comm cost for
vanilla FedAvg, then shows FLUDE recovering the loss at 40%.

    PYTHONPATH=src python examples/undependable_fleet.py
"""
from repro.configs.base import FLConfig
from repro.data.synthetic import federated_classification
from repro.fl import FleetEngine, SimConfig


def main():
    n = 60
    fl = FLConfig(num_clients=n, clients_per_round=15)
    data = federated_classification(n, seed=1, margin=1.4, noise=1.3)

    print("== FedAvg under increasing undependability (paper Fig. 1a) ==")
    for rate in (0.05, 0.2, 0.4, 0.6):
        sim = SimConfig(num_clients=n, rounds=30, seed=0,
                        undep_means=(rate,) * 3)
        h = FleetEngine(data, sim, fl).run("random")
        print(f"  undependability {rate:.0%}: acc {h.acc[-1]:.4f}  "
              f"comm {h.comm_mb[-1]:6.0f} MB")

    print("== FLUDE at 40% undependability ==")
    sim = SimConfig(num_clients=n, rounds=30, seed=0,
                    undep_means=(0.4,) * 3)
    engine = FleetEngine(data, sim, fl)
    for policy in ("random", "flude"):
        h = engine.run(policy)
        print(f"  {policy:8s}: acc {h.acc[-1]:.4f}  "
              f"comm {h.comm_mb[-1]:6.0f} MB  wall {h.wall_clock[-1]:.0f}s")


if __name__ == "__main__":
    main()
