"""Reproduce the paper's §2.2 motivation: how undependability hurts FL.

Default run sweeps the undependability rate and reports final accuracy +
comm cost for vanilla FedAvg, then shows FLUDE recovering the loss at
40%.

``--scenario NAME`` instead runs the comparison under a named fleet-
dynamics scenario from the registry (``repro.fleet.scenarios`` — markov
churn, diurnal sessions, flash crowds, correlated dropout, trace
replay), printing each scenario's availability profile first.

``--attack NAME`` runs the robust-aggregation comparison under a named
adversarial scenario (``sign-flip-10``, ``sign-flip-20``,
``label-flip-20``, ``grad-scale-10``): every registered ``agg_rule`` is
trained against the same poisoned fleet and the final accuracies are
printed side by side — the weighted mean degrades, the robust rules
hold.

``--telemetry PATH`` instruments the headline FLUDE-at-40%% comparison:
device metrics + host span traces are appended to ``PATH`` (JSONL, one
event per line; a Perfetto trace lands next to it as
``PATH + ".trace.json"``) and the per-run summary is rendered inline —
the same output as ``python -m repro.obs.report PATH``.

    PYTHONPATH=src python examples/undependable_fleet.py
    PYTHONPATH=src python examples/undependable_fleet.py --scenario diurnal
    PYTHONPATH=src python examples/undependable_fleet.py --scenario all
    PYTHONPATH=src python examples/undependable_fleet.py --attack sign-flip-20
    PYTHONPATH=src python examples/undependable_fleet.py --telemetry run.jsonl
"""
import argparse
import dataclasses

from repro.configs.base import FLConfig
from repro.core import available_agg_rules
from repro.data.synthetic import federated_classification
from repro.fl import FleetEngine, SimConfig
from repro.fleet import (apply_scenario, availability_summary,
                         available_scenarios, get_scenario, make_dynamics,
                         simulate_availability)
from repro.fl.simulator import Fleet


def paper_sweep():
    n = 60
    fl = FLConfig(num_clients=n, clients_per_round=15)
    data = federated_classification(n, seed=1, margin=1.4, noise=1.3)

    print("== FedAvg under increasing undependability (paper Fig. 1a) ==")
    for rate in (0.05, 0.2, 0.4, 0.6):
        sim = SimConfig(num_clients=n, rounds=30, seed=0,
                        undep_means=(rate,) * 3)
        h = FleetEngine(data, sim, fl).run("random")
        print(f"  undependability {rate:.0%}: acc {h.acc[-1]:.4f}  "
              f"comm {h.comm_mb[-1]:6.0f} MB")

    print("== FLUDE at 40% undependability ==")
    sim = SimConfig(num_clients=n, rounds=30, seed=0,
                    undep_means=(0.4,) * 3)
    engine = FleetEngine(data, sim, fl)
    for policy in ("random", "flude"):
        h = engine.run(policy)
        print(f"  {policy:8s}: acc {h.acc[-1]:.4f}  "
              f"comm {h.comm_mb[-1]:6.0f} MB  wall {h.wall_clock[-1]:.0f}s")


def scenario_run(names):
    n = 60
    fl = FLConfig(num_clients=n, clients_per_round=15)
    data = federated_classification(n, seed=1, margin=1.4, noise=1.3)
    sim = SimConfig(num_clients=n, rounds=30, seed=0,
                    undep_means=(0.4,) * 3)
    for name in names:
        sc = get_scenario(name)
        fleet = Fleet(sim)
        process = make_dynamics(sc.dynamics, sim, fleet=fleet,
                                params=sc.params)
        online = simulate_availability(process, rounds=96, seed=0)
        s = availability_summary(online)
        print(f"== scenario {name!r} ({sc.dynamics}) ==")
        print(f"  {sc.description}")
        print(f"  availability: mean online fraction "
              f"{s['mean_online_fraction']:.3f}, mean session length "
              f"{s['mean_session_length']:.2f} rounds "
              f"({s['num_sessions']} sessions / 96 rounds)")
        engine = FleetEngine(data, sim, apply_scenario(fl, name))
        for policy in ("random", "flude"):
            h = engine.run(policy)
            print(f"  {policy:8s}: acc {h.acc[-1]:.4f}  "
                  f"comm {h.comm_mb[-1]:6.0f} MB  "
                  f"wall {h.wall_clock[-1]:.0f}s")


def attack_run(name):
    n = 60
    data = federated_classification(n, seed=1, margin=1.4, noise=1.3)
    sim = SimConfig(num_clients=n, rounds=30, seed=0,
                    undep_means=(0.4,) * 3)
    sc = get_scenario(name)
    frac = dict(sc.adversary_params).get("malicious_frac", 0.0)
    print(f"== attack {name!r}: {sc.adversary} at {frac:.0%} malicious ==")
    print(f"  {sc.description}")
    base = apply_scenario(FLConfig(num_clients=n, clients_per_round=15),
                          name)
    clean = FleetEngine(
        data, sim, dataclasses.replace(base, adversary=None,
                                       adversary_params=())
    ).run("flude").acc[-1]
    print(f"  (clean fleet, mean aggregation: acc {clean:.4f})")
    for rule in available_agg_rules():
        fl = dataclasses.replace(base, agg_rule=rule)
        h = FleetEngine(data, sim, fl).run("flude")
        print(f"  agg_rule={rule:18s} acc {h.acc[-1]:.4f}  "
              f"({h.acc[-1] / max(clean, 1e-9):5.1%} of clean)")


def telemetry_run(path):
    from repro import obs
    from repro.obs import report as obs_report
    n = 60
    fl = FLConfig(num_clients=n, clients_per_round=15)
    data = federated_classification(n, seed=1, margin=1.4, noise=1.3)
    sim = SimConfig(num_clients=n, rounds=30, seed=0,
                    undep_means=(0.4,) * 3)
    engine = FleetEngine(data, sim, fl)
    print("== FLUDE vs random at 40% undependability, instrumented ==")
    print(f"  events -> {path}  trace -> {path}.trace.json")
    for policy in ("random", "flude"):
        tel = obs.Telemetry(level="full", jsonl=path,
                            trace=path + ".trace.json")
        engine.run(policy, telemetry=tel)
        tel.close()
    print()
    for run in obs_report.parse_runs(path)[-2:]:
        obs_report.render(run)
        print()


_ATTACKS = ("sign-flip-10", "sign-flip-20", "label-flip-20",
            "grad-scale-10")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default=None,
                    choices=sorted(available_scenarios()) + ["all"],
                    help="run under a named fleet-dynamics scenario "
                         "(default: the paper's undependability sweep)")
    ap.add_argument("--attack", default=None,
                    choices=sorted(_ATTACKS) + ["all"],
                    help="run every registered agg_rule against a named "
                         "adversarial scenario and compare final accuracy")
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="instrument the FLUDE comparison: append "
                         "telemetry JSONL to PATH, save a Perfetto trace "
                         "and print the report summary")
    args = ap.parse_args()
    if args.telemetry is not None:
        telemetry_run(args.telemetry)
    elif args.attack is not None:
        for name in (_ATTACKS if args.attack == "all" else [args.attack]):
            attack_run(name)
    elif args.scenario is None:
        paper_sweep()
    elif args.scenario == "all":
        scenario_run(available_scenarios())
    else:
        scenario_run([args.scenario])


if __name__ == "__main__":
    main()
