"""Static analysis of the round path (ISSUE 10).

Two passes over the same contracts:

* :mod:`repro.analysis.audit` — the invariant auditor: lowers the
  engine's jitted round-path dispatches and statically verifies the
  zero-sync / donation / dtype / sharding / transfer-ceiling contracts
  against the post-SPMD HLO (``python -m repro.analysis.audit``).
* :mod:`repro.analysis.lint` — the repo lint: stdlib-AST rules for the
  same contracts at the source level
  (``python -m repro.analysis.lint src/``).
* :mod:`repro.analysis.runtime` — the ``FLConfig.debug_checks``
  sanitizers (checkify round guards + recompilation detector).

Submodules are intentionally not imported here: ``lint``/``hlo_checks``
are stdlib-light CLI entry points (importing them from the package
would trip runpy's double-import warning under ``python -m``), and
``audit``/``runtime`` pull in jax + the engine.
"""
