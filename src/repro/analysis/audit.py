"""Invariant auditor: static verification of the engine's round path.

The auditor replays the construction of one engine round — the same
argument plumbing as ``FleetEngine._device_rounds``, on a live engine —
but instead of just executing the jitted dispatches it *lowers and
compiles* each one (trainer, round cut, metrics, server step, flude
plan/update, cohort index, cache expiry, eval) and statically checks
the post-SPMD HLO against the round-path contracts
(:mod:`repro.analysis.hlo_checks`):

1. no host callbacks / infeed / outfeed / host-memory copies,
2. donated inputs really alias into outputs,
3. no f64 leakage, fp32-accumulated psum,
4. fleet-shaped (N,)/(X,) operands partitioned on ``("clients",)``,
5. a static per-round ceiling on the cache stream's host transfers
   consistent with ``engine.transfer_stats``.

Run the registered-policy matrix from the CLI (the ``analysis-smoke``
CI job does exactly this, at 8 forced host devices)::

    PYTHONPATH=src python -m repro.analysis.audit --devices 8
    PYTHONPATH=src python -m repro.analysis.audit --policies flude --modes offload

or audit a live engine in tests / notebooks::

    report = audit_engine(engine, "flude")
    report.raise_on_findings()

Lowering traces but executes nothing; the replay itself runs only the
cheap setup dispatches (dynamics draw, trainer, cut) on a toy fleet, so
a full matrix audit is seconds, not minutes.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis import hlo_checks as HC


@dataclasses.dataclass(frozen=True)
class _Dispatch:
    """One jitted round-path callable plus the representative arguments
    it is lowered with."""
    name: str
    fn: object
    args: tuple
    min_aliases: int = 0        # expected donated input-output aliases
    sharded: bool = True        # subject to the ("clients",) contract


@dataclasses.dataclass
class AuditReport:
    policy: str
    mode: str                    # "full" | "cohort" | "offload"
    mesh_size: int               # 1 = single-device round path
    dispatches: List[str]
    findings: List[HC.Finding]
    transfer_ceiling: Dict[str, int]

    def ok(self) -> bool:
        return not self.findings

    def summary(self) -> str:
        head = (f"audit[{self.policy}/{self.mode}@{self.mesh_size}d] "
                f"{len(self.dispatches)} dispatches")
        if self.ok():
            return head + ": all contracts hold"
        lines = [head + f": {len(self.findings)} finding(s)"]
        lines += [f"  - {f}" for f in self.findings]
        return "\n".join(lines)

    def raise_on_findings(self) -> None:
        if not self.ok():
            raise AssertionError(self.summary())


def _mode(engine) -> str:
    if engine.cohort is None:
        return "full"
    return "offload" if engine.offload is not None else "cohort"


# ---------------------------------------------------------------------------
# Contract 5: static per-round transfer ceiling
# ---------------------------------------------------------------------------

def transfer_ceiling(engine, uses_cache: bool) -> Dict[str, int]:
    """Static per-round ceiling on the engine's cache-stream transfers.

    The offload stream's steady-state round is exactly: one async d2h of
    the cohort index plus one async d2h of the staged write-back, one
    async h2d of the fetched (X, D) block, two pre-issued host reads
    (gather + prune bookkeeping), and **zero** synchronous copies — the
    double-buffering contract ``tests/test_cache_store.py`` pins
    dynamically.  Everything else (resident caches, or a policy that
    never caches) moves nothing per round."""
    if engine.offload is None or not uses_cache:
        return {"d2h_async": 0, "h2d_async": 0,
                "pre_issued_reads": 0, "sync_copies": 0}
    return {"d2h_async": 2, "h2d_async": 1,
            "pre_issued_reads": 2, "sync_copies": 0}


def check_transfer_stats(engine, rounds: int, uses_cache: bool,
                         dispatch: str = "cache_stream",
                         ) -> List[HC.Finding]:
    """Compare ``engine.transfer_stats`` after ``rounds`` executed rounds
    against the static ceiling — the dynamic half of contract 5."""
    ceiling = transfer_ceiling(engine, uses_cache)
    stats = engine.transfer_stats
    findings: List[HC.Finding] = []
    for key, per_round in ceiling.items():
        bound = 0 if key == "sync_copies" else per_round * rounds
        got = getattr(stats, key)
        if got > bound:
            findings.append(HC.Finding(
                dispatch, "transfer",
                f"{key}={got} after {rounds} round(s) exceeds the "
                f"static ceiling {bound} "
                f"({per_round}/round) — snapshot: {stats.snapshot()}"))
    return findings


# ---------------------------------------------------------------------------
# One-round replay: collect every jitted dispatch with live arguments
# ---------------------------------------------------------------------------

def _collect_dispatches(engine, policy, fleet) -> List[_Dispatch]:
    """Mirror one ``_device_rounds`` round, recording each jitted
    dispatch with the exact arguments the engine would pass.  The cheap
    upstream dispatches (dynamics draw, trainer, cut) are executed so
    downstream ones get real, correctly-sharded operands; the expensive
    or donating ones (server step, metrics, eval) are only recorded."""
    import jax
    import numpy as np

    from repro.fl.api import RoundObservation

    uses_cache = policy.uses_cache
    process, init_fn, step_fn, trainer = engine._dynamics_fns(fleet)
    cache_every, ones_w, full_steps = engine._dyn_consts(fleet, uses_cache)
    server_step = engine._server_step(uses_cache)
    rule_state = engine._init_rule_state()
    cut_fn = engine._round_cut(policy.waits_for_stragglers)
    metrics_fn, m_keys = engine._metrics_fn(
        "full", uses_cache,
        rows_bound=None if engine.cohort is not None
        else policy.selection_bound())

    global_params = engine._template
    caches = engine._fresh_caches(global_params)
    n_samples = engine._n_samples
    rnd = 0

    dyn_base = jax.random.fold_in(jax.random.key(engine.sim_cfg.seed),
                                  0x0F1EE7)
    fstate = init_fn(jax.random.fold_in(dyn_base, 1 << 20))
    step_key = jax.random.fold_in(dyn_base, rnd)
    out: List[_Dispatch] = [
        _Dispatch("dynamics_step", step_fn, (fstate, step_key))]
    fstate, draw = step_fn(fstate, step_key)

    if engine.offload == "discard" and uses_cache:
        expire_fn = engine._expire_fn_jit()
        out.append(_Dispatch("cache_expire", expire_fn, (caches, rnd)))
        caches = expire_fn(caches, rnd)

    state = policy.init_state()
    rng = jax.random.fold_in(jax.random.key(engine.sim_cfg.seed), 1)
    plan_jit = getattr(policy, "_plan_jit", None)
    if plan_jit is not None:
        # flude: planning itself is a jitted round-path dispatch
        out.append(_Dispatch(
            "flude_plan", plan_jit,
            (state.core, caches, draw.online, rng, policy._hints)))
    obs = RoundObservation(rnd, draw.online, caches, draw=draw)
    state, plan = policy.plan(state, obs, rng)

    sel_d = engine._from_plan(plan.selected)
    dist_d = engine._from_plan(plan.distribute)
    res_d = engine._from_plan(plan.resume)
    base_steps = full_steps if plan.steps_override is None else \
        engine._from_plan(plan.steps_override, np.int32)
    extra_w = ones_w if plan.agg_weights is None else \
        engine._from_plan(plan.agg_weights, np.float32)
    extra = engine._step_extra(rule_state)
    donated = 0 if not engine.donate else (
        len(jax.tree.leaves(global_params)) + len(jax.tree.leaves(caches)))

    ctx_common = dict(selected=sel_d, distribute=dist_d, resume=res_d,
                      online=draw.online, progress=caches.progress,
                      stamp=caches.round_stamp, rnd=rnd)
    ctx_common["global"] = global_params
    if rule_state is not None:
        ctx_common["rule_state"] = rule_state
    if engine.offload == "discard" and uses_cache:
        ctx_common["stamp_pre_expire"] = caches.round_stamp

    if engine.cohort is None:
        t_args = (global_params, caches, draw, sel_d, dist_d, res_d,
                  base_steps, cache_every)
        out.append(_Dispatch("trainer", trainer, t_args))
        (final, cache_p, cached_steps, losses, _steps, fail, success,
         times) = trainer(*t_args)
        c_args = (times, plan.quorum, success, draw.online, dist_d, sel_d)
        out.append(_Dispatch("round_cut", cut_fn, c_args))
        _t, received, *_rest = cut_fn(*c_args)
        ctx_common.update(received=received, fail=fail, losses=losses,
                          times=times, rows=final, rows_mask=received)
        out.append(_Dispatch(
            "server_step", server_step,
            (global_params, caches, final, cache_p, cached_steps, sel_d,
             fail, received, res_d, n_samples, extra_w, rnd, *extra),
            min_aliases=donated))
    elif engine.offload is None:
        t_args = (global_params, caches, draw, sel_d, dist_d, res_d,
                  base_steps, cache_every)
        out.append(_Dispatch("trainer", trainer, t_args))
        (final, cache_p, cached_steps, _lx, _sx, fail, success, times,
         idx, _overflow, losses_n, fail_n, times_n) = trainer(*t_args)
        c_args = (times, plan.quorum, success, idx, draw.online, dist_d,
                  sel_d)
        out.append(_Dispatch("round_cut", cut_fn, c_args))
        _t, _rx, received, *_rest = cut_fn(*c_args)
        received_x = _rx
        ctx_common.update(received=received, fail=fail_n,
                          losses=losses_n, times=times_n, rows=final,
                          rows_mask=received_x)
        out.append(_Dispatch(
            "server_step", server_step,
            (global_params, caches, final, cache_p, cached_steps, idx,
             sel_d, fail, received_x, res_d, n_samples, extra_w, rnd,
             *extra),
            min_aliases=donated))
    else:
        idx_fn = engine._offload_idx_fn()
        out.append(_Dispatch("cohort_index", idx_fn, (sel_d,)))
        idx, _overflow = idx_fn(sel_d)
        if uses_cache:
            cache_x = engine._cache_stream.fetch(idx, rnd)
        else:
            cache_x = engine._zero_cohort_block()
        t_args = (global_params, caches, cache_x, idx, draw, sel_d,
                  dist_d, res_d, base_steps, cache_every)
        out.append(_Dispatch("trainer", trainer, t_args))
        (final, cache_p, cached_steps, _lx, _sx, fail, success, times,
         losses_n, fail_n, times_n) = trainer(*t_args)
        c_args = (times, plan.quorum, success, idx, draw.online, dist_d,
                  sel_d)
        out.append(_Dispatch("round_cut", cut_fn, c_args))
        _t, received_x, received, *_rest = cut_fn(*c_args)
        ctx_common.update(received=received, fail=fail_n,
                          losses=losses_n, times=times_n, rows=final,
                          rows_mask=received_x)
        out.append(_Dispatch(
            "server_step", server_step,
            (global_params, caches, final, cached_steps, idx, sel_d,
             fail, received_x, res_d, n_samples, extra_w, rnd, *extra),
            min_aliases=donated))

    if metrics_fn is not None:
        ctx = {k: ctx_common[k] for k in m_keys}
        out.append(_Dispatch("metrics", metrics_fn, (ctx,)))

    if plan_jit is not None:
        # flude's fused Eq. 1 update + next plan, and the run-end flush
        out.append(_Dispatch(
            "flude_update_plan", policy._update_plan_jit,
            (state.core, state.last, received, caches, draw.online, rng,
             policy._hints)))
        out.append(_Dispatch(
            "flude_update", policy._update_jit,
            (state.core, state.last, received)))

    # eval reads replicated operands by design — exempt from contract 4
    out.append(_Dispatch(
        "eval_accuracy", engine._acc_fn,
        (global_params, engine._test_x, engine._test_y), sharded=False))
    return out


# ---------------------------------------------------------------------------
# The audit itself
# ---------------------------------------------------------------------------

def _audit_dispatch(d: _Dispatch, mesh_size: int, fleet_dims,
                    ) -> List[HC.Finding]:
    import jax

    lowered = d.fn.lower(*d.args)
    compiled = lowered.compile()
    text = compiled.as_text()
    comps = HC.parse_hlo(text)

    findings: List[HC.Finding] = []
    findings += HC.check_no_host_ops(d.name, text, comps)
    findings += HC.check_no_f64(d.name, text, comps)
    findings += HC.check_psum_dtype(d.name, text, comps)
    if d.min_aliases:
        findings += HC.check_donation(d.name, text, d.min_aliases)
    if mesh_size > 1 and d.sharded:
        findings += HC.check_partition_count(d.name, text, mesh_size)
        leaves = jax.tree.leaves(tuple(d.args))
        # input_shardings[0] mirrors the args pytree with Sharding leaves,
        # minus the arguments XLA pruned as unused (_kept_var_idx holds
        # the flat leaf indices that survive into the executable)
        shardings = jax.tree.leaves(compiled.input_shardings[0])
        kept = getattr(getattr(compiled, "_executable", None),
                       "_kept_var_idx", None)
        if kept is not None and len(shardings) < len(leaves):
            order = sorted(kept)
            if len(order) == len(shardings):
                leaves = [leaves[i] for i in order]
        if len(leaves) == len(shardings):
            findings += HC.check_input_shardings(
                d.name, leaves, shardings, fleet_dims)
        else:
            findings.append(HC.Finding(
                d.name, "sharding",
                f"cannot align {len(leaves)} argument leaves with "
                f"{len(shardings)} compiled input shardings — auditor "
                f"argument replay diverged from the engine"))
    return findings


def audit_engine(engine, policy, fleet=None, *,
                 check_ceiling: bool = True) -> AuditReport:
    """Lower and verify every jitted round-path dispatch of ``engine``
    when driven by ``policy`` (a registered name or a policy instance).
    Returns an :class:`AuditReport`; ``report.raise_on_findings()``
    fails loudly with the dispatch-by-dispatch violations."""
    from repro.fl import Fleet
    from repro.fl.api import make_policy

    if fleet is None:
        fleet = engine._fleet if engine._fleet is not None \
            else Fleet(engine.sim_cfg)
    if isinstance(policy, str):
        policy = make_policy(policy, engine.sim_cfg, engine.fl_cfg,
                             fleet, mesh=engine.mesh)

    mesh_size = 1 if engine.mesh is None else engine.mesh.devices.size
    fleet_dims = {engine.fl_cfg.num_clients}
    if engine.cohort is not None:
        fleet_dims.add(int(engine.cohort))

    dispatches = _collect_dispatches(engine, policy, fleet)
    findings: List[HC.Finding] = []
    for d in dispatches:
        findings += _audit_dispatch(d, mesh_size, fleet_dims)

    ceiling = transfer_ceiling(engine, policy.uses_cache)
    if check_ceiling and ceiling["sync_copies"] != 0:
        findings.append(HC.Finding(
            "cache_stream", "transfer",
            f"static ceiling allows {ceiling['sync_copies']} sync "
            f"copies per round — the double-buffering contract is 0"))

    return AuditReport(policy=policy.name, mode=_mode(engine),
                       mesh_size=mesh_size,
                       dispatches=[d.name for d in dispatches],
                       findings=findings, transfer_ceiling=ceiling)


# ---------------------------------------------------------------------------
# Registered-policy matrix (the analysis-smoke CI entry point)
# ---------------------------------------------------------------------------

#: toy-fleet sizes chosen so no replicated operand's leading dim
#: collides with N or X (model dims 12/24/5, test set 40) — the
#: sharding check can then treat any N/X-leading entry parameter as
#: fleet state
_AUDIT_N = 48
_AUDIT_X = 16


def _build_audited(policy_name: str, mode: str, mesh: Optional[int]):
    from repro.configs.base import FLConfig
    from repro.data.synthetic import federated_classification
    from repro.fl import Fleet, FleetEngine, SimConfig
    from repro.fl.api import make_policy

    N = _AUDIT_N
    data = federated_classification(N, num_classes=5, dim=12,
                                    n_per_client=20, n_test=40, seed=4)
    sim = SimConfig(num_clients=N, rounds=2, local_steps=2, batch_size=8,
                    model_hidden=24, model_depth=1, seed=3)
    kw = dict(num_clients=N, clients_per_round=_AUDIT_X,
              dynamics="markov", donate_buffers=True)
    if mesh is not None and mesh > 1:
        kw["mesh_shape"] = (mesh,)
    if mode in ("cohort", "offload"):
        kw["cohort_size"] = _AUDIT_X
    if mode == "offload":
        kw["cache_offload"] = "host"

    def make(kw):
        fl = FLConfig(**kw)
        engine = FleetEngine(data, sim, fl)
        fleet = Fleet(sim)
        return engine, make_policy(policy_name, sim, fl, fleet,
                                   mesh=engine.mesh), fleet

    engine, policy, fleet = make(kw)
    if engine.cohort is not None \
            and policy.selection_bound() > engine.cohort:
        # select-all policies (mifa, ...) need X = N
        kw["cohort_size"] = N
        engine, policy, fleet = make(kw)
    return engine, policy, fleet


def run_matrix(policies: Optional[Sequence[str]] = None,
               modes: Sequence[str] = ("full", "cohort", "offload"),
               mesh: Optional[int] = None) -> List[AuditReport]:
    """Audit every registered policy's round path in each requested
    mode.  ``mesh=None`` uses all local devices (1 device = unsharded
    audit: contracts 1-3 and 5 still apply)."""
    import jax

    from repro.fl.api import available_policies

    if policies is None:
        policies = available_policies()
    if mesh is None:
        mesh = jax.local_device_count()
    reports = []
    for name in policies:
        for mode in modes:
            engine, policy, fleet = _build_audited(name, mode, mesh)
            reports.append(audit_engine(engine, policy, fleet))
    return reports


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.audit",
        description="Statically verify the round path's zero-sync, "
                    "donation, dtype, sharding and transfer contracts.")
    parser.add_argument("--policies", nargs="*", default=None,
                        help="registered policy names (default: all)")
    parser.add_argument("--modes", nargs="*",
                        default=("full", "cohort", "offload"),
                        choices=("full", "cohort", "offload"))
    parser.add_argument("--devices", type=int, default=None,
                        help="force this many host platform devices "
                             "(must run before any jax computation)")
    args = parser.parse_args(argv)

    if args.devices is not None:
        from repro.launch.mesh import force_host_platform_device_count
        force_host_platform_device_count(args.devices)

    reports = run_matrix(args.policies, tuple(args.modes))
    bad = 0
    for r in reports:
        print(r.summary())
        bad += len(r.findings)
    print(f"audited {len(reports)} policy/mode combinations, "
          f"{bad} finding(s)")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
