"""Static contract checks over post-SPMD HLO of the round path.

Each check takes the dispatch name (for actionable messages) plus the
compiled executable's HLO text — ``compiled.as_text()`` after SPMD
partitioning, the same artifact ``roofline/hlo.py`` consumes — and
returns a list of :class:`Finding`.  The auditor (``analysis/audit.py``)
decides which checks apply to which dispatch; this module knows only
how to read the HLO.

The five round-path contracts (ISSUE 10 / README "Static analysis &
invariants"):

1. **zero-sync** — no host callbacks, infeed/outfeed, host transfers or
   host-memory-space copies inside a round dispatch
   (:func:`check_no_host_ops`);
2. **donation** — donated inputs actually alias into outputs in the
   compiled executable (:func:`check_donation`);
3. **dtype** — no f64/c128 leakage, and every floating-point psum
   (``all-reduce``) accumulates in f32 (:func:`check_no_f64`,
   :func:`check_psum_dtype`);
4. **sharding** — fleet-shaped (N,)/(X,) operands are partitioned on the
   ``("clients",)`` mesh axis, not silently replicated
   (:func:`check_input_shardings`, :func:`check_partition_count`);
5. **transfer ceiling** — static per-round bound on the cache stream's
   host transfers (lives in ``analysis/audit.py``: it is a property of
   the engine, not of one HLO module).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.roofline.hlo import Computation, Instr, parse_hlo


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violated contract, tied to a named round-path dispatch."""
    dispatch: str
    contract: str        # "host-sync" | "donation" | "dtype" | "sharding" | "transfer"
    message: str

    def __str__(self) -> str:
        return f"[{self.contract}] {self.dispatch}: {self.message}"


def _instrs(comps: Dict[str, Computation]) -> Iterable[Tuple[str, Instr]]:
    """All instructions, each computation visited once (``__entry__`` is
    an alias of the real entry computation — skip the duplicate key)."""
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        for ins in comp.instrs:
            yield name, ins


# ---------------------------------------------------------------------------
# Contract 1: no host round-trips inside the round path
# ---------------------------------------------------------------------------

#: opcodes that move data to/from the host (or another process) and
#: therefore stall the device round pipeline
_HOST_OPCODES = frozenset({
    "infeed", "outfeed", "send", "send-done", "recv", "recv-done",
})

_CALL_TARGET_RE = re.compile(r'custom_call_target="([^"]+)"')


def _is_host_callback_target(target: str) -> bool:
    # jax host callbacks lower to custom-calls whose target names the
    # python trampoline (xla_python_cpu_callback, xla_ffi_python_*_callback,
    # ...); plain kernels (lapack_*, blas_*, Sharding, topk, ...) don't
    return "callback" in target or target in ("SendToHost", "RecvFromHost")


def check_no_host_ops(dispatch: str, text: str,
                      comps: Optional[Dict[str, Computation]] = None,
                      ) -> List[Finding]:
    """Contract 1: the compiled round dispatch must not contain host
    callbacks, infeed/outfeed, cross-host sends or host-memory-space
    copies — any of these makes the "zero per-round host syncs" claim
    false at the XLA level, whatever the python code looks like."""
    comps = parse_hlo(text) if comps is None else comps
    findings: List[Finding] = []
    for comp_name, ins in _instrs(comps):
        if ins.opcode in _HOST_OPCODES:
            findings.append(Finding(
                dispatch, "host-sync",
                f"host-transfer op '{ins.opcode}' ({ins.name} in "
                f"{comp_name}) compiled into the round path"))
        elif ins.opcode == "custom-call":
            m = _CALL_TARGET_RE.search(ins.rest)
            if m and _is_host_callback_target(m.group(1)):
                findings.append(Finding(
                    dispatch, "host-sync",
                    f"host callback custom-call "
                    f"(target={m.group(1)!r}, {ins.name} in {comp_name}) "
                    f"— a python round-trip inside the jitted round "
                    f"path"))
        elif "S(5)" in ins.type_str:
            # layout memory-space annotation 5 == host memory: a copy
            # staged through host RAM, i.e. a hidden sync transfer
            findings.append(Finding(
                dispatch, "host-sync",
                f"host-memory-space buffer in '{ins.opcode}' "
                f"({ins.name} in {comp_name}: {ins.type_str})"))
    return findings


# ---------------------------------------------------------------------------
# Contract 2: donation produces real input-output aliases
# ---------------------------------------------------------------------------

_ALIAS_ENTRY_RE = re.compile(
    r"\((\d+),\s*\{[\d,\s]*\},\s*(?:may|must)-alias\)")


def _alias_block(text: str) -> str:
    """The brace-balanced body of ``input_output_alias={...}`` in the
    HloModule header ("" if absent).  The block nests braces
    (``{ {0}: (0, {}, may-alias) }``), so this is a depth scan, not a
    regex."""
    marker = "input_output_alias={"
    start = text.find(marker)
    if start < 0:
        return ""
    i = start + len(marker)
    depth = 1
    for j in range(i, min(len(text), i + 100_000)):
        c = text[j]
        if c == "{":
            depth += 1
        elif c == "}":
            depth -= 1
            if depth == 0:
                return text[i:j]
    return ""


def count_aliases(text: str) -> int:
    """Number of input-output alias entries in the HloModule header."""
    return len(_ALIAS_ENTRY_RE.findall(_alias_block(text)))


def check_donation(dispatch: str, text: str, min_aliases: int
                   ) -> List[Finding]:
    """Contract 2: a dispatch built with ``donate_argnums`` must show at
    least ``min_aliases`` input-output aliases in the compiled module —
    a donation that XLA silently declined (shape/dtype drift, an extra
    live use) doubles the steady-state fleet-state footprint without
    any runtime error."""
    n = count_aliases(text)
    if n < min_aliases:
        return [Finding(
            dispatch, "donation",
            f"expected >= {min_aliases} donated input-output aliases in "
            f"the compiled executable, found {n} — a donated buffer is "
            f"not being aliased (check for shape/dtype drift between "
            f"the donated input and its output)")]
    return []


# ---------------------------------------------------------------------------
# Contract 3: dtype hygiene (no f64, fp32 psum)
# ---------------------------------------------------------------------------

_WIDE_RE = re.compile(r"\b(f64|c128)\[")


def check_no_f64(dispatch: str, text: str,
                 comps: Optional[Dict[str, Computation]] = None,
                 ) -> List[Finding]:
    """Contract 3a: no f64/c128 anywhere in the round dispatch.  The
    round path is an f32 system (f64 bookkeeping lives on the host
    ledger); one leaked promotion doubles bandwidth on the N-sized
    hot arrays."""
    comps = parse_hlo(text) if comps is None else comps
    offenders = [
        f"{ins.name} ({ins.opcode}: {ins.type_str})"
        for _, ins in _instrs(comps)
        if _WIDE_RE.search(ins.type_str)
    ]
    if offenders:
        shown = ", ".join(offenders[:4])
        more = f" (+{len(offenders) - 4} more)" if len(offenders) > 4 else ""
        return [Finding(
            dispatch, "dtype",
            f"f64/c128 values compiled into the round path: {shown}"
            f"{more}")]
    return []


_FLOAT_DTYPES = ("f16", "bf16", "f32", "f64", "f8e4m3fn", "f8e5m2")


def _element_dtypes(type_str: str) -> List[str]:
    # \b keeps "bf16[" from reading as "f16["
    return re.findall(r"\b([a-z][a-z0-9]*)\[", type_str)


def check_psum_dtype(dispatch: str, text: str,
                     comps: Optional[Dict[str, Computation]] = None,
                     ) -> List[Finding]:
    """Contract 3b: every floating-point ``all-reduce`` (the packed
    aggregation's psum, PR 3) must accumulate in f32.  Integer
    all-reduces (the round cut's fused ledger counts) are exempt."""
    comps = parse_hlo(text) if comps is None else comps
    findings: List[Finding] = []
    for comp_name, ins in _instrs(comps):
        if not ins.opcode.startswith("all-reduce"):
            continue
        bad = [d for d in _element_dtypes(ins.type_str)
               if d in _FLOAT_DTYPES and d != "f32"]
        if bad:
            findings.append(Finding(
                dispatch, "dtype",
                f"all-reduce {ins.name} (in {comp_name}) accumulates in "
                f"{'/'.join(sorted(set(bad)))} — the packed-aggregation "
                f"psum contract is fp32 accumulation"))
    return findings


# ---------------------------------------------------------------------------
# Contract 4: ("clients",) sharding placement
# ---------------------------------------------------------------------------

_NUM_PARTITIONS_RE = re.compile(r"num_partitions=(\d+)")


def partition_count(text: str) -> int:
    m = _NUM_PARTITIONS_RE.search(text)
    return int(m.group(1)) if m else 1


def check_partition_count(dispatch: str, text: str, expected: int
                          ) -> List[Finding]:
    """Contract 4a: under a k-device client mesh the compiled module
    must actually be SPMD-partitioned k ways — ``num_partitions=1``
    means the whole dispatch silently fell back to one device."""
    got = partition_count(text)
    if got != expected:
        return [Finding(
            dispatch, "sharding",
            f"compiled with num_partitions={got}, expected {expected} "
            f"(the ('clients',) mesh) — the dispatch is not running "
            f"SPMD over the client mesh")]
    return []


def check_input_shardings(dispatch: str, arg_leaves: Sequence,
                          shardings: Sequence, fleet_dims: Iterable[int],
                          ) -> List[Finding]:
    """Contract 4b: every (N,)/(X,)-leading operand of the compiled
    dispatch must be partitioned (on the ``("clients",)`` axis), never
    fully replicated — a replicated fleet array multiplies memory and
    collective traffic by the mesh size.

    ``arg_leaves``/``shardings`` are the flattened argument leaves and
    ``compiled.input_shardings`` of the same lowering, zipped by
    position (post-SPMD executable metadata, the authoritative record
    of what XLA actually did)."""
    fleet_dims = set(int(d) for d in fleet_dims)
    findings: List[Finding] = []
    for i, (leaf, sh) in enumerate(zip(arg_leaves, shardings)):
        shape = getattr(leaf, "shape", None)
        if not shape or shape[0] not in fleet_dims:
            continue
        if sh.is_fully_replicated:
            findings.append(Finding(
                dispatch, "sharding",
                f"operand #{i} with fleet-shaped leading dim "
                f"{shape[0]} (shape {tuple(shape)}) is fully replicated "
                f"— expected it partitioned on the ('clients',) mesh "
                f"axis"))
    return findings
