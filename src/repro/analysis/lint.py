"""Repo-specific lint: AST rules for the round path's contracts.

Pure-stdlib (``ast``) so it runs anywhere the code does::

    PYTHONPATH=src python -m repro.analysis.lint src/

Rules (each finding names the rule):

``host-sync``
    No ``jax.device_get`` / ``.item()`` / ``np.asarray`` /
    ``float()``-of-a-dispatch in the round-path modules
    (``fl/engine.py``, ``core/round.py``, ``core/cache_store.py``)
    outside the explicit allowlist of documented sync seams
    (ledger resolve, run-end readbacks, the host reference loop, the
    host store's own gather/apply).  Everything else must stay async.

``mutable-global``
    No new module-global mutable singletons — the deprecated
    ``cache_store.STATS`` pattern (``NAME = SomeClass()`` at module
    level).  Per-engine state belongs on the engine; registries built
    by ``@register_*`` decorators are dict literals and unaffected.

``registry``
    Every ``@register_policy`` / ``@register_dynamics`` /
    ``@register_agg_rule`` / ``@register_metric`` /
    ``@register_adversary`` target is registered under a string
    literal and carries a docstring, and ``FLConfig.__post_init__``
    name-validates each registry axis it configures
    (``available_agg_rules`` / ``available_adversaries`` /
    ``available_dynamics``).

``jit-determinism``
    No wall-clock or host-RNG calls (``time.*``, ``datetime.*``,
    ``random.*``, ``np.random.*``) inside jitted code — they bake a
    trace-time value into the compiled executable.

``deprecated-stats``
    No references to the removed module-global ``cache_store.STATS``.

Extending the allowlist: add the function's qualified name (e.g.
``"FleetEngine._host_rounds"``) to ``HOST_SYNC_ALLOWLIST`` under its
module, with a comment saying why the sync is legitimate.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import os
import re
import sys
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple


@dataclasses.dataclass(frozen=True)
class LintFinding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


# ---------------------------------------------------------------------------
# Rule configuration
# ---------------------------------------------------------------------------

#: modules whose code IS the per-round hot path — host syncs here stall
#: the device pipeline, so every one must be an allowlisted seam
ROUND_PATH_MODULES = (
    "repro/fl/engine.py",
    "repro/core/round.py",
    "repro/core/cache_store.py",
)

#: documented host-sync seams, by qualified name.  A listed name covers
#: everything nested inside it.
HOST_SYNC_ALLOWLIST: Dict[str, Set[str]] = {
    "repro/fl/engine.py": {
        # construction-time placement (before any round runs)
        "make_trainer",
        "FleetEngine.__init__",
        # deferred-ledger resolve: THE documented readback seam — host
        # rows materialize here, traced under a tracer span
        "_RoundLedger.resolve",
        "_RoundLedger.push",
        # run()-scoped seams outside the round loop: final eval /
        # diagnostics / trust readback, policy upload boundary
        "FleetEngine.run",
        "FleetEngine._from_plan",
        "FleetEngine._validate_plan",
        "FleetEngine._book_round",
        "FleetEngine._close_round",
        # the legacy host-RNG reference loop syncs by design
        "FleetEngine._host_rounds",
        # AOT memory profile (tooling, not a round)
        "FleetEngine.server_step_memory",
        # History (de)serialization is host-side by definition
        "History.to_json",
        "History.from_json",
        "_metric_py",
    },
    "repro/core/round.py": {
        # the numpy reference implementation of the jitted cut
        "host_round_cut",
    },
    "repro/core/cache_store.py": {
        # the host store's own plumbing: gather/apply run on host rows,
        # and the stream's pre-issued reads are the documented async
        # fetch path (counted in TransferStats.pre_issued_reads)
        "_tree_bytes",
        "HostCacheStore",
        "CohortCacheStream",
    },
}

#: sanctioned module-global singletons (immutable/stateless objects)
MUTABLE_GLOBAL_ALLOWLIST: Set[Tuple[str, str]] = {
    # stateless no-op sinks: every method is a constant-return stub
    ("repro/obs/trace.py", "NULL_TRACER"),
    ("repro/obs/trace.py", "_NULL_SPAN"),
}

_REGISTER_DECORATORS = frozenset({
    "register_policy", "register_dynamics", "register_agg_rule",
    "register_metric", "register_adversary",
})

#: registry axes FLConfig configures -> the validator its
#: ``__post_init__`` must call
_POST_INIT_VALIDATORS = (
    "available_agg_rules", "available_adversaries", "available_dynamics",
)

_NONDET_PREFIXES = (
    "time.", "datetime.", "random.", "np.random.", "numpy.random.",
)

_CAMEL_RE = re.compile(r"^_?[A-Z][A-Za-z0-9]*$")


# ---------------------------------------------------------------------------
# AST helpers
# ---------------------------------------------------------------------------

def _dotted(node: ast.AST) -> Optional[str]:
    """``jax.random.fold_in`` -> "jax.random.fold_in"; None if the
    chain bottoms out in something that isn't a plain name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _covered(qualname: str, allow: Set[str]) -> bool:
    return any(qualname == a or qualname.startswith(a + ".")
               for a in allow)


class _ScopedVisitor(ast.NodeVisitor):
    """Tracks the qualified name of the enclosing def/class."""

    def __init__(self) -> None:
        self._stack: List[str] = []

    @property
    def qualname(self) -> str:
        return ".".join(self._stack) or "<module>"

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._stack.append(node.name)
        self.generic_visit(node)
        self._stack.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._stack.append(node.name)
        self.generic_visit(node)
        self._stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef


# ---------------------------------------------------------------------------
# Rule: host-sync
# ---------------------------------------------------------------------------

class _HostSyncVisitor(_ScopedVisitor):
    def __init__(self, path: str, allow: Set[str]) -> None:
        super().__init__()
        self.path = path
        self.allow = allow
        self.findings: List[LintFinding] = []

    def _flag(self, node: ast.AST, what: str) -> None:
        if _covered(self.qualname, self.allow):
            return
        self.findings.append(LintFinding(
            self.path, node.lineno, "host-sync",
            f"{what} in round-path code ({self.qualname}) — a per-round "
            f"host sync; move it behind the round ledger or add the "
            f"function to HOST_SYNC_ALLOWLIST with a justification"))

    def visit_Call(self, node: ast.Call) -> None:
        dotted = _dotted(node.func)
        if dotted == "jax.device_get":
            self._flag(node, "jax.device_get")
        elif dotted is not None and dotted.split(".", 1)[0] in (
                "np", "numpy") and dotted.endswith(".asarray"):
            self._flag(node, f"{dotted}()")
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr == "item" and not node.args:
            self._flag(node, ".item()")
        elif isinstance(node.func, ast.Name) \
                and node.func.id in ("float", "int") and node.args \
                and isinstance(node.args[0], ast.Call):
            self._flag(node, f"{node.func.id}() over a dispatch result")
        self.generic_visit(node)


# ---------------------------------------------------------------------------
# Rule: mutable-global
# ---------------------------------------------------------------------------

def _check_mutable_globals(path: str, key: str, tree: ast.Module,
                           ) -> List[LintFinding]:
    findings = []
    for node in tree.body:
        targets: List[ast.expr] = []
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets, value = [node.target], node.value
        else:
            continue
        if not isinstance(value, ast.Call):
            continue
        callee = _dotted(value.func)
        terminal = callee.rsplit(".", 1)[-1] if callee else ""
        if not _CAMEL_RE.match(terminal):
            continue
        # repo convention: *Config classes are frozen dataclasses —
        # module-level CONFIG = ModelConfig(...) constants are immutable
        if terminal.endswith("Config"):
            continue
        for t in targets:
            if not (isinstance(t, ast.Name) and t.id.isupper()):
                continue
            if (key, t.id) in MUTABLE_GLOBAL_ALLOWLIST:
                continue
            findings.append(LintFinding(
                path, node.lineno, "mutable-global",
                f"module-global singleton {t.id} = {terminal}(...) — "
                f"the deprecated STATS pattern; hold per-engine state "
                f"on the engine (or allowlist a provably stateless "
                f"object in MUTABLE_GLOBAL_ALLOWLIST)"))
    return findings


# ---------------------------------------------------------------------------
# Rule: registry
# ---------------------------------------------------------------------------

def _check_registries(path: str, tree: ast.Module) -> List[LintFinding]:
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
            continue
        for deco in node.decorator_list:
            if not isinstance(deco, ast.Call):
                continue
            name = _dotted(deco.func)
            terminal = name.rsplit(".", 1)[-1] if name else ""
            if terminal not in _REGISTER_DECORATORS:
                continue
            if not (deco.args and isinstance(deco.args[0], ast.Constant)
                    and isinstance(deco.args[0].value, str)):
                findings.append(LintFinding(
                    path, deco.lineno, "registry",
                    f"@{terminal} on {node.name} must register a string "
                    f"literal name (found a computed value) — registry "
                    f"names are config surface and must be greppable"))
            if ast.get_docstring(node) is None:
                findings.append(LintFinding(
                    path, node.lineno, "registry",
                    f"@{terminal} target {node.name} has no docstring — "
                    f"registered names are user-facing config values "
                    f"and must be documented"))
    return findings


def _check_post_init(path: str, tree: ast.Module) -> List[LintFinding]:
    """``FLConfig.__post_init__`` must name-validate each registry axis
    it configures (applies to ``repro/configs/base.py`` only)."""
    post_init = None
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "FLConfig":
            for item in node.body:
                if isinstance(item, ast.FunctionDef) \
                        and item.name == "__post_init__":
                    post_init = item
    if post_init is None:
        return [LintFinding(
            path, 1, "registry",
            "FLConfig has no __post_init__ — registry names "
            "(agg_rule/adversary/dynamics) must fail fast at config "
            "construction")]
    used = {n.id for n in ast.walk(post_init) if isinstance(n, ast.Name)}
    used |= {n.attr for n in ast.walk(post_init)
             if isinstance(n, ast.Attribute)}
    return [
        LintFinding(
            path, post_init.lineno, "registry",
            f"FLConfig.__post_init__ does not validate against "
            f"{validator}() — unknown registry names must be rejected "
            f"at config construction, not deep inside a jitted round")
        for validator in _POST_INIT_VALIDATORS if validator not in used
    ]


# ---------------------------------------------------------------------------
# Rule: jit-determinism
# ---------------------------------------------------------------------------

def _is_jit_decorator(deco: ast.expr) -> bool:
    name = _dotted(deco)
    if name in ("jax.jit", "jit"):
        return True
    if isinstance(deco, ast.Call):
        inner = _dotted(deco.func)
        if inner in ("jax.jit", "jit"):
            return True
        if inner in ("functools.partial", "partial") and deco.args:
            return _dotted(deco.args[0]) in ("jax.jit", "jit")
    return False


def _nondet_calls(root: ast.AST) -> Iterable[ast.Call]:
    for node in ast.walk(root):
        if isinstance(node, ast.Call):
            dotted = _dotted(node.func)
            if dotted and any(dotted.startswith(p)
                              for p in _NONDET_PREFIXES):
                yield node


def _check_jit_determinism(path: str, tree: ast.Module,
                           ) -> List[LintFinding]:
    findings = []

    def flag(call: ast.Call, where: str) -> None:
        findings.append(LintFinding(
            path, call.lineno, "jit-determinism",
            f"{_dotted(call.func)}() inside jitted code ({where}) — "
            f"wall-clock/host-RNG values are baked in at trace time; "
            f"use jax.random with a threaded key, or hoist the value "
            f"to an argument"))

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and any(_is_jit_decorator(d) for d in node.decorator_list):
            for call in _nondet_calls(node):
                flag(call, node.name)
        elif isinstance(node, ast.Call) \
                and _dotted(node.func) in ("jax.jit", "jit"):
            for arg in node.args:
                for call in _nondet_calls(arg):
                    flag(call, f"jax.jit(...) at line {node.lineno}")
    return findings


# ---------------------------------------------------------------------------
# Rule: deprecated-stats
# ---------------------------------------------------------------------------

def _check_deprecated_stats(path: str, tree: ast.Module,
                            ) -> List[LintFinding]:
    findings = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr == "STATS":
            findings.append(LintFinding(
                path, node.lineno, "deprecated-stats",
                "reference to the removed module-global cache_store."
                "STATS — use the per-engine engine.transfer_stats"))
        elif isinstance(node, ast.ImportFrom) \
                and (node.module or "").endswith("cache_store") \
                and any(a.name == "STATS" for a in node.names):
            findings.append(LintFinding(
                path, node.lineno, "deprecated-stats",
                "import of the removed cache_store.STATS — use the "
                "per-engine engine.transfer_stats"))
        elif isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "STATS"
                for t in node.targets):
            findings.append(LintFinding(
                path, node.lineno, "deprecated-stats",
                "module-global STATS assignment — the aggregate "
                "transfer-counter pattern is removed; counters are "
                "per-engine"))
    return findings


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def _module_key(path: str) -> str:
    """Repo-relative module key ("repro/fl/engine.py") for rule scoping."""
    norm = path.replace(os.sep, "/")
    i = norm.rfind("repro/")
    return norm[i:] if i >= 0 else os.path.basename(norm)


def lint_source(src: str, module_key: str, path: str = "<memory>",
                ) -> List[LintFinding]:
    tree = ast.parse(src, filename=path)
    findings: List[LintFinding] = []
    if module_key in ROUND_PATH_MODULES:
        visitor = _HostSyncVisitor(
            path, HOST_SYNC_ALLOWLIST.get(module_key, set()))
        visitor.visit(tree)
        findings += visitor.findings
    findings += _check_mutable_globals(path, module_key, tree)
    findings += _check_registries(path, tree)
    if module_key == "repro/configs/base.py":
        findings += _check_post_init(path, tree)
    findings += _check_jit_determinism(path, tree)
    findings += _check_deprecated_stats(path, tree)
    return sorted(findings, key=lambda f: (f.path, f.line))


def lint_file(path: str) -> List[LintFinding]:
    with open(path, encoding="utf-8") as fh:
        return lint_source(fh.read(), _module_key(path), path)


def iter_python_files(paths: Sequence[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for name in sorted(files):
                    if name.endswith(".py"):
                        yield os.path.join(root, name)
        elif p.endswith(".py"):
            yield p


def lint_paths(paths: Sequence[str]) -> List[LintFinding]:
    findings: List[LintFinding] = []
    for path in iter_python_files(paths):
        findings += lint_file(path)
    return findings


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Repo-specific AST lint for the round-path "
                    "contracts (stdlib-only).")
    parser.add_argument("paths", nargs="*", default=["src/"],
                        help="files or directories to lint")
    args = parser.parse_args(argv)
    findings = lint_paths(args.paths or ["src/"])
    for f in findings:
        print(f)
    n_files = sum(1 for _ in iter_python_files(args.paths or ["src/"]))
    print(f"linted {n_files} files: {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
