"""``FLConfig.debug_checks`` runtime sanitizers.

Two guards, both off by default (sanitizer mode — they add one host
sync per round, which the production round path is contractually free
of):

* :func:`make_round_guard` — a ``checkify``-instrumented jit the engine
  calls after each server step: non-finite values in the new global
  model or the per-client losses, and out-of-bounds cohort indices, are
  reported with the round number instead of silently propagating NaNs
  through the trajectory.
* :class:`RecompilationDetector` — snapshots the compiled-signature
  count of every memoized jitted dispatch the engine owns and raises if
  any of them re-traces across ``run()`` calls: a re-trace means some
  round-path input changed shape/dtype/weak-type/placement between
  runs, which silently doubles compile time and breaks the "memoized
  lowerings" contract the static auditor certifies.
"""
from __future__ import annotations

from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import checkify


class RoundCheckError(RuntimeError):
    """A ``debug_checks`` round guard fired."""


def make_round_guard(num_clients: int, with_idx: bool):
    """Jitted checkify guard over the post-step round outputs.

    Returns ``guard(global_params, losses[, idx]) -> checkify.Error``.
    The cohort index is validated against ``[0, num_clients]`` — the
    sentinel pad value equals ``num_clients`` by the ``cohort_index``
    contract, anything else is out of bounds.
    """
    def body(global_params, losses, idx):
        for i, leaf in enumerate(jax.tree.leaves(global_params)):
            checkify.check(
                jnp.all(jnp.isfinite(leaf)),
                f"non-finite value in global-model leaf #{i} after the "
                f"server step")
        checkify.check(jnp.all(jnp.isfinite(losses)),
                       "non-finite per-client loss")
        if idx is not None:
            checkify.check(
                jnp.all((idx >= 0) & (idx <= num_clients)),
                "cohort index out of bounds (expected [0, N] with N as "
                "the pad sentinel)")
        return 0

    if with_idx:
        checked = checkify.checkify(
            lambda gp, losses, idx: body(gp, losses, idx))
    else:
        checked = checkify.checkify(
            lambda gp, losses: body(gp, losses, None))
    return jax.jit(checked)


def throw_round_error(err: checkify.Error, rnd: int) -> None:
    """Raise :class:`RoundCheckError` naming the round if the guard
    tripped (this readback is the sanitizer's documented host sync)."""
    msg = err.get()
    if msg:
        raise RoundCheckError(
            f"debug_checks: round {rnd}: {msg}")


class RecompilationDetector:
    """Asserts the engine's memoized jitted dispatches never re-trace.

    ``check()`` is called at the end of each ``run()``: the first call
    records a baseline compiled-signature count per dispatch; any later
    growth of an already-seen dispatch raises.  New dispatches (a
    different policy or telemetry level building new memo entries) are
    simply added to the baseline.
    """

    def __init__(self, engine) -> None:
        self.engine = engine
        self._seen: dict = {}

    def _jits(self) -> Iterator[Tuple[str, object]]:
        eng = self.engine
        for key, fn in eng._server_steps.items():
            yield f"server_step{key}", fn
        for key, fns in eng._dyn_cache.items():
            _process, init_fn, step_fn, trainer = fns
            yield f"dynamics_init{key}", init_fn
            yield f"dynamics_step{key}", step_fn
            yield f"dyn_trainer{key}", trainer
        for key, fn in eng._cut_fns.items():
            yield f"round_cut{key}", fn
        for key, (fn, _keys) in eng._metrics_fns.items():
            if fn is not None:
                yield f"metrics{key}", fn
        for attr in ("_trainer", "_acc_fn", "_idx_fn", "_expire_fn",
                     "_cache_reset"):
            fn = getattr(eng, attr, None)
            if fn is not None:
                yield attr, fn

    def check(self) -> None:
        for name, fn in self._jits():
            size_of = getattr(fn, "_cache_size", None)
            if size_of is None:
                continue
            size = size_of()
            prev = self._seen.get(name)
            if prev is not None and size > prev:
                raise RoundCheckError(
                    f"debug_checks: jitted dispatch {name} re-traced "
                    f"({prev} -> {size} compiled signatures) — a "
                    f"round-path input changed shape/dtype/placement "
                    f"between runs; the engine's memoized lowerings "
                    f"must be trace-stable")
            self._seen[name] = size if prev is None else max(size, prev)
