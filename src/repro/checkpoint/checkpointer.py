"""msgpack pytree checkpointer (server checkpoints + client cache persistence).

Arrays are serialized as (dtype, shape, raw bytes); the tree structure is
encoded with string-keyed maps / lists / namedtuple names so round-tripping
restores the exact pytree (leaves come back as numpy; callers jnp-ify).
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode(obj):
    if isinstance(obj, (jnp.ndarray, np.ndarray)) or hasattr(obj, "dtype"):
        arr = np.asarray(obj)
        return {"__arr__": True, "dtype": str(arr.dtype),
                "shape": list(arr.shape), "data": arr.tobytes()}
    if isinstance(obj, dict):
        return {"__map__": {k: _encode(v) for k, v in obj.items()}}
    if hasattr(obj, "_fields"):        # namedtuple
        return {"__nt__": type(obj).__name__,
                "fields": {f: _encode(getattr(obj, f))
                           for f in obj._fields}}
    if isinstance(obj, (list, tuple)):
        return {"__seq__": [_encode(v) for v in obj],
                "tuple": isinstance(obj, tuple)}
    if obj is None or isinstance(obj, (int, float, str, bool)):
        return {"__lit__": obj}
    raise TypeError(f"cannot checkpoint {type(obj)}")


def _decode(obj):
    if "__arr__" in obj:
        arr = np.frombuffer(obj["data"], dtype=obj["dtype"])
        return arr.reshape(obj["shape"]).copy()
    if "__map__" in obj:
        return {k: _decode(v) for k, v in obj["__map__"].items()}
    if "__nt__" in obj:
        # restored as plain dict of fields: callers re-wrap if needed
        return {f: _decode(v) for f, v in obj["fields"].items()}
    if "__seq__" in obj:
        vals = [_decode(v) for v in obj["__seq__"]]
        return tuple(vals) if obj.get("tuple") else vals
    return obj["__lit__"]


def save(path: str, tree: Any) -> None:
    tmp = path + ".tmp"
    host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(_encode(host_tree), use_bin_type=True))
    os.replace(tmp, path)


def restore(path: str) -> Any:
    with open(path, "rb") as f:
        return _decode(msgpack.unpackb(f.read(), raw=False,
                                       strict_map_key=False))


def restore_like(path: str, template: Any) -> Any:
    """Restore and re-shape into the template's pytree structure (casting
    dtypes and re-wrapping namedtuples)."""
    raw = restore(path)
    flat_raw = jax.tree.leaves(raw)
    t_leaves, treedef = jax.tree.flatten(template)
    assert len(flat_raw) == len(t_leaves), "checkpoint/template mismatch"
    leaves = [jnp.asarray(r, t.dtype) if hasattr(t, "dtype") else r
              for r, t in zip(flat_raw, t_leaves)]
    return jax.tree.unflatten(treedef, leaves)
