"""Config registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from __future__ import annotations

from repro.configs.base import (
    FLConfig,
    INPUT_SHAPES,
    InputShape,
    MeshConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    SSMConfig,
    TrainConfig,
)

from repro.configs.h2o_danube_1p8b import CONFIG as _h2o
from repro.configs.zamba2_1p2b import CONFIG as _zamba2
from repro.configs.phi3_vision_4p2b import CONFIG as _phi3v
from repro.configs.deepseek_v2_236b import CONFIG as _dsv2
from repro.configs.nemotron_4_340b import CONFIG as _nemotron
from repro.configs.qwen2_7b import CONFIG as _qwen2
from repro.configs.whisper_large_v3 import CONFIG as _whisper
from repro.configs.rwkv6_7b import CONFIG as _rwkv6
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.llama3_405b import CONFIG as _llama3
from repro.configs.flude_paper import CONFIG as _flude_paper

_REGISTRY = {
    c.name: c
    for c in [
        _h2o, _zamba2, _phi3v, _dsv2, _nemotron,
        _qwen2, _whisper, _rwkv6, _mixtral, _llama3, _flude_paper,
    ]
}

ASSIGNED_ARCHS = [
    "h2o-danube-1.8b", "zamba2-1.2b", "phi-3-vision-4.2b", "deepseek-v2-236b",
    "nemotron-4-340b", "qwen2-7b", "whisper-large-v3", "rwkv6-7b",
    "mixtral-8x7b", "llama3-405b",
]


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    return sorted(_REGISTRY)


__all__ = [
    "ASSIGNED_ARCHS", "FLConfig", "INPUT_SHAPES", "InputShape", "MeshConfig",
    "MLAConfig", "ModelConfig", "MoEConfig", "RWKVConfig", "SSMConfig",
    "TrainConfig", "get_config", "list_configs",
]
