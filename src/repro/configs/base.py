"""Config system for the FLUDE reproduction framework.

Every assigned architecture gets a ``ModelConfig``; the four assigned input
shapes are ``InputShape`` entries in ``INPUT_SHAPES``.  Configs are plain
frozen dataclasses so they hash/compare and can be embedded in jit static
args.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared_experts: int = 0
    expert_d_ff: Optional[int] = None      # d_ff of each routed expert
    shared_d_ff: Optional[int] = None      # d_ff of the shared expert(s)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block config."""
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    conv_kernel: int = 4
    chunk_size: int = 256
    n_groups: int = 1          # B/C groups (like GQA for SSM)


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora_rank: int = 64
    gate_lora_rank: int = 64
    token_shift: bool = True


@dataclass(frozen=True)
class HybridConfig:
    """zamba2-style hybrid: Mamba2 backbone + shared attention block."""
    attn_every: int = 6        # apply the shared attention block every N layers
    shared_attn_blocks: int = 1


@dataclass(frozen=True)
class EncDecConfig:
    """whisper-style encoder-decoder."""
    num_encoder_layers: int = 32
    num_decoder_layers: int = 32
    max_target_len: int = 448


@dataclass(frozen=True)
class VisionStubConfig:
    """VLM frontend stub: precomputed patch embeddings are model inputs."""
    num_image_tokens: int = 1024   # patch tokens prepended to the sequence
    patch_embed_dim: int = 1024    # CLIP-style embed dim before projector


# ---------------------------------------------------------------------------
# ModelConfig
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                     # dense | moe | ssm | hybrid | vlm | audio
    source: str                        # citation (arXiv id / hf model card)
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None     # default: d_model // num_heads
    # attention flavour
    attention: str = "gqa"             # gqa | mla | none (attention-free)
    qkv_bias: bool = False
    sliding_window: Optional[int] = None
    rope_theta: float = 10000.0
    # mlp flavour
    mlp_act: str = "silu_glu"          # silu_glu | gelu | relu2
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    tie_embeddings: bool = False
    # family-specific blocks
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vision: Optional[VisionStubConfig] = None
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # scan/remat
    scan_layers: bool = True
    remat: bool = True

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.num_heads

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized variant of the same family (<=2 layers etc.)."""
        changes = dict(
            name=self.name + "-reduced",
            num_layers=2,
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            head_dim=64,
            param_dtype="float32",
            compute_dtype="float32",
        )
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                expert_d_ff=min(self.moe.expert_d_ff or self.d_ff, 256),
                shared_d_ff=min(self.moe.shared_d_ff or self.d_ff, 256),
            )
        if self.mla is not None:
            changes["mla"] = MLAConfig(
                q_lora_rank=64, kv_lora_rank=32,
                qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)
            changes["head_dim"] = None
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=32, chunk_size=32)
        if self.rwkv is not None:
            changes["rwkv"] = dataclasses.replace(
                self.rwkv, head_dim=32, decay_lora_rank=16, gate_lora_rank=16)
        if self.hybrid is not None:
            changes["hybrid"] = dataclasses.replace(self.hybrid, attn_every=2)
            changes["num_layers"] = 4
        if self.encdec is not None:
            changes["encdec"] = dataclasses.replace(
                self.encdec, num_encoder_layers=2, num_decoder_layers=2,
                max_target_len=16)
        if self.vision is not None:
            changes["vision"] = dataclasses.replace(
                self.vision, num_image_tokens=8, patch_embed_dim=64)
        if self.sliding_window is not None:
            changes["sliding_window"] = 16
        changes.update(overrides)
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


# ---------------------------------------------------------------------------
# Train / FL configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    optimizer: str = "adamw"           # sgd | momentum | adam | adamw
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    moment_dtype: str = "float32"      # Adam m/v dtype (bf16 for >=200B)
    accum_dtype: str = "float32"       # microbatch grad accumulator dtype
    microbatch_size: Optional[int] = None   # per-silo microbatch for grad accum
    warmup_steps: int = 100
    total_steps: int = 1000
    seed: int = 0


@dataclass(frozen=True)
class FLConfig:
    """FLUDE hyper-parameters (paper §5.2 defaults)."""
    num_clients: int = 256
    clients_per_round: int = 32
    local_steps: int = 4
    # selection (Alg. 1)
    selection_mode: str = "mean"       # mean | thompson (beyond-paper)
    epsilon_init: float = 0.9          # exploration factor
    epsilon_decay: float = 0.98
    epsilon_min: float = 0.2
    sigma: float = 0.5                 # frequency penalty exponent
    # dependability prior (Eq. 1)
    beta_alpha0: float = 2.0
    beta_beta0: float = 2.0
    # staleness distribution (Eq. 4)
    lam: float = 1.0                   # λ — staleness coefficient
    mu: float = 0.5                    # μ — comm-cost coefficient
    w_init: float = 3.0                # initial staleness threshold
    w_min: float = 1.0
    w_max: float = 50.0
    # round process (Alg. 2)
    comm_budget: float = float("inf")  # B_max, in model-transmission units
    round_deadline: float = 600.0      # T, seconds (simulator wall clock)
    # caching (C3)
    cache_enabled: bool = True
    base_cache_interval: float = 60.0  # seconds between cache writes
    distribution_mode: str = "adaptive"  # adaptive | full | least
    # server aggregation (§4.3 hot path): packed whole-model kernel
    staleness_discount: float = 1.0    # per-round decay of stale-base weights
    agg_impl: str = "xla"              # xla | pallas | pallas_interpret
    agg_block_c: int = 8               # client-axis tile of the Pallas kernel
    agg_block_d: int = 2048            # packed-param-axis tile
    agg_rule: str = "mean"
    # ^ registered robust-aggregation rule (repro.core.agg_rules):
    #   "mean" (the historical weighted mean — bit-identical default),
    #   "geometric_median" (smoothed Weiszfeld / RFA), "trimmed_mean"
    #   (coordinate-wise), "trust" (per-client trust state learned on
    #   device from update-deviation norms).  Orthogonal to agg_impl.
    agg_rule_params: Tuple[Tuple[str, Any], ...] = ()
    # ^ hashable ((key, value), ...) pairs forwarded to the rule
    #   constructor (e.g. (("iters", 8),) for geometric_median)
    adversary: Optional[str] = None
    # ^ registered attack model (repro.fleet.adversary): a deterministic
    #   malicious_frac slice of the fleet misbehaves — "label_flip"
    #   corrupts local labels, "sign_flip"/"grad_scale" transform the
    #   malicious uploads inside the jitted server step.  None = benign.
    adversary_params: Tuple[Tuple[str, Any], ...] = ()
    # ^ hashable ((key, value), ...) pairs forwarded to the adversary
    #   constructor (e.g. (("malicious_frac", 0.2),))
    # mesh & memory (cross-device round path)
    mesh_shape: Optional[Tuple[int, ...]] = None
    # ^ (k,) shards the fleet k-ways over the ("clients",) mesh axis
    #   (stacked client pytree, packed (C, D) buffer, (N,) scalar state);
    #   None = single-device round path (bit-identical to the golden runs)
    donate_buffers: bool = False
    # ^ donate dead round inputs on the jitted trainer / server_round_step
    #   so XLA aliases them into the outputs (steady-state rounds allocate
    #   nothing new); donated host-side handles are invalidated
    cohort_size: Optional[int] = None
    # ^ static X: compact selected-cohort round path.  None = full scan
    #   (trainer/cut/aggregation run over all N clients, masked).  An int
    #   makes the engine gather the selected clients' data, caches, draw
    #   and plan arrays into dense (X, ...) blocks on device, run local
    #   training, the round cut and the packed aggregation over X rows,
    #   and scatter the results back into the (N,)-sized fleet state —
    #   round cost tracks the cohort instead of the fleet while fleet
    #   state stays the only N-proportional memory.  Trajectories are
    #   bit-identical to the full scan on a single device; under a client
    #   mesh the integer trajectory (received/selected/wall clock) is
    #   exact and accuracies agree to float tolerance (cohort rows
    #   regroup across shards, so the psum reassociates).  Every plan's
    #   selected count must fit in X (the engine rejects policies whose
    #   ``selection_bound()`` exceeds it up front, and flags runtime
    #   overflow — under ``pipeline_depth`` > 1 the overflow check is
    #   read back with the deferred ledger, i.e. up to depth-1 rounds
    #   late).  Requires a device dynamics process (not bernoulli_host)
    #   and, under a mesh, ``cohort_size % mesh_shape[0] == 0``.
    cache_offload: Optional[str] = None
    # ^ C3 cache residency (requires cohort_size).  None keeps today's
    #   device-resident (N, D) cache pytree.  "host" keeps only the (N,)
    #   cache *metadata* (progress, round stamp — what planning reads)
    #   on device plus the current cohort's (X, D) slot block; written
    #   slots stream back to a sparse host store with async dispatch /
    #   double buffering (repro.core.cache_store) and the next cohort's
    #   slots are prefetched as soon as its selection mask is known —
    #   device cache memory scales with X, trajectories stay
    #   bit-identical to the resident path.  "discard" additionally
    #   drops rows unselected for more than cache_staleness_bound
    #   rounds (device metadata expiry + host-store prune) — a legal
    #   memory/accuracy knob, since the paper's cache is best-effort.
    cache_staleness_bound: int = 32
    # ^ "discard" mode: rounds a cache row survives without a rewrite
    #   before it is dropped (host row pruned, device metadata reset
    #   before planning).  Ignored by the other offload modes.
    # fleet dynamics (repro.fleet): availability process + scenario params
    dynamics: str = "bernoulli_host"
    # ^ registered process name.  "bernoulli_host" is the seed simulator's
    #   host-RNG path (bit-identical golden trajectories); every other
    #   process draws on device under the client mesh — no per-round
    #   host→device hand-off.  Scenario presets (repro.fleet.scenarios)
    #   set this plus dynamics_params in one go.
    dynamics_params: Tuple[Tuple[str, Any], ...] = ()
    # ^ hashable ((key, value), ...) pairs forwarded to the process
    #   constructor (e.g. (("mean_on", 5.0),) for markov churn)
    pipeline_depth: int = 1
    # ^ rounds in flight on the device round path (device dynamics only).
    #   1 = the classic loop: the host resolves each round's bookkeeping
    #   (duration, received counts, eval) before planning the next round.
    #   depth d keeps up to d-1 rounds of bookkeeping pending, so round
    #   k+1's fused trainer + server step are dispatched while round k
    #   still executes — trajectories are bit-identical at every depth
    #   (the round close runs jitted on device; History rows are resolved
    #   from device scalars in arrival order).  ``time_budget`` runs
    #   resolve every round regardless (the budget check needs cum_time).
    telemetry: Optional[str] = None
    # ^ default device-metrics level for engine runs (repro.obs).  None
    #   compiles telemetry out entirely — the round path is bit- and
    #   dispatch-count-identical to an uninstrumented engine.  "basic"
    #   fuses the cheap participation/loss/cache counters into one extra
    #   jitted dispatch per round; "full" adds update/residual norms,
    #   trust quantiles and the staleness histogram.  Either way metric
    #   values ride the pipelined round ledger — zero added per-round
    #   host syncs.  ``FleetEngine.run(telemetry=...)`` overrides per
    #   run (a level string or a ``repro.obs.Telemetry`` session with
    #   sinks/tracing attached).
    debug_checks: bool = False
    # ^ runtime-sanitizer mode (repro.analysis.runtime): after each
    #   server step a checkify guard validates the new global model and
    #   per-client losses are finite and the cohort index is in bounds,
    #   and a recompilation detector asserts at run end that none of the
    #   engine's memoized jitted dispatches re-traced across runs.  Adds
    #   one host sync per round — a debugging tool, never a production
    #   mode; the static auditor (repro.analysis.audit) verifies the
    #   same contracts with zero runtime cost.

    def __post_init__(self):
        if self.telemetry not in (None, "basic", "full"):
            raise ValueError(
                f"FLConfig.telemetry must be None, 'basic' or 'full', "
                f"got {self.telemetry!r}")
        if self.agg_impl not in ("xla", "pallas", "pallas_interpret"):
            raise ValueError(
                f"FLConfig.agg_impl must be one of 'xla', 'pallas', "
                f"'pallas_interpret', got {self.agg_impl!r}")
        # registry lookups fail fast at construction instead of deep
        # inside the jitted round step; imported lazily — the registries
        # live above configs in the import graph
        if self.agg_rule != "mean":
            from repro.core.agg_rules import available_agg_rules
            if self.agg_rule not in available_agg_rules():
                raise ValueError(
                    f"FLConfig.agg_rule must be a registered agg rule "
                    f"({', '.join(available_agg_rules())}), got "
                    f"{self.agg_rule!r}")
        if self.adversary is not None:
            from repro.fleet.adversary import available_adversaries
            if self.adversary not in available_adversaries():
                raise ValueError(
                    f"FLConfig.adversary must be a registered adversary "
                    f"({', '.join(available_adversaries())}) or None, "
                    f"got {self.adversary!r}")
        from repro.fleet.api import available_dynamics
        if self.dynamics not in available_dynamics():
            raise ValueError(
                f"FLConfig.dynamics must be a registered dynamics "
                f"process ({', '.join(available_dynamics())}), got "
                f"{self.dynamics!r}")
        if self.cache_offload not in (None, "host", "discard"):
            raise ValueError(
                f"FLConfig.cache_offload must be None, 'host' or "
                f"'discard', got {self.cache_offload!r}")
        if self.cache_offload is not None and self.cohort_size is None:
            raise ValueError(
                f"FLConfig.cache_offload={self.cache_offload!r} requires "
                f"cohort_size — only the compact cohort path knows which "
                f"(X, D) cache slots a round touches; set cohort_size or "
                f"keep cache_offload=None for the resident pytree")
        b = self.cache_staleness_bound
        if not isinstance(b, int) or isinstance(b, bool) or b < 1:
            raise ValueError(
                f"FLConfig.cache_staleness_bound must be a positive int, "
                f"got {b!r}")
        x = self.cohort_size
        if x is None:
            return
        if not isinstance(x, int) or isinstance(x, bool) or x < 1:
            raise ValueError(
                f"FLConfig.cohort_size must be a positive int or None, "
                f"got {x!r}")
        if x > self.num_clients:
            raise ValueError(
                f"FLConfig.cohort_size ({x}) exceeds num_clients "
                f"({self.num_clients}) — a cohort cannot be larger than "
                f"the fleet; use cohort_size=None for the full scan")
        shape = self.mesh_shape
        if shape is not None and len(shape) >= 1 and shape[0] > 1 \
                and x % shape[0] != 0:
            raise ValueError(
                f"FLConfig.cohort_size ({x}) must be divisible by the "
                f"client mesh size ({shape[0]}) — the gathered (X, ...) "
                f"cohort block shards over the ('clients',) axis and "
                f"shard_map needs an even split")


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    data_axis: int = 16
    model_axis: int = 16
    pods: int = 2

    @property
    def shape(self) -> Tuple[int, ...]:
        if self.multi_pod:
            return (self.pods, self.data_axis, self.model_axis)
        return (self.data_axis, self.model_axis)

    @property
    def axis_names(self) -> Tuple[str, ...]:
        if self.multi_pod:
            return ("pod", "data", "model")
        return ("data", "model")
