"""deepseek-v2-236b — MoE with Multi-head Latent Attention.

[arXiv:2405.04434] 60L d_model=5120 128H d_ff=1536(expert) vocab=102400,
MLA kv_lora=512, 2 shared + 160 routed experts, top-6.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    source="arXiv:2405.04434",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,          # MLA decompresses to per-head K/V (MHA-like)
    d_ff=12288,                # dense-equivalent ff (first layer is dense in
                               # DeepSeek-V2; we keep all layers MoE for
                               # uniform scan, noting the delta in DESIGN.md)
    vocab_size=102400,
    attention="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, num_shared_experts=2,
                  expert_d_ff=1536, shared_d_ff=1536, capacity_factor=1.25),
    mlp_act="silu_glu",
)
