"""flude-paper — the paper's own training regime, transformer-ized.

The paper trains small CNNs (5-layer CNN / VGG-9 / ResNet-18 / 4x conv1d /
WideAndDeep) on 120 edge devices.  Our substrate is transformer-family; this
config is the ~paper-scale stand-in used by the cross-device FL examples and
benchmarks (a few-M-params causal LM; classification benchmarks use
``repro.fl.classifier`` instead).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="flude-paper",
    arch_type="dense",
    source="this paper (§5.2)",
    num_layers=4,
    d_model=256,
    num_heads=8,
    num_kv_heads=4,
    d_ff=1024,
    vocab_size=4096,
    head_dim=32,
    attention="gqa",
    mlp_act="silu_glu",
    param_dtype="float32",
    compute_dtype="float32",
)
