"""h2o-danube-1.8b — dense, llama+mistral mix with sliding-window attention.

[arXiv:2401.16818] 24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    arch_type="dense",
    source="arXiv:2401.16818",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    head_dim=80,
    attention="gqa",
    sliding_window=4096,       # mistral-style SWA
    mlp_act="silu_glu",
    rope_theta=10000.0,
)
