"""llama3-405b — dense GQA, 128k vocab.

[arXiv:2407.21783] 126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    arch_type="dense",
    source="arXiv:2407.21783",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    attention="gqa",
    mlp_act="silu_glu",
    rope_theta=500000.0,
)
