"""mixtral-8x7b — sparse MoE, 8 experts top-2, sliding-window attention.

[arXiv:2401.04088] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    arch_type="moe",
    source="arXiv:2401.04088",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    attention="gqa",
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, num_shared_experts=0,
                  expert_d_ff=14336, capacity_factor=1.25),
    mlp_act="silu_glu",
    rope_theta=1000000.0,
)
