"""phi-3-vision-4.2b — VLM: phi3-mini backbone + CLIP frontend (stub).

[hf:microsoft/Phi-3-vision-128k-instruct] 32L d_model=3072 32H (GQA kv=32)
d_ff=8192 vocab=32064.  The vision encoder is a STUB: ``input_specs()``
provides precomputed patch embeddings; the projector + language backbone are
fully implemented.
"""
from repro.configs.base import ModelConfig, VisionStubConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    source="hf:microsoft/Phi-3-vision-128k-instruct",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    attention="gqa",
    mlp_act="silu_glu",
    vision=VisionStubConfig(num_image_tokens=1024, patch_embed_dim=1024),
)
