"""qwen2-7b — dense GQA with QKV bias.

[arXiv:2407.10671] 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    arch_type="dense",
    source="arXiv:2407.10671",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    attention="gqa",
    qkv_bias=True,
    mlp_act="silu_glu",
    rope_theta=1000000.0,
)
