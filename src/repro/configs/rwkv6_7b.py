"""rwkv6-7b (Finch) — attention-free RNN with data-dependent decay.

[arXiv:2404.05892] 32L d_model=4096 d_ff=14336 vocab=65536.
"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    arch_type="ssm",
    source="arXiv:2404.05892",
    num_layers=32,
    d_model=4096,
    num_heads=64,              # 4096 / head_dim 64
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    head_dim=64,
    attention="none",
    mlp_act="relu2",           # rwkv channel-mix uses squared relu
    norm="layernorm",
    rwkv=RWKVConfig(head_dim=64, decay_lora_rank=64, gate_lora_rank=64),
)
