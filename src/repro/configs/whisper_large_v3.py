"""whisper-large-v3 — encoder-decoder audio model (conv frontend stubbed).

[arXiv:2212.04356] 32L(enc)+32L(dec) d_model=1280 20H (MHA kv=20) d_ff=5120
vocab=51866.  The mel-spectrogram + conv feature extractor is a STUB:
``input_specs()`` provides precomputed frame embeddings (B, S, d_model).
"""
from repro.configs.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    arch_type="audio",
    source="arXiv:2212.04356",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    attention="gqa",
    mlp_act="gelu",
    norm="layernorm",
    encdec=EncDecConfig(num_encoder_layers=32, num_decoder_layers=32,
                        max_target_len=448),
)
