"""zamba2-1.2b — hybrid: Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242] 38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000,
ssm_state=64.  The single shared attention+MLP block is applied every
``attn_every`` Mamba2 layers (weight sharing across applications).
"""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    source="arXiv:2411.15242",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    attention="gqa",
    mlp_act="gelu",
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, conv_kernel=4,
                  chunk_size=256),
    hybrid=HybridConfig(attn_every=6, shared_attn_blocks=1),
)
