"""FLUDE core — the paper's contribution (C1–C5), as composable JAX modules."""
from repro.core.dependability import (BetaBelief, dependability, init_belief,
                                      sample_dependability, update_belief,
                                      variance)
from repro.core.selection import (SelectionResult, decay_epsilon,
                                  freq_threshold, priority,
                                  select_participants)
from repro.core.caching import (ClientCaches, adaptive_cache_interval,
                                clear_cache, expire_caches, gather_caches,
                                has_cache, init_caches, reset_caches,
                                resume_params, scatter_clear_cache,
                                scatter_write_cache, staleness, write_cache)
from repro.core.cache_store import (CohortCacheStream, HostCacheStore,
                                    TransferStats)
from repro.core.distribution import (DistributionPlan, DistributorState,
                                     init_distributor, plan_distribution,
                                     predicted_comm_cost)
from repro.core.aggregation import (PackLayout, aggregation_weights,
                                    fed_aggregate, fed_aggregate_delta,
                                    fed_aggregate_packed, pack, pack_layout,
                                    pack_stacked, unpack)
from repro.core.round import (FludePlan, FludeState, host_round_cut,
                              init_state, make_round_cut,
                              make_server_round_step, plan_round,
                              receive_quorum, update_after_round)
from repro.core.agg_rules import (AggRule, GeometricMedianRule, MeanRule,
                                  TrimmedMeanRule, TrustRule,
                                  available_agg_rules, get_agg_rule,
                                  make_agg_rule, register_agg_rule)
