"""Robust server aggregation rules — the ``FLConfig.agg_rule`` axis.

Orthogonal to ``agg_impl`` (xla | pallas | pallas_interpret): a *rule*
decides **what** statistic of the packed (C, D) client buffer becomes
the new global model, an *impl* decides **how** its inner reductions
run.  Rules plug in through a decorator registry mirroring
``repro.fleet.register_dynamics``::

    @register_agg_rule("my-rule")
    class MyRule(AggRule):
        def reduce(self, buf, gvec, weights, *, impl, ...): ...

and are instantiated by name via ``make_agg_rule`` /
``FLConfig.agg_rule`` with the hashable ``agg_rule_params`` pairs.

Built-ins:

* ``mean`` — the staleness-discounted weighted mean (the default; the
  round step keeps its historical direct path, bit-identical).
* ``geometric_median`` — smoothed Weiszfeld (RFA, arXiv 1912.13445)
  over the packed buffer; tolerates up to half the received weight
  being arbitrarily corrupted.
* ``trimmed_mean`` — coordinate-wise trimmed mean.
* ``trust`` — stateful: a per-client (N,) trust score carried in fleet
  state like the Beta beliefs, updated *on device* every round from the
  observed update-deviation norms (cf. FedAR, arXiv 2101.03705) and
  multiplied into the aggregation weights.  Zero per-round host syncs.

Interface contract: ``reduce(buf, gvec, weights, ...)`` gets the packed
(C, D) fp32 client rows, the packed (D,) previous global vector and the
*unnormalized* (C,) aggregation weights (zero = not received) and
returns the (D,) aggregated vector; the caller applies the empty-round
gate and unpacks.  Stateful rules implement ``reduce_stateful`` taking
and returning the (C,)-aligned state rows (the round step
gathers/scatters them on the cohort path).
"""
from __future__ import annotations

from typing import Dict, Tuple, Type

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels.fed_agg.ops import (fed_agg_packed,
                                       fed_agg_packed_sharded)
from repro.kernels.robust_agg.ops import (geometric_median,
                                          geometric_median_sharded,
                                          masked_median, residual_norms,
                                          trimmed_mean,
                                          trimmed_mean_sharded)
from repro.sharding.partitioning import fleet_axis_size

TINY = 1e-30


class AggRule:
    """Robust aggregation rule: static params + a pure packed reduction.

    ``reduce`` must be jittable; the fused server round step traces it
    once.  ``stateful=True`` rules add a per-client state row threaded
    through rounds by the engine (see ``TrustRule``).
    """
    name = "base"
    stateful = False

    def __init__(self, **params):
        self.params = dict(params)

    def reduce(self, buf, gvec, weights, *, impl="xla", block_c=8,
               block_d=2048, mesh=None, axis="clients"):
        raise NotImplementedError

    # -- stateful extension -------------------------------------------------
    def init_state(self, num_clients: int):
        raise NotImplementedError(f"agg rule {self.name!r} is stateless")

    def reduce_stateful(self, buf, gvec, weights, state, *, impl="xla",
                        block_c=8, block_d=2048, mesh=None,
                        axis="clients"):
        raise NotImplementedError(f"agg rule {self.name!r} is stateless")


def _sharded(mesh) -> bool:
    return mesh is not None and fleet_axis_size(mesh) > 1


class MeanRule(AggRule):
    """The weighted mean — exactly the reduction the historical round
    step runs (the step still calls it directly when ``agg_rule="mean"``
    so the default path's jaxpr never changes; this class serves the
    registry, tests and direct callers)."""

    def reduce(self, buf, gvec, weights, *, impl="xla", block_c=8,
               block_d=2048, mesh=None, axis="clients"):
        w = weights.astype(jnp.float32)
        w_norm = w / jnp.maximum(w.sum(), TINY)
        if _sharded(mesh):
            return fed_agg_packed_sharded(buf, w_norm, mesh=mesh,
                                          axis=axis, impl=impl,
                                          block_c=block_c, block_d=block_d)
        return fed_agg_packed(buf, w_norm, impl=impl, block_c=block_c,
                              block_d=block_d)


class GeometricMedianRule(AggRule):
    """Smoothed Weiszfeld geometric median (RFA)."""

    def __init__(self, iters: int = 6, eps: float = 1e-6):
        super().__init__(iters=int(iters), eps=float(eps))
        self.iters = int(iters)
        self.eps = float(eps)

    def reduce(self, buf, gvec, weights, *, impl="xla", block_c=8,
               block_d=2048, mesh=None, axis="clients"):
        if _sharded(mesh):
            return geometric_median_sharded(
                buf, weights, mesh=mesh, axis=axis, iters=self.iters,
                eps=self.eps, impl=impl, block_c=block_c, block_d=block_d)
        return geometric_median(buf, weights, iters=self.iters,
                                eps=self.eps, impl=impl, block_c=block_c,
                                block_d=block_d)


class TrimmedMeanRule(AggRule):
    """Coordinate-wise weighted trimmed mean."""

    def __init__(self, trim: float = 0.2):
        super().__init__(trim=float(trim))
        self.trim = float(trim)

    def reduce(self, buf, gvec, weights, *, impl="xla", block_c=8,
               block_d=2048, mesh=None, axis="clients"):
        if _sharded(mesh):
            return trimmed_mean_sharded(buf, weights, mesh=mesh,
                                        axis=axis, trim=self.trim)
        return trimmed_mean(buf, weights, trim=self.trim)


class TrustRule(AggRule):
    """Trust-weighted mean with on-device trust learning.

    Every round, each received client's deviation norm
    ``dist_c = ||u_c - g||`` is compared against the received-set median
    (a robust scale reference): ``score_c = (ref / max(dist_c, ref))
    ** power`` is 1 for typical updates and falls quadratically for
    outliers.  Trust is an EMA ``t <- (1 - eta) * t + eta * score`` over
    the rounds a client reports, and the aggregation weight becomes
    ``w_c * clip(t_c, floor, 1)`` — persistent outliers fade to the
    ``floor`` weight, mirroring how the Beta beliefs fade undependable
    devices out of *selection*.  The (N,) trust vector lives in fleet
    state on device; nothing syncs per round.
    """
    stateful = True

    def __init__(self, eta: float = 0.3, floor: float = 0.05,
                 power: float = 2.0, init: float = 1.0):
        super().__init__(eta=float(eta), floor=float(floor),
                         power=float(power), init=float(init))
        self.eta = float(eta)
        self.floor = float(floor)
        self.power = float(power)
        self.init = float(init)

    def init_state(self, num_clients: int):
        import numpy as np
        return np.full((num_clients,), self.init, np.float32)

    def _update(self, dist, weights, state, ref):
        valid = weights > 0
        ref = jnp.maximum(ref, 1e-12)
        score = (ref / jnp.maximum(dist, ref)) ** self.power
        return jnp.where(valid, (1.0 - self.eta) * state
                         + self.eta * score, state)

    def reduce_stateful(self, buf, gvec, weights, state, *, impl="xla",
                        block_c=8, block_d=2048, mesh=None,
                        axis="clients"):
        if _sharded(mesh):
            def body(w_blk, u_blk, g_rep, t_blk):
                w = w_blk.astype(jnp.float32)
                dist = residual_norms(u_blk, g_rep, impl=impl,
                                      block_c=block_c, block_d=block_d)
                dg = jax.lax.all_gather(dist, axis, tiled=True)
                wg = jax.lax.all_gather(w, axis, tiled=True)
                ref = masked_median(dg, wg > 0)
                new_t = self._update(dist, w, t_blk, ref)
                w_eff = w * jnp.clip(new_t, self.floor, 1.0)
                wsum = jax.lax.psum(w_eff.sum(), axis)
                vec = jax.lax.psum(
                    fed_agg_packed(u_blk, w_eff / jnp.maximum(wsum, TINY),
                                   impl=impl, block_c=block_c,
                                   block_d=block_d).astype(jnp.float32),
                    axis)
                return vec, new_t

            return shard_map(
                body, mesh=mesh,
                in_specs=(P(axis), P(axis, None), P(None), P(axis)),
                out_specs=(P(), P(axis)),
                check_rep=False)(weights, buf, gvec, state)

        w = weights.astype(jnp.float32)
        dist = residual_norms(buf, gvec, impl=impl, block_c=block_c,
                              block_d=block_d)
        ref = masked_median(dist, w > 0)
        new_state = self._update(dist, w, state, ref)
        w_eff = w * jnp.clip(new_state, self.floor, 1.0)
        vec = fed_agg_packed(buf, w_eff / jnp.maximum(w_eff.sum(), TINY),
                             impl=impl, block_c=block_c, block_d=block_d)
        return vec, new_state


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[AggRule]] = {}


def register_agg_rule(name: str, *, allow_override: bool = False):
    """Class decorator: ``@register_agg_rule("huber")`` makes the rule
    constructible by name through ``make_agg_rule`` /
    ``FLConfig.agg_rule``."""
    def deco(cls: Type[AggRule]) -> Type[AggRule]:
        if not (isinstance(cls, type) and issubclass(cls, AggRule)):
            raise TypeError(f"@register_agg_rule expects an AggRule "
                            f"subclass, got {cls!r}")
        if name in _REGISTRY and not allow_override:
            raise ValueError(f"agg rule {name!r} already registered "
                             f"(pass allow_override=True to replace)")
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_agg_rule(name: str) -> Type[AggRule]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown agg rule {name!r}; registered: "
                       f"{', '.join(available_agg_rules())}") from None


def available_agg_rules():
    return sorted(_REGISTRY)


def make_agg_rule(name: str, params: Tuple = ()) -> AggRule:
    """Instantiate a registered rule.  ``params`` is the hashable
    ``FLConfig.agg_rule_params`` tuple of ``(key, value)`` pairs."""
    return get_agg_rule(name)(**dict(params))


register_agg_rule("mean")(MeanRule)
register_agg_rule("geometric_median")(GeometricMedianRule)
register_agg_rule("trimmed_mean")(TrimmedMeanRule)
register_agg_rule("trust")(TrustRule)
