"""Server-side model aggregation (FedAvg-compatible, masked + weighted).

The received-set mask realizes FLUDE's semantics: devices that became
undependable contribute *zero* (they never uploaded).  Optional staleness
discounting down-weights updates that started from stale cached models
(cited staleness handling, e.g. refs [28–32] in the paper).

``fed_aggregate`` operates on leading-axis-stacked updates (N, ...) —
this is the hot-spot the ``repro.kernels.fed_agg`` Pallas kernel tiles.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp


def aggregation_weights(received: jax.Array,
                        n_samples: Optional[jax.Array] = None,
                        staleness: Optional[jax.Array] = None,
                        staleness_discount: float = 0.0) -> jax.Array:
    """Per-client aggregation weights.

    received: (N,) bool — uploaded this round.
    n_samples: (N,) — local dataset sizes (FedAvg weighting).
    staleness: (N,) — rounds of staleness of the base model trained from.
    """
    w = received.astype(jnp.float32)
    if n_samples is not None:
        w = w * n_samples.astype(jnp.float32)
    if staleness is not None and staleness_discount > 0.0:
        w = w * jnp.power(1.0 + jnp.maximum(staleness, 0.0),
                          -staleness_discount)
    return w


def fed_aggregate(global_params: Any, client_params: Any,
                  weights: jax.Array, *, kernel=None) -> Any:
    """Weighted average of client models; falls back to the previous global
    model when nobody reported (Σw == 0).

    client_params leaves: (N, ...) stacked.  ``kernel`` optionally points at
    repro.kernels.fed_agg.ops.fed_agg for the Pallas path.
    """
    total = jnp.maximum(weights.sum(), 1e-30)
    any_received = weights.sum() > 0

    def agg(g, c):
        if kernel is not None:
            avg = kernel(c, weights / total)
        else:
            wshape = (-1,) + (1,) * (c.ndim - 1)
            avg = (c.astype(jnp.float32)
                   * (weights / total).reshape(wshape)).sum(0)
        return jnp.where(any_received, avg.astype(g.dtype), g)

    return jax.tree.map(agg, global_params, client_params)


def fed_aggregate_delta(global_params: Any, client_params: Any,
                        weights: jax.Array, server_lr: float = 1.0) -> Any:
    """FedOpt-style: aggregate client *deltas* and apply with a server LR."""
    total = jnp.maximum(weights.sum(), 1e-12)

    def agg(g, c):
        wshape = (-1,) + (1,) * (c.ndim - 1)
        delta = ((c.astype(jnp.float32) - g.astype(jnp.float32)[None])
                 * (weights / total).reshape(wshape)).sum(0)
        return (g.astype(jnp.float32) + server_lr * delta).astype(g.dtype)

    return jax.tree.map(agg, global_params, client_params)
