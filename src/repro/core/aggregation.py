"""Server-side model aggregation (FedAvg-compatible, masked + weighted).

The received-set mask realizes FLUDE's semantics: devices that became
undependable contribute *zero* (they never uploaded).  Optional staleness
discounting down-weights updates that started from stale cached models
(cited staleness handling, e.g. refs [28–32] in the paper).

``fed_aggregate`` operates on leading-axis-stacked updates (N, ...) —
this is the hot-spot the ``repro.kernels.fed_agg`` Pallas kernel tiles.
The *packed* path (``pack_layout`` / ``fed_aggregate_packed``) flattens the
whole stacked pytree into one (C, D) buffer so the entire model aggregates
in a single kernel launch instead of one per leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def aggregation_weights(received: jax.Array,
                        n_samples: Optional[jax.Array] = None,
                        staleness: Optional[jax.Array] = None,
                        staleness_discount: float = 0.0) -> jax.Array:
    """Per-client aggregation weights.

    received: (N,) bool — uploaded this round.
    n_samples: (N,) — local dataset sizes (FedAvg weighting).
    staleness: (N,) — rounds of staleness of the base model trained from.
    """
    w = received.astype(jnp.float32)
    if n_samples is not None:
        w = w * n_samples.astype(jnp.float32)
    if staleness is not None and staleness_discount > 0.0:
        w = w * jnp.power(1.0 + jnp.maximum(staleness, 0.0),
                          -staleness_discount)
    return w


def fed_aggregate(global_params: Any, client_params: Any,
                  weights: jax.Array, *, kernel=None) -> Any:
    """Weighted average of client models; falls back to the previous global
    model when nobody reported (Σw == 0).

    client_params leaves: (N, ...) stacked.  ``kernel`` optionally points at
    repro.kernels.fed_agg.ops.fed_agg for the Pallas path.
    """
    total = jnp.maximum(weights.sum(), 1e-30)
    any_received = weights.sum() > 0

    def agg(g, c):
        if kernel is not None:
            avg = kernel(c, weights / total)
        else:
            wshape = (-1,) + (1,) * (c.ndim - 1)
            avg = (c.astype(jnp.float32)
                   * (weights / total).reshape(wshape)).sum(0)
        return jnp.where(any_received, avg.astype(g.dtype), g)

    return jax.tree.map(agg, global_params, client_params)


# ---------------------------------------------------------------------------
# Packed aggregation: whole-pytree single-buffer path
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PackLayout:
    """Static layout descriptor for flattening a param pytree to one row.

    Built once from an *unstacked* template (the global model); reused every
    round, so pack/unpack are pure reshape/concat/slice ops that fuse into
    the surrounding jit.  The packed buffer is always fp32 (aggregation
    accumulates in fp32; leaves cast back to their own dtype on unpack).
    """
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    sizes: Tuple[int, ...]
    offsets: Tuple[int, ...]
    dim: int                     # D — total packed element count

    def buffer_bytes(self, rows: int) -> int:
        """Bytes of the packed fp32 (rows, D) aggregation buffer.

        ``rows`` is the client axis of the *stacked inputs actually
        aggregated*: N on the full-scan path, the static cohort size X on
        the compact path — the compact engine reports (X, D) here, which
        is the buffer that really lives on device (see
        ``FleetEngine.server_step_memory``)."""
        return int(rows) * self.dim * 4


def _prod(shape) -> int:
    out = 1
    for d in shape:
        out *= int(d)
    return out


def pack_layout(template_params: Any) -> PackLayout:
    leaves, treedef = jax.tree.flatten(template_params)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(l.dtype for l in leaves)
    sizes = tuple(_prod(s) for s in shapes)
    offsets, off = [], 0
    for n in sizes:
        offsets.append(off)
        off += n
    return PackLayout(treedef, shapes, dtypes, sizes, tuple(offsets), off)


def _check_layout(tree: Any, layout: PackLayout, lead: int) -> list:
    """Leaves in layout order, with structure/shape validated — a mismatched
    tree would otherwise pack into wrong offsets and corrupt silently."""
    leaves, treedef = jax.tree.flatten(tree)
    if treedef != layout.treedef:
        raise ValueError(f"pytree structure does not match pack layout: "
                         f"{treedef} vs {layout.treedef}")
    for l, shape in zip(leaves, layout.shapes):
        if tuple(l.shape[lead:]) != shape:
            raise ValueError(f"leaf shape {l.shape} does not match "
                             f"layout entry {shape}")
    return leaves


def pack(params: Any, layout: PackLayout) -> jax.Array:
    """Unstacked pytree -> (D,) fp32 vector."""
    leaves = _check_layout(params, layout, lead=0)
    return jnp.concatenate(
        [l.reshape(-1).astype(jnp.float32) for l in leaves])


def pack_stacked(client_params: Any, layout: PackLayout) -> jax.Array:
    """Stacked pytree (leaves (C, ...)) -> (C, D) fp32 buffer.

    The client axis C is whatever the caller stacked: the full fleet N,
    or — on the compact-cohort round path — the static cohort size X
    (the layout describes the packed D axis only, so one layout serves
    both row counts)."""
    leaves = _check_layout(client_params, layout, lead=1)
    C = leaves[0].shape[0]
    return jnp.concatenate(
        [l.reshape(C, -1).astype(jnp.float32) for l in leaves], axis=1)


def unpack(vec: jax.Array, layout: PackLayout) -> Any:
    """(D,) vector -> pytree with the template's shapes and dtypes."""
    leaves = [
        jax.lax.slice(vec, (off,), (off + n,)).reshape(shape).astype(dt)
        for off, n, shape, dt in zip(layout.offsets, layout.sizes,
                                     layout.shapes, layout.dtypes)
    ]
    return jax.tree.unflatten(layout.treedef, leaves)


def fed_aggregate_packed(global_params: Any, client_params: Any,
                         weights: jax.Array,
                         layout: Optional[PackLayout] = None, *,
                         impl: str = "xla", block_c: int = 8,
                         block_d: int = 2048, mesh: Any = None,
                         client_axis: str = "clients") -> Any:
    """Weighted average over the whole pytree in ONE aggregation call.

    Semantically identical to ``fed_aggregate(..., kernel=None)``: weights
    are normalized by their sum, and when nobody reported (Σw == 0) the
    previous global model passes through unchanged.

    impl: "xla" (einsum on the packed buffer), "pallas" (TPU kernel), or
    "pallas_interpret" (kernel in interpret mode — CPU CI).

    With a ``mesh`` carrying a ``client_axis`` axis of size > 1 the packed
    (C, D) buffer stays sharded over clients and aggregation runs as
    per-shard partial weighted sums + one fp32 ``psum``
    (``fed_agg_packed_sharded``); the impl switch is preserved per shard.
    """
    from repro.kernels.fed_agg.ops import (fed_agg_packed,
                                           fed_agg_packed_sharded)
    from repro.sharding.partitioning import fleet_axis_size

    if layout is None:
        layout = pack_layout(global_params)
    buf = pack_stacked(client_params, layout)                # (C, D) fp32
    total = jnp.maximum(weights.sum(), 1e-30)
    w_norm = (weights / total).astype(jnp.float32)
    if mesh is not None and fleet_axis_size(mesh) > 1:
        agg = fed_agg_packed_sharded(buf, w_norm, mesh=mesh,
                                     axis=client_axis, impl=impl,
                                     block_c=block_c, block_d=block_d)
    else:
        agg = fed_agg_packed(buf, w_norm, impl=impl, block_c=block_c,
                             block_d=block_d)
    any_received = weights.sum() > 0
    # empty-round gate per leaf — avoids packing the global model just to
    # serve the nobody-reported fallback
    return jax.tree.map(lambda avg, g: jnp.where(any_received, avg, g),
                        unpack(agg, layout), global_params)


def fed_aggregate_delta(global_params: Any, client_params: Any,
                        weights: jax.Array, server_lr: float = 1.0) -> Any:
    """FedOpt-style: aggregate client *deltas* and apply with a server LR."""
    total = jnp.maximum(weights.sum(), 1e-12)

    def agg(g, c):
        wshape = (-1,) + (1,) * (c.ndim - 1)
        delta = ((c.astype(jnp.float32) - g.astype(jnp.float32)[None])
                 * (weights / total).reshape(wshape)).sum(0)
        return (g.astype(jnp.float32) + server_lr * delta).astype(g.dtype)

    return jax.tree.map(agg, global_params, client_params)
