"""Host-offloaded C3 cache store (``FLConfig.cache_offload``).

Under ``cache_offload="host"`` the fleet's (N, D) C3 cache params no
longer live on device: the device keeps only the (N,) cache *metadata*
(progress, round stamp — everything planning reads) plus the current
cohort's (X, D) slot block, and this module owns the host side of that
round trip:

* :class:`HostCacheStore` — a sparse per-client row store (one entry per
  client that actually holds a cached model), so host memory tracks the
  number of *live* cache slots, not the enrolled fleet.  A fetch of a
  never-written (or sentinel-padded, or cleared) row reads as the empty
  slot — zero params — which is exactly what the resident pytree's
  gather produces for rows whose metadata says "no cache", so the jitted
  round body needs no special handling.
* :class:`CohortCacheStream` — the async double-buffering protocol
  around the store.  Written slots stream back with
  ``copy_to_host_async`` immediately after the server step is
  *dispatched* and are drained one round later, when the next fetch
  needs them; the next cohort's slots are gathered and shipped with an
  async ``jax.device_put`` as soon as the cohort index is known.  No
  O(X·D) copy ever blocks the round that produced it — the only
  blocking reads are on handles whose device-to-host copies were issued
  a full dispatch earlier (counted in the stream's own
  :class:`TransferStats`, exposed as ``FleetEngine.transfer_stats`` —
  counters are strictly per-engine; the old process-wide ``STATS``
  aggregate is gone, and ``repro.analysis.lint`` rejects the pattern).

``cache_offload="discard"`` additionally drops rows whose round stamp is
more than ``cache_staleness_bound`` rounds old (the paper's cache is
best-effort — §4.2 — so expiry is a legal memory/accuracy knob).  The
matching device-side metadata expiry lives in
``repro.core.caching.expire_caches`` and runs *before* planning each
round with the same bound, so the planner never resumes a pruned row.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import numpy as np


@dataclasses.dataclass
class TransferStats:
    """Per-stream counters of the offload stream's host transfers.

    ``*_async`` count *dispatches* of asynchronous copies (one per
    pytree, not per leaf); ``pre_issued_reads`` counts blocking
    ``np.asarray`` reads on handles whose device-to-host copy was
    already issued a dispatch earlier (the double-buffering drain);
    ``sync_copies`` counts synchronous round-blocking copies — the
    streaming protocol never performs one, and the transfer-count tests
    assert it stays zero.
    """
    h2d_async: int = 0
    d2h_async: int = 0
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    pre_issued_reads: int = 0
    sync_copies: int = 0

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)


def _tree_bytes(tree) -> int:
    return sum(int(np.asarray(l).nbytes) for l in jax.tree.leaves(tree))


class HostCacheStore:
    """Sparse host-side store of per-client C3 cache rows.

    One entry per client id that currently holds a cached local model;
    each entry is the flattened list of per-leaf numpy rows (owned
    copies — never views into a transient cohort block) plus the round
    stamp the row was written with.  ``num_clients`` is the sentinel id:
    gathers treat it (and any never-written id) as the empty slot.
    """

    def __init__(self, template_params, num_clients: int,
                 staleness_bound: Optional[int] = None):
        leaves, treedef = jax.tree.flatten(template_params)
        self._treedef = treedef
        self._shapes = [tuple(np.shape(l)) for l in leaves]
        self._dtypes = [np.asarray(l).dtype for l in leaves]
        self.num_clients = int(num_clients)
        self.staleness_bound = None if staleness_bound is None \
            else int(staleness_bound)
        self.row_bytes = sum(
            int(np.prod(s, dtype=np.int64)) * d.itemsize
            for s, d in zip(self._shapes, self._dtypes))
        self._rows: Dict[int, List[np.ndarray]] = {}
        self._stamps: Dict[int, int] = {}

    # -- introspection ------------------------------------------------------

    def __len__(self) -> int:
        return len(self._rows)

    @property
    def nbytes(self) -> int:
        """Live host bytes of stored cache rows (excludes dict overhead)."""
        return len(self._rows) * self.row_bytes

    def stamp_of(self, client_id: int) -> Optional[int]:
        return self._stamps.get(int(client_id))

    def clear(self) -> None:
        self._rows.clear()
        self._stamps.clear()

    # -- fetch / apply ------------------------------------------------------

    def gather(self, idx: np.ndarray) -> Any:
        """Stacked (X, ...) host pytree of the rows at ``idx``.

        Sentinel ids (``num_clients``) and ids with no stored row read as
        zeros — the empty-slot value the resident pytree's gather
        produces for the same rows.  (Rows whose device metadata was
        *cleared* keep their stale buffer in the resident pytree but
        read as zeros here; nothing consumes either value — resume is
        False wherever the metadata says "no cache" — so round outputs
        are identical.)
        """
        idx = np.asarray(idx)
        x = idx.shape[0]
        out = [np.zeros((x,) + s, d)
               for s, d in zip(self._shapes, self._dtypes)]
        for k in range(x):
            row = self._rows.get(int(idx[k]))
            if row is not None:
                for j, leaf in enumerate(row):
                    out[j][k] = leaf
        return jax.tree.unflatten(self._treedef, out)

    def apply(self, idx: np.ndarray, write: np.ndarray, clear: np.ndarray,
              stamps: np.ndarray, block, current_round: int) -> None:
        """Apply one round's cache bookkeeping to the store.

        ``idx``/``write``/``clear``/``stamps`` are (X,) host arrays;
        ``block`` is the (X, ...) cohort cache-params pytree the trainer
        produced.  Rows are written where ``write`` (owned copies),
        deleted where ``clear`` (a received upload invalidates the slot
        — the host row becomes unreachable because the device metadata
        is reset, so keeping it would only leak memory).  ``write`` and
        ``clear`` are disjoint by construction (fail vs success).
        Under a staleness bound, rows older than the bound at
        ``current_round`` are pruned — mirroring the device-side
        ``expire_caches`` metadata expiry, which runs with the same
        bound before this round's plan, so no pruned row can be fetched
        as a resume.
        """
        idx = np.asarray(idx)
        write = np.asarray(write)
        clear = np.asarray(clear)
        stamps = np.asarray(stamps)
        leaves = [np.asarray(l) for l in jax.tree.leaves(block)]
        n = self.num_clients
        for k in range(idx.shape[0]):
            cid = int(idx[k])
            if cid >= n:
                continue
            if write[k]:
                self._rows[cid] = [np.array(l[k]) for l in leaves]
                self._stamps[cid] = int(stamps[k])
            elif clear[k]:
                self._rows.pop(cid, None)
                self._stamps.pop(cid, None)
        if self.staleness_bound is not None:
            self.prune(current_round)

    def prune(self, current_round: int) -> None:
        """Drop rows staler than the bound at ``current_round``."""
        bound = self.staleness_bound
        if bound is None:
            return
        dead = [cid for cid, st in self._stamps.items()
                if int(current_round) - st > bound]
        for cid in dead:
            self._rows.pop(cid, None)
            self._stamps.pop(cid, None)


class CohortCacheStream:
    """Double-buffered device↔host streaming of cohort cache slots.

    The engine drives it with two calls per round:

    * ``fetch(idx, rnd)`` — called as soon as the round's cohort index
      is dispatched.  Starts the async device-to-host copy of ``idx``,
      drains the *previous* round's staged write-back (whose async
      copies have been in flight since that round's server step was
      dispatched), gathers the cohort's rows from the store and ships
      them back with an async ``jax.device_put`` onto the cohort
      sharding.
    * ``stage(idx, write, clear, block, stamps)`` — called right after
      the server step is dispatched.  Starts ``copy_to_host_async`` on
      every handle and parks them; nothing blocks until the next
      round's ``fetch`` (or ``flush``) reads them.
    """

    def __init__(self, store: HostCacheStore, mesh=None,
                 cohort_size: Optional[int] = None,
                 stats: Optional[TransferStats] = None):
        self.store = store
        self.mesh = mesh
        self.cohort_size = cohort_size
        # per-stream counters (the engine passes its own instance)
        self.stats = stats if stats is not None else TransferStats()
        self._pending = None

    def _sharding(self, tree):
        if self.mesh is None:
            return None
        from repro.sharding import partitioning as SP
        return jax.tree.map(
            lambda l: SP.cohort_sharding(self.mesh, np.asarray(l).ndim),
            tree)

    def _start_d2h(self, tree) -> None:
        for leaf in jax.tree.leaves(tree):
            if isinstance(leaf, jax.Array):
                leaf.copy_to_host_async()
        self.stats.d2h_async += 1
        self.stats.d2h_bytes += _tree_bytes(tree)

    def _read(self, tree):
        """Blocking read of handles whose copy was pre-issued."""
        self.stats.pre_issued_reads += 1
        return jax.tree.map(np.asarray, tree)

    def fetch(self, idx, rnd: int):
        """(X, ...) device block of the cohort's cache rows (async put)."""
        self._start_d2h(idx)           # overlap with draining the pending
        self.drain(rnd)
        idx_np = self._read(idx)
        block = self.store.gather(idx_np)
        sh = self._sharding(block)
        put = jax.device_put(block) if sh is None \
            else jax.device_put(block, sh)
        self.stats.h2d_async += 1
        self.stats.h2d_bytes += _tree_bytes(block)
        return put

    def stage(self, idx, write, clear, block, stamps) -> None:
        """Park one round's cache write-back; copies start now."""
        self.drain()                   # at most one round in flight
        payload = (idx, write, clear, stamps, block)
        self._start_d2h(payload)
        self._pending = payload

    def drain(self, rnd: Optional[int] = None) -> None:
        """Apply the parked write-back (blocks on pre-issued copies)."""
        if self._pending is None:
            return
        idx, write, clear, stamps, block = self._read(self._pending)
        self._pending = None
        self.store.apply(idx, write, clear, stamps, block,
                         0 if rnd is None else int(rnd))

    def flush(self, rnd: Optional[int] = None) -> None:
        self.drain(rnd)

    def reset(self) -> None:
        self._pending = None
        self.store.clear()
