"""C3 — local model caching (paper §4.2).

Each device keeps a *rolling single-slot* cache of its latest local training
state (model params, progress fraction, round stamp).  When an interrupted
device rejoins, it resumes from the cache unless the server's staleness-aware
distributor (C4) overrides it with a fresh global model.

In cross-device mode the fleet's caches are a leading-axis-stacked pytree
(N_clients first dim on every leaf) so cache update/resume are pure
``jnp.where`` ops that shard over the client mesh axes.  In cross-silo mode
(huge models) only the metadata (progress, round stamp) is kept — see
DESIGN.md §3 hardware adaptation.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class ClientCaches(NamedTuple):
    params: Any              # pytree, each leaf (N, ...) — cached local state
    progress: jax.Array      # (N,) float32 in [0,1] — fraction completed
    round_stamp: jax.Array   # (N,) int32 — round when cached (-1 = empty)


def init_caches(template_params, num_clients: int) -> ClientCaches:
    stacked = jax.tree.map(
        lambda a: jnp.zeros((num_clients,) + a.shape, a.dtype),
        template_params)
    return ClientCaches(
        stacked,
        jnp.zeros((num_clients,), jnp.float32),
        jnp.full((num_clients,), -1, jnp.int32))


def write_cache(caches: ClientCaches, mask: jax.Array, new_params,
                progress: jax.Array, rnd) -> ClientCaches:
    """Rolling update: overwrite the slot for masked clients (latest only).

    new_params leaves are (N, ...) stacked local states.
    """
    def upd(old, new):
        m = mask.reshape((-1,) + (1,) * (old.ndim - 1))
        return jnp.where(m, new.astype(old.dtype), old)

    return ClientCaches(
        jax.tree.map(upd, caches.params, new_params),
        jnp.where(mask, progress, caches.progress),
        jnp.where(mask, jnp.asarray(rnd, jnp.int32), caches.round_stamp))


def clear_cache(caches: ClientCaches, mask: jax.Array) -> ClientCaches:
    """After a successful upload the local cache slot is invalidated."""
    return ClientCaches(
        caches.params,
        jnp.where(mask, 0.0, caches.progress),
        jnp.where(mask, -1, caches.round_stamp))


def staleness(caches: ClientCaches, current_round) -> jax.Array:
    """Rounds elapsed since the cache was written (∞-ish if empty)."""
    empty = caches.round_stamp < 0
    s = (jnp.asarray(current_round, jnp.int32) - caches.round_stamp)
    return jnp.where(empty, jnp.int32(1 << 20), s).astype(jnp.float32)


def has_cache(caches: ClientCaches) -> jax.Array:
    return caches.round_stamp >= 0


def resume_params(caches: ClientCaches, global_params, use_cache_mask):
    """Per-client starting state: cached params where resuming, else the
    fresh global model (broadcast).  Leaves: (N, ...)."""
    def pick(cached, g):
        m = use_cache_mask.reshape((-1,) + (1,) * (cached.ndim - 1))
        return jnp.where(m, cached, g[None].astype(cached.dtype))

    return jax.tree.map(pick, caches.params, global_params)


def adaptive_cache_interval(base_interval, battery: jax.Array,
                            stability: jax.Array) -> jax.Array:
    """§4.2 "adjusting caching frequency": lower battery / flakier network
    ⇒ cache more often (smaller interval); stable+charged ⇒ less often.

    battery, stability ∈ [0, 1].  Returns per-device seconds, clamped to
    [base/2, 5·base] (paper's examples: 30 s … 5 min around a 1-min base).
    """
    scale = jnp.clip(2.0 * battery * stability, 0.5, 5.0)
    return base_interval * scale
