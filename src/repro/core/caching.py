"""C3 — local model caching (paper §4.2).

Each device keeps a *rolling single-slot* cache of its latest local training
state (model params, progress fraction, round stamp).  When an interrupted
device rejoins, it resumes from the cache unless the server's staleness-aware
distributor (C4) overrides it with a fresh global model.

In cross-device mode the fleet's caches are a leading-axis-stacked pytree
(N_clients first dim on every leaf) so cache update/resume are pure
``jnp.where`` ops that shard over the client mesh axes.  In cross-silo mode
(huge models) only the metadata (progress, round stamp) is kept — see
DESIGN.md §3 hardware adaptation.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class ClientCaches(NamedTuple):
    params: Any              # pytree, each leaf (N, ...) — cached local state
    progress: jax.Array      # (N,) float32 in [0,1] — fraction completed
    round_stamp: jax.Array   # (N,) int32 — round when cached (-1 = empty)


def init_caches(template_params, num_clients: int) -> ClientCaches:
    stacked = jax.tree.map(
        lambda a: jnp.zeros((num_clients,) + a.shape, a.dtype),
        template_params)
    return ClientCaches(
        stacked,
        jnp.zeros((num_clients,), jnp.float32),
        jnp.full((num_clients,), -1, jnp.int32))


def reset_caches(caches: ClientCaches) -> ClientCaches:
    """Value-identical to :func:`init_caches`, but shaped for buffer
    recycling: jitted with ``donate_argnums=0`` the zero/-1 fills write
    into the donated leaves in place, so a fresh run on a long-lived
    engine memsets the existing (N, ...) fleet buffers instead of
    faulting in a new cache pytree (at N=4096 the fresh allocation is
    ~7x the memset)."""
    return ClientCaches(
        jax.tree.map(jnp.zeros_like, caches.params),
        jnp.zeros_like(caches.progress),
        jnp.full_like(caches.round_stamp, -1))


def write_cache(caches: ClientCaches, mask: jax.Array, new_params,
                progress: jax.Array, rnd) -> ClientCaches:
    """Rolling update: overwrite the slot for masked clients (latest only).

    new_params leaves are (N, ...) stacked local states.
    """
    def upd(old, new):
        m = mask.reshape((-1,) + (1,) * (old.ndim - 1))
        return jnp.where(m, new.astype(old.dtype), old)

    return ClientCaches(
        jax.tree.map(upd, caches.params, new_params),
        jnp.where(mask, progress, caches.progress),
        jnp.where(mask, jnp.asarray(rnd, jnp.int32), caches.round_stamp))


def clear_cache(caches: ClientCaches, mask: jax.Array) -> ClientCaches:
    """After a successful upload the local cache slot is invalidated."""
    return ClientCaches(
        caches.params,
        jnp.where(mask, 0.0, caches.progress),
        jnp.where(mask, -1, caches.round_stamp))


# ---------------------------------------------------------------------------
# Compact cohorts: gather (N,) slots into dense (X,) blocks and scatter back
# ---------------------------------------------------------------------------
#
# The cohort index is an ascending (X,) int array of selected client ids,
# padded with the out-of-range sentinel N (``repro.fl.api.cohort_index``).
# Gathers use ``mode="fill"`` so sentinel rows read as *empty* slots;
# scatters predicate their row mask into the index (unwritten rows point at
# the sentinel) and drop out-of-range writes — together a gather→update→
# scatter round trip equals the full-fleet ``jnp.where`` update exactly.

def gather_caches(caches: ClientCaches, idx: jax.Array) -> ClientCaches:
    """Dense (X, ...) view of the cache slots at ``idx``.

    Sentinel (padding) rows read as empty: zero params, zero progress,
    round stamp -1 — the same values an untouched fresh slot holds, so
    downstream resume/staleness logic needs no special pad handling.
    """
    def take(a, fill):
        return jnp.take(a, idx, axis=0, mode="fill", fill_value=fill)

    return ClientCaches(
        jax.tree.map(lambda a: take(a, 0), caches.params),
        take(caches.progress, 0.0),
        take(caches.round_stamp, -1))


def scatter_write_cache(caches: ClientCaches, idx: jax.Array,
                        mask: jax.Array, new_params,
                        progress: jax.Array, rnd) -> ClientCaches:
    """:func:`write_cache` restricted to the cohort rows ``idx``.

    ``mask``/``new_params``/``progress``/``rnd`` are (X,)-leading cohort
    arrays.  Masked-off rows are redirected to the sentinel and dropped,
    so every unwritten (N,) slot keeps its existing buffer — equal to the
    full-fleet rolling ``jnp.where`` update when the full write mask is
    zero outside the cohort (which it is: writes require selection).
    """
    n = caches.progress.shape[0]
    target = jnp.where(mask, idx, n)

    def upd(old, new):
        return old.at[target].set(new.astype(old.dtype), mode="drop")

    return ClientCaches(
        jax.tree.map(upd, caches.params, new_params),
        caches.progress.at[target].set(
            progress.astype(jnp.float32), mode="drop"),
        caches.round_stamp.at[target].set(
            jnp.asarray(rnd, jnp.int32), mode="drop"))


def scatter_clear_cache(caches: ClientCaches, idx: jax.Array,
                        mask: jax.Array) -> ClientCaches:
    """:func:`clear_cache` restricted to the cohort rows ``idx`` (params
    stay, metadata resets — identical to the full-fleet clear for masks
    that are zero outside the cohort)."""
    n = caches.progress.shape[0]
    target = jnp.where(mask, idx, n)
    return ClientCaches(
        caches.params,
        caches.progress.at[target].set(0.0, mode="drop"),
        caches.round_stamp.at[target].set(-1, mode="drop"))


def expire_caches(caches: ClientCaches, current_round,
                  staleness_bound: int) -> ClientCaches:
    """Drop cache slots staler than ``staleness_bound`` rounds.

    The device half of ``FLConfig.cache_offload="discard"``: metadata of
    rows whose stamp is more than ``staleness_bound`` rounds old resets
    to the empty slot (progress 0, stamp -1) *before* planning reads it,
    so the planner consistently sees the slot as absent and never
    schedules a resume the host store has pruned.  Params leaves pass
    through untouched — in offload mode there are none on device, and
    an unreachable resident row is dead weight either way.
    """
    stale = (jnp.asarray(current_round, jnp.int32) - caches.round_stamp) \
        > staleness_bound
    return ClientCaches(
        caches.params,
        jnp.where(stale, 0.0, caches.progress),
        jnp.where(stale, -1, caches.round_stamp))


def staleness(caches: ClientCaches, current_round) -> jax.Array:
    """Rounds elapsed since the cache was written (∞-ish if empty)."""
    empty = caches.round_stamp < 0
    s = (jnp.asarray(current_round, jnp.int32) - caches.round_stamp)
    return jnp.where(empty, jnp.int32(1 << 20), s).astype(jnp.float32)


def has_cache(caches: ClientCaches) -> jax.Array:
    return caches.round_stamp >= 0


def resume_params(caches: ClientCaches, global_params, use_cache_mask):
    """Per-client starting state: cached params where resuming, else the
    fresh global model (broadcast).  Leaves: (N, ...)."""
    def pick(cached, g):
        m = use_cache_mask.reshape((-1,) + (1,) * (cached.ndim - 1))
        return jnp.where(m, cached, g[None].astype(cached.dtype))

    return jax.tree.map(pick, caches.params, global_params)


def adaptive_cache_interval(base_interval, battery: jax.Array,
                            stability: jax.Array) -> jax.Array:
    """§4.2 "adjusting caching frequency": lower battery / flakier network
    ⇒ cache more often (smaller interval); stable+charged ⇒ less often.

    battery, stability ∈ [0, 1].  Returns per-device seconds, clamped to
    [base/2, 5·base] (paper's examples: 30 s … 5 min around a 1-min base).
    """
    scale = jnp.clip(2.0 * battery * stability, 0.5, 5.0)
    return base_interval * scale
