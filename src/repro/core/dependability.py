"""C1 — device dependability assessment (paper §4.1, Eq. 1).

Each device's probability of successfully completing a training round is
modeled as a Beta(α, β) posterior updated by Bayes' rule on observed
successes/failures:

    α_new = α + s,   β_new = β + f,   E[R(i)] = α_new / (α_new + β_new)

The fleet posterior is a pair of (N,) arrays — a jit-able pytree.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class BetaBelief(NamedTuple):
    alpha: jax.Array     # (N,) float32
    beta: jax.Array      # (N,) float32


def init_belief(num_devices: int, alpha0: float = 2.0,
                beta0: float = 2.0) -> BetaBelief:
    """Neutral prior Beta(2, 2) — "neither dependable nor undependable"."""
    return BetaBelief(
        jnp.full((num_devices,), alpha0, jnp.float32),
        jnp.full((num_devices,), beta0, jnp.float32))


def update_belief(belief: BetaBelief, successes: jax.Array,
                  failures: jax.Array) -> BetaBelief:
    """Eq. (1): add per-device success/failure counts (int or bool arrays)."""
    return BetaBelief(
        belief.alpha + successes.astype(jnp.float32),
        belief.beta + failures.astype(jnp.float32))


def dependability(belief: BetaBelief) -> jax.Array:
    """E[R(i)] = α / (α + β)  — the per-device dependability estimate."""
    return belief.alpha / (belief.alpha + belief.beta)


def variance(belief: BetaBelief) -> jax.Array:
    """Posterior variance — used by tests / exploration heuristics."""
    a, b = belief.alpha, belief.beta
    return a * b / ((a + b) ** 2 * (a + b + 1.0))


def sample_dependability(belief: BetaBelief, rng) -> jax.Array:
    """Thompson sample R(i) ~ Beta(α_i, β_i) (optional selection variant)."""
    return jax.random.beta(rng, belief.alpha, belief.beta)
