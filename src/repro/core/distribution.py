"""C4 — staleness-aware model distribution (paper §4.3, Eq. 4).

Selected devices split into:
  U — completed last participation (or never selected): always get the
      fresh global model;
  V — failed last participation and hold a local cache: get the fresh model
      only if their cache staleness exceeds the adaptive threshold W.

Threshold adaptation (Eq. 4):
  W'  = W_old · (1 − λ·(H_new − H_old)/H_old)      — staleness pressure
  W   = W'   · (1 + μ·(N_new − N_old)/N_old)       — comm-cost pressure
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class DistributorState(NamedTuple):
    w_threshold: jax.Array   # scalar float32 — W
    h_old: jax.Array         # scalar — previous average staleness
    n_old: jax.Array         # scalar — previous distribution count


class DistributionPlan(NamedTuple):
    distribute: jax.Array    # (N,) bool — S_distr (receive fresh global)
    resume: jax.Array        # (N,) bool — train from local cache
    state: DistributorState  # updated threshold state
    avg_staleness: jax.Array


def init_distributor(w_init: float = 3.0) -> DistributorState:
    return DistributorState(jnp.float32(w_init), jnp.float32(0.0),
                            jnp.float32(1.0))


def plan_distribution(state: DistributorState, selected: jax.Array,
                      in_v: jax.Array, has_cache: jax.Array,
                      staleness: jax.Array, *, lam: float, mu: float,
                      w_min: float, w_max: float,
                      mode: str = "adaptive") -> DistributionPlan:
    """Decide who receives the fresh global model this round.

    selected:  (N,) bool — S (Algorithm 1 output)
    in_v:      (N,) bool — failed their last participation
    has_cache: (N,) bool — hold a valid local cache
    staleness: (N,) float — rounds since their cache was written
    """
    cacheable = selected & in_v & has_cache

    if mode == "full":
        distribute = selected
        resume = jnp.zeros_like(selected)
        return DistributionPlan(distribute, resume, state,
                                jnp.float32(0.0))
    if mode == "least":
        resume = cacheable
        distribute = selected & ~resume
        return DistributionPlan(distribute, resume, state,
                                jnp.float32(0.0))

    # --- adaptive (Eq. 4) -------------------------------------------------
    nv = jnp.maximum(cacheable.sum(), 1)
    h_new = jnp.where(cacheable, staleness, 0.0).sum() / nv

    w_old, h_old, n_old = state
    # first observation (h_old == 0): no staleness pressure yet
    h_ref = jnp.where(h_old > 0, h_old, jnp.maximum(h_new, 1e-3))
    delta_h = jnp.where(h_old > 0, h_new - h_old, 0.0)
    w_prime = w_old * (1.0 - lam * delta_h / h_ref)
    n_new = (cacheable & (staleness > w_prime)).sum().astype(jnp.float32)
    n_ref = jnp.maximum(n_old, 1.0)
    w_new = w_prime * (1.0 + mu * (n_new - n_old) / n_ref)
    w_new = jnp.clip(w_new, w_min, w_max)

    too_stale = staleness > w_new
    resume = cacheable & ~too_stale
    distribute = selected & ~resume
    new_state = DistributorState(w_new, h_new, n_new)
    return DistributionPlan(distribute, resume, new_state, h_new)


def predicted_comm_cost(distribute: jax.Array, selected: jax.Array,
                        avg_dependability) -> jax.Array:
    """Algorithm 2 line 11: B_pred = |S_distr| + |S| · R̄  (model-transmission
    units: downloads actually sent + uploads expected back)."""
    return (distribute.sum().astype(jnp.float32)
            + selected.sum().astype(jnp.float32) * avg_dependability)
