"""C5 — the FLUDE round process (paper §4.4, Algorithm 2), server side.

``plan_round`` runs lines 3–12: budget-adaptive participant count X,
Algorithm-1 selection, staleness-aware distribution, predicted comm cost.
``update_after_round`` runs the post-aggregation bookkeeping: Beta-posterior
updates (Eq. 1), participation counters (Eq. 3 numerator), U/V membership,
ε decay.  Both are pure jnp over fixed-shape fleet arrays.

Round *termination* (lines 13–16: first |S|·R̄ uploads or deadline T) is a
wall-clock matter and lives in ``repro.fl.simulator``/the launcher, which
call ``receive_quorum`` below for the cutoff count.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core import caching as C
from repro.core import distribution as D
from repro.core import selection as SEL
from repro.core.dependability import BetaBelief, dependability, init_belief


class FludeState(NamedTuple):
    """Full server-side fleet state (a jit-able pytree)."""
    belief: BetaBelief
    part_count: jax.Array       # (N,) int32 — q_i
    explored: jax.Array         # (N,) bool — C
    in_v: jax.Array             # (N,) bool — failed last participation
    distributor: D.DistributorState
    epsilon: jax.Array          # scalar
    total_selected: jax.Array   # scalar — Σ_k |S_k|
    round: jax.Array            # scalar int32


class RoundPlan(NamedTuple):
    selected: jax.Array         # (N,) bool — S
    distribute: jax.Array       # (N,) bool — S_distr (fresh global model)
    resume: jax.Array           # (N,) bool — train from local cache
    predicted_cost: jax.Array   # scalar — B_pred (model transmissions)
    quorum: jax.Array           # scalar — |S| · R̄ receive cutoff
    avg_dependability: jax.Array
    priority: jax.Array         # (N,) — P(i), for logging
    distributor: D.DistributorState


def init_state(cfg: FLConfig) -> FludeState:
    return FludeState(
        belief=init_belief(cfg.num_clients, cfg.beta_alpha0, cfg.beta_beta0),
        part_count=jnp.zeros((cfg.num_clients,), jnp.int32),
        explored=jnp.zeros((cfg.num_clients,), bool),
        in_v=jnp.zeros((cfg.num_clients,), bool),
        distributor=D.init_distributor(cfg.w_init),
        epsilon=jnp.float32(cfg.epsilon_init),
        total_selected=jnp.float32(0.0),
        round=jnp.int32(0),
    )


def _plan_once(state: FludeState, caches: C.ClientCaches,
               online: jax.Array, X, cfg: FLConfig, rng,
               explore_hints=None) -> RoundPlan:
    sel = SEL.select_participants(
        state.belief, state.part_count, state.explored, online,
        state.total_selected, X, state.epsilon, cfg.sigma, rng,
        explore_hints=explore_hints, mode=cfg.selection_mode)
    stale = C.staleness(caches, state.round)
    plan = D.plan_distribution(
        state.distributor, sel.selected, state.in_v, C.has_cache(caches),
        stale, lam=cfg.lam, mu=cfg.mu, w_min=cfg.w_min, w_max=cfg.w_max,
        mode=cfg.distribution_mode)
    r_sel = jnp.where(sel.selected, dependability(state.belief), 0.0)
    n_sel = jnp.maximum(sel.selected.sum(), 1)
    r_bar = r_sel.sum() / n_sel
    cost = D.predicted_comm_cost(plan.distribute, sel.selected, r_bar)
    # floor: with quorum = ceil(|S|·R̄), ~half the rounds have fewer
    # successes than the quorum and idle-wait the full deadline T —
    # exactly the waste Algorithm 2 is designed to avoid
    quorum = jnp.maximum(jnp.floor(sel.selected.sum() * r_bar), 1.0)
    return RoundPlan(sel.selected, plan.distribute, plan.resume, cost,
                     quorum, r_bar, sel.priority, plan.state)


def plan_round(state: FludeState, caches: C.ClientCaches,
               online: jax.Array, cfg: FLConfig, rng,
               max_budget_iters: int = 8,
               explore_hints=None) -> RoundPlan:
    """Algorithm 2 lines 3–11: shrink X until B_pred ≤ B_max.

    ``explore_hints``: optional (N,) device-status scores (battery ×
    stability) biasing exploration order — §4.1's optional heuristic."""
    X = jnp.minimum(jnp.int32(cfg.clients_per_round), online.sum())
    plan = _plan_once(state, caches, online, X, cfg, rng, explore_hints)
    if cfg.comm_budget == float("inf"):
        return plan
    b_max = jnp.float32(cfg.comm_budget)
    for _ in range(max_budget_iters):
        X = jnp.where(plan.predicted_cost > b_max,
                      jnp.maximum(
                          (X * b_max / jnp.maximum(plan.predicted_cost, 1e-9)
                           ).astype(jnp.int32), 1),
                      X)
        plan = _plan_once(state, caches, online, X, cfg, rng,
                          explore_hints)
    return plan


def receive_quorum(plan: RoundPlan) -> jax.Array:
    """Line 15 cutoff: the round ends after ⌈|S|·R̄⌉ received uploads."""
    return plan.quorum


def update_after_round(state: FludeState, plan: RoundPlan,
                       received: jax.Array, cfg: FLConfig) -> FludeState:
    """Post-round bookkeeping.  received: (N,) bool — uploaded in time."""
    sel = plan.selected
    success = sel & received
    failure = sel & ~received
    belief = BetaBelief(state.belief.alpha + success.astype(jnp.float32),
                        state.belief.beta + failure.astype(jnp.float32))
    explored = state.explored | sel
    in_v = jnp.where(sel, failure, state.in_v)
    return FludeState(
        belief=belief,
        part_count=state.part_count + sel.astype(jnp.int32),
        explored=explored,
        in_v=in_v,
        distributor=plan.distributor,
        epsilon=SEL.decay_epsilon(state.epsilon, cfg.epsilon_decay,
                                  cfg.epsilon_min),
        total_selected=state.total_selected
        + sel.sum().astype(jnp.float32),
        round=state.round + 1,
    )
