"""C5 — the FLUDE round process (paper §4.4, Algorithm 2), server side.

``plan_round`` runs lines 3–12: budget-adaptive participant count X,
Algorithm-1 selection, staleness-aware distribution, predicted comm cost.
``update_after_round`` runs the post-aggregation bookkeeping: Beta-posterior
updates (Eq. 1), participation counters (Eq. 3 numerator), U/V membership,
ε decay.  Both are pure jnp over fixed-shape fleet arrays.

``make_server_round_step`` builds the fused per-round server step: weight
computation (incl. staleness discount), packed single-kernel aggregation,
and cache write/clear in ONE jitted call — the per-round hot path (§4.3)
stays on device with no per-leaf dispatch or host round-trips.

Round *termination* (lines 13–16: first |S|·R̄ uploads or deadline T)
lives here too: ``host_round_cut`` is the numpy reference (the legacy
host-RNG loop still runs it), ``make_round_cut`` is the jitted
device-resident equivalent the engine's dynamics loop dispatches — the
cut, billed duration and receive mask never leave the device, which is
what lets the loop pipeline rounds (``FLConfig.pipeline_depth``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import aggregation as AGG
from repro.core import caching as C
from repro.core import distribution as D
from repro.core import selection as SEL
from repro.core.dependability import BetaBelief, dependability, init_belief


class FludeState(NamedTuple):
    """Full server-side fleet state (a jit-able pytree)."""
    belief: BetaBelief
    part_count: jax.Array       # (N,) int32 — q_i
    explored: jax.Array         # (N,) bool — C
    in_v: jax.Array             # (N,) bool — failed last participation
    distributor: D.DistributorState
    epsilon: jax.Array          # scalar
    total_selected: jax.Array   # scalar — Σ_k |S_k|
    round: jax.Array            # scalar int32


class FludePlan(NamedTuple):
    selected: jax.Array         # (N,) bool — S
    distribute: jax.Array       # (N,) bool — S_distr (fresh global model)
    resume: jax.Array           # (N,) bool — train from local cache
    predicted_cost: jax.Array   # scalar — B_pred (model transmissions)
    quorum: jax.Array           # scalar — |S| · R̄ receive cutoff
    avg_dependability: jax.Array
    priority: jax.Array         # (N,) — P(i), for logging
    distributor: D.DistributorState


def init_state(cfg: FLConfig) -> FludeState:
    return FludeState(
        belief=init_belief(cfg.num_clients, cfg.beta_alpha0, cfg.beta_beta0),
        part_count=jnp.zeros((cfg.num_clients,), jnp.int32),
        explored=jnp.zeros((cfg.num_clients,), bool),
        in_v=jnp.zeros((cfg.num_clients,), bool),
        distributor=D.init_distributor(cfg.w_init),
        epsilon=jnp.float32(cfg.epsilon_init),
        total_selected=jnp.float32(0.0),
        round=jnp.int32(0),
    )


def _plan_once(state: FludeState, caches: C.ClientCaches,
               online: jax.Array, X, cfg: FLConfig, rng,
               explore_hints=None) -> FludePlan:
    sel = SEL.select_participants(
        state.belief, state.part_count, state.explored, online,
        state.total_selected, X, state.epsilon, cfg.sigma, rng,
        explore_hints=explore_hints, mode=cfg.selection_mode)
    stale = C.staleness(caches, state.round)
    plan = D.plan_distribution(
        state.distributor, sel.selected, state.in_v, C.has_cache(caches),
        stale, lam=cfg.lam, mu=cfg.mu, w_min=cfg.w_min, w_max=cfg.w_max,
        mode=cfg.distribution_mode)
    r_sel = jnp.where(sel.selected, dependability(state.belief), 0.0)
    n_sel = jnp.maximum(sel.selected.sum(), 1)
    r_bar = r_sel.sum() / n_sel
    cost = D.predicted_comm_cost(plan.distribute, sel.selected, r_bar)
    # floor: with quorum = ceil(|S|·R̄), ~half the rounds have fewer
    # successes than the quorum and idle-wait the full deadline T —
    # exactly the waste Algorithm 2 is designed to avoid
    quorum = jnp.maximum(jnp.floor(sel.selected.sum() * r_bar), 1.0)
    return FludePlan(sel.selected, plan.distribute, plan.resume, cost,
                     quorum, r_bar, sel.priority, plan.state)


def plan_round(state: FludeState, caches: C.ClientCaches,
               online: jax.Array, cfg: FLConfig, rng,
               max_budget_iters: int = 8,
               explore_hints=None) -> FludePlan:
    """Algorithm 2 lines 3–11: shrink X until B_pred ≤ B_max.

    ``explore_hints``: optional (N,) device-status scores (battery ×
    stability) biasing exploration order — §4.1's optional heuristic."""
    X = jnp.minimum(jnp.int32(cfg.clients_per_round), online.sum())
    plan = _plan_once(state, caches, online, X, cfg, rng, explore_hints)
    if cfg.comm_budget == float("inf"):
        return plan
    b_max = jnp.float32(cfg.comm_budget)
    for _ in range(max_budget_iters):
        X = jnp.where(plan.predicted_cost > b_max,
                      jnp.maximum(
                          (X * b_max / jnp.maximum(plan.predicted_cost, 1e-9)
                           ).astype(jnp.int32), 1),
                      X)
        plan = _plan_once(state, caches, online, X, cfg, rng,
                          explore_hints)
    return plan


def make_server_round_step(template_params, *, local_steps: int,
                           agg_impl: str = "xla",
                           agg_rule: str = "mean",
                           agg_rule_params: tuple = (),
                           adversary_scale: Optional[float] = None,
                           staleness_discount: float = 1.0,
                           uses_cache: bool = True,
                           block_c: int = 8, block_d: int = 2048,
                           mesh=None, donate: bool = False,
                           cohort_size: Optional[int] = None,
                           cache_offload: Optional[str] = None):
    """Build the fused per-round server step (one jit, zero host syncs).

    The returned callable runs everything the server does between "uploads
    arrived" and "next round plans": aggregation weights (sample-count ×
    staleness discount for resumed bases, §4.3), the packed whole-model
    weighted aggregation, and C3 cache bookkeeping (write failed devices'
    progress, clear received slots).

    template_params: the *unstacked* global model pytree — fixes the packed
    (C, D) layout once.  ``uses_cache=False`` policies get an identity
    cache path (compiled out).

    ``mesh``: optional fleet mesh with a ``clients`` axis — the packed
    (C, D) buffer aggregates as per-shard partial sums + psum and the
    cache bookkeeping stays sharded.  ``donate=True`` donates the previous
    global model and the caches: every output (new global, new caches)
    then aliases a donated input and the step allocates nothing persistent
    — the packed (C, D) buffer lives only as jit-internal workspace.  The
    stacked trainer outputs are deliberately NOT donated: the one stacked
    output slot (new cache params) is already served by the donated
    caches, so donating them could never alias and would only raise
    jax's unusable-donation warning.  Donated host handles (the caller's
    previous global/caches references) are invalidated by the call.

    ``cohort_size``: static X switches to the compact-cohort variant: the
    stacked trainer outputs arrive as dense (X, ...) blocks plus the (X,)
    cohort index, aggregation packs and reduces an (X, D) buffer instead
    of (N, D), and the C3 cache bookkeeping scatters back into the (N,)
    fleet state (predicated ``.at[].set(mode="drop")`` writes — sentinel
    and masked-off rows touch nothing).  Weighted aggregation over the
    gathered rows is the same sequential fp32 reduction over the same
    nonzero-weight terms, so a single-device compact round is
    bit-identical to the full scan; under a client mesh cohort members
    regroup across shards and the psum reassociates (integer trajectory
    exact, accuracies to float tolerance — same contract as the sharded
    full scan vs single device).

    ``agg_rule`` / ``agg_rule_params``: the robust-aggregation axis
    (``repro.core.agg_rules``), orthogonal to ``agg_impl``.  The default
    ``"mean"`` keeps the historical direct ``fed_aggregate_packed`` call
    — the traced jaxpr (and therefore the trajectory) is bit-identical
    to the pre-registry step.  Non-mean rules pack the stacked trainer
    outputs once and run the rule's reduction on the (C, D) buffer; a
    *stateful* rule ("trust") appends one (N,) state input and output to
    the jitted signature — the engine threads it like the caches, so
    rounds still sync nothing.

    ``adversary_scale``: when set, the returned step additionally takes
    an (N,) malicious mask (appended after ``rnd``, before any rule
    state) and transforms the marked clients' uploads inside the jit:
    ``u' = g + adversary_scale * (u - g)`` — the model-poisoning channel
    of ``repro.fleet.adversary``.  Benign runs compile the attack out.

    ``cache_offload`` (cohort variant only): the host-offloaded cache
    path.  ``caches`` then carries *metadata only* (params is an empty
    pytree — the (N, D) slots live in ``repro.core.cache_store``), the
    ``cache_params`` argument is dropped (the engine streams the
    trainer's (X, ...) cache block to host itself) and the step returns
    ``(new_global, new_caches_meta, write, base_round[, rule_state])``
    — ``write``/``base_round`` are the (X,) cache-write mask and round
    stamps the engine's write-back stages to the host store.  The
    weight math, aggregation and metadata scatters are the exact ops of
    the resident cohort step, so trajectories are bit-identical.
    """
    layout = AGG.pack_layout(template_params)
    donate_argnums = (0, 1) if donate else ()
    rule = None
    if agg_rule not in (None, "mean"):
        from repro.core.agg_rules import make_agg_rule
        rule = make_agg_rule(agg_rule, agg_rule_params)
    stateful = rule is not None and rule.stateful
    has_adv = adversary_scale is not None

    def poison(final_params, global_params, mal_rows):
        """Model-poisoning transform on the malicious rows (stacked
        leaves), against the round's base model."""
        s = float(adversary_scale)

        def pz(f, g):
            m = mal_rows.reshape((-1,) + (1,) * (f.ndim - 1))
            g32 = g.astype(jnp.float32)[None]
            return jnp.where(m, (g32 + s * (f.astype(jnp.float32) - g32))
                             .astype(f.dtype), f)

        return jax.tree.map(pz, final_params, global_params)

    def aggregate(global_params, final_params, w, rule_state):
        """Dispatch the configured rule.  Returns ``(new_global,
        new_rule_state)`` — state rows pass through untouched for
        stateless rules."""
        if rule is None:
            new_global = AGG.fed_aggregate_packed(
                global_params, final_params, w, layout, impl=agg_impl,
                block_c=block_c, block_d=block_d, mesh=mesh)
            return new_global, rule_state
        buf = AGG.pack_stacked(final_params, layout)     # (C, D) fp32
        gvec = AGG.pack(global_params, layout)           # (D,) fp32
        kw = dict(impl=agg_impl, block_c=block_c, block_d=block_d,
                  mesh=mesh)
        if stateful:
            vec, rule_state = rule.reduce_stateful(buf, gvec, w,
                                                   rule_state, **kw)
        else:
            vec = rule.reduce(buf, gvec, w, **kw)
        any_received = w.sum() > 0
        new_global = jax.tree.map(
            lambda avg, g: jnp.where(any_received, avg, g),
            AGG.unpack(vec, layout), global_params)
        return new_global, rule_state

    def split_extra(extra):
        """(malicious, rule_state) from the trailing jit args."""
        expect = int(has_adv) + int(stateful)
        if len(extra) != expect:
            raise TypeError(
                f"server round step expects {expect} trailing arg(s) "
                f"(adversary mask: {has_adv}, rule state: {stateful}), "
                f"got {len(extra)}")
        malicious = extra[0] if has_adv else None
        rule_state = extra[-1] if stateful else None
        return malicious, rule_state

    if cache_offload is not None and cohort_size is None:
        raise ValueError("cache_offload requires the cohort server-step "
                         "variant (pass cohort_size)")

    if cohort_size is not None and cache_offload is not None:
        @functools.partial(jax.jit, donate_argnums=donate_argnums)
        def server_round_step_cohort_offload(global_params,
                                             caches: C.ClientCaches,
                                             final_params, cached_steps,
                                             idx, selected, fail,
                                             received, resume, n_samples,
                                             extra_weights, rnd, *extra):
            """-> (new_global, new_caches_meta, write, base_round
            [, new_rule_state]).

            The host-offload twin of ``server_round_step_cohort``:
            ``caches`` is the metadata-only ClientCaches (empty params
            pytree), and instead of scattering the cohort's cache params
            back into a resident (N, D) pytree the step returns the (X,)
            write mask and base-round stamps — the engine stages the
            trainer's cache block to the host store with them.  Every
            weight / aggregation / metadata op is identical to the
            resident cohort step.
            """
            from repro.sharding import partitioning as SP

            malicious, rule_state = split_extra(extra)
            rnd = jnp.asarray(rnd, jnp.int32)

            def take(a, fill):
                return jnp.take(a, idx, axis=0, mode="fill",
                                fill_value=fill)

            selected = take(selected, False)              # (X,)
            resume = take(resume, False)
            stamp = take(caches.round_stamp, -1)          # (X,)
            base_stale = jnp.where(resume & (stamp >= 0),
                                   jnp.maximum(rnd - stamp, 0),
                                   0).astype(jnp.float32)
            w = AGG.aggregation_weights(
                received, n_samples=take(n_samples, 0.0),
                staleness=base_stale,
                staleness_discount=staleness_discount) \
                * take(extra_weights, 0.0)
            w = SP.cohort_constraint(w, mesh, cohort_size)
            if has_adv:
                mal_x = SP.cohort_constraint(take(malicious, False),
                                             mesh, cohort_size)
                final_params = poison(final_params, global_params, mal_x)
            state_x = None
            if stateful:
                state_x = SP.cohort_constraint(take(rule_state, 0.0),
                                               mesh, cohort_size)
            new_global, state_x = aggregate(global_params, final_params,
                                            w, state_x)
            if stateful:
                rule_state = rule_state.at[idx].set(state_x, mode="drop")
                rule_state = SP.cohort_scatter_constraint(
                    rule_state, mesh, rule_state.shape[0])
            if uses_cache:
                prior_steps = jnp.round(
                    take(caches.progress, 0.0) * local_steps
                ).astype(jnp.int32)
                total_cached = jnp.where(resume, prior_steps, 0) \
                    + cached_steps
                write = selected & fail & (total_cached > 0)
                base_round = jnp.where(resume & (stamp >= 0), stamp, rnd)
                # metadata-only scatters: the params pytree is empty, so
                # the same predicated writes the resident step runs
                # touch only progress / round_stamp
                caches = C.scatter_write_cache(
                    caches, idx, write, caches.params,
                    (total_cached / max(local_steps, 1)
                     ).astype(jnp.float32), base_round)
                caches = C.scatter_clear_cache(caches, idx, received)
                caches = SP.cohort_scatter_constraint(
                    caches, mesh, caches.progress.shape[0])
            else:
                write = jnp.zeros((cohort_size,), bool)
                base_round = jnp.full((cohort_size,), -1, jnp.int32)
            write, base_round = SP.cohort_constraint(
                (write, base_round), mesh, cohort_size)
            if stateful:
                return new_global, caches, write, base_round, rule_state
            return new_global, caches, write, base_round

        return server_round_step_cohort_offload

    if cohort_size is not None:
        @functools.partial(jax.jit, donate_argnums=donate_argnums)
        def server_round_step_cohort(global_params,
                                     caches: C.ClientCaches,
                                     final_params, cache_params,
                                     cached_steps, idx, selected, fail,
                                     received, resume, n_samples,
                                     extra_weights, rnd, *extra):
            """-> (new_global_params, new_caches[, new_rule_state]).

            final_params / cache_params / cached_steps and the
            ``fail``/``received`` masks are (X,)-leading cohort blocks
            (trainer / round-cut outputs); ``idx`` is the (X,) cohort
            index (sentinel-padded).  ``selected``/``resume`` arrive as
            the (N,) plan masks the engine holds and are gathered here;
            caches / n_samples / extra_weights stay (N,)-sized — the
            only fleet-proportional state the step touches.  ``extra``
            appends the (N,) malicious mask (adversary configured) and
            the (N,) rule state (stateful rule) — both gathered here
            and, for the state, scattered back.
            """
            from repro.sharding import partitioning as SP

            malicious, rule_state = split_extra(extra)
            rnd = jnp.asarray(rnd, jnp.int32)

            def take(a, fill):
                return jnp.take(a, idx, axis=0, mode="fill",
                                fill_value=fill)

            selected = take(selected, False)              # (X,)
            resume = take(resume, False)
            stamp = take(caches.round_stamp, -1)          # (X,)
            base_stale = jnp.where(resume & (stamp >= 0),
                                   jnp.maximum(rnd - stamp, 0),
                                   0).astype(jnp.float32)
            w = AGG.aggregation_weights(
                received, n_samples=take(n_samples, 0.0),
                staleness=base_stale,
                staleness_discount=staleness_discount) \
                * take(extra_weights, 0.0)
            w = SP.cohort_constraint(w, mesh, cohort_size)
            if has_adv:
                mal_x = SP.cohort_constraint(take(malicious, False),
                                             mesh, cohort_size)
                final_params = poison(final_params, global_params, mal_x)
            state_x = None
            if stateful:
                state_x = SP.cohort_constraint(take(rule_state, 0.0),
                                               mesh, cohort_size)
            new_global, state_x = aggregate(global_params, final_params,
                                            w, state_x)
            if stateful:
                rule_state = rule_state.at[idx].set(state_x, mode="drop")
                rule_state = SP.cohort_scatter_constraint(
                    rule_state, mesh, rule_state.shape[0])
            if uses_cache:
                prior_steps = jnp.round(
                    take(caches.progress, 0.0) * local_steps
                ).astype(jnp.int32)
                total_cached = jnp.where(resume, prior_steps, 0) \
                    + cached_steps
                write = selected & fail & (total_cached > 0)
                base_round = jnp.where(resume & (stamp >= 0), stamp, rnd)
                caches = C.scatter_write_cache(
                    caches, idx, write, cache_params,
                    (total_cached / max(local_steps, 1)
                     ).astype(jnp.float32), base_round)
                caches = C.scatter_clear_cache(caches, idx, received)
                caches = SP.cohort_scatter_constraint(
                    caches, mesh, caches.progress.shape[0])
            if stateful:
                return new_global, caches, rule_state
            return new_global, caches

        return server_round_step_cohort

    @functools.partial(jax.jit, donate_argnums=donate_argnums)
    def server_round_step(global_params, caches: C.ClientCaches,
                          final_params, cache_params, cached_steps,
                          selected, fail, received, resume,
                          n_samples, extra_weights, rnd, *extra):
        """-> (new_global_params, new_caches[, new_rule_state]).

        final_params / cache_params: stacked (N, ...) trainer outputs.
        selected/fail/received/resume: (N,) bool round masks.
        extra_weights: (N,) policy weight multiplier (ones if unused).
        rnd: scalar int32 — current round index.
        extra: the (N,) malicious mask (adversary configured) then the
        (N,) rule state (stateful rule) — see the factory docstring.
        """
        malicious, rule_state = split_extra(extra)
        rnd = jnp.asarray(rnd, jnp.int32)
        stamp = caches.round_stamp
        # staleness of the BASE model each update was trained from
        base_stale = jnp.where(resume & (stamp >= 0),
                               jnp.maximum(rnd - stamp, 0),
                               0).astype(jnp.float32)
        w = AGG.aggregation_weights(
            received, n_samples=n_samples, staleness=base_stale,
            staleness_discount=staleness_discount) * extra_weights
        if has_adv:
            final_params = poison(final_params, global_params, malicious)
        new_global, rule_state = aggregate(global_params, final_params,
                                           w, rule_state)
        if uses_cache:
            prior_steps = jnp.round(
                caches.progress * local_steps).astype(jnp.int32)
            total_cached = jnp.where(resume, prior_steps, 0) + cached_steps
            write = selected & fail & (total_cached > 0)
            base_round = jnp.where(resume & (stamp >= 0), stamp, rnd)
            caches = C.write_cache(
                caches, write, cache_params,
                (total_cached / max(local_steps, 1)).astype(jnp.float32),
                base_round)
            caches = C.clear_cache(caches, received)
        if stateful:
            return new_global, caches, rule_state
        return new_global, caches

    return server_round_step


def host_round_cut(times, quorum, round_deadline: float,
                   waits_for_stragglers: bool):
    """Round termination (Algorithm 2 lines 13–16), numpy reference.

    ``times``: (N,) per-device finish times, inf where the device never
    uploads.  The round closes at the ``ceil(quorum)``-th upload (capped
    by the deadline T); async/semi-async designs
    (``waits_for_stragglers=False``) close at the last arrival when the
    quorum is not met; otherwise the server idle-waits the full deadline.
    Returns ``(t_cut, duration)`` — ``duration`` is the billed round wall
    clock (always finite when the deadline is).
    """
    times = np.asarray(times)
    q = int(np.ceil(float(quorum)))
    finite = np.sort(times[np.isfinite(times)])
    if finite.size >= q and q > 0:
        t_cut = min(float(finite[q - 1]), round_deadline)
    elif not waits_for_stragglers and finite.size > 0:
        t_cut = min(float(finite[-1]), round_deadline)
    else:
        t_cut = round_deadline
    duration = t_cut if np.isfinite(t_cut) else round_deadline
    return t_cut, duration


def make_round_cut(num_clients: int, round_deadline: float,
                   waits_for_stragglers: bool, mesh=None,
                   scatter_num_clients: Optional[int] = None,
                   with_counts: bool = False):
    """Build the jitted device-resident round cut (lines 13–16).

    Semantically identical to :func:`host_round_cut` — and bit-identical
    on float32 times (property-tested in tests/test_round_close*.py):
    uncapped cuts are exact float32 arrival times, and deadline-capped
    rounds are flagged instead of billed in float32.  The returned
    callable maps ``(times, quorum, success)`` to ``(t_cut, received,
    capped)``:

    * ``t_cut`` — float32 device scalar; the billed host-side duration is
      ``round_deadline if capped else float(t_cut)`` (the host reference
      bills the *float64* deadline, which float32 cannot always
      represent — e.g. ``round_deadline=100.3`` — so the cap is returned
      as a flag and the ledger substitutes the exact config value);
    * ``received`` — the (N,) receive mask, pinned to the client-mesh
      sharding when ``mesh`` is given.  Deadline-capped rounds compare
      against the float32-*nearest* cast of the deadline — exactly what
      the pre-pipelining loop's jitted ``times <= cut`` did with the
      host's float64 cut, so depth-1 receive masks stay bit-identical;
    * ``capped`` — bool device scalar: the round idle-waited (or closed
      at) the deadline rather than an arrival.  The flag itself is exact
      (``t > deadline`` decided via the largest float32 ≤ deadline).

    Everything stays on device, so the engine can dispatch the server
    step — and further rounds — without draining the device queue.
    ``waits_for_stragglers`` is a static policy trait: the async variant
    compiles the extra close-at-last-arrival branch in, the sync variant
    compiles it out.

    ``scatter_num_clients``: compact-cohort variant.  ``num_clients`` is
    then the static cohort size X — ``times``/``success`` arrive as (X,)
    gathered blocks — and the returned callable additionally takes the
    (X,) cohort index ``idx`` and returns ``(t_cut, received,
    received_full, capped)`` where ``received_full`` is the (N,) receive
    mask scattered back onto the fleet (sentinel rows dropped).  The cut
    itself is exact: every finite finish time belongs to a selected
    client, selected ⊆ cohort, so the order statistics over the X rows
    equal those over the full N — bit-identical even under a mesh.

    ``with_counts``: fuse the round's three History ledger reductions
    into the cut dispatch.  The callable then takes three trailing (N,)
    masks ``(online, distribute, selected)`` and appends the device
    scalars ``(received_count, download_count, selected_count)`` to its
    outputs (``download_count`` is ``(distribute & online).sum()`` —
    ``FleetDraw.download_mask`` inlined).  This removes the separate
    per-round ledger-counts dispatch: everything the deferred History
    needs leaves the cut as O(1) replicated device scalars.
    """
    deadline = float(round_deadline)
    # nearest float32 (what the old received_fn's weak f64->f32 cast did)
    d_cmp = np.float32(deadline)
    # largest float32 <= deadline: for float32 t, (t > d_flag) == (t > d)
    d_flag = d_cmp
    if float(d_flag) > deadline:
        d_flag = np.nextafter(d_flag, np.float32(-np.inf))

    def cut_core(times, quorum, success):
        q = jnp.ceil(jnp.asarray(quorum, jnp.float32)).astype(jnp.int32)
        order = jnp.sort(times)                   # inf sorts to the end
        finite_count = jnp.isfinite(times).sum()
        t_quorum = order[jnp.clip(q - 1, 0, num_clients - 1)]
        has_quorum = (finite_count >= q) & (q > 0)
        t_raw = jnp.where(has_quorum, t_quorum, jnp.inf)
        if not waits_for_stragglers:
            # async/semi-async designs close at the last arrival
            t_last = order[jnp.clip(finite_count - 1, 0, num_clients - 1)]
            t_raw = jnp.where(~has_quorum & (finite_count > 0), t_last,
                              t_raw)
        capped = t_raw > d_flag
        t_cut = jnp.where(capped, d_cmp, t_raw)
        received = success & (times <= t_cut)
        return t_cut, received, capped

    def ledger_counts(received_rows, online, distribute, selected):
        """The three (N,)→scalar History reductions, fused into the cut
        (``received_rows`` may be the (X,) cohort block — sentinel rows
        are never received, so its sum equals the fleet sum)."""
        from repro.sharding import partitioning as SP
        counts = (received_rows.sum(), (distribute & online).sum(),
                  selected.sum())
        return SP.replicated_constraint(counts, mesh)

    if scatter_num_clients is not None:
        @jax.jit
        def round_cut_cohort(times, quorum, success, idx, *masks):
            from repro.sharding import partitioning as SP
            t_cut, received, capped = cut_core(times, quorum, success)
            received_full = jnp.zeros((scatter_num_clients,), bool) \
                .at[idx].set(received, mode="drop")
            if mesh is not None:
                received = SP.cohort_constraint(received, mesh,
                                                num_clients)
                received_full = SP.cohort_scatter_constraint(
                    received_full, mesh, scatter_num_clients)
                t_cut, capped = SP.replicated_constraint(
                    (t_cut, capped), mesh)
            if with_counts:
                return (t_cut, received, received_full, capped) \
                    + ledger_counts(received, *masks)
            return t_cut, received, received_full, capped

        return round_cut_cohort

    @jax.jit
    def round_cut(times, quorum, success, *masks):
        t_cut, received, capped = cut_core(times, quorum, success)
        if mesh is not None:
            from repro.sharding import partitioning as SP
            received = SP.fleet_constraint(received, mesh, num_clients)
            t_cut, capped = SP.replicated_constraint((t_cut, capped),
                                                     mesh)
        if with_counts:
            return (t_cut, received, capped) \
                + ledger_counts(received, *masks)
        return t_cut, received, capped

    return round_cut


def receive_quorum(plan: FludePlan) -> jax.Array:
    """Line 15 cutoff: the round ends after ⌈|S|·R̄⌉ received uploads."""
    return plan.quorum


def update_after_round(state: FludeState, plan: FludePlan,
                       received: jax.Array, cfg: FLConfig) -> FludeState:
    """Post-round bookkeeping.  received: (N,) bool — uploaded in time."""
    sel = plan.selected
    success = sel & received
    failure = sel & ~received
    belief = BetaBelief(state.belief.alpha + success.astype(jnp.float32),
                        state.belief.beta + failure.astype(jnp.float32))
    explored = state.explored | sel
    in_v = jnp.where(sel, failure, state.in_v)
    return FludeState(
        belief=belief,
        part_count=state.part_count + sel.astype(jnp.int32),
        explored=explored,
        in_v=in_v,
        distributor=plan.distributor,
        epsilon=SEL.decay_epsilon(state.epsilon, cfg.epsilon_decay,
                                  cfg.epsilon_min),
        total_selected=state.total_selected
        + sel.sum().astype(jnp.float32),
        round=state.round + 1,
    )
