"""C2 — adaptive device selection (paper §4.1, Algorithm 1, Eqs. 2–3).

Priority:  P(i) = R(i) · (Q / q_i)^(1(Q < q_i) · σ)       (Eq. 2)
Threshold: Q = Σ_k |S_k| / |A|                            (Eq. 3)

ε-greedy bandit: exploit the top-priority (1-ε)·X explored devices, explore
ε·X uniformly among never-explored devices.  Everything is fixed-shape jnp
so the whole selector jits (dynamic counts are realized as rank thresholds).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.dependability import BetaBelief, dependability

NEG = -1e30


class SelectionResult(NamedTuple):
    selected: jax.Array       # (N,) bool — S
    exploited: jax.Array      # (N,) bool
    explored_new: jax.Array   # (N,) bool — O (newly explored this round)
    priority: jax.Array       # (N,) float32 — P(i) (for logging/tests)


def freq_threshold(total_selected, num_devices) -> jax.Array:
    """Eq. (3): average per-device frequency under uniform random picks."""
    return total_selected / jnp.maximum(num_devices, 1)


def priority(belief: BetaBelief, part_count: jax.Array, Q,
             sigma: float) -> jax.Array:
    """Eq. (2).  part_count q_i == 0 never exceeds Q, so the factor is 1."""
    R = dependability(belief)
    q = part_count.astype(jnp.float32)
    ratio = jnp.where(q > 0, Q / jnp.maximum(q, 1e-9), 1.0)
    exceeds = (q > Q).astype(jnp.float32)
    penalty = jnp.power(jnp.maximum(ratio, 1e-9), exceeds * sigma)
    return R * penalty


def _rank_mask(scores: jax.Array, k) -> jax.Array:
    """Boolean mask of the top-k scores (k may be a traced scalar)."""
    order = jnp.argsort(-scores)
    ranks = jnp.zeros_like(order).at[order].set(jnp.arange(scores.shape[0]))
    return (ranks < k) & (scores > NEG / 2)


def select_participants(belief: BetaBelief, part_count: jax.Array,
                        explored: jax.Array, online: jax.Array,
                        total_selected, X, epsilon, sigma: float,
                        rng, explore_hints=None,
                        mode: str = "mean") -> SelectionResult:
    """Algorithm 1.  X may be traced (budget-adapted by Algorithm 2).

    - exploit (1-ε)·X among explored ∩ online, by priority (Eq. 2)
    - explore ε·X among (not explored) ∩ online — uniformly at random, or
      biased by ``explore_hints`` (paper §4.1: "one can also explore new
      devices characterized by low CPU/GPU usage, high battery level":
      higher hint ⇒ explored earlier)
    - ``mode="thompson"`` replaces the posterior MEAN in Eq. 2 with a
      Thompson sample R(i) ~ Beta(α_i, β_i) — a beyond-paper variant that
      keeps probing uncertain devices even after ε decays (see
      benchmarks/bench_beyond.py)
    - if the explore pool is too small, the exploit share absorbs the rest
      (and vice versa), so |S| == min(X, |online|).
    """
    N = online.shape[0]
    Q = freq_threshold(total_selected, N)
    if mode == "thompson":
        rng, k_ts = jax.random.split(rng)
        from repro.core.dependability import sample_dependability
        R = sample_dependability(BetaBelief(belief.alpha, belief.beta),
                                 k_ts)
        q = part_count.astype(jnp.float32)
        ratio = jnp.where(q > 0, Q / jnp.maximum(q, 1e-9), 1.0)
        exceeds = (q > Q).astype(jnp.float32)
        P = R * jnp.power(jnp.maximum(ratio, 1e-9), exceeds * sigma)
    else:
        P = priority(belief, part_count, Q, sigma)

    X = jnp.minimum(X, online.sum())
    n_explore_want = jnp.round(epsilon * X).astype(jnp.int32)
    pool_explore = (~explored) & online
    pool_exploit = explored & online
    n_explore = jnp.minimum(n_explore_want, pool_explore.sum())
    n_exploit = jnp.minimum(X - n_explore, pool_exploit.sum())
    # re-grow explore if exploit pool was short
    n_explore = jnp.minimum(X - n_exploit, pool_explore.sum())

    exploit_scores = jnp.where(pool_exploit, P, NEG)
    exploited = _rank_mask(exploit_scores, n_exploit)

    noise = jax.random.uniform(rng, (N,))
    if explore_hints is not None:
        # status-aware exploration (§4.1 optional): rank by hint, noise
        # only breaks ties
        noise = explore_hints.astype(jnp.float32) + 0.01 * noise
    explore_scores = jnp.where(pool_explore, noise, NEG)
    explored_new = _rank_mask(explore_scores, n_explore)

    return SelectionResult(exploited | explored_new, exploited,
                           explored_new, P)


def decay_epsilon(epsilon, decay: float, floor: float):
    """Paper §5.2: ε ← ε·0.98 while ε > 0.2."""
    return jnp.maximum(epsilon * decay, floor)
