"""Synthetic federated datasets.

Two task families mirror the paper's evaluation:

* ``federated_classification`` — a Gaussian-mixture multi-class task with
  label-shard non-IID partitioning (each client holds ``classes_per_client``
  classes, paper §2.2: "each device holds 2 classes").  Stands in for
  CIFAR-10/100 and Google Speech.
* ``lm_dataset`` — token streams with planted bigram structure so a causal
  LM's loss actually decreases; non-IID via per-client vocabulary shards.
  Used by the transformer examples/driver.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class FederatedClassification(NamedTuple):
    x: np.ndarray           # (N_clients, n_per_client, dim)
    y: np.ndarray           # (N_clients, n_per_client)
    test_x: np.ndarray      # (n_test, dim)
    test_y: np.ndarray      # (n_test,)
    client_classes: np.ndarray  # (N_clients, classes_per_client)
    num_classes: int


def federated_classification(num_clients: int, *, num_classes: int = 10,
                             dim: int = 32, n_per_client: int = 128,
                             classes_per_client: int = 2,
                             n_test: int = 2048, margin: float = 2.2,
                             noise: float = 1.0, partition: str = "shard",
                             dirichlet_alpha: float = 0.3,
                             seed: int = 0) -> FederatedClassification:
    """partition="shard": each client holds ``classes_per_client`` classes
    (paper §2.2); partition="dirichlet": class mixture ~ Dir(α) per client
    (the other standard non-IID protocol)."""
    rng = np.random.RandomState(seed)
    centers = rng.randn(num_classes, dim) * margin

    def sample(cls, n):
        return (centers[cls][None] + noise * rng.randn(n, dim)
                ).astype(np.float32)

    xs, ys, ccls = [], [], []
    for i in range(num_clients):
        if partition == "dirichlet":
            probs = rng.dirichlet(
                np.full(num_classes, dirichlet_alpha))
            classes = np.argsort(-probs)[:classes_per_client]
            ccls.append(classes)
            y = rng.choice(num_classes, n_per_client, p=probs)
            x = np.stack([sample(c, 1)[0] for c in y])
            xs.append(x)
            ys.append(y)
            continue
        # anchor class round-robin guarantees every class is represented
        anchor = i % num_classes
        rest = rng.choice([c for c in range(num_classes) if c != anchor],
                          classes_per_client - 1, replace=False)
        classes = np.concatenate([[anchor], rest])
        ccls.append(classes)
        y = rng.choice(classes, n_per_client)
        x = np.stack([sample(c, 1)[0] for c in y])
        xs.append(x)
        ys.append(y)
    ty = rng.randint(0, num_classes, n_test)
    tx = np.stack([sample(c, 1)[0] for c in ty])
    return FederatedClassification(
        np.stack(xs), np.stack(ys).astype(np.int32),
        tx, ty.astype(np.int32), np.stack(ccls), num_classes)


class LMData(NamedTuple):
    tokens: np.ndarray       # (N_clients, n_seq, seq_len + 1)
    vocab_size: int


def lm_dataset(num_clients: int, *, vocab_size: int = 4096,
               seq_len: int = 128, n_seq: int = 32,
               shard_frac: float = 0.25, seed: int = 0) -> LMData:
    """Bigram-structured token streams; client i only emits tokens from its
    vocabulary shard (non-IID)."""
    rng = np.random.RandomState(seed)
    # global bigram successor table: tok -> 4 plausible next tokens
    succ = rng.randint(0, vocab_size, size=(vocab_size, 4))
    shard = max(int(vocab_size * shard_frac), 64)
    out = np.zeros((num_clients, n_seq, seq_len + 1), np.int32)
    for i in range(num_clients):
        lo = rng.randint(0, vocab_size - shard)
        for j in range(n_seq):
            t = rng.randint(lo, lo + shard)
            seq = [t]
            for _ in range(seq_len):
                if rng.rand() < 0.8:
                    t = succ[t, rng.randint(4)]
                else:
                    t = rng.randint(lo, lo + shard)
                seq.append(t)
            out[i, j] = seq
    return LMData(out, vocab_size)


class CTRData(NamedTuple):
    x: np.ndarray            # (N_clients, n, dim) — user×ad feature vectors
    y: np.ndarray            # (N_clients, n) — click labels {0, 1}
    test_x: np.ndarray
    test_y: np.ndarray
    num_classes: int


def ctr_dataset(num_clients: int, *, dim: int = 24, n_per_client: int = 96,
                n_test: int = 2048, seed: int = 0) -> CTRData:
    """Synthetic CTR task (the paper's Avazu/WideAndDeep stand-in).

    Each record is a user×ad interaction vector; the global click model is
    logistic in a sparse weight vector plus a per-device preference shift
    (deviceID-partitioned non-IID, like the paper's Avazu split)."""
    rng = np.random.RandomState(seed)
    w_true = rng.randn(dim) * (rng.rand(dim) < 0.4)      # sparse weights
    xs, ys = [], []
    for i in range(num_clients):
        shift = rng.randn(dim) * 0.6                     # device preference
        x = (rng.randn(n_per_client, dim) + shift).astype(np.float32)
        logits = x @ w_true + 0.5 * rng.randn(n_per_client)
        y = (1 / (1 + np.exp(-logits)) > rng.rand(n_per_client))
        xs.append(x)
        ys.append(y.astype(np.int32))
    tx = rng.randn(n_test, dim).astype(np.float32)
    ty = ((1 / (1 + np.exp(-(tx @ w_true))) > rng.rand(n_test))
          ).astype(np.int32)
    return CTRData(np.stack(xs), np.stack(ys), tx, ty, 2)


def auc(scores: np.ndarray, labels: np.ndarray) -> float:
    """Rank-based AUC (the paper's recommendation metric)."""
    order = np.argsort(scores)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(scores) + 1)
    pos = labels == 1
    n_pos, n_neg = pos.sum(), (~pos).sum()
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[pos].sum() - n_pos * (n_pos + 1) / 2)
                 / (n_pos * n_neg))


def batch_iterator(x: np.ndarray, y: np.ndarray, batch: int, seed: int = 0):
    """Simple epoch-shuffling batcher used by the single-host trainer."""
    rng = np.random.RandomState(seed)
    n = x.shape[0]
    while True:
        order = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            idx = order[i:i + batch]
            yield x[idx], y[idx]
