from repro.fl.simulator import Fleet, SimConfig
from repro.fl.api import (Policy, RoundObservation, RoundPlan, RoundReport,
                          available_policies, get_policy, make_policy,
                          register_policy)
from repro.fl.engine import FleetEngine, History, make_trainer
from repro.fl import policies  # noqa: F401 — registers the built-ins
from repro.fl.runner import run_fl
from repro.fleet import (available_dynamics,  # noqa: F401 — re-exported
                         available_scenarios, apply_scenario, get_dynamics,
                         get_scenario, make_dynamics, register_dynamics)
