from repro.fl.simulator import Fleet, SimConfig
from repro.fl.runner import History, run_fl, make_trainer
