"""Typed policy API for the cross-device FL runner.

The server policy loop — dependability-aware selection (Alg. 1),
staleness-aware distribution (Eq. 4), cache resume (C3) — speaks three
typed, jit-friendly pytree dataclasses instead of string-keyed dicts:

* ``RoundPlan``      — what the server decides *before* a round (who is
                       selected, who gets a fresh model, who resumes from
                       cache, the receive quorum, optional per-device step
                       counts and aggregation-weight multipliers);
* ``RoundObservation`` — what a policy may look at when planning (round
                       index, online mask, the device-resident caches,
                       static fleet features);
* ``RoundReport``    — what actually happened (received/fail masks, local
                       losses, per-device finish times, billed duration).

A ``Policy`` is a thin object holding static configuration; all mutable
state lives in an explicit ``PolicyState`` threaded through pure(-ish)
``plan``/``observe`` transitions so the engine — not the policy — owns the
loop.  Policies plug in through a decorator registry::

    @register_policy("my-policy")
    class MyPolicy(Policy):
        ...

and are instantiated by name via ``make_policy`` — no runner edits needed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.caching import ClientCaches
from repro.fl.simulator import Fleet, SimConfig

_BOOL_FIELDS = ("selected", "distribute", "resume")


def cohort_index(selected, cohort_size: int) -> jax.Array:
    """Device cohort index of a selection mask: the ascending client ids
    of the selected set, padded to the static ``cohort_size`` with the
    out-of-range sentinel N (= ``selected.shape[0]``).

    Traceable (fixed output shape), so the engine derives it *inside* the
    jitted round body — no host sync.  Sentinel entries make every
    ``mode="fill"`` gather read a benign default and every
    ``mode="drop"`` scatter skip the row, which is what keeps the compact
    (X, ...) round path bit-identical to the full scan.  When more than
    ``cohort_size`` clients are selected the index silently truncates to
    the lowest ids — pair with :func:`cohort_overflow` (the engine defers
    the flag through its round ledger and raises at readback).
    """
    sel = jnp.asarray(selected)
    return jnp.flatnonzero(sel, size=cohort_size,
                           fill_value=sel.shape[0])


def cohort_overflow(selected, cohort_size: int) -> jax.Array:
    """Device bool scalar: did the plan select more clients than the
    static cohort can hold (i.e. did :func:`cohort_index` truncate)?"""
    return jnp.asarray(selected).sum() > cohort_size


# ---------------------------------------------------------------------------
# Typed round messages
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RoundPlan:
    """Server-side decisions for one round (a jit-able pytree).

    selected/distribute/resume: (N,) bool masks.  ``quorum`` is the receive
    cutoff — the round closes after that many successful uploads (§4.4
    Alg. 2 line 15).  ``steps_override`` (optional, (N,) int) replaces the
    uniform ``local_steps`` workload; ``agg_weights`` (optional, (N,)
    float) multiplies the server aggregation weights.
    """
    selected: Any
    distribute: Any
    resume: Any
    quorum: Any
    steps_override: Optional[Any] = None
    agg_weights: Optional[Any] = None

    @classmethod
    def create(cls, selected, distribute, resume, quorum,
               steps_override=None, agg_weights=None,
               num_clients: Optional[int] = None) -> "RoundPlan":
        """Canonicalize + validate.  Host-side entry point: accepts numpy
        or jax arrays, coerces mask dtypes to bool, and runs the full
        shape/value validation (use the bare constructor inside jit where
        values are abstract)."""
        plan = cls(selected=np.asarray(selected, bool)
                   if not isinstance(selected, jax.Array)
                   else selected.astype(bool),
                   distribute=np.asarray(distribute, bool)
                   if not isinstance(distribute, jax.Array)
                   else distribute.astype(bool),
                   resume=np.asarray(resume, bool)
                   if not isinstance(resume, jax.Array)
                   else resume.astype(bool),
                   quorum=float(quorum),
                   steps_override=steps_override,
                   agg_weights=agg_weights)
        plan.validate(num_clients)
        object.__setattr__(plan, "_validated", True)
        return plan

    @classmethod
    def device(cls, selected, distribute, resume, quorum,
               steps_override=None, agg_weights=None) -> "RoundPlan":
        """Device-native construction for jnp policies.

        Runs the *structural* checks only (1-D bool masks of one length,
        int/float optionals of the same length) — shape and dtype are
        array metadata, so nothing syncs and ``quorum`` stays a device
        scalar.  This is what keeps a jitted policy's ``plan`` a pure
        dispatch: the engine's pipelined device loop can enqueue the
        round without draining the device queue.  The value invariants
        (quorum ≤ |selected|, resume ⊆ selected, override ≤ the trainer's
        scan length) are the caller's responsibility — built-in device
        policies guarantee them by construction, and the engine clamps
        the workload regardless.
        """
        plan = cls(selected=selected, distribute=distribute, resume=resume,
                   quorum=quorum, steps_override=steps_override,
                   agg_weights=agg_weights)
        n = plan._check_structure()
        if getattr(quorum, "ndim", 0) != 0:
            raise ValueError(
                f"RoundPlan.quorum must be a scalar, got shape "
                f"{getattr(quorum, 'shape', None)} — a non-scalar quorum "
                f"broadcasts through the jitted round cut and only fails "
                f"rounds later at ledger readback")
        if steps_override is not None and (
                getattr(steps_override, "shape", None) != (n,)
                or not np.issubdtype(np.dtype(steps_override.dtype),
                                     np.integer)):
            raise ValueError(
                f"RoundPlan.steps_override must be ({n},) int, got shape "
                f"{getattr(steps_override, 'shape', None)} dtype "
                f"{getattr(steps_override, 'dtype', None)}")
        if agg_weights is not None and \
                getattr(agg_weights, "shape", None) != (n,):
            raise ValueError(
                f"RoundPlan.agg_weights must be ({n},), got "
                f"{getattr(agg_weights, 'shape', None)}")
        object.__setattr__(plan, "_validated", True)
        return plan

    def _check_structure(self, num_clients: Optional[int] = None) -> int:
        """Shape/dtype checks on array metadata (no value sync)."""
        n = num_clients
        for name in _BOOL_FIELDS:
            arr = getattr(self, name)
            if arr is None:
                raise ValueError(f"RoundPlan.{name} is required")
            if getattr(arr, "ndim", None) != 1:
                raise ValueError(f"RoundPlan.{name} must be a 1-D mask, "
                                 f"got shape {getattr(arr, 'shape', None)}")
            if np.dtype(arr.dtype) != np.bool_:
                raise ValueError(f"RoundPlan.{name} must be bool, got "
                                 f"{arr.dtype}")
            if n is None:
                n = arr.shape[0]
            elif arr.shape[0] != n:
                raise ValueError(
                    f"RoundPlan.{name} has {arr.shape[0]} entries, "
                    f"expected {n}")
        return n

    def validate(self, num_clients: Optional[int] = None,
                 local_steps: Optional[int] = None) -> "RoundPlan":
        """Shape/dtype/value checks on concrete (host) values.

        Raises ``ValueError`` on malformed plans; returns self so calls
        chain.  ``local_steps`` (when given) caps ``steps_override`` at
        the trainer's scan length: requesting more work than the trainer
        can run would silently truncate training while the timing model
        charges the full request.  Under tracing the value checks are
        skipped (abstract arrays have no concrete sums)."""
        n = self._check_structure(num_clients)
        if isinstance(self.selected, jax.core.Tracer):
            return self
        n_sel = int(np.asarray(self.selected).sum())
        q = float(self.quorum)
        if q < 0:
            raise ValueError(f"RoundPlan.quorum must be >= 0, got {q}")
        if q > n_sel:
            raise ValueError(
                f"RoundPlan.quorum ({q}) exceeds the selected count "
                f"({n_sel}) — the round could never close on uploads")
        if n_sel > 0 and q < 1:
            raise ValueError(
                "RoundPlan.quorum must be >= 1 when any device is "
                "selected — a zero quorum idle-waits the full deadline")
        if np.asarray(self.resume & ~self.selected).any():
            raise ValueError("RoundPlan.resume must be a subset of "
                             "RoundPlan.selected")
        if self.steps_override is not None:
            so = np.asarray(self.steps_override)
            if so.shape != (n,) or not np.issubdtype(so.dtype, np.integer):
                raise ValueError(
                    f"RoundPlan.steps_override must be (N,) int, got "
                    f"shape {so.shape} dtype {so.dtype}")
            if (so < 0).any():
                raise ValueError("RoundPlan.steps_override must be >= 0")
            if local_steps is not None and so.size \
                    and int(so.max()) > local_steps:
                raise ValueError(
                    f"RoundPlan.steps_override requests up to "
                    f"{int(so.max())} local steps but the trainer scans "
                    f"only {local_steps} — the excess would silently not "
                    f"run while the timing model charged it")
        if self.agg_weights is not None:
            w = np.asarray(self.agg_weights, np.float32)
            if w.shape != (n,):
                raise ValueError(
                    f"RoundPlan.agg_weights must be (N,), got {w.shape}")
            if not np.isfinite(w).all() or (w < 0).any():
                raise ValueError(
                    "RoundPlan.agg_weights must be finite and >= 0")
        return self

    def cohort_index(self, cohort_size: int) -> jax.Array:
        """This plan's device cohort-index view (see module-level
        :func:`cohort_index`): ascending selected client ids padded with
        the sentinel N to the static ``cohort_size``."""
        return cohort_index(self.selected, cohort_size)


@dataclasses.dataclass(frozen=True)
class RoundReport:
    """What happened in one round, fed back to ``Policy.observe``.

    received: (N,) bool — uploaded before the cutoff.
    fail:     (N,) bool — interrupted mid-round (undependability draw).
    losses:   (N,) float — mean local training loss (garbage for idle).
    durations:(N,) float — per-device finish time, inf if never uploaded.
    duration: float — billed round wall clock (cutoff or deadline).
    rnd:      int — round index.

    On the legacy host-RNG path the array fields are numpy and
    ``duration`` is a python float.  On the device round path everything
    but ``rnd`` is a device array (``duration`` a float32 device scalar —
    the jitted round cut never syncs; rounds that idle-waited the
    deadline carry the float32-*nearest* cast of ``round_deadline``,
    which may sit one ulp above it, while History bills the exact f64
    config value): jnp-native policies fold the
    report in as one more dispatch, which is what keeps the pipelined
    loop (``FLConfig.pipeline_depth`` > 1) free of per-round host
    blocking; host-side policies pay one ``np.asarray`` sync as before.
    """
    received: Any
    fail: Any
    losses: Any
    durations: Any
    duration: float
    rnd: int


@dataclasses.dataclass(frozen=True)
class RoundObservation:
    """What a policy may read when planning round ``rnd``.

    ``caches`` stays device-resident — jnp-native policies (flude, safa)
    consume it directly; host-side policies pull the (N,) metadata only.
    ``draw`` is the round's device-resident fleet draw when a
    ``repro.fleet`` dynamics process produced it (None on the legacy
    host-RNG path): jnp-native policies read ``draw.online`` /
    ``draw.bandwidth`` / ``draw.battery`` directly instead of re-uploading
    the host mask.  On that path ``online`` is the *device* mask
    (``draw.online`` itself — reading it eagerly would stall the
    pipelined loop); host-side policies convert with ``np.asarray`` at
    their own sync point.
    """
    rnd: int
    online: Any                # (N,) bool — numpy, or jax on the device path
    caches: ClientCaches
    draw: Optional[Any] = None


for _cls, _data in ((RoundPlan, ["selected", "distribute", "resume",
                                 "quorum", "steps_override",
                                 "agg_weights"]),
                    (RoundReport, ["received", "fail", "losses",
                                   "durations"]),):
    jax.tree_util.register_dataclass(
        _cls, data_fields=_data,
        meta_fields=[f.name for f in dataclasses.fields(_cls)
                     if f.name not in _data])


# ---------------------------------------------------------------------------
# Policy protocol
# ---------------------------------------------------------------------------

class Policy:
    """Server-side policy: static config + pure state transitions.

    ``init_state`` builds the policy's mutable state (host RNGs, belief
    arrays, ...).  ``plan`` maps (state, observation, jax rng) to
    (state', RoundPlan); ``observe`` folds a RoundReport back into the
    state.  Subclasses override the three methods and the class flags.
    """
    name = "base"
    uses_cache = False            # wants the C3 client cache machinery
    waits_for_stragglers = True   # sync designs idle-wait to the deadline
    selects_at_most_clients_per_round = False
    # ^ static trait: every plan's selected count is bounded by
    #   FLConfig.clients_per_round (flude/random/oort/safa/fedsea).
    #   Select-all designs (mifa, asyncfeded) leave it False — their
    #   bound is the fleet size.

    def __init__(self, sim_cfg: SimConfig, fl_cfg: FLConfig,
                 fleet: Optional[Fleet] = None, mesh: Any = None):
        self.sim_cfg = sim_cfg
        self.fl_cfg = fl_cfg
        self.fleet = fleet
        # fleet mesh ("clients" axis) the engine runs under — policies that
        # keep (N,) device-resident state place it sharded over this
        self.mesh = mesh

    def init_state(self) -> Any:
        return None

    def selection_bound(self) -> int:
        """Static upper bound on any plan's selected count — what the
        engine checks ``FLConfig.cohort_size`` against up front (a cohort
        smaller than a plan's selection would silently truncate
        training).  Derived from ``selects_at_most_clients_per_round``;
        override for policies with a different static bound."""
        n = self.fl_cfg.num_clients
        if self.selects_at_most_clients_per_round:
            return min(self.fl_cfg.clients_per_round, n)
        return n

    def plan(self, state: Any, obs: RoundObservation,
             rng) -> Tuple[Any, RoundPlan]:
        raise NotImplementedError

    def observe(self, state: Any, plan: RoundPlan,
                report: RoundReport) -> Any:
        return state

    def history_extras(self, state: Any) -> Dict[str, Any]:
        """Optional end-of-run diagnostics merged into ``History``."""
        return {}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[Policy]] = {}


def register_policy(name: str, *, allow_override: bool = False):
    """Class decorator: ``@register_policy("flude")`` makes the policy
    constructible by name through ``make_policy`` / ``FleetEngine.run``."""
    def deco(cls: Type[Policy]) -> Type[Policy]:
        if not (isinstance(cls, type) and issubclass(cls, Policy)):
            raise TypeError(f"@register_policy expects a Policy subclass, "
                            f"got {cls!r}")
        if name in _REGISTRY and not allow_override:
            raise ValueError(f"policy {name!r} already registered "
                             f"(pass allow_override=True to replace)")
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_policy(name: str) -> Type[Policy]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown policy {name!r}; registered: "
                       f"{', '.join(available_policies())}") from None


def available_policies():
    return sorted(_REGISTRY)


def make_policy(name: str, sim_cfg: SimConfig, fl_cfg: FLConfig,
                fleet: Optional[Fleet] = None, mesh: Any = None) -> Policy:
    return get_policy(name)(sim_cfg, fl_cfg, fleet, mesh=mesh)
