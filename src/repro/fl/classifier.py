"""Small MLP classifier for cross-device FL simulation (paper-scale models).

Stands in for the paper's 5-layer CNN / VGG-9 / speech CNN: a few-10k-param
model that 100+ simulated devices can train replicas of, exactly the paper's
regime (≤50 MB models on phones).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def classifier_spec(dim: int = 32, hidden: int = 128,
                    num_classes: int = 10, depth: int = 2):
    spec = {}
    d_in = dim
    for i in range(depth):
        spec[f"h{i}"] = {
            "w": L.ParamSpec((d_in, hidden), jnp.float32,
                             ("embed", "mlp"), "normal"),
            "b": L.ParamSpec((hidden,), jnp.float32, ("mlp",), "zeros"),
        }
        d_in = hidden
    spec["out"] = {
        "w": L.ParamSpec((d_in, num_classes), jnp.float32,
                         ("mlp", "vocab"), "normal"),
        "b": L.ParamSpec((num_classes,), jnp.float32, ("vocab",), "zeros"),
    }
    return spec


def init_classifier(rng, **kw):
    return L.init_params(classifier_spec(**kw), rng)


def clf_logits(params, x):
    h = x
    i = 0
    while f"h{i}" in params:
        h = jnp.tanh(h @ params[f"h{i}"]["w"] + params[f"h{i}"]["b"])
        i += 1
    return h @ params["out"]["w"] + params["out"]["b"]


def clf_loss(params, x, y):
    logits = clf_logits(params, x)
    lp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(lp, y[:, None], axis=-1).mean()


def clf_accuracy(params, x, y):
    return (clf_logits(params, x).argmax(-1) == y).mean()


def clf_per_class_accuracy(params, x, y, num_classes: int):
    pred = clf_logits(params, x).argmax(-1)
    acc = []
    for c in range(num_classes):
        m = (y == c)
        acc.append(jnp.where(m.sum() > 0,
                             ((pred == y) & m).sum() / jnp.maximum(
                                 m.sum(), 1), 0.0))
    return jnp.stack(acc)
