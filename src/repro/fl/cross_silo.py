"""Cross-silo FLUDE training step — the compiled multi-pod program.

Each FL *client* is a data-parallel silo (one slice of the mesh along the
(pod, data) axes).  FLUDE's per-round decisions enter the compiled step as a
per-silo weight vector:

    w_i = selected_i · dependability-derived weight · staleness discount

Silos with w_i = 0 contribute exactly nothing to the gradient psum — the
compiled realization of "an undependable device never uploads".  If no silo
reports (Σw = 0) the global model and optimizer state pass through
unchanged (the paper's empty-round case).  See DESIGN.md §3 for why the
per-silo *parameter* cache is realized at data/weight granularity here.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import transformer as T
from repro.models.model import Model
from repro.optim.optimizers import Optimizer, make_optimizer


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


def init_train_state(model: Model, rng, opt: Optimizer) -> TrainState:
    params = model.init(rng)
    return TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))


def abstract_train_state(model: Model, opt: Optimizer) -> TrainState:
    params = model.abstract_params()
    opt_state = jax.eval_shape(opt.init, params)
    return TrainState(params, opt_state,
                      jax.ShapeDtypeStruct((), jnp.int32))


def make_train_step(model: Model, train_cfg: TrainConfig, n_silos: int,
                    exec_cfg: Optional[T.ExecConfig] = None,
                    microbatches: int = 1):
    """Builds train_step(state, batch, silo_weights) -> (state, metrics).

    batch leaves have leading dim B = global batch; silo i owns the
    contiguous block [i·B/n_silos, (i+1)·B/n_silos).  ``silo_weights`` is
    (n_silos,) — the FLUDE round plan's per-silo aggregation weights.
    """
    exec_cfg = exec_cfg or T.ExecConfig()
    opt = make_optimizer(train_cfg)
    cfg = model.cfg

    def weighted_loss(params, batch, silo_weights):
        loss, metrics = model.loss(params, batch, exec_cfg,
                                   per_example=True)
        ce = metrics["ce_per_example"]                      # (B,)
        B = ce.shape[0]
        per_silo = B // n_silos
        # silo-major fp32 reduction: sum each silo's examples first, then
        # weight — the partial-sum order then matches the data-sharded
        # program (silo blocks = shard blocks), so sharded and
        # single-device steps reduce in the same order
        per = ce.astype(jnp.float32).reshape(n_silos, per_silo).sum(1)
        w = silo_weights.astype(jnp.float32)
        denom = jax.lax.stop_gradient(
            jnp.maximum(w.sum() * per_silo, 1e-9))
        wl = (per * w).sum() / denom
        aux = metrics.get("aux", 0.0)
        return wl + (aux if isinstance(aux, float) else aux), ce.mean()

    grad_fn = jax.value_and_grad(weighted_loss, has_aux=True)

    def train_step(state: TrainState, batch, silo_weights):
        if microbatches > 1:
            def split(x):
                """(B, ...) -> (mb, B/mb, ...) preserving silo-major order:
                each microbatch holds per_silo/mb rows of EVERY silo."""
                B = x.shape[0]
                per_silo = B // n_silos
                y = x.reshape((n_silos, microbatches,
                               per_silo // microbatches) + x.shape[1:])
                y = jnp.swapaxes(y, 0, 1)
                return y.reshape((microbatches, B // microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            adt = {"float32": jnp.float32,
                   "bfloat16": jnp.bfloat16}[train_cfg.accum_dtype]

            def acc_fn(carry, mbatch):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(state.params, mbatch, silo_weights)
                g_acc = jax.tree.map(
                    lambda a, b: (a + b.astype(adt)), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), state.params)
            (grads, loss), _ = jax.lax.scan(acc_fn, (g0, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
        else:
            (loss, _), grads = grad_fn(state.params, batch, silo_weights)

        new_params, new_opt = opt.step(state.params, grads,
                                       state.opt_state)
        # FLUDE empty-round gate: no received silos ⇒ model unchanged
        any_received = silo_weights.sum() > 0
        new_params = jax.tree.map(
            lambda n, o: jnp.where(any_received, n, o),
            new_params, state.params)
        new_opt = jax.tree.map(
            lambda n, o: jnp.where(any_received, n, o), new_opt,
            state.opt_state)
        metrics = {"loss": loss,
                   "received_weight": silo_weights.sum()}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def make_prefill_step(model: Model, exec_cfg: Optional[T.ExecConfig] = None):
    exec_cfg = exec_cfg or T.ExecConfig()

    def prefill_step(params, batch):
        return model.prefill(params, batch, exec_cfg)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, tokens, positions, cache):
        return model.decode_step(params, tokens, positions, cache)

    return decode_step
