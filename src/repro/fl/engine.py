"""FleetEngine: the device-resident FL round loop behind the typed API.

The engine owns the vectorized local trainer, the fused jitted server
round step (weights + packed aggregation + C3 cache bookkeeping) and the
fleet simulator; policies are pure ``plan``/``observe`` transitions over
typed ``RoundPlan``/``RoundReport`` messages (see ``repro.fl.api``).

Global params and client caches stay device-resident across rounds.  On
the device-dynamics round path the round *close* is device-resident too:
a jitted quorum cut (``core.make_round_cut``) turns the (N,) finish
times into the cut, the billed duration and the receive mask without a
host sync, History bookkeeping is deferred through a ``_RoundLedger``
(read back at eval boundaries and run end), and
``FLConfig.pipeline_depth`` > 1 lets the host dispatch round k+1's fused
trainer + server step while round k still executes — trajectories are
bit-identical at every depth.  The legacy host-RNG loop
(``bernoulli_host``) keeps the historical numpy close verbatim.

With ``FLConfig.mesh_shape`` set, the fleet lives *sharded* over a
``("clients",)`` mesh axis: client training data, the stacked client
pytree (caches + trainer outputs), the packed (C, D) aggregation buffer
and every (N,) per-client array are placed with ``jax.device_put`` at
engine construction and stay sharded across rounds; aggregation runs as
per-shard partial weighted sums + one fp32 psum (shard_map).  The global
model is replicated.  ``FLConfig.donate_buffers`` additionally donates
the dead round inputs on the jitted trainer / server-step calls so XLA
aliases them into the outputs and steady-state rounds allocate nothing
new.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.configs.base import FLConfig
from repro.data.synthetic import FederatedClassification
from repro.fl import classifier as CLF
from repro.fl.api import (Policy, RoundObservation, RoundPlan, RoundReport,
                          cohort_index, cohort_overflow, make_policy)
from repro.fl import policies as _builtin_policies  # noqa: F401  (registers)
from repro.fl.simulator import Fleet, SimConfig, place_per_client
from repro.fleet import (get_dynamics, make_adversary,  # registers processes
                         make_dynamics)
from repro.launch.mesh import make_fleet_mesh
from repro import obs
from repro.sharding import partitioning as SP

BIG = 1 << 20


# ---------------------------------------------------------------------------
# Vectorized local trainer
# ---------------------------------------------------------------------------

def make_trainer(sim_cfg: SimConfig, data: FederatedClassification,
                 mesh=None, donate: bool = False, dynamics_features=None,
                 cohort_size: Optional[int] = None,
                 external_cache_params: bool = False):
    """Build the jitted all-fleet local trainer.

    ``mesh``: optional ``("clients",)`` fleet mesh — the per-client
    training set (N, n, d)/(N, n) is placed sharded over clients so each
    device trains only its own shard of the fleet (the computation is
    embarrassingly parallel; the only broadcast input is the global
    model).  ``donate=True`` donates the per-round (N,) step-count carry
    (steps_needed) so its buffer is recycled into the (N,)-shaped
    cached-steps output; the other big inputs — global model and caches —
    are still live after the call (the server step reads them) and must
    not be donated here.

    ``dynamics_features``: a ``repro.fleet.FleetFeatures`` switches the
    build to the device-resident dynamics variant: the round's workload
    (steps from cache progress), exposure-scaled failures + interruption
    points (from the ``FleetDraw`` variates) and the per-device timing
    model are fused *into* the jitted trainer, so the whole round body is
    one dispatch over device-resident inputs — nothing is drawn on the
    host and nothing (N,)-sized is uploaded per round.  No argument is
    donated on this variant (the draw is also exposed to policies via
    ``RoundObservation`` and must stay live).

    ``cohort_size``: static X (dynamics variant only) switches to the
    compact-cohort round body: the cohort index is derived on device
    from the plan's selection mask, the clients' data / caches / draw /
    plan arrays are gathered into dense (X, ...) blocks, and the
    vmap+scan runs over X rows instead of N — round FLOPs track the
    cohort, not the fleet.  Returns the (X,) blocks the compact cut and
    server step consume, plus scattered (N,) report views (losses /
    fail / finish times) for policies, the cohort index, and a device
    overflow flag (``|selected| > X`` — the engine defers it through
    the round ledger).  Everything happens inside the one jitted
    dispatch: compaction adds no per-round host transfer.

    ``external_cache_params``: the ``cache_offload`` trainer variant
    (requires ``cohort_size``).  ``caches`` then carries metadata only
    (empty params pytree) and the cohort's (X, ...) cache-params block
    arrives as an explicit argument — fetched from the host store by
    the engine's cache stream — together with the precomputed cohort
    index (the engine derives it in its own small jit so the host can
    start the fetch as soon as the selection mask is dispatched).  The
    round body is otherwise identical, so outputs are bit-identical to
    the resident cohort variant fed the same rows.
    """
    x_all = jnp.asarray(data.x)            # (N, n, d)
    y_all = jnp.asarray(data.y)            # (N, n)
    if mesh is not None:
        # the engine only builds a mesh that divides the fleet evenly
        x_all = jax.device_put(x_all, SP.fleet_sharding(mesh, x_all.ndim))
        y_all = jax.device_put(y_all, SP.fleet_sharding(mesh, y_all.ndim))
    n = x_all.shape[1]
    b = min(sim_cfg.batch_size, n)
    lr = sim_cfg.lr
    max_steps = sim_cfg.local_steps

    grad_fn = jax.vmap(jax.value_and_grad(CLF.clf_loss))
    donate_argnums = (3,) if donate and dynamics_features is None else ()
    if cohort_size is not None and dynamics_features is None:
        raise ValueError("cohort_size requires the dynamics trainer "
                         "variant (pass dynamics_features)")
    if external_cache_params and cohort_size is None:
        raise ValueError("external_cache_params requires the compact "
                         "cohort trainer variant (pass cohort_size)")

    def local_scan(x_arr, y_arr, start_params, steps_needed, stop_step,
                   cache_every):
        """The shared masked local-training scan body.  ``x_arr``/
        ``y_arr`` carry the client axis — the full (N, n, d) fleet or a
        gathered (X, n, d) cohort block; the per-client math is
        elementwise over that axis either way."""
        zero_cache = start_params
        loss0 = jnp.zeros((x_arr.shape[0],), jnp.float32)

        def step_fn(carry, j):
            params, cache, cached_steps, loss_sum = carry
            idx = (j * b + jnp.arange(b)) % n
            xb = x_arr[:, idx]
            yb = y_arr[:, idx]
            loss, grads = grad_fn(params, xb, yb)
            active = (j < steps_needed) & (j < stop_step)

            def upd(p, g):
                m = active.reshape((-1,) + (1,) * (p.ndim - 1))
                return jnp.where(m, p - lr * g, p)

            params = jax.tree.map(upd, params, grads)
            do_cache = active & (((j + 1) % jnp.maximum(cache_every, 1))
                                 == 0)

            def cupd(c, p):
                m = do_cache.reshape((-1,) + (1,) * (p.ndim - 1))
                return jnp.where(m, p, c)

            cache = jax.tree.map(cupd, cache, params)
            cached_steps = jnp.where(do_cache, j + 1, cached_steps)
            loss_sum = loss_sum + jnp.where(active, loss, 0.0)
            return (params, cache, cached_steps, loss_sum), None

        init = (start_params, zero_cache,
                jnp.zeros((x_arr.shape[0],), jnp.int32), loss0)
        (params, cache, cached_steps, loss_sum), _ = jax.lax.scan(
            step_fn, init, jnp.arange(max_steps))
        # normalize by the steps that actually *ran*: the scan is
        # max_steps long, so a larger request trains (and accumulates
        # loss over) max_steps at most
        done = jnp.minimum(jnp.minimum(steps_needed, stop_step), max_steps)
        mean_loss = loss_sum / jnp.maximum(done, 1)
        return params, cache, cached_steps, mean_loss

    if dynamics_features is None:
        @functools.partial(jax.jit, donate_argnums=donate_argnums)
        def train_all(global_params, caches, resume, steps_needed,
                      stop_step, cache_every):
            """All-fleet masked local training (incl. fused resume
            selection).

            global_params: unstacked global model; each client starts from
                           it unless ``resume`` picks its cached state.
            caches:       core.ClientCaches (stacked (N, ...) params).
            resume:       (N,) bool — train from local cache (C3/C4).
            steps_needed: (N,) steps each device must run (0 = idle).
            stop_step:    (N,) interruption step (>= steps_needed: no
                          failure).
            cache_every:  (N,) cache interval in steps (C3 adaptive).
            Returns (final_params, cache_params, cached_steps, mean_loss).
            """
            start_params = core.resume_params(caches, global_params, resume)
            return local_scan(x_all, y_all, start_params, steps_needed,
                              stop_step, cache_every)

        return train_all

    feats = dynamics_features
    model_mb = sim_cfg.model_mb

    def round_body(x_arr, y_arr, steps_per_sec, global_params, caches,
                   draw, selected, distribute, resume, base_steps,
                   cache_every):
        """Workload + failures + training + timing over one client axis
        (the full fleet, or a gathered cohort block — every input is
        aligned along dim 0)."""
        # clamp to the scan length: an oversized steps_override would
        # otherwise charge un-run steps in the timing model below
        base_steps = jnp.minimum(base_steps, max_steps)
        prior = jnp.round(caches.progress * max_steps).astype(jnp.int32)
        steps_needed = jnp.where(resume, jnp.maximum(base_steps - prior, 1),
                                 base_steps)
        steps_needed = jnp.where(selected, steps_needed, 0) \
            .astype(jnp.int32)
        fail = draw.failure_mask(steps_needed / max(max_steps, 1)) \
            & selected
        stop = jnp.where(fail, draw.interruption_step(steps_needed), BIG)
        start_params = core.resume_params(caches, global_params, resume)
        params, cache, cached_steps, mean_loss = local_scan(
            x_arr, y_arr, start_params, steps_needed, stop, cache_every)
        # timing model (Algorithm 2 lines 13–16) on the round's bandwidth
        success = selected & ~fail & (steps_needed > 0)
        completed = jnp.minimum(steps_needed, stop)
        comm = model_mb * 8.0 / draw.bandwidth
        t = jnp.where(distribute, comm, 0.0) \
            + completed / steps_per_sec \
            + jnp.where(success, comm, 0.0)
        times = jnp.where(success, t, jnp.inf)
        return (params, cache, cached_steps, mean_loss, steps_needed, fail,
                success, times)

    if cohort_size is None:
        @jax.jit
        def train_all_dyn(global_params, caches, draw, selected,
                          distribute, resume, base_steps, cache_every):
            """Dynamics round body: workload + failures + training +
            timing.

            draw:       repro.fleet.FleetDraw for this round (device
                        arrays).
            selected/distribute/resume: (N,) bool plan masks.
            base_steps: (N,) int planned steps before resume credit.
            Returns (final_params, cache_params, cached_steps, mean_loss,
            steps_needed, fail, success, times) — times in simulated
            seconds, inf where the device never uploads.
            """
            return round_body(x_all, y_all, feats.steps_per_sec,
                              global_params, caches, draw, selected,
                              distribute, resume, base_steps, cache_every)

        return train_all_dyn

    X = int(cohort_size)
    N = x_all.shape[0]

    def cohort_round(idx, cache_params_x, global_params, caches, draw,
                     selected, distribute, resume, base_steps,
                     cache_every):
        """Shared gather → (X, ...) round body → scatter given the cohort
        index.  ``cache_params_x`` is None on the resident path (the
        cohort's cache slots are gathered from the (N, D) pytree) or the
        externally-fetched (X, ...) block on the offload path — every
        other op is identical, which is what keeps the two variants
        bit-identical row for row."""
        def take(a, fill):
            return jnp.take(a, idx, axis=0, mode="fill", fill_value=fill)

        sel_x = take(selected, False)
        dist_x = take(distribute, False)
        res_x = take(resume, False)
        base_x = take(base_steps, 0)
        ce_x = take(cache_every, 1)
        sps_x = take(feats.steps_per_sec, 1.0)
        draw_x = draw.take(idx)
        if cache_params_x is None:
            caches_x = core.gather_caches(caches, idx)
        else:
            caches_x = core.ClientCaches(cache_params_x,
                                         take(caches.progress, 0.0),
                                         take(caches.round_stamp, -1))
        x_x = jnp.take(x_all, idx, axis=0, mode="fill", fill_value=0)
        y_x = jnp.take(y_all, idx, axis=0, mode="fill", fill_value=0)
        (x_x, y_x, caches_x, draw_x, sel_x, dist_x, res_x, base_x, ce_x,
         sps_x) = SP.cohort_constraint(
            (x_x, y_x, caches_x, draw_x, sel_x, dist_x, res_x, base_x,
             ce_x, sps_x), mesh, X)

        (params, cache, cached_steps, mean_loss, steps_needed, fail,
         success, times) = round_body(
            x_x, y_x, sps_x, global_params, caches_x, draw_x, sel_x,
            dist_x, res_x, base_x, ce_x)

        # (N,) report views: scatter the cohort rows, fill the rest with
        # exactly what the full scan computes for idle clients (loss 0,
        # no failure, inf finish time); sentinel rows drop
        losses_n = jnp.zeros((N,), mean_loss.dtype) \
            .at[idx].set(mean_loss, mode="drop")
        fail_n = jnp.zeros((N,), bool).at[idx].set(fail, mode="drop")
        times_n = jnp.full((N,), jnp.inf, times.dtype) \
            .at[idx].set(times, mode="drop")
        losses_n, fail_n, times_n = SP.cohort_scatter_constraint(
            (losses_n, fail_n, times_n), mesh, N)
        return (params, cache, cached_steps, mean_loss, steps_needed,
                fail, success, times, losses_n, fail_n, times_n)

    if external_cache_params:
        @jax.jit
        def train_cohort_dyn_offload(global_params, caches,
                                     cache_params_x, idx, draw, selected,
                                     distribute, resume, base_steps,
                                     cache_every):
            """Offload cohort round body: like ``train_cohort_dyn`` but
            the cohort index arrives precomputed (the engine's idx jit —
            same ``cohort_index`` values) and the cohort's cache params
            arrive as the host-store fetch; ``caches`` carries metadata
            only.  Returns the 11-tuple without ``idx``/``overflow``
            (the engine already holds both)."""
            idx = SP.cohort_constraint(idx, mesh, X)
            return cohort_round(idx, cache_params_x, global_params,
                                caches, draw, selected, distribute,
                                resume, base_steps, cache_every)

        return train_cohort_dyn_offload

    @jax.jit
    def train_cohort_dyn(global_params, caches, draw, selected,
                         distribute, resume, base_steps, cache_every):
        """Compact-cohort dynamics round body (see the factory
        docstring): gather → (X, ...) round body → scatter, one dispatch.

        Inputs are the same (N,)-sized round arrays as the full-scan
        variant; the cohort index is derived *inside* the jit.  Returns
        ``(final_params_x, cache_params_x, cached_steps_x, mean_loss_x,
        steps_needed_x, fail_x, success_x, times_x, idx, overflow,
        losses_n, fail_n, times_n)`` — the ``_x`` blocks are (X,)-leading
        cohort arrays; ``losses_n``/``fail_n``/``times_n`` are the (N,)
        report views policies consume (idle clients read the same
        zero-loss / no-fail / inf-time values the full scan computes for
        them).
        """
        idx = cohort_index(selected, X)
        idx = SP.cohort_constraint(idx, mesh, X)
        overflow = cohort_overflow(selected, X)
        outs = cohort_round(idx, None, global_params, caches, draw,
                            selected, distribute, resume, base_steps,
                            cache_every)
        overflow, = SP.replicated_constraint((overflow,), mesh)
        return outs[:8] + (idx, overflow) + outs[8:]

    return train_cohort_dyn


# ---------------------------------------------------------------------------
# Round history
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class History:
    acc: List[float] = dataclasses.field(default_factory=list)
    comm_mb: List[float] = dataclasses.field(default_factory=list)   # cum.
    wall_clock: List[float] = dataclasses.field(default_factory=list)
    received: List[int] = dataclasses.field(default_factory=list)
    selected: List[int] = dataclasses.field(default_factory=list)
    # eval_mask[t] is False when acc[t] is a carried-forward stale value
    # (eval_every > 1 skipped the measurement that round)
    eval_mask: List[bool] = dataclasses.field(default_factory=list)
    part_count: Optional[np.ndarray] = None
    per_class_acc: Optional[np.ndarray] = None
    per_client_acc: Optional[np.ndarray] = None
    final_params: Any = None
    # per-round device telemetry (FLConfig.telemetry / run(telemetry=..)):
    # metric column -> list over rounds; None when telemetry is off
    metrics: Optional[dict] = None

    # optional ndarray attributes that round-trip through to_json (trust
    # is attached dynamically by stateful robust rules)
    _ARRAY_EXTRAS = ("part_count", "per_class_acc", "per_client_acc",
                     "trust")

    def to_json(self) -> dict:
        """JSON-serializable trajectory dict (the golden-file format);
        ``final_params`` is deliberately excluded."""
        d = {"acc": [float(a) for a in self.acc],
             "comm_mb": [float(c) for c in self.comm_mb],
             "wall_clock": [float(t) for t in self.wall_clock],
             "received": [int(r) for r in self.received],
             "selected": [int(s) for s in self.selected],
             "eval_mask": [bool(m) for m in self.eval_mask]}
        for name in self._ARRAY_EXTRAS:
            v = getattr(self, name, None)
            if v is not None:
                d[name] = np.asarray(v).tolist()
        if self.metrics is not None:
            d["metrics"] = {k: list(v) for k, v in self.metrics.items()}
        return d

    @classmethod
    def from_json(cls, d: dict) -> "History":
        """Inverse of ``to_json``; tolerates pre-refactor golden dicts
        (no ``eval_mask``/extras — the empty mask reads as all-True,
        matching ``_evaluated``)."""
        h = cls(acc=[float(a) for a in d.get("acc", ())],
                comm_mb=[float(c) for c in d.get("comm_mb", ())],
                wall_clock=[float(t) for t in d.get("wall_clock", ())],
                received=[int(r) for r in d.get("received", ())],
                selected=[int(s) for s in d.get("selected", ())],
                eval_mask=[bool(m) for m in d.get("eval_mask", ())])
        for name in cls._ARRAY_EXTRAS:
            if d.get(name) is not None:
                setattr(h, name, np.asarray(d[name]))
        if d.get("metrics") is not None:
            h.metrics = {k: list(v) for k, v in d["metrics"].items()}
        return h

    def _evaluated(self):
        mask = self.eval_mask or [True] * len(self.acc)
        for t, c, a, m in zip(self.wall_clock, self.comm_mb, self.acc,
                              mask):
            if m:
                yield t, c, a

    def time_to_accuracy(self, target: float) -> float:
        for t, _, a in self._evaluated():
            if a >= target:
                return t
        return float("inf")

    def comm_to_accuracy(self, target: float) -> float:
        for _, c, a in self._evaluated():
            if a >= target:
                return c
        return float("inf")


def _metric_py(v):
    """One resolved metric value -> plain python (scalar or list)."""
    a = np.asarray(v)
    if a.ndim:
        return a.tolist()
    if a.dtype == bool or np.issubdtype(a.dtype, np.integer):
        return int(a)
    return float(a)


class _RoundLedger:
    """Deferred History bookkeeping for the pipelined device round loop.

    Each round the loop *dispatches* the device scalars one History row
    needs — billed duration, received/download/selected counts and (at
    eval boundaries) the round's test accuracy — and pushes the handles
    here.  ``resolve`` reads rows back oldest-first; the loop calls it
    with ``keep = pipeline_depth - 1`` so at most that many rounds of
    bookkeeping stay in flight, and with ``keep=0`` at run end (and every
    round under a ``time_budget``, whose check needs ``cum_time``).

    The f64 accumulation of ``cum_comm``/``cum_time`` happens here on the
    host at resolve time, over per-round values that are exact float32 —
    deadline-capped rounds arrive as a ``capped`` flag and bill the exact
    (float64) ``round_deadline`` — so trajectories are bit-identical at
    every depth, and identical to the old eager ``_book_round`` loop.
    """

    def __init__(self, hist: History, model_mb: float,
                 round_deadline: float, progress: Optional[Callable],
                 n_rounds: int, cohort_info: Optional[tuple] = None,
                 telemetry=None, tracer=None):
        self.hist = hist
        self.model_mb = model_mb
        self.round_deadline = round_deadline
        self.progress = progress
        self.n_rounds = n_rounds
        self.cohort_info = cohort_info    # (policy_name, cohort_size)
        self.telemetry = telemetry        # repro.obs.Telemetry | None
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER
        self.pending: List[tuple] = []
        self.cum_comm = 0.0
        self.cum_time = 0.0
        self.acc = float("nan")

    def push(self, rnd, evaluated, duration, capped, received, downloads,
             selected, acc, overflow=None, metrics=None):
        """Queue one round's device-scalar bookkeeping handles.

        ``overflow`` (compact-cohort rounds) is the device flag for
        ``|selected| > cohort_size``: like every other handle it is read
        back at resolve time, so under ``pipeline_depth`` > 1 a cohort
        overflow surfaces up to depth-1 rounds after it happened — the
        documented cost of keeping the check off the per-round hot path.

        ``metrics`` (telemetry on) is the fused metrics dispatch's dict
        of device scalars/vectors: it joins the same deferred read, so
        telemetry adds handles to an existing host sync, never a new
        one.
        """
        self.pending.append((rnd, evaluated, duration, capped, received,
                             downloads, selected, acc, overflow,
                             metrics))

    def resolve(self, keep: int = 0):
        """Read back (host-sync) all but the newest ``keep`` rounds."""
        while len(self.pending) > keep:
            (rnd, evaluated, duration, capped, received, downloads,
             selected, acc_dev, overflow, metrics) = self.pending.pop(0)
            with self.tracer.span("ledger_resolve", round=rnd):
                (duration, capped, received, downloads, selected,
                 overflow, metrics) = jax.device_get(
                    (duration, capped, received, downloads, selected,
                     overflow, metrics))
            if overflow is not None and bool(overflow):
                name, x = self.cohort_info or ("<unknown>", "?")
                raise RuntimeError(
                    f"cohort overflow in round {rnd}: policy {name!r} "
                    f"selected {int(selected)} clients but "
                    f"FLConfig.cohort_size={x} — the compact round "
                    f"trained a truncated cohort.  Raise cohort_size "
                    f"(or set it to None for the full scan).")
            self.cum_comm += (int(downloads) + int(received)) \
                * self.model_mb
            billed = self.round_deadline if bool(capped) \
                else float(duration)
            self.cum_time += billed
            if evaluated:
                # the eval scalar is the one extra readback an eval
                # boundary costs — spanned so Perfetto shows it next to
                # ledger_resolve like every other host-sync seam
                with self.tracer.span("eval_readback", round=rnd):
                    self.acc = float(jax.device_get(acc_dev))
            hist = self.hist
            hist.acc.append(self.acc)
            hist.eval_mask.append(evaluated)
            hist.comm_mb.append(self.cum_comm)
            hist.wall_clock.append(self.cum_time)
            hist.received.append(int(received))
            hist.selected.append(int(selected))
            if metrics is not None:
                vals = {k: _metric_py(v) for k, v in metrics.items()}
                if hist.metrics is None:
                    hist.metrics = {}
                for k, v in vals.items():
                    hist.metrics.setdefault(k, []).append(v)
                if self.telemetry is not None:
                    self.telemetry.record_round({
                        "round": rnd, "evaluated": evaluated,
                        "acc": None if self.acc != self.acc else self.acc,
                        "duration": billed, "comm_mb": self.cum_comm,
                        "wall_clock": self.cum_time,
                        "received": int(received),
                        "downloads": int(downloads),
                        "selected": int(selected), **vals})
            if self.progress and (rnd % 10 == 0
                                  or rnd == self.n_rounds - 1):
                self.progress(rnd, self.acc, self.cum_comm, self.cum_time)


# ---------------------------------------------------------------------------
# FleetEngine
# ---------------------------------------------------------------------------

class FleetEngine:
    """Owns trainer + fused server step + fleet; runs policies by name.

    The fleet trainer (legacy or dynamics variant, per
    ``FLConfig.dynamics``) is jitted on first use and reused across
    ``run`` calls (different policies, same task) — the multi-policy
    comparison loop of the paper's Table 1.

        engine = FleetEngine(data, sim_cfg, fl_cfg)
        hist = engine.run("flude")                      # sim_cfg.rounds
        hist = engine.run("random", time_budget=3600.0)

    A fleet passed to the constructor is reused (and its RNG advances
    across runs); otherwise each run draws a fresh ``Fleet(sim_cfg)`` so
    fixed seeds reproduce.
    """

    def __init__(self, data: FederatedClassification, sim_cfg: SimConfig,
                 fl_cfg: FLConfig, fleet: Optional[Fleet] = None):
        # adversarial fleet (repro.fleet.adversary): resolve the attack
        # model up front — the malicious mask is drawn once (determin-
        # istic in the sim seed), label poisoning rewrites the training
        # set before the trainer ever sees it, and model poisoning rides
        # inside the jitted server step via ``adversary_scale``.  Rounds
        # add zero host syncs either way.
        self._adversary = None
        self._adv_scale = None
        self._malicious_np = None
        if fl_cfg.adversary is not None:
            self._adversary = make_adversary(fl_cfg.adversary,
                                             fl_cfg.adversary_params)
            self._malicious_np = self._adversary.malicious_mask(
                fl_cfg.num_clients, sim_cfg.seed)
            self._adv_scale = self._adversary.delta_scale
            if self._adversary.flips_labels:
                data = self._adversary.corrupt_data(data,
                                                    self._malicious_np)
        # robust aggregation rule (repro.core.agg_rules): "mean" keeps
        # the historical direct path (rule None); a stateful rule adds a
        # device-resident (N,) state vector threaded through rounds
        self._agg_rule = None
        if fl_cfg.agg_rule not in (None, "mean"):
            self._agg_rule = core.make_agg_rule(fl_cfg.agg_rule,
                                                fl_cfg.agg_rule_params)
        self._agg_stateful = (self._agg_rule is not None
                              and self._agg_rule.stateful)
        self.data = data
        self.sim_cfg = sim_cfg
        self.fl_cfg = fl_cfg
        self._fleet = fleet
        self.mesh = self._build_mesh(fl_cfg)
        self.donate = bool(fl_cfg.donate_buffers)
        self.pipeline_depth = int(fl_cfg.pipeline_depth)
        if self.pipeline_depth < 1:
            raise ValueError(f"FLConfig.pipeline_depth must be >= 1, got "
                             f"{fl_cfg.pipeline_depth}")
        self.cohort = fl_cfg.cohort_size
        self.offload = fl_cfg.cache_offload
        if self.cohort is not None \
                and get_dynamics(fl_cfg.dynamics).host_side:
            raise ValueError(
                f"FLConfig.cohort_size requires a device dynamics "
                f"process, but {fl_cfg.dynamics!r} is host-side — the "
                f"legacy numpy round loop has no compact path (pick a "
                f"device process, e.g. 'bernoulli', or set "
                f"cohort_size=None)")
        self._trainer = None      # legacy trainer, built on first host run
        self._acc_fn = jax.jit(CLF.clf_accuracy)
        self._server_steps = {}
        self._last_caches = None  # previous run's fleet caches (recycled)
        self._cache_reset = None  # donated in-place zero-fill, built lazily
        template = CLF.init_classifier(
            jax.random.key(sim_cfg.seed + 1), dim=data.x.shape[-1],
            num_classes=data.num_classes, hidden=sim_cfg.model_hidden,
            depth=sim_cfg.model_depth)
        # place everything the rounds touch once, at construction: the
        # global model + test set replicated, per-client arrays sharded
        if self.mesh is not None:
            template = jax.device_put(
                template, jax.tree.map(
                    lambda _: SP.replicated_sharding(self.mesh), template))
        self._template = template
        self._test_x, self._test_y, self._n_samples = self._place_eval()
        # device-resident fleet dynamics (repro.fleet): jitted step /
        # fused round trainer, memoized per (process, params); per-run
        # (N,) constants are placed once and reused so steady-state
        # rounds never re-upload anything
        get_dynamics(fl_cfg.dynamics)          # fail fast on unknown names
        self._dyn_cache = {}
        self._round_consts = {}
        self._cut_fns = {}                     # jitted round cut per trait
        # the malicious mask is per-run-invariant: placed once, reused
        self._malicious = None if self._adv_scale is None else \
            self._put1(self._malicious_np)
        # host-offloaded C3 cache store (cache_offload="host"/"discard"):
        # the (N, D) cache params live in a sparse host store; the device
        # keeps (N,) metadata plus the round's (X, D) cohort block, and
        # the stream double-buffers the fetch/write-back copies
        self.cache_store = None
        self._cache_stream = None
        self._idx_fn = None
        self._expire_fn = None
        self._zeros_x = None
        # per-engine transfer counters (strictly per-engine — the old
        # module-global aggregate is gone)
        self._transfer_stats = core.TransferStats()
        if self.offload is not None:
            bound = fl_cfg.cache_staleness_bound \
                if self.offload == "discard" else None
            self.cache_store = core.HostCacheStore(
                self._template, fl_cfg.num_clients,
                staleness_bound=bound)
            self._cache_stream = core.CohortCacheStream(
                self.cache_store, mesh=self.mesh,
                cohort_size=self.cohort, stats=self._transfer_stats)
        # telemetry (repro.obs): fused metrics dispatches are memoized
        # per (level, path); the run-scoped tracer is NULL when off, so
        # instrumented seams cost one attribute lookup on default runs
        self._metrics_fns = {}
        self._tracer = obs.NULL_TRACER
        # debug_checks sanitizer mode (repro.analysis.runtime): checkify
        # round guard + recompilation detector, both built lazily —
        # default runs never import the analysis package
        self.debug_checks = bool(fl_cfg.debug_checks)
        self._round_guard = None
        self._recomp_detector = None

    def _build_mesh(self, fl_cfg: FLConfig):
        if fl_cfg.mesh_shape is None:
            return None
        shape = tuple(fl_cfg.mesh_shape)
        if len(shape) != 1:
            raise ValueError(f"FLConfig.mesh_shape must be 1-D (clients "
                             f"axis), got {shape}")
        if shape[0] == 1:
            return None          # single device: today's exact round path
        if fl_cfg.num_clients % shape[0] != 0:
            raise ValueError(
                f"mesh_shape {shape} does not divide the "
                f"{fl_cfg.num_clients}-client fleet — shard_map needs an "
                f"even client split")
        return make_fleet_mesh(shape[0])

    def _place_eval(self):
        test_x = jnp.asarray(self.data.test_x)
        test_y = jnp.asarray(self.data.test_y)
        n_samples = jnp.full((self.fl_cfg.num_clients,),
                             self.data.x.shape[1], jnp.float32)
        if self.mesh is not None:
            rep = SP.replicated_sharding(self.mesh)
            test_x = jax.device_put(test_x, rep)
            test_y = jax.device_put(test_y, rep)
            n_samples = jax.device_put(n_samples,
                                       SP.fleet_sharding(self.mesh))
        return test_x, test_y, n_samples

    @property
    def trainer(self):
        """The legacy (host-draw) jitted trainer, built lazily: an engine
        configured with a device dynamics process never calls it, and the
        dynamics trainer places its own copy of the training set — eager
        construction would keep two full device copies of the data."""
        if self._trainer is None:
            self._trainer = make_trainer(self.sim_cfg, self.data,
                                         mesh=self.mesh,
                                         donate=self.donate)
        return self._trainer

    def _put1(self, arr):
        """Place one (N,) per-client array (sharded under the mesh)."""
        return place_per_client(arr, self.mesh)

    def _fresh_caches(self, template):
        """Empty (N, ...) C3 cache state for a new run.

        With ``donate_buffers``, the previous run's final caches (stashed
        on ``_last_caches``) are recycled: a donated jitted reset memsets
        zeros/-1 into the existing fleet buffers in place, so back-to-back
        runs skip re-faulting the O(N·D) cache pytree — at N=4096 the
        fresh allocation costs ~7x the in-place reset.  Sharding carries
        through (``zeros_like`` keeps the donated leaves' placement)."""
        N = self.fl_cfg.num_clients
        spent, self._last_caches = self._last_caches, None
        if self.offload is not None:
            # offload: params live in the host store — reset it (and any
            # write-back still in flight) and keep only (N,) metadata on
            # device; the reset-recycling below applies unchanged to the
            # metadata-only pytree
            self._cache_stream.reset()
            template = {}
        if self.donate and spent is not None:
            if self._cache_reset is None:
                self._cache_reset = jax.jit(core.reset_caches,
                                            donate_argnums=0)
            return self._cache_reset(spent)
        caches = core.init_caches(template, N)
        if self.mesh is not None:
            caches = SP.place_fleet(caches, self.mesh, N)
        return caches

    def _server_step(self, uses_cache: bool):
        # keyed on mesh shape + donation + cohort so ``run(policy)``
        # reuse stays valid if the engine's placement knobs ever diverge
        # per run (the cohort key is what memoizes the compact (X, D)
        # step separately from the full-scan one)
        mesh_key = None if self.mesh is None else \
            tuple(self.mesh.devices.shape)
        key = (bool(uses_cache), mesh_key, self.donate, self.cohort,
               self.offload)
        if key not in self._server_steps:
            self._server_steps[key] = core.make_server_round_step(
                self._template, local_steps=self.sim_cfg.local_steps,
                agg_impl=self.fl_cfg.agg_impl,
                agg_rule=self.fl_cfg.agg_rule,
                agg_rule_params=self.fl_cfg.agg_rule_params,
                adversary_scale=self._adv_scale,
                staleness_discount=self.fl_cfg.staleness_discount,
                uses_cache=bool(uses_cache),
                block_c=self.fl_cfg.agg_block_c,
                block_d=self.fl_cfg.agg_block_d, mesh=self.mesh,
                donate=self.donate, cohort_size=self.cohort,
                cache_offload=self.offload)
        return self._server_steps[key]

    # -- telemetry plumbing (repro.obs) -------------------------------------

    @property
    def transfer_stats(self) -> "core.TransferStats":
        """This engine's cache-stream transfer counters (all zero when
        no offload stream is configured).  Strictly per-engine, so
        concurrent engines never clobber each other's counts; the
        static per-round ceiling these must respect lives in
        ``repro.analysis.audit.transfer_ceiling``."""
        return self._transfer_stats

    # -- debug_checks sanitizers (repro.analysis.runtime) --------------------

    def _debug_round_check(self, global_params, losses, idx, rnd):
        """``FLConfig.debug_checks`` round guard: checkify the post-step
        global model / losses for non-finite values and the cohort index
        for OOB.  Reads one error scalar back per round — the sanitizer's
        documented host sync, never active on production runs."""
        from repro.analysis import runtime as RT
        if self._round_guard is None:
            self._round_guard = RT.make_round_guard(
                self.fl_cfg.num_clients, with_idx=idx is not None)
        err, _ = self._round_guard(global_params, losses) if idx is None \
            else self._round_guard(global_params, losses, idx)
        RT.throw_round_error(err, rnd)

    def _debug_recompile_check(self):
        """``FLConfig.debug_checks`` run-end assertion: none of the
        engine's memoized jitted dispatches re-traced across runs."""
        from repro.analysis import runtime as RT
        if self._recomp_detector is None:
            self._recomp_detector = RT.RecompilationDetector(self)
        self._recomp_detector.check()

    def _resolve_telemetry(self, arg):
        """``run(telemetry=...)`` -> ``Telemetry | None``.

        ``None`` defers to ``FLConfig.telemetry`` (a bare session at
        that level, metrics land on ``History.metrics``); ``False``
        forces telemetry off for this run; a level string builds a bare
        session; a ``repro.obs.Telemetry`` is used as-is (sinks, trace
        paths and profiler window included)."""
        if arg is False:
            return None
        if arg is None:
            lvl = self.fl_cfg.telemetry
            return None if lvl is None else obs.Telemetry(level=lvl)
        if isinstance(arg, str):
            return obs.Telemetry(level=arg)
        return arg

    def _metrics_fn(self, level: str, uses_cache: bool,
                    rows_bound: Optional[int] = None):
        """Memoized fused metrics dispatch for the active round path:
        ``(jitted fn, needed ctx keys)`` — ``(None, ())`` when nothing
        applies.  The availability set advertises exactly what the
        path produces, so registered metrics with unmet needs are never
        traced.  ``rows_bound`` is the policy's static selection bound
        on the full-scan path (rows there are the fleet-sized (N, ...)
        stack): O(rows · D) metrics use it to gather the received rows
        into a compact block before reducing."""
        key = (level, self.cohort, self.offload, self._agg_stateful,
               bool(uses_cache), rows_bound)
        if key not in self._metrics_fns:
            avail = {"selected", "distribute", "resume", "online",
                     "received", "fail", "losses", "times", "progress",
                     "stamp", "rnd", "rows", "rows_mask", "global"}
            if self.cohort is not None:
                avail.add("cohort_size")
            if self._agg_stateful:
                avail.add("rule_state")
            if self.offload == "discard" and uses_cache:
                avail.add("stamp_pre_expire")
            static = {"num_clients": self.fl_cfg.num_clients,
                      "cohort_size": self.cohort,
                      "local_steps": self.sim_cfg.local_steps,
                      "staleness_edges": obs.metrics.STALENESS_EDGES,
                      "rows_bound": rows_bound}
            self._metrics_fns[key] = obs.make_metrics_fn(
                level, avail, static, mesh=self.mesh)
        return self._metrics_fns[key]

    def _metrics_dispatch(self, metrics_fn, m_keys, tracer, rnd,
                          global_params, caches, rule_state,
                          stamp_pre_expire, **cand):
        """Issue the fused metrics dispatch.  Must be called *before*
        the round's server step: with ``donate_buffers`` the step
        consumes (invalidates) the pre-step global model and cache
        metadata the reductions read."""
        if metrics_fn is None:
            return None
        cand.update(progress=caches.progress, stamp=caches.round_stamp,
                    rnd=rnd)
        cand["global"] = global_params
        if rule_state is not None:
            cand["rule_state"] = rule_state
        if stamp_pre_expire is not None:
            cand["stamp_pre_expire"] = stamp_pre_expire
        with tracer.span("metrics", round=rnd):
            return metrics_fn({k: cand[k] for k in m_keys})

    # -- robust-aggregation state / adversary plumbing ----------------------

    def _init_rule_state(self):
        """Fresh per-run (N,) rule state (stateful rules only), placed
        on device (sharded under the mesh) — the only fleet-state the
        robust axis adds, threaded through the step like the caches."""
        if not self._agg_stateful:
            return None
        return self._put1(self._agg_rule.init_state(
            self.fl_cfg.num_clients))

    def _step_extra(self, rule_state):
        """Trailing args of the fused server step: the device-resident
        malicious mask (adversary configured), then the rule state."""
        extra = ()
        if self._adv_scale is not None:
            extra += (self._malicious,)
        if self._agg_stateful:
            extra += (rule_state,)
        return extra

    def server_step_memory(self, uses_cache: bool = True) -> dict:
        """Allocation profile of the compiled fused server step (bytes).

        Lowers the step on representative round inputs and reads XLA's
        memory analysis.  With ``donate_buffers`` the previous global
        model + caches alias into the outputs (``alias_bytes`` > 0), so
        the steady-state peak — arguments + outputs + temps − aliased —
        drops by exactly the persistent fleet state the step no longer
        double-buffers.

        The profile describes the *active* step: with
        ``FLConfig.cohort_size`` set, the stacked trainer outputs and the
        packed aggregation buffer are (X, ...) cohort blocks, not (N, ...)
        — ``packed_rows``/``packed_buffer_bytes`` report which buffer
        actually lives on device.

        Beyond the XLA analysis, the profile reports the engine's
        persistent fleet-state residency: ``rule_state_bytes`` (the
        stateful robust-aggregation (N,) vector, 0 for stateless rules)
        and the C3 cache split ``cache_device_bytes`` /
        ``cache_host_bytes`` — resident mode keeps the whole (N, D)
        pytree on device and 0 bytes on host; under ``cache_offload``
        the device holds only (N,) metadata plus the (X, D) cohort
        block (O(X·D), fleet-size-independent) and the host side is the
        store's current live rows.
        """
        N = self.fl_cfg.num_clients
        rows = N if self.cohort is None else int(self.cohort)
        step = self._server_step(uses_cache)
        meta_only = self.offload is not None
        caches = core.init_caches({} if meta_only else self._template, N)
        stacked = jax.tree.map(
            lambda a: jnp.zeros((rows,) + a.shape, a.dtype),
            self._template)
        if self.mesh is not None:
            caches = SP.place_fleet(caches, self.mesh, N)
            stacked = SP.place_fleet(stacked, self.mesh, rows)
        mask = self._put1(np.zeros(rows, bool))
        steps_i = self._put1(np.zeros(rows, np.int32))
        ones = self._put1(np.ones(N, np.float32))
        rule_state = self._init_rule_state()
        extra = self._step_extra(rule_state)
        # lower() only traces — nothing executes, nothing is donated
        if self.cohort is None:
            lowered = step.lower(self._template, caches, stacked, stacked,
                                 steps_i, mask, mask, mask, mask,
                                 self._n_samples, ones, 0, *extra)
        elif meta_only:
            idx = self._put1(np.arange(rows, dtype=np.int32))
            mask_n = self._put1(np.zeros(N, bool))
            lowered = step.lower(self._template, caches, stacked,
                                 steps_i, idx, mask_n, mask, mask, mask_n,
                                 self._n_samples, ones, 0, *extra)
        else:
            idx = self._put1(np.arange(rows, dtype=np.int32))
            mask_n = self._put1(np.zeros(N, bool))
            lowered = step.lower(self._template, caches, stacked, stacked,
                                 steps_i, idx, mask_n, mask, mask, mask_n,
                                 self._n_samples, ones, 0, *extra)
        ma = lowered.compile().memory_analysis()
        out = {"argument_bytes": int(ma.argument_size_in_bytes),
               "output_bytes": int(ma.output_size_in_bytes),
               "temp_bytes": int(ma.temp_size_in_bytes),
               "alias_bytes": int(ma.alias_size_in_bytes)}
        out["peak_live_bytes"] = (out["argument_bytes"]
                                  + out["output_bytes"]
                                  + out["temp_bytes"]
                                  - out["alias_bytes"])
        layout = core.pack_layout(self._template)
        out["packed_rows"] = rows
        out["packed_buffer_bytes"] = layout.buffer_bytes(rows)

        def tree_bytes(tree):
            return sum(int(np.prod(np.shape(l), dtype=np.int64))
                       * np.dtype(jnp.asarray(l).dtype).itemsize
                       for l in jax.tree.leaves(tree))

        out["rule_state_bytes"] = 0 if rule_state is None \
            else tree_bytes(rule_state)
        meta_bytes = tree_bytes((caches.progress, caches.round_stamp))
        if meta_only:
            # device residency: (N,) metadata + the per-round (X, D)
            # cohort slot block — O(X·D), independent of fleet size
            out["cache_device_bytes"] = meta_bytes \
                + rows * self.cache_store.row_bytes
            out["cache_host_bytes"] = self.cache_store.nbytes
        else:
            out["cache_device_bytes"] = meta_bytes \
                + tree_bytes(caches.params)
            out["cache_host_bytes"] = 0
        return out

    def run(self, policy: Union[str, Policy], rounds: Optional[int] = None,
            time_budget: Optional[float] = None, eval_every: int = 1,
            progress: Optional[Callable] = None,
            diagnostics: bool = True, telemetry=None) -> History:
        """Run FL rounds.  ``time_budget`` (simulated seconds) caps the run
        by wall clock instead of round count — the paper's comparison
        regime: faster policies (shorter rounds) fit more rounds in the
        same budget.  ``rounds`` (default ``sim_cfg.rounds``) remains the
        hard round cap.  ``diagnostics=False`` skips the O(N)-eval
        end-of-run per-class/per-client accuracy sweep (benchmarks).

        ``FLConfig.dynamics`` picks the availability process: the default
        ``bernoulli_host`` runs the seed simulator's host-RNG loop
        (bit-identical golden trajectories); every other registered
        process (``repro.fleet``) runs the device-resident loop — draws,
        workload, failures, timing AND the round cut are produced on
        device, sharded over the client mesh, with no per-round
        host→device hand-off.  On that loop ``FLConfig.pipeline_depth``
        > 1 keeps up to depth-1 rounds of bookkeeping in flight (History
        is read back at eval boundaries and run end), overlapping round
        k+1's dispatches with round k's device execution; trajectories
        are bit-identical at every depth.

        ``telemetry`` (see ``_resolve_telemetry``): ``None`` defers to
        ``FLConfig.telemetry``, a level string or ``repro.obs.Telemetry``
        enables device metrics + host span tracing for this run, and
        ``False`` forces it off.  Metric values ride the round ledger's
        existing readback, so the trajectory is bit-identical (and the
        per-round host-sync count unchanged) with telemetry on or
        off."""
        sim_cfg, fl_cfg = self.sim_cfg, self.fl_cfg
        fleet = self._fleet if self._fleet is not None else Fleet(sim_cfg)
        if isinstance(policy, str):
            policy = make_policy(policy, sim_cfg, fl_cfg, fleet,
                                 mesh=self.mesh)
        if self.cohort is not None:
            bound = policy.selection_bound()
            if bound > self.cohort:
                raise ValueError(
                    f"policy {policy.name!r} can select up to {bound} "
                    f"clients per round but FLConfig.cohort_size="
                    f"{self.cohort} — the compact round path would "
                    f"truncate its cohort.  Raise cohort_size to at "
                    f"least {bound} (or set it to None for the full "
                    f"scan).")
        state = policy.init_state()
        n_rounds = sim_cfg.rounds if rounds is None else rounds

        rng = jax.random.key(sim_cfg.seed)
        global_params = self._template
        if self.donate:
            # the first round's server step donates its global-model input;
            # the template must survive for subsequent run() calls
            global_params = jax.tree.map(jnp.copy, global_params)
        caches = self._fresh_caches(global_params)

        hist = History()
        tel = self._resolve_telemetry(telemetry)
        tracer = tel.tracer if tel is not None else obs.NULL_TRACER
        self._tracer = tracer       # seams outside the loops (placement)
        if tel is not None:
            tel.open_run({"policy": policy.name,
                          "num_clients": fl_cfg.num_clients,
                          "rounds": n_rounds,
                          "dynamics": fl_cfg.dynamics,
                          "cohort_size": fl_cfg.cohort_size,
                          "cache_offload": fl_cfg.cache_offload,
                          "pipeline_depth": fl_cfg.pipeline_depth})
            hist.metrics = {}
        rounds_loop = self._host_rounds \
            if get_dynamics(fl_cfg.dynamics).host_side \
            else self._device_rounds
        with tracer.span("rounds"):
            state, global_params, caches = rounds_loop(
                policy, state, fleet, hist, global_params, caches, rng,
                n_rounds, time_budget, eval_every, progress, tel)
        if self.debug_checks:
            self._debug_recompile_check()

        # a time_budget break can land between eval boundaries, leaving
        # the final booked round with a stale carried-forward (or NaN)
        # accuracy — force a measurement on the final global model so
        # time/comm_to_accuracy and "final acc" reports see fresh data
        if time_budget is not None and hist.eval_mask \
                and not hist.eval_mask[-1]:
            hist.acc[-1] = float(self._acc_fn(global_params, self._test_x,
                                              self._test_y))
            hist.eval_mask[-1] = True

        # final diagnostics (paper Fig. 1(b)(c))
        if diagnostics:
            with tracer.span("diagnostics"):
                hist.per_class_acc = np.asarray(
                    CLF.clf_per_class_accuracy(
                        global_params, self._test_x, self._test_y,
                        self.data.num_classes))
                pc = []
                for i in range(min(fl_cfg.num_clients,
                                   self.data.x.shape[0])):
                    pc.append(float(self._acc_fn(
                        global_params, jnp.asarray(self.data.x[i]),
                        jnp.asarray(self.data.y[i]))))
                hist.per_client_acc = np.asarray(pc)
        for k, v in policy.history_extras(state).items():
            setattr(hist, k, v)
        if self._agg_stateful:
            # final per-client trust scores (stateful robust rules): the
            # read-back happens once, at run end — rounds stay sync-free
            setattr(hist, "trust",
                    np.asarray(jax.device_get(self._last_rule_state)))
        if tel is not None:
            final_acc = hist.acc[-1] if hist.acc else None
            tel.close_run({
                "policy": policy.name, "rounds": len(hist.acc),
                "final_acc": None if final_acc is None
                or final_acc != final_acc else final_acc,
                "comm_mb": hist.comm_mb[-1] if hist.comm_mb else 0.0,
                "wall_clock": hist.wall_clock[-1] if hist.wall_clock
                else 0.0,
                "transfer_stats": self._transfer_stats.snapshot()})
            self._tracer = obs.NULL_TRACER
        hist.final_params = global_params
        # final device-resident fleet state (stays sharded under the mesh;
        # the seam for multi-round pipelining / warm restarts)
        self._last_caches = caches
        return hist

    # -- shared host-side round closing / bookkeeping -----------------------

    def _close_round(self, times, plan, policy):
        """Round termination (Algorithm 2 lines 13–16) on the per-device
        finish times — the host numpy path, kept for the legacy host-RNG
        loop (and as the property-test reference of the jitted cut)."""
        return core.host_round_cut(times, float(np.asarray(plan.quorum)),
                                   self.sim_cfg.round_deadline,
                                   policy.waits_for_stragglers)

    def _round_cut(self, waits_for_stragglers: bool):
        """Memoized jitted device round cut (one variant per the policy's
        straggler trait), everything device-resident.  With a cohort the
        cut runs over the (X,) gathered finish times and additionally
        scatters the (N,) receive mask (every finite time belongs to a
        cohort member, so the order statistics — and the cut — are
        exact).  Built with ``with_counts=True``: the cut also returns
        the round's (received, download, selected) ledger counts as
        device scalars, fused into the same dispatch — the loop hands
        them straight to the ledger, so per-round host bookkeeping is
        O(1) scalar handles instead of an extra (N,)-reducing jit."""
        key = (bool(waits_for_stragglers), self.cohort)
        if key not in self._cut_fns:
            if self.cohort is None:
                self._cut_fns[key] = core.make_round_cut(
                    self.fl_cfg.num_clients, self.sim_cfg.round_deadline,
                    key[0], mesh=self.mesh, with_counts=True)
            else:
                self._cut_fns[key] = core.make_round_cut(
                    self.cohort, self.sim_cfg.round_deadline, key[0],
                    mesh=self.mesh,
                    scatter_num_clients=self.fl_cfg.num_clients,
                    with_counts=True)
        return self._cut_fns[key]

    def _validate_plan(self, plan):
        """Per-round plan admission, shared by both loops.  Plans built
        through ``RoundPlan.create``/``RoundPlan.device`` already ran
        their checks — only fleet-size agreement (and, for host-side
        overrides, the scan-length cap) is left to confirm."""
        fl_cfg, sim_cfg = self.fl_cfg, self.sim_cfg
        if getattr(plan, "_validated", False):
            if plan.selected.shape[0] != fl_cfg.num_clients:
                raise ValueError(
                    f"RoundPlan sized {plan.selected.shape[0]} for a "
                    f"{fl_cfg.num_clients}-client fleet")
            so = plan.steps_override
            if so is not None and not isinstance(so, jax.Array) \
                    and np.asarray(so).size \
                    and int(np.asarray(so).max()) > sim_cfg.local_steps:
                raise ValueError(
                    f"RoundPlan.steps_override requests up to "
                    f"{int(np.asarray(so).max())} local steps but the "
                    f"trainer scans only {sim_cfg.local_steps}")
        else:
            plan.validate(fl_cfg.num_clients,
                          local_steps=sim_cfg.local_steps)

    def _book_round(self, hist, rnd, n_rounds, eval_every, global_params,
                    downloads, received, selected, duration, cum_comm,
                    cum_time, acc, progress):
        """Comm/time accumulation, eval cadence and the History appends
        for one round; returns the updated ``(cum_comm, cum_time, acc)``.
        ``downloads``/``received``/``selected`` are host (N,) bools —
        ``downloads`` is the distribute mask already gated by the round's
        online mask (§4.4 only transmits to reachable devices)."""
        cum_comm += (downloads.sum() + received.sum()) \
            * self.sim_cfg.model_mb
        cum_time += duration
        evaluated = rnd % eval_every == 0 or rnd == n_rounds - 1
        if evaluated:
            acc = float(self._acc_fn(global_params, self._test_x,
                                     self._test_y))
        hist.acc.append(acc)
        hist.eval_mask.append(evaluated)
        hist.comm_mb.append(cum_comm)
        hist.wall_clock.append(cum_time)
        hist.received.append(int(received.sum()))
        hist.selected.append(int(selected.sum()))
        if progress and (rnd % 10 == 0 or rnd == n_rounds - 1):
            progress(rnd, acc, cum_comm, cum_time)
        return cum_comm, cum_time, acc

    # -- legacy host-RNG round loop (bernoulli_host) ------------------------

    def _host_rounds(self, policy, state, fleet, hist, global_params,
                     caches, rng, n_rounds, time_budget, eval_every,
                     progress, tel=None):
        """The seed simulator's numpy round loop — draw-for-draw identical
        to the pre-dynamics engine, so the golden trajectories of every
        registered policy stay bit-identical."""
        sim_cfg, fl_cfg = self.sim_cfg, self.fl_cfg
        n_samples = self._n_samples
        tracer = tel.tracer if tel is not None else obs.NULL_TRACER
        metrics_fn, m_keys = (None, ()) if tel is None else \
            self._metrics_fn(tel.level, policy.uses_cache,
                             rows_bound=policy.selection_bound())

        # adaptive cache frequency (C3): steps between cache writes
        cache_every_np = np.clip(np.round(
            core.adaptive_cache_interval(2.0, fleet.battery,
                                         fleet.stability)), 1, 4
        ).astype(np.int32) if policy.uses_cache else \
            np.full(fl_cfg.num_clients, BIG, np.int32)
        cache_every = self._put1(cache_every_np)

        cum_comm = 0.0
        cum_time = 0.0
        acc = float("nan")
        full_steps = np.full(fl_cfg.num_clients, sim_cfg.local_steps,
                             np.int32)
        ones_w = self._put1(np.ones((fl_cfg.num_clients,), np.float32))
        server_step = self._server_step(policy.uses_cache)
        rule_state = self._init_rule_state()

        for rnd in range(n_rounds):
            if time_budget is not None and cum_time >= time_budget:
                break
            rng, k_sel = jax.random.split(rng)
            online = fleet.online_mask()
            with tracer.span("plan", round=rnd):
                state, plan = policy.plan(
                    state, RoundObservation(rnd, online, caches), k_sel)
            self._validate_plan(plan)
            selected = np.asarray(plan.selected)
            distribute = np.asarray(plan.distribute)
            resume = np.asarray(plan.resume)

            # per-device workload (override clamped to the scan length)
            prior_steps = np.round(
                np.asarray(caches.progress) * sim_cfg.local_steps
            ).astype(np.int32)
            base_steps = full_steps if plan.steps_override is None \
                else np.minimum(np.asarray(plan.steps_override),
                                sim_cfg.local_steps)
            steps_needed = np.where(resume,
                                    np.maximum(base_steps - prior_steps, 1),
                                    base_steps).astype(np.int32)
            steps_needed = np.where(selected, steps_needed, 0)

            # failures (exposure-scaled) + interruption points
            fail = fleet.failure_draw(
                steps_needed / max(sim_cfg.local_steps, 1))
            fail &= selected
            stop = np.where(fail, fleet.failure_step(steps_needed), BIG)

            # local training; the start state (fresh global vs cached
            # local) is selected on device inside the jitted trainer
            with tracer.span("trainer", round=rnd):
                final, cache_p, cached_steps, losses = self.trainer(
                    global_params, caches, self._put1(resume),
                    self._put1(steps_needed), self._put1(stop),
                    cache_every)

            # timing + round termination
            success = selected & ~fail & (steps_needed > 0)
            completed = np.minimum(steps_needed, stop)
            times = fleet.round_times(steps_needed, distribute, completed,
                                      success)
            t_cut, duration = self._close_round(times, plan, policy)
            received = success & (times <= t_cut)

            # fused server step (§4.3 hot path): aggregation weights with
            # the staleness discount for stale BASE models, packed
            # whole-model weighted aggregation, C3 cache write/clear —
            # one jitted call, params never leave the device.
            extra_w = ones_w if plan.agg_weights is None else \
                self._put1(np.asarray(plan.agg_weights, np.float32))
            # fused metrics dispatch (telemetry on): reductions over the
            # pre-step state — the legacy loop is host-synchronous, so
            # values are read back within the round below
            mx = self._metrics_dispatch(
                metrics_fn, m_keys, tracer, rnd, global_params, caches,
                rule_state, None, selected=selected, distribute=distribute,
                resume=resume, online=online, received=received,
                fail=fail, losses=losses, times=times, rows=final,
                rows_mask=received)
            with tracer.span("server_step", round=rnd):
                out = server_step(
                    global_params, caches, final, cache_p, cached_steps,
                    self._put1(selected), self._put1(fail),
                    self._put1(received), self._put1(resume),
                    n_samples, extra_w, rnd,
                    *self._step_extra(rule_state))
            if self._agg_stateful:
                global_params, caches, rule_state = out
            else:
                global_params, caches = out

            if self.debug_checks:
                self._debug_round_check(global_params, losses, None, rnd)
            with tracer.span("observe", round=rnd):
                state = policy.observe(
                    state, plan,
                    RoundReport(received=received, fail=fail,
                                losses=np.asarray(losses),
                                durations=times, duration=duration,
                                rnd=rnd))

            cum_comm, cum_time, acc = self._book_round(
                hist, rnd, n_rounds, eval_every, global_params,
                distribute & online, received, selected, duration,
                cum_comm, cum_time, acc, progress)
            if tel is not None:
                vals = {} if mx is None else \
                    {k: _metric_py(v) for k, v in
                     jax.device_get(mx).items()}
                if vals:
                    for k, v in vals.items():
                        hist.metrics.setdefault(k, []).append(v)
                tel.record_round({
                    "round": rnd, "evaluated": bool(hist.eval_mask[-1]),
                    "acc": None if acc != acc else acc,
                    "duration": float(duration), "comm_mb": cum_comm,
                    "wall_clock": cum_time,
                    "received": int(received.sum()),
                    "downloads": int((distribute & online).sum()),
                    "selected": int(selected.sum()), **vals})

        self._last_rule_state = rule_state
        return state, global_params, caches

    # -- device-resident dynamics round loop (repro.fleet) ------------------

    def _dynamics_fns(self, fleet):
        """Memoized device-dynamics artifacts for the configured process:
        (process, jitted init, jitted step, fused dynamics trainer).  The
        jitted step applies the fleet sharding constraint so draws stay
        sharded over the client mesh no matter what the process body
        produced.  (The round cut is memoized separately per straggler
        trait — see ``_round_cut``.)"""
        key = (self.fl_cfg.dynamics, self.fl_cfg.dynamics_params,
               self.cohort, self.offload)
        if key not in self._dyn_cache:
            N = self.fl_cfg.num_clients
            mesh = self.mesh
            feats = fleet.features(mesh)
            process = make_dynamics(self.fl_cfg.dynamics, self.sim_cfg,
                                    features=feats, mesh=mesh,
                                    params=self.fl_cfg.dynamics_params)

            def step(fstate, k):
                s, d = process.step(fstate, k)
                return (SP.fleet_constraint(s, mesh, N),
                        SP.fleet_constraint(d, mesh, N))

            init_fn = jax.jit(lambda k: SP.fleet_constraint(
                process.init_state(k), mesh, N))
            trainer = make_trainer(
                self.sim_cfg, self.data, mesh=mesh,
                dynamics_features=feats, cohort_size=self.cohort,
                external_cache_params=self.offload is not None)
            self._dyn_cache[key] = (process, init_fn, jax.jit(step),
                                    trainer)
        return self._dyn_cache[key]

    def _dyn_consts(self, fleet, uses_cache):
        """Per-run (N,) constants, placed once and reused across runs —
        steady-state dynamics rounds upload nothing."""
        key = ("cache_every", bool(uses_cache))
        if key not in self._round_consts:
            N = self.fl_cfg.num_clients
            ce = np.clip(np.round(core.adaptive_cache_interval(
                2.0, fleet.battery, fleet.stability)), 1, 4
            ).astype(np.int32) if uses_cache else np.full(N, BIG, np.int32)
            self._round_consts[key] = self._put1(ce)
        if "ones" not in self._round_consts:
            N = self.fl_cfg.num_clients
            self._round_consts["ones"] = self._put1(
                np.ones(N, np.float32))
            self._round_consts["full_steps"] = self._put1(
                np.full(N, self.sim_cfg.local_steps, np.int32))
        return (self._round_consts[key], self._round_consts["ones"],
                self._round_consts["full_steps"])

    def _from_plan(self, arr, dtype=None):
        """One (N,) plan field onto the fleet.  Device-native plans
        (flude) pass through untouched; host-side policy arrays cost one
        upload — the *draws* are device-resident either way."""
        if isinstance(arr, jax.Array):
            return arr
        with self._tracer.span("place_per_client"):
            return self._put1(np.asarray(arr) if dtype is None
                              else np.asarray(arr, dtype))

    # -- cache-offload round plumbing ----------------------------------------

    def _offload_idx_fn(self):
        """Memoized jit deriving the round's cohort index + overflow flag
        from the selection mask.  On the resident path this lives inside
        the trainer jit; the offload path needs the index *before* the
        trainer runs (the host-store fetch consumes it), so it gets its
        own small dispatch — same ``cohort_index`` computation, so the
        values (and everything downstream) are identical."""
        if self._idx_fn is None:
            X, mesh = int(self.cohort), self.mesh

            @jax.jit
            def idx_fn(selected):
                idx = SP.cohort_constraint(cohort_index(selected, X),
                                           mesh, X)
                overflow, = SP.replicated_constraint(
                    (cohort_overflow(selected, X),), mesh)
                return idx, overflow

            self._idx_fn = idx_fn
        return self._idx_fn

    def _expire_fn_jit(self):
        """Memoized jit of the device-side discard expiry (metadata-only
        ``core.expire_caches`` with the configured bound)."""
        if self._expire_fn is None:
            mesh, N = self.mesh, self.fl_cfg.num_clients
            bound = int(self.fl_cfg.cache_staleness_bound)

            @jax.jit
            def expire_fn(caches, rnd):
                return SP.fleet_constraint(
                    core.expire_caches(caches, rnd, bound), mesh, N)

            self._expire_fn = expire_fn
        return self._expire_fn

    def _zero_cohort_block(self):
        """Memoized all-zero (X, ...) cache block for policies that never
        cache (``uses_cache=False``): the resident path would gather the
        never-written zero pytree, so a constant zeros block placed once
        keeps the offload trainer's inputs — and its rounds — identical,
        with no per-round transfer at all."""
        if self._zeros_x is None:
            X = int(self.cohort)
            block = jax.tree.map(
                lambda a: jnp.zeros((X,) + a.shape, a.dtype),
                self._template)
            if self.mesh is not None:
                block = jax.device_put(block, jax.tree.map(
                    lambda l: SP.cohort_sharding(self.mesh, l.ndim),
                    block))
            self._zeros_x = block
        return self._zeros_x

    def _device_rounds(self, policy, state, fleet, hist, global_params,
                       caches, rng, n_rounds, time_budget, eval_every,
                       progress, tel=None):
        """Dynamics round loop: the round's availability/failure draw,
        workload, local training, timing model AND the quorum cut run on
        device (sharded over the client mesh) — process step, fused
        trainer, round cut, fused server step, four dispatches with no
        host value in between.  Bookkeeping is deferred through a
        ``_RoundLedger``: History rows are read back only when the
        pipeline depth forces it, at eval boundaries (the accuracy
        scalar), or at run end — with ``pipeline_depth`` > 1 the host
        dispatches round k+1 while round k still executes.  jnp-native
        policies (flude) keep even planning on device; host-side policies
        sync at their own ``np.asarray`` boundaries as before."""
        sim_cfg = self.sim_cfg
        n_samples = self._n_samples
        process, init_fn, step_fn, trainer = self._dynamics_fns(fleet)
        cache_every, ones_w, full_steps = self._dyn_consts(
            fleet, policy.uses_cache)
        server_step = self._server_step(policy.uses_cache)
        rule_state = self._init_rule_state()
        cut_fn = self._round_cut(policy.waits_for_stragglers)
        cohort_info = None if self.cohort is None \
            else (policy.name, self.cohort)
        tracer = tel.tracer if tel is not None else obs.NULL_TRACER
        # cohort rows are already the compact (X, ...) block; the full
        # scan advertises the policy's selection bound so O(rows · D)
        # metrics gather received rows instead of reading all N
        metrics_fn, m_keys = (None, ()) if tel is None else \
            self._metrics_fn(tel.level, policy.uses_cache,
                             rows_bound=None if self.cohort is not None
                             else policy.selection_bound())
        ledger = _RoundLedger(hist, sim_cfg.model_mb,
                              sim_cfg.round_deadline, progress, n_rounds,
                              cohort_info=cohort_info, telemetry=tel,
                              tracer=tracer)

        # independent dynamics key stream, reproducible per run
        dyn_base = jax.random.fold_in(jax.random.key(sim_cfg.seed),
                                      0x0F1EE7)
        fstate = init_fn(jax.random.fold_in(dyn_base, 1 << 20))

        draw = None
        for rnd in range(n_rounds):
            if time_budget is not None:
                # the budget check needs cum_time: resolve everything
                # in flight (budget runs are effectively depth 1)
                ledger.resolve()
                if ledger.cum_time >= time_budget:
                    break
            if tel is not None:
                tel.maybe_profile(rnd)
            rng, k_sel = jax.random.split(rng)
            with tracer.span("dynamics_step", round=rnd):
                fstate, draw = step_fn(fstate,
                                       jax.random.fold_in(dyn_base, rnd))
            stamp_pre_expire = None
            if self.offload == "discard" and policy.uses_cache:
                # device half of the discard bound: expire stale cache
                # metadata *before* planning reads it, so the planner
                # never resumes a row the host store prunes (the store
                # prunes with the same bound at write-back drain).  The
                # pre-expiry stamps stay live for the metrics dispatch
                # (cache_expired counts; the expire jit donates nothing)
                if metrics_fn is not None:
                    stamp_pre_expire = caches.round_stamp
                with tracer.span("cache_expire", round=rnd):
                    caches = self._expire_fn_jit()(caches, rnd)
            with tracer.span("plan", round=rnd):
                state, plan = policy.plan(
                    state, RoundObservation(rnd, draw.online, caches,
                                            draw=draw), k_sel)
            self._validate_plan(plan)
            sel_d = self._from_plan(plan.selected)
            dist_d = self._from_plan(plan.distribute)
            res_d = self._from_plan(plan.resume)
            base_steps = full_steps if plan.steps_override is None else \
                self._from_plan(plan.steps_override, np.int32)

            extra_w = ones_w if plan.agg_weights is None else \
                self._from_plan(plan.agg_weights, np.float32)
            if self.cohort is None:
                # fused round body: workload + failure/interruption +
                # masked local training + per-device timing, one dispatch
                with tracer.span("trainer", round=rnd):
                    (final, cache_p, cached_steps, losses, steps_needed,
                     fail, success, times) = trainer(
                        global_params, caches, draw, sel_d, dist_d,
                        res_d, base_steps, cache_every)

                # round termination on device: the cut is a device scalar
                # and the receive mask stays sharded; deadline-capped
                # rounds come back as a flag so the ledger bills the
                # exact f64 deadline.  The ledger counts ride the same
                # dispatch (``with_counts``).
                with tracer.span("round_cut", round=rnd):
                    (t_cut, received, capped, recv_n, down_n,
                     sel_n) = cut_fn(times, plan.quorum, success,
                                     draw.online, dist_d, sel_d)
                overflow = None
                mx = self._metrics_dispatch(
                    metrics_fn, m_keys, tracer, rnd, global_params,
                    caches, rule_state, stamp_pre_expire,
                    selected=sel_d, distribute=dist_d, resume=res_d,
                    online=draw.online, received=received, fail=fail,
                    losses=losses, times=times, rows=final,
                    rows_mask=received)
                with tracer.span("server_step", round=rnd):
                    out = server_step(
                        global_params, caches, final, cache_p,
                        cached_steps, sel_d, fail, received, res_d,
                        n_samples, extra_w, rnd,
                        *self._step_extra(rule_state))
                if self._agg_stateful:
                    global_params, caches, rule_state = out
                else:
                    global_params, caches = out
                report = RoundReport(received=received, fail=fail,
                                     losses=losses, durations=times,
                                     duration=t_cut, rnd=rnd)
            elif self.offload is None:
                # compact cohort: the trainer gathers the selected rows
                # into (X, ...) blocks on device and hands back scattered
                # (N,) report views; cut + aggregation run over X rows
                with tracer.span("trainer", round=rnd):
                    (final, cache_p, cached_steps, _losses_x, _steps_x,
                     fail, success, times, idx, overflow, losses_n,
                     fail_n, times_n) = trainer(
                        global_params, caches, draw, sel_d, dist_d,
                        res_d, base_steps, cache_every)
                with tracer.span("round_cut", round=rnd):
                    (t_cut, _received_x, received, capped, recv_n,
                     down_n, sel_n) = cut_fn(times, plan.quorum, success,
                                             idx, draw.online, dist_d,
                                             sel_d)
                # observability seam (tests / debugging): the last
                # round's device cohort index, still sharded
                self._last_cohort_idx = idx
                mx = self._metrics_dispatch(
                    metrics_fn, m_keys, tracer, rnd, global_params,
                    caches, rule_state, stamp_pre_expire,
                    selected=sel_d, distribute=dist_d, resume=res_d,
                    online=draw.online, received=received, fail=fail_n,
                    losses=losses_n, times=times_n, rows=final,
                    rows_mask=_received_x)
                with tracer.span("server_step", round=rnd):
                    out = server_step(
                        global_params, caches, final, cache_p,
                        cached_steps, idx, sel_d, fail, _received_x,
                        res_d, n_samples, extra_w, rnd,
                        *self._step_extra(rule_state))
                if self._agg_stateful:
                    global_params, caches, rule_state = out
                else:
                    global_params, caches = out
                report = RoundReport(received=received, fail=fail_n,
                                     losses=losses_n, durations=times_n,
                                     duration=t_cut, rnd=rnd)
            else:
                # host-offloaded cohort caches: derive the cohort index
                # in its own small jit so the host can start streaming
                # the cohort's cache rows (async d2h of idx, drain of
                # last round's write-back, async device_put of the (X,
                # ...) block) while this round's other dispatches are
                # being issued; the trainer/cut/server step are the same
                # cohort ops over the same rows, so trajectories stay
                # bit-identical to the resident path
                idx, overflow = self._offload_idx_fn()(sel_d)
                if policy.uses_cache:
                    with tracer.span("cache_fetch", round=rnd):
                        cache_x = self._cache_stream.fetch(idx, rnd)
                else:
                    cache_x = self._zero_cohort_block()
                with tracer.span("trainer", round=rnd):
                    (final, cache_p, cached_steps, _losses_x, _steps_x,
                     fail, success, times, losses_n, fail_n,
                     times_n) = trainer(
                        global_params, caches, cache_x, idx, draw, sel_d,
                        dist_d, res_d, base_steps, cache_every)
                with tracer.span("round_cut", round=rnd):
                    (t_cut, _received_x, received, capped, recv_n,
                     down_n, sel_n) = cut_fn(times, plan.quorum, success,
                                             idx, draw.online, dist_d,
                                             sel_d)
                self._last_cohort_idx = idx
                mx = self._metrics_dispatch(
                    metrics_fn, m_keys, tracer, rnd, global_params,
                    caches, rule_state, stamp_pre_expire,
                    selected=sel_d, distribute=dist_d, resume=res_d,
                    online=draw.online, received=received, fail=fail_n,
                    losses=losses_n, times=times_n, rows=final,
                    rows_mask=_received_x)
                with tracer.span("server_step", round=rnd):
                    out = server_step(
                        global_params, caches, final, cached_steps, idx,
                        sel_d, fail, _received_x, res_d, n_samples,
                        extra_w, rnd, *self._step_extra(rule_state))
                if self._agg_stateful:
                    (global_params, caches, write_x, stamp_x,
                     rule_state) = out
                else:
                    global_params, caches, write_x, stamp_x = out
                if policy.uses_cache:
                    # park the round's write-back: async copies start
                    # now, nothing blocks until next round's fetch
                    with tracer.span("cache_stage", round=rnd):
                        self._cache_stream.stage(idx, write_x,
                                                 _received_x, cache_p,
                                                 stamp_x)
                report = RoundReport(received=received, fail=fail_n,
                                     losses=losses_n, durations=times_n,
                                     duration=t_cut, rnd=rnd)

            if self.debug_checks:
                self._debug_round_check(
                    global_params, report.losses,
                    None if self.cohort is None else idx, rnd)
            with tracer.span("observe", round=rnd):
                state = policy.observe(state, plan, report)

            evaluated = rnd % eval_every == 0 or rnd == n_rounds - 1
            acc_dev = None
            if evaluated:
                with tracer.span("eval", round=rnd):
                    acc_dev = self._acc_fn(global_params, self._test_x,
                                           self._test_y)
            ledger.push(rnd, evaluated, t_cut, capped, recv_n,
                        down_n, sel_n, acc_dev, overflow=overflow,
                        metrics=mx)
            if progress and rnd % 10 == 0:
                ledger.resolve()        # live ticks resolve on schedule
            else:
                ledger.resolve(keep=self.pipeline_depth - 1)

        ledger.resolve()
        if self._cache_stream is not None:
            # apply the last round's parked write-back so the host store
            # reflects the final cache state (its copies have been in
            # flight since that round's server step was dispatched)
            with tracer.span("cache_flush"):
                self._cache_stream.flush(n_rounds)
        # pipelining seam: the process state (and last draw) stay
        # device-resident between runs, like the caches
        self._last_fleet_state = fstate
        self._last_draw = draw
        self._last_rule_state = rule_state
        return state, global_params, caches
