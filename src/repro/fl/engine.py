"""FleetEngine: the device-resident FL round loop behind the typed API.

The engine owns the vectorized local trainer, the fused jitted server
round step (weights + packed aggregation + C3 cache bookkeeping) and the
fleet simulator; policies are pure ``plan``/``observe`` transitions over
typed ``RoundPlan``/``RoundReport`` messages (see ``repro.fl.api``).

Global params and client caches stay device-resident across rounds —
the host only sees (N,)-sized masks/metadata each round, plus the test
accuracy at eval/progress boundaries (``eval_every``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.configs.base import FLConfig
from repro.data.synthetic import FederatedClassification
from repro.fl import classifier as CLF
from repro.fl.api import (Policy, RoundObservation, RoundPlan, RoundReport,
                          make_policy)
from repro.fl import policies as _builtin_policies  # noqa: F401  (registers)
from repro.fl.simulator import Fleet, SimConfig

BIG = 1 << 20


# ---------------------------------------------------------------------------
# Vectorized local trainer
# ---------------------------------------------------------------------------

def make_trainer(sim_cfg: SimConfig, data: FederatedClassification):
    x_all = jnp.asarray(data.x)            # (N, n, d)
    y_all = jnp.asarray(data.y)            # (N, n)
    n = x_all.shape[1]
    b = min(sim_cfg.batch_size, n)
    lr = sim_cfg.lr
    max_steps = sim_cfg.local_steps

    grad_fn = jax.vmap(jax.value_and_grad(CLF.clf_loss))

    @jax.jit
    def train_all(global_params, caches, resume, steps_needed, stop_step,
                  cache_every):
        """All-fleet masked local training (incl. fused resume selection).

        global_params: unstacked global model; each client starts from it
                       unless ``resume`` picks its cached local state.
        caches:       core.ClientCaches (stacked (N, ...) params).
        resume:       (N,) bool — train from local cache (C3/C4).
        steps_needed: (N,) steps each device must run this round (0 = idle).
        stop_step:    (N,) interruption step (>= steps_needed: no failure).
        cache_every:  (N,) cache interval in steps (C3 adaptive frequency).
        Returns (final_params, cache_params, cached_steps, mean_loss).
        """
        start_params = core.resume_params(caches, global_params, resume)
        zero_cache = start_params
        loss0 = jnp.zeros((x_all.shape[0],), jnp.float32)

        def step_fn(carry, j):
            params, cache, cached_steps, loss_sum = carry
            idx = (j * b + jnp.arange(b)) % n
            xb = x_all[:, idx]
            yb = y_all[:, idx]
            loss, grads = grad_fn(params, xb, yb)
            active = (j < steps_needed) & (j < stop_step)

            def upd(p, g):
                m = active.reshape((-1,) + (1,) * (p.ndim - 1))
                return jnp.where(m, p - lr * g, p)

            params = jax.tree.map(upd, params, grads)
            do_cache = active & (((j + 1) % jnp.maximum(cache_every, 1))
                                 == 0)

            def cupd(c, p):
                m = do_cache.reshape((-1,) + (1,) * (p.ndim - 1))
                return jnp.where(m, p, c)

            cache = jax.tree.map(cupd, cache, params)
            cached_steps = jnp.where(do_cache, j + 1, cached_steps)
            loss_sum = loss_sum + jnp.where(active, loss, 0.0)
            return (params, cache, cached_steps, loss_sum), None

        init = (start_params, zero_cache,
                jnp.zeros((x_all.shape[0],), jnp.int32), loss0)
        (params, cache, cached_steps, loss_sum), _ = jax.lax.scan(
            step_fn, init, jnp.arange(max_steps))
        done = jnp.minimum(steps_needed, stop_step)
        mean_loss = loss_sum / jnp.maximum(done, 1)
        return params, cache, cached_steps, mean_loss

    return train_all


# ---------------------------------------------------------------------------
# Round history
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class History:
    acc: List[float] = dataclasses.field(default_factory=list)
    comm_mb: List[float] = dataclasses.field(default_factory=list)   # cum.
    wall_clock: List[float] = dataclasses.field(default_factory=list)
    received: List[int] = dataclasses.field(default_factory=list)
    selected: List[int] = dataclasses.field(default_factory=list)
    # eval_mask[t] is False when acc[t] is a carried-forward stale value
    # (eval_every > 1 skipped the measurement that round)
    eval_mask: List[bool] = dataclasses.field(default_factory=list)
    part_count: Optional[np.ndarray] = None
    per_class_acc: Optional[np.ndarray] = None
    per_client_acc: Optional[np.ndarray] = None
    final_params: Any = None

    def _evaluated(self):
        mask = self.eval_mask or [True] * len(self.acc)
        for t, c, a, m in zip(self.wall_clock, self.comm_mb, self.acc,
                              mask):
            if m:
                yield t, c, a

    def time_to_accuracy(self, target: float) -> float:
        for t, _, a in self._evaluated():
            if a >= target:
                return t
        return float("inf")

    def comm_to_accuracy(self, target: float) -> float:
        for _, c, a in self._evaluated():
            if a >= target:
                return c
        return float("inf")


# ---------------------------------------------------------------------------
# FleetEngine
# ---------------------------------------------------------------------------

class FleetEngine:
    """Owns trainer + fused server step + fleet; runs policies by name.

    Construction jits the fleet trainer once; ``run`` can then be called
    repeatedly (different policies, same task) reusing the compiled round
    path — the multi-policy comparison loop of the paper's Table 1.

        engine = FleetEngine(data, sim_cfg, fl_cfg)
        hist = engine.run("flude")                      # sim_cfg.rounds
        hist = engine.run("random", time_budget=3600.0)

    A fleet passed to the constructor is reused (and its RNG advances
    across runs); otherwise each run draws a fresh ``Fleet(sim_cfg)`` so
    fixed seeds reproduce.
    """

    def __init__(self, data: FederatedClassification, sim_cfg: SimConfig,
                 fl_cfg: FLConfig, fleet: Optional[Fleet] = None):
        self.data = data
        self.sim_cfg = sim_cfg
        self.fl_cfg = fl_cfg
        self._fleet = fleet
        self.trainer = make_trainer(sim_cfg, data)
        self._acc_fn = jax.jit(CLF.clf_accuracy)
        self._server_steps = {}
        self._template = CLF.init_classifier(
            jax.random.key(sim_cfg.seed + 1), dim=data.x.shape[-1],
            num_classes=data.num_classes)

    def _server_step(self, uses_cache: bool):
        key = bool(uses_cache)
        if key not in self._server_steps:
            self._server_steps[key] = core.make_server_round_step(
                self._template, local_steps=self.sim_cfg.local_steps,
                agg_impl=self.fl_cfg.agg_impl,
                staleness_discount=self.fl_cfg.staleness_discount,
                uses_cache=key, block_c=self.fl_cfg.agg_block_c,
                block_d=self.fl_cfg.agg_block_d)
        return self._server_steps[key]

    def run(self, policy: Union[str, Policy], rounds: Optional[int] = None,
            time_budget: Optional[float] = None, eval_every: int = 1,
            progress: Optional[Callable] = None,
            diagnostics: bool = True) -> History:
        """Run FL rounds.  ``time_budget`` (simulated seconds) caps the run
        by wall clock instead of round count — the paper's comparison
        regime: faster policies (shorter rounds) fit more rounds in the
        same budget.  ``rounds`` (default ``sim_cfg.rounds``) remains the
        hard round cap.  ``diagnostics=False`` skips the O(N)-eval
        end-of-run per-class/per-client accuracy sweep (benchmarks)."""
        sim_cfg, fl_cfg = self.sim_cfg, self.fl_cfg
        fleet = self._fleet if self._fleet is not None else Fleet(sim_cfg)
        if isinstance(policy, str):
            policy = make_policy(policy, sim_cfg, fl_cfg, fleet)
        state = policy.init_state()
        n_rounds = sim_cfg.rounds if rounds is None else rounds

        rng = jax.random.key(sim_cfg.seed)
        global_params = self._template
        caches = core.init_caches(global_params, fl_cfg.num_clients)
        test_x = jnp.asarray(self.data.test_x)
        test_y = jnp.asarray(self.data.test_y)
        n_samples = jnp.full((fl_cfg.num_clients,), self.data.x.shape[1],
                             jnp.float32)

        # adaptive cache frequency (C3): steps between cache writes
        cache_every_np = np.clip(np.round(
            core.adaptive_cache_interval(2.0, fleet.battery,
                                         fleet.stability)), 1, 4
        ).astype(np.int32) if policy.uses_cache else \
            np.full(fl_cfg.num_clients, BIG, np.int32)
        cache_every = jnp.asarray(cache_every_np)

        hist = History()
        cum_comm = 0.0
        cum_time = 0.0
        acc = float("nan")
        full_steps = np.full(fl_cfg.num_clients, sim_cfg.local_steps,
                             np.int32)
        ones_w = jnp.ones((fl_cfg.num_clients,), jnp.float32)
        server_step = self._server_step(policy.uses_cache)

        for rnd in range(n_rounds):
            if time_budget is not None and cum_time >= time_budget:
                break
            rng, k_sel = jax.random.split(rng)
            online = fleet.online_mask()
            state, plan = policy.plan(
                state, RoundObservation(rnd, online, caches), k_sel)
            if getattr(plan, "_validated", False):
                # RoundPlan.create already ran the full checks; only the
                # fleet-size agreement is left to confirm
                if plan.selected.shape[0] != fl_cfg.num_clients:
                    raise ValueError(
                        f"RoundPlan sized {plan.selected.shape[0]} for a "
                        f"{fl_cfg.num_clients}-client fleet")
            else:
                plan.validate(fl_cfg.num_clients)
            selected = np.asarray(plan.selected)
            distribute = np.asarray(plan.distribute)
            resume = np.asarray(plan.resume)

            # per-device workload
            prior_steps = np.round(
                np.asarray(caches.progress) * sim_cfg.local_steps
            ).astype(np.int32)
            base_steps = full_steps if plan.steps_override is None \
                else np.asarray(plan.steps_override)
            steps_needed = np.where(resume,
                                    np.maximum(base_steps - prior_steps, 1),
                                    base_steps).astype(np.int32)
            steps_needed = np.where(selected, steps_needed, 0)

            # failures (exposure-scaled) + interruption points
            fail = fleet.failure_draw(
                steps_needed / max(sim_cfg.local_steps, 1))
            fail &= selected
            stop = np.where(fail, fleet.failure_step(steps_needed), BIG)

            # local training; the start state (fresh global vs cached
            # local) is selected on device inside the jitted trainer
            final, cache_p, cached_steps, losses = self.trainer(
                global_params, caches, jnp.asarray(resume),
                jnp.asarray(steps_needed), jnp.asarray(stop), cache_every)

            # timing + round termination (Algorithm 2 lines 13–16)
            success = selected & ~fail & (steps_needed > 0)
            completed = np.minimum(steps_needed, stop)
            times = fleet.round_times(steps_needed, distribute, completed,
                                      success)
            quorum = int(np.ceil(plan.quorum))
            finite = np.sort(times[np.isfinite(times)])
            if finite.size >= quorum and quorum > 0:
                t_cut = min(finite[quorum - 1], sim_cfg.round_deadline)
            elif not policy.waits_for_stragglers and finite.size > 0:
                # async/semi-async designs close at the last arrival
                t_cut = min(finite[-1], sim_cfg.round_deadline)
            else:
                t_cut = sim_cfg.round_deadline
            received = success & (times <= t_cut)
            duration = t_cut if np.isfinite(t_cut) else \
                sim_cfg.round_deadline

            # fused server step (§4.3 hot path): aggregation weights with
            # the staleness discount for stale BASE models, packed
            # whole-model weighted aggregation, C3 cache write/clear —
            # one jitted call, params never leave the device.
            extra_w = ones_w if plan.agg_weights is None else \
                jnp.asarray(plan.agg_weights, jnp.float32)
            global_params, caches = server_step(
                global_params, caches, final, cache_p, cached_steps,
                jnp.asarray(selected), jnp.asarray(fail),
                jnp.asarray(received), jnp.asarray(resume),
                n_samples, extra_w, rnd)

            state = policy.observe(
                state, plan,
                RoundReport(received=received, fail=fail,
                            losses=np.asarray(losses), durations=times,
                            duration=duration, rnd=rnd))

            cum_comm += (distribute.sum() + received.sum()) \
                * sim_cfg.model_mb
            cum_time += duration
            evaluated = rnd % eval_every == 0 or rnd == n_rounds - 1
            if evaluated:
                acc = float(self._acc_fn(global_params, test_x, test_y))
            hist.acc.append(acc)
            hist.eval_mask.append(evaluated)
            hist.comm_mb.append(cum_comm)
            hist.wall_clock.append(cum_time)
            hist.received.append(int(received.sum()))
            hist.selected.append(int(selected.sum()))
            if progress and rnd % 10 == 0:
                progress(rnd, acc, cum_comm, cum_time)

        # final diagnostics (paper Fig. 1(b)(c))
        if diagnostics:
            hist.per_class_acc = np.asarray(CLF.clf_per_class_accuracy(
                global_params, test_x, test_y, self.data.num_classes))
            pc = []
            for i in range(min(fl_cfg.num_clients, self.data.x.shape[0])):
                pc.append(float(self._acc_fn(
                    global_params, jnp.asarray(self.data.x[i]),
                    jnp.asarray(self.data.y[i]))))
            hist.per_client_acc = np.asarray(pc)
        for k, v in policy.history_extras(state).items():
            setattr(hist, k, v)
        hist.final_params = global_params
        return hist
