"""The six built-in server policies, ported to the typed registry API.

FLUDE (the paper) plus the five comparison baselines.  Each policy keeps
its mutable per-run state in an explicit ``PolicyState`` returned by
``init_state`` and threaded through ``plan``/``observe`` — the engine owns
the loop.  flude/safa/asyncfeded plan from device-resident cache metadata;
oort/fedsea are inherently host-side (numpy utility bookkeeping) and stay
so behind the same typed interface.

Caveat on purity: states that carry a ``np.random.RandomState`` (random,
oort, safa, fedsea) advance it *in place* inside ``plan`` — the typed
transitions are pure in their array fields but the host RNG is a cursor,
matching the historical runner's draw sequence exactly.  Replaying a
retained state re-draws fresh randomness; speculative/pipelined planning
over these policies must checkpoint the RandomState explicitly
(``state.get_state()``/``set_state``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.fl.api import (Policy, RoundObservation, RoundPlan, RoundReport,
                          register_policy)
from repro.fl.simulator import place_per_client

BIG = 1 << 20


# ---------------------------------------------------------------------------
# FLUDE (paper §4, Algorithms 1–2)
# ---------------------------------------------------------------------------

class FludePolicyState(NamedTuple):
    core: core.FludeState
    last: Optional[core.FludePlan]     # plan pending its belief update
    # the raw receive mask of ``last``'s round, parked dispatch-free by
    # ``observe`` and folded into the *next* round's plan dispatch (or
    # flushed at run end) — Eq. 1 bookkeeping costs zero extra dispatches
    pending_received: Optional[jax.Array] = None


# Alg. 1/2 planning and Eq. 1/3 bookkeeping are pure jnp over fixed-shape
# fleet arrays — one jitted dispatch per round each, instead of the old
# runner's eager op-by-op evaluation.  Memoized per config so repeated
# short runs (test suites, policy sweeps) never re-trace; bounded so a
# config sweep doesn't pin compiled executables for the process lifetime.
def _plan_body(st, caches, online, rng, hints, fl_cfg, with_hints):
    p = core.plan_round(st, caches, online, fl_cfg, rng,
                        explore_hints=hints if with_hints else None)
    # quorum clamp (can't wait for more receipts than selections)
    # fused into the plan dispatch: eager it is three op-by-op
    # round-trips per round; the f32 minimum here equals the host
    # path's float() min bit-for-bit (both operands are exact f32)
    q = jnp.minimum(p.quorum, p.selected.sum().astype(jnp.float32))
    return p._replace(quorum=q)


@functools.lru_cache(maxsize=8)
def _flude_plan_jit(fl_cfg, with_hints: bool):
    return jax.jit(lambda st, caches, online, rng, hints: _plan_body(
        st, caches, online, rng, hints, fl_cfg, with_hints))


@functools.lru_cache(maxsize=8)
def _flude_update_plan_jit(fl_cfg, with_hints: bool):
    """Fused Eq. 1 belief update (previous round's receipts) + this
    round's Alg. 1/2 plan — one dispatch where the eager split costs
    two.  The update runs first on the same values ``observe`` would
    have passed, so the state sequence (and every plan drawn from it)
    is unchanged."""
    def update_plan(st, last, received, caches, online, rng, hints):
        st = core.update_after_round(st, last, received, fl_cfg)
        return st, _plan_body(st, caches, online, rng, hints, fl_cfg,
                              with_hints)
    return jax.jit(update_plan)


@functools.lru_cache(maxsize=8)
def _flude_update_jit(fl_cfg):
    return jax.jit(lambda st, plan, received:
                   core.update_after_round(st, plan, received, fl_cfg))


@register_policy("flude")
class FludePolicy(Policy):
    """The paper's policy: Beta-belief dependability selection (Alg. 1),
    adaptive staleness/quorum control (Alg. 2) and C3 cache resume, all
    planned on device in one fused jitted dispatch per round."""
    uses_cache = True
    # Alg. 2 line 3 caps X at clients_per_round before budget shrinking
    selects_at_most_clients_per_round = True

    def __init__(self, sim_cfg, fl_cfg, fleet=None, mesh=None):
        super().__init__(sim_cfg, fl_cfg, fleet, mesh=mesh)
        # §4.1 optional: bias exploration toward charged/stable devices.
        # The product stays host-side fp64 (bit-identical to the golden
        # runs); only the *placement* changes under a fleet mesh.
        self._hints = None
        if fleet is not None:
            self._hints = place_per_client(
                np.asarray(fleet.battery * fleet.stability, np.float32),
                mesh)
        self._plan_jit = _flude_plan_jit(fl_cfg, self._hints is not None)
        self._update_plan_jit = _flude_update_plan_jit(
            fl_cfg, self._hints is not None)
        self._update_jit = _flude_update_jit(fl_cfg)
        if self._hints is None:
            self._hints = place_per_client(
                np.zeros((fl_cfg.num_clients,), np.float32), mesh)

    def init_state(self) -> FludePolicyState:
        return FludePolicyState(core.init_state(self.fl_cfg), None, None)

    def plan(self, state, obs: RoundObservation, rng):
        # fold the parked previous-round receipts (Eq. 1) into this
        # round's plan dispatch — same update on the same values, one
        # dispatch instead of two
        if state.pending_received is not None:
            plan_fused = lambda st, caches, online, rng_, hints: \
                self._update_plan_jit(st, state.last,
                                      state.pending_received, caches,
                                      online, rng_, hints)
        else:
            plan_fused = lambda st, caches, online, rng_, hints: \
                (st, self._plan_jit(st, caches, online, rng_, hints))
        if obs.draw is not None:
            # device round path: the online mask, the belief update, the
            # plan AND the quorum clamp stay on device, and
            # RoundPlan.device runs structural checks only — planning is
            # a pure dispatch, so the pipelined engine loop never drains
            # the device queue here.
            st, p = plan_fused(state.core, obs.caches, obs.draw.online,
                               rng, self._hints)
            plan = RoundPlan.device(p.selected, p.distribute, p.resume,
                                    p.quorum)
            return FludePolicyState(st, p, None), plan
        # legacy host-RNG path: re-upload the numpy mask, validate on host
        st, p = plan_fused(state.core, obs.caches,
                           jnp.asarray(obs.online), rng, self._hints)
        quorum = float(p.quorum)    # already clamped inside the plan jit
        # masks stay jax arrays: the engine consumes them in place, and
        # the host path's np.asarray sees equal values
        plan = RoundPlan.create(p.selected, p.distribute, p.resume, quorum)
        return FludePolicyState(st, p, None), plan

    def observe(self, state, plan, report: RoundReport):
        # under correlated dynamics (markov/sessions/trace) the received
        # mask folds *correlated* outcomes into the Beta dependability
        # beliefs (Eq. 1) — the posterior tracks the realized process,
        # not an i.i.d. idealization; the update rule is unchanged.  The
        # mask is parked as-is (zero dispatches here) and the update
        # rides the next plan's jit (or the run-end flush below).
        return FludePolicyState(state.core, state.last,
                                jnp.asarray(report.received))

    def _flush(self, state) -> core.FludeState:
        """Apply the parked final-round update (run end: no next plan
        dispatch will fold it in)."""
        if state.pending_received is None:
            return state.core
        return self._update_jit(state.core, state.last,
                                state.pending_received)

    def history_extras(self, state):
        return {"part_count": np.asarray(self._flush(state).part_count)}


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

@register_policy("random")
class RandomPolicy(Policy):
    """Vanilla FedAvg: uniform random selection, full distribution."""
    selects_at_most_clients_per_round = True

    def init_state(self) -> np.random.RandomState:
        return np.random.RandomState(self.sim_cfg.seed + 17)

    def plan(self, state, obs, rng):
        N = self.fl_cfg.num_clients
        sel = np.zeros(N, bool)
        idx = np.flatnonzero(obs.online)
        take = min(self.fl_cfg.clients_per_round, idx.size)
        sel[state.choice(idx, take, replace=False)] = True
        return state, RoundPlan.create(sel, sel, np.zeros(N, bool),
                                       float(take))


@dataclasses.dataclass(frozen=True)
class OortState:
    util: np.ndarray          # (N,) statistical utility (inf = unexplored)
    duration: np.ndarray      # (N,) last observed round duration
    eps: float
    rs: np.random.RandomState


@register_policy("oort")
class OortPolicy(Policy):
    """Oort [OSDI'21], simplified: statistical utility = loss·sqrt(n) with a
    system-speed penalty, ε-greedy exploration."""
    selects_at_most_clients_per_round = True

    def __init__(self, sim_cfg, fl_cfg, fleet=None, mesh=None):
        super().__init__(sim_cfg, fl_cfg, fleet, mesh=mesh)
        if fleet is None:
            raise ValueError("oort needs the fleet's speed profile")
        self.pref_duration = np.median(
            sim_cfg.local_steps / fleet.steps_per_sec)

    def init_state(self) -> OortState:
        N = self.fl_cfg.num_clients
        return OortState(np.full(N, np.inf), np.ones(N), 0.9,
                         np.random.RandomState(self.sim_cfg.seed + 29))

    def plan(self, state, obs, rng):
        N = self.fl_cfg.num_clients
        online = obs.online
        X = min(self.fl_cfg.clients_per_round, int(online.sum()))
        n_explore = int(round(state.eps * X))
        sel = np.zeros(N, bool)
        explored = np.isfinite(state.util)
        pool_new = np.flatnonzero(online & ~explored)
        take_new = min(n_explore, pool_new.size)
        if take_new:
            sel[state.rs.choice(pool_new, take_new, replace=False)] = True
        penal = np.where(state.duration > self.pref_duration,
                         (self.pref_duration / state.duration) ** 0.5, 1.0)
        score = np.where(online & explored & ~sel,
                         np.nan_to_num(state.util, posinf=0.0) * penal,
                         -np.inf)
        rest = X - sel.sum()
        if rest > 0:
            top = np.argsort(-score)[:rest]
            sel[top[score[top] > -np.inf]] = True
        new_state = dataclasses.replace(
            state, eps=max(state.eps * 0.98, 0.2))
        return new_state, RoundPlan.create(sel, sel, np.zeros(N, bool),
                                           float(sel.sum()))

    def observe(self, state, plan, report):
        upd = np.asarray(plan.selected) & report.received
        util = np.where(upd, report.losses * np.sqrt(
            self.sim_cfg.batch_size * self.sim_cfg.local_steps), state.util)
        duration = np.where(upd, report.durations, state.duration)
        return dataclasses.replace(state, util=util, duration=duration)


@register_policy("safa")
class SafaPolicy(Policy):
    """SAFA [IEEE TC'20], simplified semi-async: crashed/straggling devices
    keep local progress (lag-tolerant cache) and are force-synced only when
    their version lag exceeds τ.  Rounds close on SAFA's synchronization
    quota (a fraction of the selected set), not on the last arrival —
    that is what makes it SEMI-async."""
    uses_cache = True
    quota = 0.75
    selects_at_most_clients_per_round = True

    def __init__(self, sim_cfg, fl_cfg, fleet=None, mesh=None,
                 tau: int = 5):
        super().__init__(sim_cfg, fl_cfg, fleet, mesh=mesh)
        self.tau = tau

    def init_state(self) -> np.random.RandomState:
        return np.random.RandomState(self.sim_cfg.seed + 43)

    def plan(self, state, obs, rng):
        N = self.fl_cfg.num_clients
        sel = np.zeros(N, bool)
        idx = np.flatnonzero(obs.online)
        take = min(self.fl_cfg.clients_per_round, idx.size)
        sel[state.choice(idx, take, replace=False)] = True
        stamp = np.asarray(obs.caches.round_stamp)
        lag = np.where(stamp >= 0, obs.rnd - stamp, BIG)
        resume = sel & (lag <= self.tau)
        # quota of a small selected set can floor to 0, which would
        # idle-wait the full deadline every round — any selected set
        # needs a quorum of at least one upload
        quorum = float(np.floor(sel.sum() * self.quota))
        if take > 0:
            quorum = max(quorum, 1.0)
        return state, RoundPlan.create(sel, sel & ~resume, resume, quorum)


@register_policy("fedsea")
class FedSeaPolicy(Policy):
    """FedSEA [SenSys'22], simplified: balance completion times by scaling
    local steps with device speed; deadline-based aggregation."""
    waits_for_stragglers = False
    selects_at_most_clients_per_round = True

    def __init__(self, sim_cfg, fl_cfg, fleet=None, mesh=None):
        super().__init__(sim_cfg, fl_cfg, fleet, mesh=mesh)
        if fleet is None:
            raise ValueError("fedsea needs the fleet's speed profile")
        rel = fleet.steps_per_sec / fleet.steps_per_sec.max()
        self.steps = np.clip(
            np.round(sim_cfg.local_steps * rel), 1,
            sim_cfg.local_steps).astype(np.int32)

    def init_state(self) -> np.random.RandomState:
        return np.random.RandomState(self.sim_cfg.seed + 57)

    def plan(self, state, obs, rng):
        N = self.fl_cfg.num_clients
        sel = np.zeros(N, bool)
        idx = np.flatnonzero(obs.online)
        take = min(self.fl_cfg.clients_per_round, idx.size)
        sel[state.choice(idx, take, replace=False)] = True
        return state, RoundPlan.create(sel, sel, np.zeros(N, bool),
                                       float(sel.sum()),
                                       steps_override=self.steps)


@register_policy("mifa")
class MifaPolicy(Policy):
    """MIFA [NeurIPS'21, arXiv 2106.04159], adapted: memorized-update FL
    under arbitrary device unavailability.

    MIFA's server keeps every client's most recent update and aggregates
    *all* of them each round, stale or not, at full weight — that
    unbiasedness under unavailability is the whole point.  In this engine
    the memory is realized through the C3 cache machinery: every online
    device trains (no subsampling), interrupted devices keep their local
    progress cached and *always* resume it at the next opportunity, and the
    policy cancels the server's staleness discount through ``agg_weights``
    (``(1+s)^{+d}`` against the engine's ``(1+s)^{-d}``) so memorized
    stale-base updates aggregate undiscounted — the memorized-update
    stress test for the aggregation-weight machinery.
    """
    uses_cache = True
    waits_for_stragglers = False

    def init_state(self):
        return None

    def plan(self, state, obs, rng):
        sel = obs.online.copy()
        stamp = np.asarray(obs.caches.round_stamp)
        resume = sel & (stamp >= 0)
        # undo the engine's staleness discount on resumed (memorized) bases
        stale = np.where(resume, np.maximum(obs.rnd - stamp, 0), 0)
        w = np.power(1.0 + stale,
                     self.fl_cfg.staleness_discount).astype(np.float32)
        return state, RoundPlan.create(sel, sel & ~resume, resume,
                                       float(sel.sum()), agg_weights=w)


@register_policy("asyncfeded")
class AsyncFedEdPolicy(Policy):
    """AsyncFedED [2022], simplified: every online device trains; arrivals
    are aggregated with staleness-adaptive weights (euclidean-distance
    surrogate = version lag)."""
    waits_for_stragglers = False

    def init_state(self) -> np.ndarray:
        return np.zeros(self.fl_cfg.num_clients, np.int32)   # last sync rnd

    def plan(self, state, obs, rng):
        sel = obs.online.copy()
        lag = obs.rnd - state
        w = 1.0 / (1.0 + np.maximum(lag, 0))
        return state, RoundPlan.create(sel, sel, np.zeros_like(sel),
                                       float(sel.sum()), agg_weights=w)

    def observe(self, state, plan, report):
        return np.where(report.received, report.rnd, state)
