"""Back-compat runner entry point over the FleetEngine.

``run_fl(policy_name, data, sim_cfg, fl_cfg)`` is the historical one-shot
API; it now builds a :class:`repro.fl.engine.FleetEngine` and delegates.
New code should construct the engine directly (it reuses the compiled
round path across policies) and the typed policy API in ``repro.fl.api``.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.configs.base import FLConfig
from repro.data.synthetic import FederatedClassification
from repro.fl.api import (Policy, RoundObservation, RoundPlan,  # noqa: F401
                          RoundReport, available_policies, make_policy,
                          register_policy)
from repro.fl.engine import FleetEngine, History, make_trainer  # noqa: F401
from repro.fl.policies import (AsyncFedEdPolicy, FedSeaPolicy,  # noqa: F401
                               FludePolicy, MifaPolicy, OortPolicy,
                               RandomPolicy, SafaPolicy)
from repro.fl.simulator import Fleet, SimConfig


def run_fl(policy_name: str, data: FederatedClassification,
           sim_cfg: SimConfig, fl_cfg: FLConfig,
           fleet: Optional[Fleet] = None, eval_every: int = 1,
           time_budget: Optional[float] = None,
           progress: Optional[Callable] = None) -> History:
    """One-shot FL run: engine construction + ``engine.run`` in one call."""
    engine = FleetEngine(data, sim_cfg, fl_cfg, fleet=fleet)
    return engine.run(policy_name, time_budget=time_budget,
                      eval_every=eval_every, progress=progress)
