"""Cross-device FL round runner: FLUDE + baselines over the fleet simulator.

Local training is vectorized over the whole fleet (vmap) with per-device
step masks realizing selection, interruption and cache-resume — fixed-shape,
jits once.  Server-side policy logic (FLUDE Algorithms 1–2, or a baseline
policy) runs between rounds.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.configs.base import FLConfig
from repro.data.synthetic import FederatedClassification
from repro.fl import classifier as CLF
from repro.fl.simulator import Fleet, SimConfig

BIG = 1 << 20


# ---------------------------------------------------------------------------
# Vectorized local trainer
# ---------------------------------------------------------------------------

def make_trainer(sim_cfg: SimConfig, data: FederatedClassification):
    x_all = jnp.asarray(data.x)            # (N, n, d)
    y_all = jnp.asarray(data.y)            # (N, n)
    n = x_all.shape[1]
    b = min(sim_cfg.batch_size, n)
    lr = sim_cfg.lr
    max_steps = sim_cfg.local_steps

    grad_fn = jax.vmap(jax.value_and_grad(CLF.clf_loss))

    @jax.jit
    def train_all(global_params, caches, resume, steps_needed, stop_step,
                  cache_every):
        """All-fleet masked local training (incl. fused resume selection).

        global_params: unstacked global model; each client starts from it
                       unless ``resume`` picks its cached local state.
        caches:       core.ClientCaches (stacked (N, ...) params).
        resume:       (N,) bool — train from local cache (C3/C4).
        steps_needed: (N,) steps each device must run this round (0 = idle).
        stop_step:    (N,) interruption step (>= steps_needed: no failure).
        cache_every:  (N,) cache interval in steps (C3 adaptive frequency).
        Returns (final_params, cache_params, cached_steps, mean_loss).
        """
        start_params = core.resume_params(caches, global_params, resume)
        zero_cache = start_params
        loss0 = jnp.zeros((x_all.shape[0],), jnp.float32)

        def step_fn(carry, j):
            params, cache, cached_steps, loss_sum = carry
            idx = (j * b + jnp.arange(b)) % n
            xb = x_all[:, idx]
            yb = y_all[:, idx]
            loss, grads = grad_fn(params, xb, yb)
            active = (j < steps_needed) & (j < stop_step)

            def upd(p, g):
                m = active.reshape((-1,) + (1,) * (p.ndim - 1))
                return jnp.where(m, p - lr * g, p)

            params = jax.tree.map(upd, params, grads)
            do_cache = active & (((j + 1) % jnp.maximum(cache_every, 1))
                                 == 0)

            def cupd(c, p):
                m = do_cache.reshape((-1,) + (1,) * (p.ndim - 1))
                return jnp.where(m, p, c)

            cache = jax.tree.map(cupd, cache, params)
            cached_steps = jnp.where(do_cache, j + 1, cached_steps)
            loss_sum = loss_sum + jnp.where(active, loss, 0.0)
            return (params, cache, cached_steps, loss_sum), None

        init = (start_params, zero_cache,
                jnp.zeros((x_all.shape[0],), jnp.int32), loss0)
        (params, cache, cached_steps, loss_sum), _ = jax.lax.scan(
            step_fn, init, jnp.arange(max_steps))
        done = jnp.minimum(steps_needed, stop_step)
        mean_loss = loss_sum / jnp.maximum(done, 1)
        return params, cache, cached_steps, mean_loss

    return train_all


# ---------------------------------------------------------------------------
# Round history
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class History:
    acc: List[float] = dataclasses.field(default_factory=list)
    comm_mb: List[float] = dataclasses.field(default_factory=list)   # cum.
    wall_clock: List[float] = dataclasses.field(default_factory=list)
    received: List[int] = dataclasses.field(default_factory=list)
    selected: List[int] = dataclasses.field(default_factory=list)
    part_count: Optional[np.ndarray] = None
    per_class_acc: Optional[np.ndarray] = None
    per_client_acc: Optional[np.ndarray] = None

    def time_to_accuracy(self, target: float) -> float:
        for t, a in zip(self.wall_clock, self.acc):
            if a >= target:
                return t
        return float("inf")

    def comm_to_accuracy(self, target: float) -> float:
        for c, a in zip(self.comm_mb, self.acc):
            if a >= target:
                return c
        return float("inf")


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------

class Policy:
    """Server-side selection/distribution policy interface."""
    name = "base"
    uses_cache = False
    waits_for_stragglers = True   # sync designs idle-wait to the deadline

    def __init__(self, sim_cfg: SimConfig, fl_cfg: FLConfig):
        self.sim_cfg = sim_cfg
        self.fl_cfg = fl_cfg

    def plan(self, rnd, online, caches, rng) -> Dict[str, np.ndarray]:
        raise NotImplementedError

    def observe(self, plan, received, losses, durations):
        pass


class FludePolicy(Policy):
    name = "flude"
    uses_cache = True

    def __init__(self, sim_cfg, fl_cfg, fleet=None):
        super().__init__(sim_cfg, fl_cfg)
        self.state = core.init_state(fl_cfg)
        # §4.1 optional: bias exploration toward charged/stable devices
        self._hints = None
        if fleet is not None:
            self._hints = jnp.asarray(fleet.battery * fleet.stability,
                                      jnp.float32)

    def plan(self, rnd, online, caches, rng):
        p = core.plan_round(self.state, caches, jnp.asarray(online),
                            self.fl_cfg, rng, explore_hints=self._hints)
        self._last = p
        return {"selected": np.asarray(p.selected),
                "distribute": np.asarray(p.distribute),
                "resume": np.asarray(p.resume),
                "quorum": float(p.quorum)}

    def observe(self, plan, received, losses, durations):
        self.state = core.update_after_round(
            self.state, self._last, jnp.asarray(received), self.fl_cfg)


class RandomPolicy(Policy):
    """Vanilla FedAvg: uniform random selection, full distribution."""
    name = "random"

    def __init__(self, sim_cfg, fl_cfg):
        super().__init__(sim_cfg, fl_cfg)
        self._rng = np.random.RandomState(sim_cfg.seed + 17)

    def plan(self, rnd, online, caches, rng):
        N = self.fl_cfg.num_clients
        sel = np.zeros(N, bool)
        idx = np.flatnonzero(online)
        take = min(self.fl_cfg.clients_per_round, idx.size)
        sel[self._rng.choice(idx, take, replace=False)] = True
        return {"selected": sel, "distribute": sel,
                "resume": np.zeros(N, bool), "quorum": float(take)}


class OortPolicy(Policy):
    """Oort [OSDI'21], simplified: statistical utility = loss·sqrt(n) with a
    system-speed penalty, ε-greedy exploration."""
    name = "oort"

    def __init__(self, sim_cfg, fl_cfg, fleet: Fleet):
        super().__init__(sim_cfg, fl_cfg)
        N = fl_cfg.num_clients
        self.util = np.full(N, np.inf)        # unexplored = max utility
        self.duration = np.ones(N)
        self.eps = 0.9
        self._rng = np.random.RandomState(sim_cfg.seed + 29)
        self.pref_duration = np.median(
            sim_cfg.local_steps / fleet.steps_per_sec)

    def plan(self, rnd, online, caches, rng):
        N = self.fl_cfg.num_clients
        X = min(self.fl_cfg.clients_per_round, int(online.sum()))
        n_explore = int(round(self.eps * X))
        sel = np.zeros(N, bool)
        explored = np.isfinite(self.util)
        pool_new = np.flatnonzero(online & ~explored)
        take_new = min(n_explore, pool_new.size)
        if take_new:
            sel[self._rng.choice(pool_new, take_new, replace=False)] = True
        penal = np.where(self.duration > self.pref_duration,
                         (self.pref_duration / self.duration) ** 0.5, 1.0)
        score = np.where(online & explored & ~sel,
                         np.nan_to_num(self.util, posinf=0.0) * penal,
                         -np.inf)
        rest = X - sel.sum()
        if rest > 0:
            top = np.argsort(-score)[:rest]
            sel[top[score[top] > -np.inf]] = True
        self.eps = max(self.eps * 0.98, 0.2)
        return {"selected": sel, "distribute": sel,
                "resume": np.zeros(N, bool), "quorum": float(sel.sum())}

    def observe(self, plan, received, losses, durations):
        upd = plan["selected"] & received
        self.util = np.where(upd, losses * np.sqrt(
            self.sim_cfg.batch_size * self.sim_cfg.local_steps), self.util)
        self.duration = np.where(upd, durations, self.duration)


class SafaPolicy(Policy):
    """SAFA [IEEE TC'20], simplified semi-async: crashed/straggling devices
    keep local progress (lag-tolerant cache) and are force-synced only when
    their version lag exceeds τ.  Rounds close on SAFA's synchronization
    quota (a fraction of the selected set), not on the last arrival —
    that is what makes it SEMI-async."""
    name = "safa"
    uses_cache = True
    quota = 0.75

    def __init__(self, sim_cfg, fl_cfg, tau: int = 5):
        super().__init__(sim_cfg, fl_cfg)
        self.tau = tau
        self._rng = np.random.RandomState(sim_cfg.seed + 43)

    def plan(self, rnd, online, caches, rng):
        N = self.fl_cfg.num_clients
        sel = np.zeros(N, bool)
        idx = np.flatnonzero(online)
        take = min(self.fl_cfg.clients_per_round, idx.size)
        sel[self._rng.choice(idx, take, replace=False)] = True
        stamp = np.asarray(caches.round_stamp)
        lag = np.where(stamp >= 0, rnd - stamp, BIG)
        resume = sel & (lag <= self.tau)
        return {"selected": sel, "distribute": sel & ~resume,
                "resume": resume,
                "quorum": float(np.floor(sel.sum() * self.quota))}


class FedSeaPolicy(Policy):
    """FedSEA [SenSys'22], simplified: balance completion times by scaling
    local steps with device speed; deadline-based aggregation."""
    name = "fedsea"
    waits_for_stragglers = False

    def __init__(self, sim_cfg, fl_cfg, fleet: Fleet):
        super().__init__(sim_cfg, fl_cfg)
        self.fleet = fleet
        self._rng = np.random.RandomState(sim_cfg.seed + 57)
        rel = fleet.steps_per_sec / fleet.steps_per_sec.max()
        self.steps = np.clip(
            np.round(sim_cfg.local_steps * rel), 1,
            sim_cfg.local_steps).astype(np.int32)

    def plan(self, rnd, online, caches, rng):
        N = self.fl_cfg.num_clients
        sel = np.zeros(N, bool)
        idx = np.flatnonzero(online)
        take = min(self.fl_cfg.clients_per_round, idx.size)
        sel[self._rng.choice(idx, take, replace=False)] = True
        return {"selected": sel, "distribute": sel,
                "resume": np.zeros(N, bool), "quorum": float(sel.sum()),
                "steps_override": self.steps}


class AsyncFedEdPolicy(Policy):
    """AsyncFedED [2022], simplified: every online device trains; arrivals
    are aggregated with staleness-adaptive weights (euclidean-distance
    surrogate = version lag)."""
    name = "asyncfeded"
    waits_for_stragglers = False

    def __init__(self, sim_cfg, fl_cfg):
        super().__init__(sim_cfg, fl_cfg)
        N = fl_cfg.num_clients
        self.last_sync = np.zeros(N, np.int32)

    def plan(self, rnd, online, caches, rng):
        sel = online.copy()
        lag = rnd - self.last_sync
        w = 1.0 / (1.0 + np.maximum(lag, 0))
        self._rnd = rnd
        return {"selected": sel, "distribute": sel,
                "resume": np.zeros_like(sel), "quorum": float(sel.sum()),
                "agg_weights": w}

    def observe(self, plan, received, losses, durations):
        self.last_sync = np.where(received, self._rnd, self.last_sync)


def make_policy(name: str, sim_cfg: SimConfig, fl_cfg: FLConfig,
                fleet: Fleet) -> Policy:
    if name == "flude":
        return FludePolicy(sim_cfg, fl_cfg, fleet)
    if name == "random":
        return RandomPolicy(sim_cfg, fl_cfg)
    if name == "oort":
        return OortPolicy(sim_cfg, fl_cfg, fleet)
    if name == "safa":
        return SafaPolicy(sim_cfg, fl_cfg)
    if name == "fedsea":
        return FedSeaPolicy(sim_cfg, fl_cfg, fleet)
    if name == "asyncfeded":
        return AsyncFedEdPolicy(sim_cfg, fl_cfg)
    raise KeyError(name)


# ---------------------------------------------------------------------------
# Main loop
# ---------------------------------------------------------------------------

def run_fl(policy_name: str, data: FederatedClassification,
           sim_cfg: SimConfig, fl_cfg: FLConfig,
           fleet: Optional[Fleet] = None, eval_every: int = 1,
           time_budget: Optional[float] = None,
           progress: Optional[Callable] = None) -> History:
    """Run FL rounds.  ``time_budget`` (simulated seconds) caps the run by
    wall clock instead of round count — the paper's comparison regime:
    faster policies (shorter rounds) fit more rounds in the same budget.
    ``sim_cfg.rounds`` remains the hard round cap."""
    fleet = fleet or Fleet(sim_cfg)
    policy = make_policy(policy_name, sim_cfg, fl_cfg, fleet)
    trainer = make_trainer(sim_cfg, data)

    rng = jax.random.key(sim_cfg.seed)
    global_params = CLF.init_classifier(
        jax.random.key(sim_cfg.seed + 1), dim=data.x.shape[-1],
        num_classes=data.num_classes)
    caches = core.init_caches(global_params, fl_cfg.num_clients)
    test_x = jnp.asarray(data.test_x)
    test_y = jnp.asarray(data.test_y)
    n_samples = jnp.full((fl_cfg.num_clients,), data.x.shape[1], jnp.float32)

    # adaptive cache frequency (C3): steps between cache writes
    cache_every_np = np.clip(np.round(
        core.adaptive_cache_interval(2.0, fleet.battery,
                                     fleet.stability)), 1, 4
    ).astype(np.int32) if policy.uses_cache else \
        np.full(fl_cfg.num_clients, BIG, np.int32)

    hist = History()
    cum_comm = 0.0
    cum_time = 0.0
    acc_fn = jax.jit(CLF.clf_accuracy)
    ones_w = jnp.ones((fl_cfg.num_clients,), jnp.float32)
    # fused server step: weights + packed aggregation + cache bookkeeping
    server_step = core.make_server_round_step(
        global_params, local_steps=sim_cfg.local_steps,
        agg_impl=fl_cfg.agg_impl, staleness_discount=1.0,
        uses_cache=policy.uses_cache, block_c=fl_cfg.agg_block_c,
        block_d=fl_cfg.agg_block_d)

    for rnd in range(sim_cfg.rounds):
        if time_budget is not None and cum_time >= time_budget:
            break
        rng, k_sel = jax.random.split(rng)
        online = fleet.online_mask()
        plan = policy.plan(rnd, online, caches, k_sel)
        selected = plan["selected"]
        distribute = plan["distribute"]
        resume = plan["resume"]

        # per-device workload
        prior_steps = np.round(
            np.asarray(caches.progress) * sim_cfg.local_steps
        ).astype(np.int32)
        base_steps = plan.get("steps_override",
                              np.full(fl_cfg.num_clients,
                                      sim_cfg.local_steps, np.int32))
        steps_needed = np.where(resume,
                                np.maximum(base_steps - prior_steps, 1),
                                base_steps).astype(np.int32)
        steps_needed = np.where(selected, steps_needed, 0)

        # failures (exposure-scaled) + interruption points
        fail = fleet.failure_draw(steps_needed / max(sim_cfg.local_steps, 1))
        fail &= selected
        stop = np.where(fail, fleet.failure_step(steps_needed), BIG)

        # local training; the start state (fresh global vs cached local)
        # is selected on device inside the jitted trainer
        final, cache_p, cached_steps, losses = trainer(
            global_params, caches, jnp.asarray(resume),
            jnp.asarray(steps_needed), jnp.asarray(stop),
            jnp.asarray(cache_every_np))

        # timing + round termination (Algorithm 2 lines 13–16)
        success = selected & ~fail & (steps_needed > 0)
        completed = np.minimum(steps_needed, stop)
        times = fleet.round_times(steps_needed, distribute, completed,
                                  success)
        quorum = int(np.ceil(plan["quorum"]))
        finite = np.sort(times[np.isfinite(times)])
        if finite.size >= quorum and quorum > 0:
            t_cut = min(finite[quorum - 1], sim_cfg.round_deadline)
        elif not policy.waits_for_stragglers and finite.size > 0:
            # async/semi-async designs close the round at the last arrival
            t_cut = min(finite[-1], sim_cfg.round_deadline)
        else:
            t_cut = sim_cfg.round_deadline
        received = success & (times <= t_cut)
        duration = t_cut if np.isfinite(t_cut) else sim_cfg.round_deadline

        # fused server step (§4.3 hot path): aggregation weights with the
        # staleness discount for stale BASE models (refs [28–32]; applies
        # uniformly to every policy that resumes from old state — FLUDE
        # caches, SAFA lag-tolerant clients), packed whole-model weighted
        # aggregation, and C3 cache write/clear — one jitted call, params
        # never leave the device.
        extra_w = jnp.asarray(plan["agg_weights"], jnp.float32) \
            if "agg_weights" in plan else ones_w
        global_params, caches = server_step(
            global_params, caches, final, cache_p, cached_steps,
            jnp.asarray(selected), jnp.asarray(fail),
            jnp.asarray(received), jnp.asarray(resume),
            n_samples, extra_w, rnd)

        policy.observe(plan, received, np.asarray(losses), times)

        cum_comm += (distribute.sum() + received.sum()) * sim_cfg.model_mb
        cum_time += duration
        if rnd % eval_every == 0 or rnd == sim_cfg.rounds - 1:
            acc = float(acc_fn(global_params, test_x, test_y))
        hist.acc.append(acc)
        hist.comm_mb.append(cum_comm)
        hist.wall_clock.append(cum_time)
        hist.received.append(int(received.sum()))
        hist.selected.append(int(selected.sum()))
        if progress and rnd % 10 == 0:
            progress(rnd, acc, cum_comm, cum_time)

    # final diagnostics (paper Fig. 1(b)(c))
    hist.per_class_acc = np.asarray(CLF.clf_per_class_accuracy(
        global_params, test_x, test_y, data.num_classes))
    pc = []
    for i in range(min(fl_cfg.num_clients, data.x.shape[0])):
        pc.append(float(acc_fn(global_params, jnp.asarray(data.x[i]),
                               jnp.asarray(data.y[i]))))
    hist.per_client_acc = np.asarray(pc)
    if isinstance(policy, FludePolicy):
        hist.part_count = np.asarray(policy.state.part_count)
    hist.final_params = global_params
    return hist
