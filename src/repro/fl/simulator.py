"""Fleet simulator: device undependability, online dynamics, timing model.

Mirrors the paper's experimental setup (§5.2):
  * three dependability groups with normal-distributed undependability rates
    (means 0.2/0.4/0.6, variance 0.04);
  * online/offline state re-drawn every ``state_interval`` seconds with a
    per-device online rate in [0.2, 0.8];
  * heterogeneous compute speeds (three device tiers, like Reno/Find/A
    phones and TX2/NX/AGX Jetsons) and WiFi bandwidths (1–30 Mb/s).

Role within the fleet-dynamics subsystem (``repro.fleet``): ``Fleet`` is
the *population sampler* — its static per-device arrays seed
``FleetFeatures`` (see :meth:`Fleet.features`) for every registered
availability process — while its per-round draw methods
(``online_mask``/``failure_draw``/``failure_step``) remain the
``bernoulli_host`` process: the host-RNG path the golden trajectories
pin bit-for-bit.  Device-resident processes (markov, sessions, trace)
replace only the draws, never the population.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


def place_per_client(arr, mesh=None):
    """Host → device hand-off for one (N,) per-client array.

    The simulator's numpy arrays stay the host-side source of truth (the
    per-round draws are host RNG); everything that enters the jitted round
    path goes through here so with a fleet mesh it lands already sharded
    over the ``clients`` axis instead of being replicated and resharded
    inside the jit.  jax imports are local — importing the simulator never
    touches device state.
    """
    import jax
    import jax.numpy as jnp
    if mesh is None:
        return jnp.asarray(np.asarray(arr))
    from repro.sharding.partitioning import fleet_sharding
    host = np.asarray(arr)
    return jax.device_put(host, fleet_sharding(mesh, max(host.ndim, 1)))


@dataclasses.dataclass(frozen=True)
class SimConfig:
    num_clients: int = 100
    rounds: int = 100
    local_steps: int = 8
    batch_size: int = 32
    lr: float = 0.05
    # classifier capacity (repro.fl.classifier MLP).  Defaults match the
    # historical hard-coded model, so golden trajectories are untouched;
    # the N=1M fleet-state smoke shrinks these (the C3 cache pytree is
    # (N, params) — at a million clients the default ~17k-param model
    # would need ~70 GB of cache alone).
    model_hidden: int = 128
    model_depth: int = 2
    # undependability (three groups, paper §5.2)
    undep_means: tuple = (0.2, 0.4, 0.6)
    undep_std: float = 0.2           # sqrt(0.04)
    # online dynamics
    online_low: float = 0.2
    online_high: float = 0.8
    state_interval: float = 600.0    # 10 min
    # compute/communication heterogeneity
    steps_per_sec: tuple = (2.0, 1.0, 0.5)   # three device tiers
    bandwidth_mbps: tuple = (1.0, 30.0)      # WiFi range (megabits/s)
    model_mb: float = 20.0                   # transmitted model size
    round_deadline: float = 600.0            # T (seconds)
    group_mode: str = "random"               # random | class (dependability
                                             # correlated with data classes —
                                             # the paper's "unique and
                                             # critical data" scenario §2.2)
    seed: int = 0


class Fleet:
    """numpy-side device population; per-round draws are methods."""

    def __init__(self, cfg: SimConfig,
                 undep_means: Optional[tuple] = None):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        N = cfg.num_clients
        means = undep_means if undep_means is not None else cfg.undep_means
        if cfg.group_mode == "class":
            # align groups with the data partitioner's anchor classes
            # (client i anchors class i % 10) so whole classes live on
            # less-dependable devices — the paper's bias scenario
            group = (np.arange(N) % 10) % len(means)
        else:
            group = rng.randint(0, len(means), N)
        self.undep = np.clip(
            rng.randn(N) * cfg.undep_std + np.asarray(means)[group],
            0.02, 0.98)
        self.online_rate = rng.uniform(cfg.online_low, cfg.online_high, N)
        tier = rng.randint(0, len(cfg.steps_per_sec), N)
        self.steps_per_sec = np.asarray(cfg.steps_per_sec)[tier] \
            * rng.uniform(0.8, 1.2, N)
        lo, hi = cfg.bandwidth_mbps
        self.bandwidth = rng.uniform(lo, hi, N)          # megabits/s
        self.battery = rng.uniform(0.2, 1.0, N)
        self.stability = rng.uniform(0.3, 1.0, N)
        self._rng = rng

    def features(self, mesh=None):
        """Device-resident ``repro.fleet.FleetFeatures`` of this
        population (placed sharded over the client mesh when given) —
        the one-time host→device hand-off every dynamics process draws
        its static per-device parameters from."""
        from repro.fleet import FleetFeatures
        return FleetFeatures.from_fleet(self, mesh)

    # -- per-round draws ----------------------------------------------------
    def online_mask(self) -> np.ndarray:
        return self._rng.rand(self.cfg.num_clients) < self.online_rate

    def failure_draw(self, work_frac: np.ndarray) -> np.ndarray:
        """Bernoulli failure with exposure scaling: a device doing a
        fraction ``work_frac`` of a full local pass fails with probability
        1 - (1 - p)^work_frac (resumed devices are safer — §4.2)."""
        p = 1.0 - np.power(1.0 - self.undep, np.clip(work_frac, 0.0, 1.0))
        return self._rng.rand(self.cfg.num_clients) < p

    def failure_step(self, steps: np.ndarray) -> np.ndarray:
        """Uniform interruption point within each device's planned steps."""
        u = self._rng.rand(self.cfg.num_clients)
        return np.floor(u * np.maximum(steps, 1)).astype(np.int32)

    # -- timing model --------------------------------------------------------
    def comm_seconds(self) -> np.ndarray:
        """One model transmission (download or upload) per device."""
        return self.cfg.model_mb * 8.0 / self.bandwidth

    def train_seconds(self, steps: np.ndarray) -> np.ndarray:
        return steps / self.steps_per_sec

    def round_times(self, steps: np.ndarray, downloaded: np.ndarray,
                    completed_steps: np.ndarray,
                    success: np.ndarray) -> np.ndarray:
        """Wall-clock finish time per device (np.inf if it never uploads)."""
        t = np.where(downloaded, self.comm_seconds(), 0.0)
        t = t + self.train_seconds(completed_steps)
        t = t + np.where(success, self.comm_seconds(), 0.0)
        return np.where(success, t, np.inf)
