"""Device-resident fleet dynamics: availability processes, traces,
scenarios.

The typed ``init_state``/``step`` process API lives in
``repro.fleet.api``; importing this package registers the built-in
processes (``bernoulli_host``, ``bernoulli``, ``markov``, ``sessions``,
``trace``) and the named scenario presets.
"""
from repro.fleet.api import (DynamicsProcess, FleetDraw, FleetFeatures,
                             FleetState, availability_summary,
                             available_dynamics, get_dynamics,
                             make_dynamics, register_dynamics,
                             simulate_availability)
from repro.fleet import processes  # noqa: F401 — registers the built-ins
from repro.fleet import traces  # noqa: F401 — registers trace replay
from repro.fleet.traces import TraceProcess, synthesize_trace
from repro.fleet.processes import (BernoulliHostProcess, BernoulliProcess,
                                   MarkovProcess, SessionsProcess)
from repro.fleet.scenarios import (Scenario, apply_scenario,
                                   available_scenarios, get_scenario,
                                   register_scenario)
from repro.fleet.adversary import (Adversary, available_adversaries,
                                   get_adversary, make_adversary,
                                   register_adversary)
