"""Adversarial fleet models: Byzantine client attacks at a configurable
malicious fraction.

FLUDE's dependability machinery sees *undependable* devices (they fail
to upload); it is blind to *malicious* ones (they upload poison).  This
module supplies the attack side of the robust-aggregation story: a
registry of attack models mirroring ``repro.fleet.register_dynamics``,
selected via ``FLConfig.adversary`` / ``adversary_params`` and wired
into scenario presets.

An adversary is static per run: ``malicious_mask(num_clients, seed)``
deterministically marks ``malicious_frac`` of the fleet (an exact count,
seeded independently of the availability draws so attack sweeps hold
the fleet fixed).  Two corruption channels:

* ``flips_labels`` — data poisoning: the marked clients' local labels
  are flipped once at engine construction (``corrupt_data``); their
  *training* is honest on corrupt data.
* ``delta_scale`` — model poisoning: the marked clients' uploads are
  transformed inside the jitted server round step as
  ``u' = g + delta_scale * (u - g)``.  ``delta_scale = -s`` is the
  scaled sign-flip (reverse) attack — at 20% malicious and s=4 the
  weighted-mean update cancels almost exactly; ``delta_scale = +s`` is
  the gradient-scaling (boosting) attack.

The malicious mask is placed on device once; rounds add zero host syncs.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Type

import numpy as np


class Adversary:
    """Attack model: a deterministic malicious slice + corruption spec."""
    name = "base"
    flips_labels = False
    delta_scale: Optional[float] = None   # u' = g + delta_scale * (u - g)

    def __init__(self, malicious_frac: float = 0.1, **params):
        if not 0.0 <= float(malicious_frac) <= 1.0:
            raise ValueError(f"malicious_frac must be in [0, 1], got "
                             f"{malicious_frac!r}")
        self.malicious_frac = float(malicious_frac)
        self.params = dict(params)

    def malicious_mask(self, num_clients: int, seed: int) -> np.ndarray:
        """(N,) bool — exactly ``round(frac * N)`` marked clients, drawn
        from a salted RNG so the same sim seed compares attack fractions
        on the same fleet."""
        rng = np.random.RandomState((int(seed) + 0xAD5) % (2 ** 31))
        k = int(round(self.malicious_frac * num_clients))
        mask = np.zeros(num_clients, bool)
        mask[rng.permutation(num_clients)[:k]] = True
        return mask

    def corrupt_data(self, data, mask: np.ndarray):
        """Data-poisoning hook; identity unless ``flips_labels``."""
        return data


class _ScaledDeltaAdversary(Adversary):
    """Shared base for model-poisoning attacks parameterized by a scale."""
    _sign = 1.0
    _default_scale = 1.0

    def __init__(self, malicious_frac: float = 0.1,
                 scale: Optional[float] = None):
        super().__init__(malicious_frac)
        s = self._default_scale if scale is None else float(scale)
        if s <= 0:
            raise ValueError(f"scale must be positive, got {scale!r}")
        self.delta_scale = self._sign * s


class SignFlipAdversary(_ScaledDeltaAdversary):
    """Scaled reverse attack: ``u' = g - scale * (u - g)`` — malicious
    updates point *against* the honest descent direction, amplified."""
    _sign = -1.0
    _default_scale = 4.0


class GradScaleAdversary(_ScaledDeltaAdversary):
    """Boosting attack: ``u' = g + scale * (u - g)`` — malicious updates
    overshoot, dragging the mean far past the honest step."""
    _sign = 1.0
    _default_scale = 10.0


class LabelFlipAdversary(Adversary):
    """Data poisoning: malicious clients train honestly on flipped
    labels ``y' = (num_classes - 1) - y``."""
    flips_labels = True

    def corrupt_data(self, data, mask: np.ndarray):
        y = np.array(data.y)
        y[mask] = (data.num_classes - 1) - y[mask]
        return data._replace(y=y)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[Adversary]] = {}


def register_adversary(name: str, *, allow_override: bool = False):
    """Class decorator: ``@register_adversary("backdoor")`` makes the
    attack constructible by name through ``make_adversary`` /
    ``FLConfig.adversary``."""
    def deco(cls: Type[Adversary]) -> Type[Adversary]:
        if not (isinstance(cls, type) and issubclass(cls, Adversary)):
            raise TypeError(f"@register_adversary expects an Adversary "
                            f"subclass, got {cls!r}")
        if name in _REGISTRY and not allow_override:
            raise ValueError(f"adversary {name!r} already registered "
                             f"(pass allow_override=True to replace)")
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_adversary(name: str) -> Type[Adversary]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown adversary {name!r}; registered: "
                       f"{', '.join(available_adversaries())}") from None


def available_adversaries():
    return sorted(_REGISTRY)


def make_adversary(name: str, params: Tuple = ()) -> Adversary:
    """Instantiate a registered adversary.  ``params`` is the hashable
    ``FLConfig.adversary_params`` tuple of ``(key, value)`` pairs."""
    return get_adversary(name)(**dict(params))


register_adversary("sign_flip")(SignFlipAdversary)
register_adversary("grad_scale")(GradScaleAdversary)
register_adversary("label_flip")(LabelFlipAdversary)
