"""Typed fleet-dynamics process API: FleetState/FleetDraw + a registry.

FLUDE's premise is that device behavior follows *structured* probability
distributions over time (paper §3–4), but the seed simulator only drew
memoryless i.i.d. Bernoulli masks on the host.  This module defines the
device-resident alternative, mirroring the typed policy API of
``repro.fl.api``:

* ``FleetFeatures`` — the static per-device population (undependability,
  online rate, compute speed, bandwidth, battery, stability), placed on
  device (sharded over the ``("clients",)`` mesh axis) once;
* ``FleetState``    — a pytree threaded through rounds: a replicated round
  clock ``t`` plus a process-specific ``slot`` (Markov on/off bits,
  semi-Markov session clocks, a trace cursor, ...);
* ``FleetDraw``     — what one round's stochastic draw exposes to the
  engine: online mask, failure variates (mask at any work fraction via
  ``failure_mask``), interruption point (``interruption_step``),
  bandwidth and battery;
* ``DynamicsProcess`` — ``init_state(key)``/``step(state, key)`` pure
  transitions; ``step`` is jitted by the engine and must be traceable.

Failure coupling: a process emits one uniform variate ``fail_u`` and a
per-round full-exposure failure probability ``fail_p``.  The mask at work
fraction ``w`` is ``fail_u < 1 - (1 - fail_p)**w`` — monotone in ``w``, so
a single variate yields a consistent failure decision for every exposure
the planner might choose (the §4.2 resumed-devices-are-safer rule), and
the draw itself never has to wait for the plan.

Processes plug in through a decorator registry::

    @register_dynamics("my-process")
    class MyProcess(DynamicsProcess):
        ...

and are instantiated by name via ``make_dynamics`` /
``FLConfig.dynamics`` — no engine edits needed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Static population features
# ---------------------------------------------------------------------------

class FleetFeatures(NamedTuple):
    """Static per-device arrays, device-resident (each (N,) float32)."""
    undep: jax.Array           # full-exposure failure probability
    online_rate: jax.Array     # long-run availability target in [0.2, 0.8]
    steps_per_sec: jax.Array   # compute speed (device tier)
    bandwidth: jax.Array       # WiFi bandwidth, megabits/s
    battery: jax.Array         # [0, 1]
    stability: jax.Array       # [0, 1] network stability

    @classmethod
    def from_fleet(cls, fleet, mesh=None) -> "FleetFeatures":
        """Place the legacy numpy ``Fleet`` population on device (sharded
        over the client mesh axis when one is given).  One-time hand-off —
        per-round draws never touch the host again."""
        from repro.fl.simulator import place_per_client

        def put(a):
            return place_per_client(np.asarray(a, np.float32), mesh)

        return cls(undep=put(fleet.undep), online_rate=put(fleet.online_rate),
                   steps_per_sec=put(fleet.steps_per_sec),
                   bandwidth=put(fleet.bandwidth), battery=put(fleet.battery),
                   stability=put(fleet.stability))

    @property
    def num_clients(self) -> int:
        return self.undep.shape[0]


# ---------------------------------------------------------------------------
# Round state / draw pytrees
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FleetState:
    """Fleet-dynamics carry: a replicated round clock + process slot."""
    t: Any                     # scalar int32 round counter
    slot: Any = ()             # process-specific pytree ((N,)-leading leaves)


@dataclasses.dataclass(frozen=True)
class FleetDraw:
    """One round's stochastic fleet draw (all (N,) arrays, device-side).

    ``online`` is the availability mask; ``fail_p``/``fail_u`` encode the
    failure decision at any exposure (see module docstring); ``stop_u``
    places the interruption point uniformly within the planned steps;
    ``bandwidth``/``battery`` feed the timing model and caching policy.
    """
    online: Any                # (N,) bool
    fail_p: Any                # (N,) float32 — full-exposure failure prob
    fail_u: Any                # (N,) float32 — failure coupling variate
    stop_u: Any                # (N,) float32 — interruption position variate
    bandwidth: Any             # (N,) float32 — megabits/s this round
    battery: Any               # (N,) float32

    @property
    def fail(self):
        """Failure mask at full exposure (work_frac == 1)."""
        return self.fail_u < self.fail_p

    def failure_mask(self, work_frac):
        """Exposure-scaled failure: P = 1 - (1 - p)^work_frac (§4.2)."""
        w = jnp.clip(work_frac, 0.0, 1.0)
        p = 1.0 - jnp.power(1.0 - self.fail_p, w)
        return self.fail_u < p

    def interruption_step(self, steps):
        """Uniform interruption point within each device's planned steps."""
        return jnp.floor(self.stop_u * jnp.maximum(steps, 1)).astype(
            jnp.int32)

    def download_mask(self, distribute):
        """Downloads that actually happen this round.

        §4.4 transmits the fresh model only to *reachable* devices: a
        device the plan marks for distribution but the draw finds offline
        never receives it, so comm accounting must not bill the transfer.
        """
        return jnp.asarray(distribute) & self.online

    def take(self, idx):
        """Compact-cohort gather: the draw's rows at ``idx`` as a dense
        (X,) FleetDraw.  Out-of-range sentinel rows (the cohort index
        pads with N) fill with benign values — offline, failure
        impossible (p=0 against u=1), unit bandwidth so the timing model
        never divides by zero — matching what the full-scan path computes
        for never-selected devices.
        """
        def g(a, fill):
            return jnp.take(jnp.asarray(a), idx, axis=0, mode="fill",
                            fill_value=fill)

        return FleetDraw(
            online=g(self.online, False),
            fail_p=g(self.fail_p, 0.0),
            fail_u=g(self.fail_u, 1.0),
            stop_u=g(self.stop_u, 0.0),
            bandwidth=g(self.bandwidth, 1.0),
            battery=g(self.battery, 0.0))


for _cls, _data in ((FleetState, ["t", "slot"]),
                    (FleetDraw, ["online", "fail_p", "fail_u", "stop_u",
                                 "bandwidth", "battery"])):
    jax.tree_util.register_dataclass(_cls, data_fields=_data, meta_fields=[])


# ---------------------------------------------------------------------------
# Process protocol
# ---------------------------------------------------------------------------

class DynamicsProcess:
    """Fleet-dynamics process: static config + pure state transitions.

    ``init_state(key)`` builds the ``FleetState`` carry; ``step(state,
    key)`` maps it to ``(state', FleetDraw)`` and must be pure and
    jittable — the engine jits it once (with the fleet sharding
    constraint applied under a client mesh) and calls it every round with
    a per-round folded key.  ``host_side=True`` marks legacy processes
    whose draws come from the host RNG (``bernoulli_host``); the engine
    routes those through the historical numpy round path instead.
    """
    name = "base"
    host_side = False

    def __init__(self, sim_cfg, features: Optional[FleetFeatures] = None,
                 fleet=None, mesh=None, **params):
        if features is None:
            if fleet is None:
                raise ValueError(
                    f"dynamics process {self.name!r} needs FleetFeatures "
                    f"(or a Fleet to derive them from)")
            features = FleetFeatures.from_fleet(fleet, mesh)
        self.sim_cfg = sim_cfg
        self.features = features
        self.mesh = mesh
        self.params = dict(params)

    @property
    def num_clients(self) -> int:
        return self.features.num_clients

    def init_state(self, key) -> FleetState:
        return FleetState(t=jnp.int32(0))

    def step(self, state: FleetState, key) -> Tuple[FleetState, FleetDraw]:
        raise NotImplementedError

    # -- shared draw plumbing ----------------------------------------------
    def _base_draw(self, key, online, fail_p=None, bandwidth=None,
                   battery=None) -> FleetDraw:
        """Fill the coupling variates + defaults around a process's
        online mask (and optional overrides)."""
        f = self.features
        k_fail, k_stop = jax.random.split(key)
        n = (self.num_clients,)
        return FleetDraw(
            online=online,
            fail_p=f.undep if fail_p is None else fail_p,
            fail_u=jax.random.uniform(k_fail, n),
            stop_u=jax.random.uniform(k_stop, n),
            bandwidth=f.bandwidth if bandwidth is None else bandwidth,
            battery=f.battery if battery is None else battery)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[DynamicsProcess]] = {}


def register_dynamics(name: str, *, allow_override: bool = False):
    """Class decorator: ``@register_dynamics("markov")`` makes the process
    constructible by name through ``make_dynamics`` /
    ``FLConfig.dynamics``."""
    def deco(cls: Type[DynamicsProcess]) -> Type[DynamicsProcess]:
        if not (isinstance(cls, type)
                and issubclass(cls, DynamicsProcess)):
            raise TypeError(f"@register_dynamics expects a DynamicsProcess "
                            f"subclass, got {cls!r}")
        if name in _REGISTRY and not allow_override:
            raise ValueError(f"dynamics {name!r} already registered "
                             f"(pass allow_override=True to replace)")
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_dynamics(name: str) -> Type[DynamicsProcess]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown dynamics {name!r}; registered: "
                       f"{', '.join(available_dynamics())}") from None


def available_dynamics():
    return sorted(_REGISTRY)


def make_dynamics(name: str, sim_cfg, features=None, fleet=None, mesh=None,
                  params: Tuple = ()) -> DynamicsProcess:
    """Instantiate a registered process.  ``params`` is the hashable
    ``FLConfig.dynamics_params`` tuple of ``(key, value)`` pairs."""
    return get_dynamics(name)(sim_cfg, features=features, fleet=fleet,
                              mesh=mesh, **dict(params))


# ---------------------------------------------------------------------------
# Offline simulation helpers (examples / tests / summaries)
# ---------------------------------------------------------------------------

def simulate_availability(process: DynamicsProcess, rounds: int,
                          seed: int = 0) -> np.ndarray:
    """Roll a process forward ``rounds`` rounds; returns the (T, N) bool
    online matrix.  Works for host-side processes too (their draws come
    from the wrapped Fleet's RNG)."""
    if process.host_side:
        return np.stack([process.online_mask() for _ in range(rounds)])
    step = jax.jit(process.step)
    base = jax.random.key(seed)
    state = process.init_state(jax.random.fold_in(base, 1 << 16))
    rows = []
    for t in range(rounds):
        state, draw = step(state, jax.random.fold_in(base, t))
        rows.append(np.asarray(draw.online))
    return np.stack(rows)


def availability_summary(online: np.ndarray) -> Dict[str, float]:
    """Summary statistics of a (T, N) availability matrix: mean online
    fraction and mean session length (consecutive-online run length, in
    rounds, over sessions that started within the window)."""
    online = np.asarray(online, bool)
    frac = float(online.mean())
    # session starts: online now, offline (or window edge) before
    prev = np.vstack([np.zeros((1, online.shape[1]), bool), online[:-1]])
    starts = online & ~prev
    n_sessions = int(starts.sum())
    mean_len = float(online.sum() / n_sessions) if n_sessions else 0.0
    return {"mean_online_fraction": frac,
            "mean_session_length": mean_len,
            "num_sessions": n_sessions}
