"""Built-in fleet-dynamics processes.

Four availability regimes over the same static population
(``FleetFeatures``), all device-resident except the legacy host wrapper:

* ``bernoulli_host`` — the seed simulator's host-numpy RNG path, kept
  bit-identical for the golden trajectories (``host_side=True``: the
  engine routes it through the historical round loop);
* ``bernoulli``      — the same memoryless i.i.d. model, drawn on device
  from a folded jax key (the apples-to-apples device baseline);
* ``markov``         — two-state on/off churn with per-device transition
  rates whose stationary distribution matches each device's
  ``online_rate`` (correlated availability *in time*; cf. the
  correlated-failure regimes of arXiv 2305.09856);
* ``sessions``       — semi-Markov Weibull session/gap lengths with a
  diurnal gap modulation; mid-round interruption follows the session
  hazard, so with shape k=1 (memoryless) the engine's exposure-scaled
  Bernoulli rule ``1-(1-p)^work_frac`` is *exact*, and k<1 produces the
  heavy-tailed churn real fleets show.

The trace-replay process lives in ``repro.fleet.traces``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.fleet.api import (DynamicsProcess, FleetDraw, FleetState,
                             register_dynamics)


@register_dynamics("bernoulli_host")
class BernoulliHostProcess(DynamicsProcess):
    """Legacy host-RNG draws (the seed ``Fleet`` methods), unchanged.

    Exists so the registry covers the historical path; the engine detects
    ``host_side`` and runs the numpy round loop against the wrapped
    ``Fleet`` — every pre-existing golden trajectory stays bit-identical.
    """
    host_side = True

    def __init__(self, sim_cfg, features=None, fleet=None, mesh=None,
                 **params):
        if fleet is None:
            raise ValueError("bernoulli_host wraps the legacy Fleet — "
                             "pass fleet=")
        self.sim_cfg = sim_cfg
        self.fleet = fleet
        self.mesh = mesh
        self.params = dict(params)

    def online_mask(self):
        return self.fleet.online_mask()

    def failure_draw(self, work_frac):
        return self.fleet.failure_draw(work_frac)

    def failure_step(self, steps):
        return self.fleet.failure_step(steps)


@register_dynamics("bernoulli")
class BernoulliProcess(DynamicsProcess):
    """Memoryless i.i.d. availability, drawn on device.

    Distributionally the ``bernoulli_host`` model (online ~
    Bern(online_rate), exposure-scaled failures from ``undep``) but from
    a folded ``jax.random`` key — no host RNG, no per-round transfer."""

    def step(self, state, key):
        k_on, k_draw = jax.random.split(key)
        u = jax.random.uniform(k_on, (self.num_clients,))
        online = u < self.features.online_rate
        draw = self._base_draw(k_draw, online)
        return FleetState(t=state.t + 1, slot=state.slot), draw


@register_dynamics("markov")
class MarkovProcess(DynamicsProcess):
    """Two-state on/off churn chain, per-device rates.

    ``mean_on`` (rounds) sets the expected on-sojourn: the off→on rate is
    solved so each device's stationary availability equals its
    ``online_rate`` (clipped where the rates would leave [0, 1]).  Unlike
    ``bernoulli``, availability is correlated across rounds — a device
    seen online will likely stay online ~``mean_on`` rounds, which is
    what session-persistent selection policies exploit."""

    def __init__(self, sim_cfg, features=None, fleet=None, mesh=None,
                 mean_on: float = 5.0, **params):
        super().__init__(sim_cfg, features=features, fleet=fleet, mesh=mesh,
                         mean_on=mean_on, **params)
        self.mean_on = float(mean_on)
        r = self.features.online_rate
        self._p_on_off = jnp.clip(1.0 / self.mean_on, 0.0, 1.0)
        self._p_off_on = jnp.clip(self._p_on_off * r / (1.0 - r), 0.0, 1.0)

    def stationary(self) -> np.ndarray:
        """Analytic stationary P(online) per device (after clipping)."""
        p10 = np.broadcast_to(np.asarray(self._p_on_off),
                              (self.num_clients,))
        p01 = np.asarray(self._p_off_on)
        return p01 / (p01 + p10)

    def init_state(self, key):
        on0 = jax.random.uniform(key, (self.num_clients,)) \
            < self.features.online_rate
        return FleetState(t=jnp.int32(0), slot=on0)

    def step(self, state, key):
        k_flip, k_draw = jax.random.split(key)
        u = jax.random.uniform(k_flip, (self.num_clients,))
        on = jnp.where(state.slot, u >= self._p_on_off, u < self._p_off_on)
        draw = self._base_draw(k_draw, on)
        return FleetState(t=state.t + 1, slot=on), draw


def _weibull(key, shape, scale, k):
    """Weibull(scale, k) via inverse CDF: scale * (-ln(1-U))^{1/k}."""
    u = jax.random.uniform(key, shape, minval=1e-7, maxval=1.0)
    return scale * jnp.power(-jnp.log1p(-u), 1.0 / k)


@register_dynamics("sessions")
class SessionsProcess(DynamicsProcess):
    """Semi-Markov session/gap process with diurnal modulation.

    Devices alternate between online *sessions* and offline *gaps* whose
    lengths (in rounds) are Weibull-distributed: ``shape_on``/``shape_gap``
    < 1 gives the heavy-tailed sojourns measured on real fleets; per-device
    gap means are solved so long-run availability matches ``online_rate``.
    Gap draws are scaled by a diurnal factor ``1 + amp*cos(2π(t-phase)/
    period)`` — long gaps at "night" depress fleet-wide availability in a
    correlated, periodic way.

    Mid-round interruption uses the *session hazard*: ``fail_p`` is the
    probability the current session (age ``a``) ends within one more
    round, ``1 - S(a+1)/S(a)``, optionally mixed with the device's
    intrinsic ``undep`` (``undep_mix``).  With ``shape_on == 1`` the
    hazard is constant and the engine's exposure rule ``1-(1-p)^w`` is
    exactly the memoryless session-end probability within work ``w``
    (property-tested in tests/test_fleet_dynamics.py)."""

    def __init__(self, sim_cfg, features=None, fleet=None, mesh=None,
                 mean_on: float = 4.0, shape_on: float = 1.0,
                 shape_gap: float = 1.0, amp: float = 0.0,
                 period: float = 24.0, phase: float = 0.0,
                 undep_mix: float = 0.0, **params):
        super().__init__(sim_cfg, features=features, fleet=fleet, mesh=mesh,
                         mean_on=mean_on, shape_on=shape_on,
                         shape_gap=shape_gap, amp=amp, period=period,
                         phase=phase, undep_mix=undep_mix, **params)
        self.mean_on = float(mean_on)
        self.shape_on = float(shape_on)
        self.shape_gap = float(shape_gap)
        self.amp = float(amp)
        self.period = float(period)
        self.phase = float(phase)
        self.undep_mix = float(undep_mix)
        r = self.features.online_rate
        mean_gap = self.mean_on * (1.0 - r) / r
        # Weibull scale from mean: λ = mean / Γ(1 + 1/k)
        self._scale_on = self.mean_on / math.gamma(1.0 + 1.0 / self.shape_on)
        self._scale_gap = mean_gap / math.gamma(1.0 + 1.0 / self.shape_gap)

    def _diurnal(self, t):
        return 1.0 + self.amp * jnp.cos(
            2.0 * jnp.pi * (t - self.phase) / self.period)

    def session_hazard(self, age):
        """P(session ends within one more round | survived to ``age``)."""
        lam = self._scale_on
        k = self.shape_on
        return 1.0 - jnp.exp(jnp.power(age / lam, k)
                             - jnp.power((age + 1.0) / lam, k))

    def init_state(self, key):
        k_on, k_dur = jax.random.split(key)
        n = (self.num_clients,)
        on0 = jax.random.uniform(k_on, n) < self.features.online_rate
        dur_on = _weibull(k_dur, n, self._scale_on, self.shape_on)
        dur_gap = _weibull(jax.random.fold_in(k_dur, 1), n,
                           self._scale_gap, self.shape_gap)
        remaining = jnp.where(on0, dur_on, dur_gap)
        slot = {"on": on0, "remaining": remaining,
                "age": jnp.zeros(n, jnp.float32)}
        return FleetState(t=jnp.int32(0), slot=slot)

    def step(self, state, key):
        k_on, k_gap, k_draw = jax.random.split(key, 3)
        n = (self.num_clients,)
        slot = state.slot
        remaining = slot["remaining"] - 1.0
        expired = remaining <= 0.0
        on = jnp.where(expired, ~slot["on"], slot["on"])
        new_on = _weibull(k_on, n, self._scale_on, self.shape_on)
        new_gap = _weibull(k_gap, n,
                           self._scale_gap * self._diurnal(state.t),
                           self.shape_gap)
        remaining = jnp.where(expired, jnp.where(on, new_on, new_gap),
                              remaining)
        age = jnp.where(expired, 0.0, slot["age"] + 1.0)
        p_sess = self.session_hazard(age)
        fail_p = 1.0 - (1.0 - p_sess) \
            * (1.0 - self.undep_mix * self.features.undep)
        draw = self._base_draw(k_draw, on, fail_p=fail_p.astype(jnp.float32))
        new_slot = {"on": on, "remaining": remaining, "age": age}
        return FleetState(t=state.t + 1, slot=new_slot), draw
