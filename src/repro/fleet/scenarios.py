"""Named fleet-dynamics scenario presets.

A ``Scenario`` binds a registered dynamics process to a concrete,
hashable parameterization — the unit of comparison for "how does a
policy behave when the fleet churns / follows the sun / drops out in
regions".  ``apply_scenario(fl_cfg, name)`` returns an ``FLConfig`` with
``dynamics``/``dynamics_params`` set; everything else about the run is
untouched, so the same engine sweeps scenarios the way it sweeps
policies::

    for name in available_scenarios():
        engine = FleetEngine(data, sim, apply_scenario(fl, name))
        hist = engine.run("flude")
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.fleet.api import get_dynamics
from repro.fleet.adversary import get_adversary


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    dynamics: str                       # registered process name
    params: Tuple = ()                  # FLConfig.dynamics_params payload
    description: str = ""
    adversary: Optional[str] = None     # registered attack model (or None)
    adversary_params: Tuple = ()        # FLConfig.adversary_params payload

    def apply(self, fl_cfg):
        """FLConfig with this scenario's dynamics (and attack, if the
        scenario carries one) installed.  Benign scenarios leave the
        config's adversary untouched."""
        get_dynamics(self.dynamics)     # fail fast on unknown processes
        changes = dict(dynamics=self.dynamics,
                       dynamics_params=self.params)
        if self.adversary is not None:
            get_adversary(self.adversary)
            changes.update(adversary=self.adversary,
                           adversary_params=self.adversary_params)
        return dataclasses.replace(fl_cfg, **changes)


_REGISTRY: Dict[str, Scenario] = {}


def register_scenario(scenario: Scenario, *,
                      allow_override: bool = False) -> Scenario:
    if scenario.name in _REGISTRY and not allow_override:
        raise ValueError(f"scenario {scenario.name!r} already registered")
    _REGISTRY[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> Scenario:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; registered: "
                       f"{', '.join(available_scenarios())}") from None


def available_scenarios():
    return sorted(_REGISTRY)


def apply_scenario(fl_cfg, name: str):
    return get_scenario(name).apply(fl_cfg)


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------

register_scenario(Scenario(
    "paper", "bernoulli_host",
    description="The paper's §5.2 setup verbatim: host-RNG i.i.d. "
                "Bernoulli availability — bit-identical to the golden "
                "trajectories."))

register_scenario(Scenario(
    "churn", "markov", params=(("mean_on", 5.0),),
    description="Two-state Markov on/off churn: availability correlated "
                "across rounds (~5-round sessions), stationary rates "
                "matching the paper's online rates."))

register_scenario(Scenario(
    "diurnal", "sessions",
    params=(("mean_on", 4.0), ("shape_on", 0.8), ("shape_gap", 0.8),
            ("amp", 0.6), ("period", 24.0), ("undep_mix", 0.5)),
    description="Heavy-tailed Weibull sessions with a strong day/night "
                "gap modulation — fleet availability follows the sun."))

register_scenario(Scenario(
    "flash-crowd", "trace",
    params=(("pattern", "flash-crowd"), ("horizon", 96.0),
            ("trace_seed", 11.0)),
    description="Sparse baseline availability punctuated by bursts where "
                "most of the fleet arrives at once."))

register_scenario(Scenario(
    "correlated-dropout", "trace",
    params=(("pattern", "correlated-dropout"), ("horizon", 96.0),
            ("trace_seed", 13.0)),
    description="Regional outage events: whole device clusters drop "
                "offline for consecutive rounds (cf. arXiv 2305.09856)."))

register_scenario(Scenario(
    "trace-replay", "trace",
    params=(("pattern", "diurnal"), ("horizon", 168.0),
            ("trace_seed", 17.0)),
    description="Replay of a week-long recorded availability matrix "
                "(synthesized diurnal stand-in) — the evaluation regime "
                "for production traces."))

register_scenario(Scenario(
    "sign-flip-10", "bernoulli",
    adversary="sign_flip", adversary_params=(("malicious_frac", 0.1),),
    description="Byzantine scaled reverse attack: 10% of the fleet "
                "uploads u' = g - 4(u - g).  The weighted mean limps; "
                "robust agg_rules shrug it off."))

register_scenario(Scenario(
    "sign-flip-20", "bernoulli",
    adversary="sign_flip", adversary_params=(("malicious_frac", 0.2),),
    description="Byzantine scaled reverse attack at 20% malicious "
                "clients — the weighted-mean update cancels almost "
                "exactly; the acceptance regime for robust rules."))

register_scenario(Scenario(
    "label-flip-20", "bernoulli",
    adversary="label_flip", adversary_params=(("malicious_frac", 0.2),),
    description="Data poisoning: 20% of clients train honestly on "
                "flipped labels (y' = K-1-y)."))

register_scenario(Scenario(
    "grad-scale-10", "bernoulli",
    adversary="grad_scale", adversary_params=(("malicious_frac", 0.1),),
    description="Boosting attack: 10% of clients upload "
                "u' = g + 10(u - g), dragging the mean far past the "
                "honest step."))
