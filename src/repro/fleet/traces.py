"""Trace replay: recorded availability matrices + a synthetic generator.

``TraceProcess`` replays an (N, T) boolean availability matrix on device
— the matrix is placed (sharded over the client mesh axis) once at
``init_state`` and indexed by the round clock, wrapping at T.  Real
deployments record such matrices from production fleets; here
``synthesize_trace`` manufactures three structured regimes the i.i.d.
simulator cannot express:

* ``diurnal``            — per-device sinusoidal availability with a few
  timezone clusters (phase groups), so whole cohorts rise and set
  together;
* ``flash-crowd``        — a low-availability baseline punctuated by
  bursts where a large random cohort comes online simultaneously (the
  news-event / charging-hour pattern);
* ``correlated-dropout`` — regional outage events that knock an entire
  cluster offline for several consecutive rounds (the correlated client
  failures studied in arXiv 2305.09856).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.fleet.api import (DynamicsProcess, FleetState, register_dynamics)

TRACE_PATTERNS = ("diurnal", "flash-crowd", "correlated-dropout")


def synthesize_trace(num_clients: int, horizon: int,
                     pattern: str = "diurnal", seed: int = 0,
                     online_rate: Optional[np.ndarray] = None,
                     period: int = 24, amp: float = 0.4,
                     n_clusters: int = 4, event_rate: float = 0.05,
                     outage_len: int = 3, burst_frac: float = 0.8,
                     base_rate: float = 0.15) -> np.ndarray:
    """Generate an (N, T) boolean availability matrix.

    ``online_rate`` (per-device long-run target, (N,)) anchors the
    diurnal/correlated-dropout baselines; defaults to U[0.2, 0.8].
    """
    rng = np.random.RandomState(seed)
    N, T = num_clients, horizon
    if online_rate is None:
        online_rate = rng.uniform(0.2, 0.8, N)
    r = np.clip(np.asarray(online_rate, np.float64), 0.02, 0.98)
    cluster = rng.randint(0, max(n_clusters, 1), N)
    t = np.arange(T)

    if pattern == "diurnal":
        # timezone clusters: one phase per cluster, availability follows
        # a clipped sinusoid around each device's base rate
        phases = rng.uniform(0, period, max(n_clusters, 1))[cluster]
        p = r[:, None] + amp * np.cos(
            2 * np.pi * (t[None, :] + phases[:, None]) / period)
        return rng.rand(N, T) < np.clip(p, 0.02, 0.98)

    if pattern == "flash-crowd":
        # sparse baseline; every ``period`` rounds a burst pulls a large
        # random cohort online for a couple of rounds
        p = np.full((N, T), base_rate)
        for t0 in range(0, T, period):
            crowd = rng.rand(N) < burst_frac
            p[crowd, t0:t0 + max(period // 8, 2)] = 0.95
        return rng.rand(N, T) < p

    if pattern == "correlated-dropout":
        # independent baseline + regional outages: an event takes one
        # whole cluster offline for ``outage_len`` consecutive rounds
        online = rng.rand(N, T) < r[:, None]
        for t0 in range(T):
            if rng.rand() < event_rate:
                hit = cluster == rng.randint(0, max(n_clusters, 1))
                online[hit, t0:t0 + outage_len] = False
        return online

    raise ValueError(f"unknown trace pattern {pattern!r}; "
                     f"available: {', '.join(TRACE_PATTERNS)}")


@register_dynamics("trace")
class TraceProcess(DynamicsProcess):
    """Replay an (N, T) availability matrix, wrapping at T.

    Construct with an explicit ``trace=`` matrix (recorded data) or let
    it synthesize one via ``pattern``/``horizon``/``trace_seed`` — the
    scenario presets use the latter.  Failure/interruption variates stay
    stochastic (exposure-scaled from ``undep``); availability is the
    deterministic replay."""

    def __init__(self, sim_cfg, features=None, fleet=None, mesh=None,
                 trace: Optional[np.ndarray] = None,
                 pattern: str = "diurnal", horizon: float = 96,
                 trace_seed: float = 0, **params):
        super().__init__(sim_cfg, features=features, fleet=fleet, mesh=mesh,
                         pattern=pattern, horizon=horizon,
                         trace_seed=trace_seed, **params)
        if trace is None:
            trace = synthesize_trace(
                self.num_clients, int(horizon), pattern=pattern,
                seed=int(trace_seed),
                online_rate=np.asarray(self.features.online_rate),
                **{k: v for k, v in params.items()
                   if k in ("period", "amp", "n_clusters", "event_rate",
                            "outage_len", "burst_frac", "base_rate")})
        trace = np.asarray(trace, bool)
        if trace.ndim != 2 or trace.shape[0] != self.num_clients:
            raise ValueError(f"trace must be (num_clients, T), got "
                             f"{trace.shape} for {self.num_clients} clients")
        from repro.fl.simulator import place_per_client
        # one-time placement: (N, T) sharded over clients under a mesh
        self.trace = place_per_client(trace, mesh)
        self.horizon = trace.shape[1]

    def step(self, state, key):
        online = jnp.take(self.trace, state.t % self.horizon, axis=1)
        draw = self._base_draw(key, online)
        return FleetState(t=state.t + 1, slot=state.slot), draw
