"""Pallas TPU kernels for the compute hot-spots.

Each kernel package ships:
  kernel.py — pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (padding, GQA head mapping, interpret flag)
  ref.py    — pure-jnp oracle used by the allclose sweep tests

On this CPU container kernels are validated with interpret=True; model code
defaults to the XLA path (kernel_impl="xla").
"""
