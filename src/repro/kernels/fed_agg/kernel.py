"""Pallas TPU kernel: weighted federated aggregation  out = Σ_c w_c · u_c.

Tiling: parameters are flattened to (C, D) and blocked (BC, BD); the grid is
(nd, nc) with the client dimension innermost so each output tile accumulates
in a VMEM fp32 scratch across client blocks (grid iterations on TPU are
sequential over the trailing axis, so the scratch carries).  Weights ride in
VMEM as (BC,) blocks; MXU sees a (1, BC) × (BC, BD) matmul per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _agg_kernel(w_ref, u_ref, o_ref, acc_ref, *, n_cblocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...].astype(jnp.float32)          # (BC,)
    u = u_ref[...].astype(jnp.float32)          # (BC, BD)
    acc_ref[...] += jnp.einsum("c,cd->d", w, u)

    @pl.when(j == n_cblocks - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_c", "block_d", "interpret"))
def fed_agg_pallas(updates: jnp.ndarray, weights: jnp.ndarray,
                   *, block_c: int = 8, block_d: int = 2048,
                   interpret: bool = False) -> jnp.ndarray:
    """updates: (C, D) flattened client tensors; weights: (C,)."""
    C, D = updates.shape
    bc = min(block_c, C)
    bd = min(block_d, D)
    # pad to multiples
    Cp = -(-C // bc) * bc
    Dp = -(-D // bd) * bd
    if (Cp, Dp) != (C, D):
        updates = jnp.pad(updates, ((0, Cp - C), (0, Dp - D)))
        weights = jnp.pad(weights, (0, Cp - C))
    nd, nc = Dp // bd, Cp // bc

    out = pl.pallas_call(
        functools.partial(_agg_kernel, n_cblocks=nc),
        grid=(nd, nc),
        in_specs=[
            pl.BlockSpec((bc,), lambda i, j: (j,)),
            pl.BlockSpec((bc, bd), lambda i, j: (j, i)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Dp,), updates.dtype),
        scratch_shapes=[pltpu.VMEM((bd,), jnp.float32)],
        interpret=interpret,
    )(weights, updates)
    return out[:D]
