"""Public jit'd wrapper: aggregate a whole pytree of stacked client updates."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.fed_agg.kernel import fed_agg_pallas
from repro.kernels.fed_agg.ref import fed_agg_ref


def fed_agg(updates: jnp.ndarray, weights: jnp.ndarray, *,
            impl: str = "pallas_interpret", block_c: int = 8,
            block_d: int = 2048) -> jnp.ndarray:
    """Σ_c w_c · u_c for one stacked tensor (C, ...)."""
    C = updates.shape[0]
    shape = updates.shape[1:]
    if impl == "xla":
        return fed_agg_ref(updates, weights)
    flat = updates.reshape(C, -1)
    out = fed_agg_pallas(flat, weights, block_c=block_c, block_d=block_d,
                         interpret=(impl == "pallas_interpret"))
    return out.reshape(shape).astype(updates.dtype)


def fed_agg_tree(updates_tree: Any, weights: jnp.ndarray,
                 **kw) -> Any:
    """Aggregate every leaf of a stacked client-update pytree."""
    return jax.tree.map(lambda u: fed_agg(u, weights, **kw), updates_tree)


def fed_agg_packed(updates: jnp.ndarray, weights: jnp.ndarray, *,
                   impl: str = "xla", block_c: int = 8,
                   block_d: int = 2048) -> jnp.ndarray:
    """Σ_c w_c · u_c over an already-packed (C, D) buffer -> (D,).

    The packed buffer holds ALL leaves of a stacked client pytree
    (``repro.core.aggregation.pack_stacked``), so one call aggregates the
    whole model.  impl: "xla" | "pallas" | "pallas_interpret".
    """
    if impl == "xla":
        return fed_agg_ref(updates, weights)
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(f"unknown fed_agg impl: {impl!r}")
    return fed_agg_pallas(updates, weights, block_c=block_c,
                          block_d=block_d,
                          interpret=(impl == "pallas_interpret"))
