"""Public jit'd wrapper: aggregate a whole pytree of stacked client updates."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels.fed_agg.kernel import fed_agg_pallas
from repro.kernels.fed_agg.ref import fed_agg_ref


def fed_agg(updates: jnp.ndarray, weights: jnp.ndarray, *,
            impl: str = "pallas_interpret", block_c: int = 8,
            block_d: int = 2048) -> jnp.ndarray:
    """Σ_c w_c · u_c for one stacked tensor (C, ...)."""
    C = updates.shape[0]
    shape = updates.shape[1:]
    if impl == "xla":
        return fed_agg_ref(updates, weights)
    flat = updates.reshape(C, -1)
    out = fed_agg_pallas(flat, weights, block_c=block_c, block_d=block_d,
                         interpret=(impl == "pallas_interpret"))
    return out.reshape(shape).astype(updates.dtype)


def fed_agg_tree(updates_tree: Any, weights: jnp.ndarray,
                 **kw) -> Any:
    """Aggregate every leaf of a stacked client-update pytree."""
    return jax.tree.map(lambda u: fed_agg(u, weights, **kw), updates_tree)


def fed_agg_packed(updates: jnp.ndarray, weights: jnp.ndarray, *,
                   impl: str = "xla", block_c: int = 8,
                   block_d: int = 2048) -> jnp.ndarray:
    """Σ_c w_c · u_c over an already-packed (C, D) buffer -> (D,).

    The packed buffer holds ALL leaves of a stacked client pytree
    (``repro.core.aggregation.pack_stacked``), so one call aggregates the
    whole model.  impl: "xla" | "pallas" | "pallas_interpret".
    """
    if impl == "xla":
        return fed_agg_ref(updates, weights)
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(f"unknown fed_agg impl: {impl!r}")
    return fed_agg_pallas(updates, weights, block_c=block_c,
                          block_d=block_d,
                          interpret=(impl == "pallas_interpret"))


def fed_agg_packed_sharded(updates: jnp.ndarray, weights: jnp.ndarray, *,
                           mesh: Mesh, axis: str = "clients",
                           impl: str = "xla", block_c: int = 8,
                           block_d: int = 2048) -> jnp.ndarray:
    """``fed_agg_packed`` over a client-sharded (C, D) buffer -> (D,).

    shard_map over the ``axis`` mesh axis: every device runs the chosen
    single-device impl (xla einsum | pallas | pallas_interpret) on its
    *local* (C/k, D) block of clients — the Pallas kernel therefore never
    sees a partitioned operand, which GSPMD could not guarantee — and the
    fp32 partial weighted sums combine with one ``psum``.  The result is
    replicated (P()) so the surrounding unpack stays device-local.

    Weights must already be normalized globally (Σw = 1 across ALL
    clients); each shard contributes w_local · u_local unscaled.
    """
    if impl not in ("xla", "pallas", "pallas_interpret"):
        raise ValueError(f"unknown fed_agg impl: {impl!r}")

    def partial_sum(w_blk, u_blk):
        # per-shard partial Σ_c w_c·u_c in fp32, then one cross-shard psum
        part = fed_agg_packed(u_blk.astype(jnp.float32),
                              w_blk.astype(jnp.float32), impl=impl,
                              block_c=block_c, block_d=block_d)
        return jax.lax.psum(part.astype(jnp.float32), axis)

    return shard_map(partial_sum, mesh=mesh,
                     in_specs=(P(axis), P(axis, None)),
                     out_specs=P(),
                     check_rep=False)(weights, updates)
