"""Pure-jnp oracle for weighted federated aggregation."""
from __future__ import annotations

import jax.numpy as jnp


def fed_agg_ref(updates: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """out[d...] = Σ_c weights[c] · updates[c, d...]   (fp32 accumulate).

    updates: (C, ...) stacked client tensors; weights: (C,).
    """
    C = updates.shape[0]
    flat = updates.reshape(C, -1).astype(jnp.float32)
    out = jnp.einsum("c,cd->d", weights.astype(jnp.float32), flat)
    return out.reshape(updates.shape[1:]).astype(updates.dtype)
