"""Pallas TPU kernel: flash attention (online softmax), GQA + causal + SWA.

Tiling: grid (B, Hq, nq, nk) — the kv-block axis is innermost so the online
softmax state (m, l, acc) carries in VMEM scratch across kv blocks.  Block
shapes keep (Bq, D) / (Bk, D) tiles in VMEM with D padded to the 128-lane
MXU width by the wrapper.  GQA is realized in the k/v index_map
(kv_head = q_head // group); causal and sliding-window masking use
broadcasted iotas over absolute positions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window, q_offset: int,
                  bq: int, bk: int, n_kblocks: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (Bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (Bk, D)
    v = v_ref[0, 0].astype(jnp.float32)                  # (Bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Bq, Bk)

    qp = q_offset + i * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 0)
    kp = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_kblocks - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "q_offset",
                              "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window=None,
                           scale=None, q_offset: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) -> (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, "wrapper must pad seq lens"
    nq, nk = Sq // bq, Sk // bk

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk, n_kblocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
