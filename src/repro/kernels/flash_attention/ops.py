"""Public wrapper: padding to MXU-aligned tiles + layout adaptation.

Accepts the model-side layout (B, S, Hk, G, D) or the canonical
(B, H, S, D); pads D to 128 lanes and S to block multiples; strips padding
after the call.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, scale=None,
                    q_offset: int = 0, block_q: int = 128,
                    block_k: int = 128, impl: str = "pallas_interpret"):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) -> (B, Hq, Sq, D)."""
    if impl == "xla":
        return attention_ref(q, k, v, causal=causal, window=window,
                             scale=scale, q_offset=q_offset)
    B, Hq, Sq, D = q.shape
    if scale is None:
        scale = D ** -0.5
    q, _ = _pad_to(q, 3, 128)
    k, _ = _pad_to(k, 3, 128)
    v, _ = _pad_to(v, 3, 128)
    bq = min(block_q, Sq)
    bk = min(block_k, k.shape[2])
    q, _ = _pad_to(q, 2, bq)
    # pad kv with positions masked out by never matching (append at end and
    # rely on causal/window mask only when Sk is already aligned; otherwise
    # mask via -inf on padded keys by zero-padding + explicit length mask is
    # unnecessary because padded kp > every qp when causal)
    k, Sk0 = _pad_to(k, 2, bk)
    v, _ = _pad_to(v, 2, bk)
    if not causal and k.shape[2] != Sk0:
        raise ValueError("non-causal flash requires Sk % block_k == 0")
    out = flash_attention_pallas(
        q, k, v, causal=causal, window=window, scale=scale,
        q_offset=q_offset, block_q=bq, block_k=bk,
        interpret=(impl == "pallas_interpret"))
    return out[:, :, :Sq, :D]


def flash_attention_model_layout(q, k, v, **kw):
    """Model layout adapter: q (B,S,Hk,G,D); k,v (B,S,Hk,D)."""
    B, S, Hk, G, D = q.shape
    qc = jnp.transpose(q, (0, 2, 3, 1, 4)).reshape(B, Hk * G, S, D)
    kc = jnp.transpose(k, (0, 2, 1, 3))
    vc = jnp.transpose(v, (0, 2, 1, 3))
    o = flash_attention(qc, kc, vc, **kw)
    return jnp.transpose(o.reshape(B, Hk, G, S, D), (0, 3, 1, 2, 4))
