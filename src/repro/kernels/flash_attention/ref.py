"""Pure-jnp oracle: dense softmax attention with causal/window masking."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: Optional[int] = None,
                  scale: Optional[float] = None,
                  q_offset: int = 0) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D); GQA via head grouping.

    Returns (B, Hq, Sq, D) in q's dtype (fp32 softmax inside).
    """
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    qg = q.reshape(B, Hkv, g, Sq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    qp = q_offset + jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)
