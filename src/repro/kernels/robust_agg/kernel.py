"""Pallas TPU kernel: per-client residual norms  dist_c = ||u_c - z||.

The Weiszfeld inner loop needs one (C,) distance vector per iteration —
the only part of the geometric median the weighted-sum kernel
(``repro.kernels.fed_agg``) cannot serve.  Tiling mirrors that kernel
with the roles of the axes swapped: the packed (C, D) buffer is blocked
(BC, BD) and the grid is (nc, nd) with the *parameter* dimension
innermost, so each client block accumulates its squared residuals in a
(BC,) VMEM fp32 scratch across D blocks (TPU grid iterations are
sequential over the trailing axis, so the scratch carries) and takes one
sqrt at the flush.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dist_kernel(z_ref, u_ref, o_ref, acc_ref, *, n_dblocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    z = z_ref[...].astype(jnp.float32)          # (BD,)
    u = u_ref[...].astype(jnp.float32)          # (BC, BD)
    r = u - z[None, :]
    acc_ref[...] += jnp.sum(r * r, axis=1)

    @pl.when(j == n_dblocks - 1)
    def _done():
        o_ref[...] = jnp.sqrt(acc_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_c", "block_d", "interpret"))
def residual_norms_pallas(updates: jnp.ndarray, center: jnp.ndarray,
                          *, block_c: int = 8, block_d: int = 2048,
                          interpret: bool = False) -> jnp.ndarray:
    """updates: (C, D) packed client rows; center: (D,) -> (C,) fp32.

    Zero-padding is exact: padded D columns are zero in both operands
    (residual 0), padded client rows are sliced off the output.
    """
    C, D = updates.shape
    bc = min(block_c, C)
    bd = min(block_d, D)
    Cp = -(-C // bc) * bc
    Dp = -(-D // bd) * bd
    if (Cp, Dp) != (C, D):
        updates = jnp.pad(updates, ((0, Cp - C), (0, Dp - D)))
        center = jnp.pad(center, (0, Dp - D))
    nc, nd = Cp // bc, Dp // bd

    out = pl.pallas_call(
        functools.partial(_dist_kernel, n_dblocks=nd),
        grid=(nc, nd),
        in_specs=[
            pl.BlockSpec((bd,), lambda i, j: (j,)),
            pl.BlockSpec((bc, bd), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bc,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Cp,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bc,), jnp.float32)],
        interpret=interpret,
    )(center, updates)
    return out[:C]
