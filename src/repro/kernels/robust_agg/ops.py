"""Robust reductions over the packed (C, D) aggregation buffer.

``geometric_median`` is the smoothed Weiszfeld iteration (RFA, Pillutla
et al. arXiv 1912.13445) built from two device primitives per step: the
per-client residual-norm kernel (``residual_norms``) and the existing
weighted-sum kernel (``repro.kernels.fed_agg``) — so every ``impl``
(xla | pallas | pallas_interpret) the mean path supports works here too.
The iteration count is static: the loop unrolls into one jit with no
convergence sync.

``*_sharded`` variants run under a ``("clients",)`` mesh via one
shard_map around the whole iteration: distances are shard-local (each
row lives whole on one device), and each Weiszfeld step needs exactly
two fp32 ``psum``s (Σβ_c·u_c and Σβ_c) — zero host syncs, matching the
mean path's collective discipline.  ``trimmed_mean_sharded`` instead
``all_gather``s the client rows and runs the coordinate-wise sort
replicated (a per-coordinate order statistic has no shard-local form);
fine at cohort scale, where the (X, D) buffer is small.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels.fed_agg.ops import fed_agg_packed
from repro.kernels.robust_agg.kernel import residual_norms_pallas

TINY = 1e-30


def residual_norms(updates: jnp.ndarray, center: jnp.ndarray, *,
                   impl: str = "xla", block_c: int = 8,
                   block_d: int = 2048) -> jnp.ndarray:
    """dist_c = ||u_c - z||_2 over a packed (C, D) buffer -> (C,) fp32."""
    if impl == "xla":
        r = updates.astype(jnp.float32) - center.astype(jnp.float32)[None]
        return jnp.sqrt(jnp.sum(r * r, axis=1))
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(f"unknown robust_agg impl: {impl!r}")
    return residual_norms_pallas(updates, center, block_c=block_c,
                                 block_d=block_d,
                                 interpret=(impl == "pallas_interpret"))


def _weiszfeld_step(updates, w, z, *, eps, impl, block_c, block_d,
                    psum_axis=None):
    """One smoothed Weiszfeld reweighting; ``psum_axis`` makes the two
    reductions (Σβ·u and Σβ) cross-shard."""
    dist = residual_norms(updates, z, impl=impl, block_c=block_c,
                          block_d=block_d)
    beta = jnp.where(w > 0, w / jnp.maximum(dist, eps), 0.0)
    bsum = beta.sum()
    if psum_axis is not None:
        bsum = jax.lax.psum(bsum, psum_axis)
    z = fed_agg_packed(updates, beta / jnp.maximum(bsum, TINY), impl=impl,
                       block_c=block_c, block_d=block_d)
    if psum_axis is not None:
        z = jax.lax.psum(z.astype(jnp.float32), psum_axis)
    return z


def geometric_median(updates: jnp.ndarray, weights: jnp.ndarray, *,
                     iters: int = 6, eps: float = 1e-6, impl: str = "xla",
                     block_c: int = 8, block_d: int = 2048) -> jnp.ndarray:
    """Smoothed Weiszfeld geometric median of (C, D) rows -> (D,) fp32.

    ``weights`` are the (unnormalized) aggregation weights — zero rows
    (clients that did not report) never influence the iteration.  The
    init point is the weighted mean, so ``iters=0`` degrades to the mean
    path exactly.
    """
    w = weights.astype(jnp.float32)
    u = updates.astype(jnp.float32)
    z = fed_agg_packed(u, w / jnp.maximum(w.sum(), TINY), impl=impl,
                       block_c=block_c, block_d=block_d)
    for _ in range(int(iters)):
        z = _weiszfeld_step(u, w, z, eps=eps, impl=impl, block_c=block_c,
                            block_d=block_d)
    return z


def geometric_median_sharded(updates: jnp.ndarray, weights: jnp.ndarray,
                             *, mesh: Mesh, axis: str = "clients",
                             iters: int = 6, eps: float = 1e-6,
                             impl: str = "xla", block_c: int = 8,
                             block_d: int = 2048) -> jnp.ndarray:
    """``geometric_median`` over a client-sharded (C, D) buffer -> (D,).

    One shard_map wraps the whole iteration; the result is replicated
    (P()) like the mean path's psum output.
    """
    def body(w_blk, u_blk):
        w = w_blk.astype(jnp.float32)
        u = u_blk.astype(jnp.float32)
        wsum = jax.lax.psum(w.sum(), axis)
        z = jax.lax.psum(
            fed_agg_packed(u, w / jnp.maximum(wsum, TINY), impl=impl,
                           block_c=block_c, block_d=block_d)
            .astype(jnp.float32), axis)
        for _ in range(int(iters)):
            z = _weiszfeld_step(u, w, z, eps=eps, impl=impl,
                                block_c=block_c, block_d=block_d,
                                psum_axis=axis)
        return z

    return shard_map(body, mesh=mesh, in_specs=(P(axis), P(axis, None)),
                     out_specs=P(), check_rep=False)(weights, updates)


def trimmed_mean(updates: jnp.ndarray, weights: jnp.ndarray, *,
                 trim: float = 0.2) -> jnp.ndarray:
    """Coordinate-wise weighted trimmed mean of (C, D) rows -> (D,) fp32.

    Per coordinate, the ``k = floor(trim * m)`` smallest and largest
    values among the ``m`` valid (weight > 0) clients are dropped and
    the survivors average with their weights (``k`` is capped so at
    least one row always survives).  Rank computation is the double
    argsort over the client axis — O(C log C) per coordinate, one fused
    sort kernel for the whole buffer.
    """
    u = updates.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    valid = w > 0
    m = valid.sum()
    k = jnp.minimum(jnp.floor(trim * m + 1e-6).astype(jnp.int32),
                    jnp.maximum((m - 1) // 2, 0))
    key = jnp.where(valid[:, None], u, jnp.inf)   # invalid ranks land last
    order = jnp.argsort(key, axis=0)
    ranks = jnp.argsort(order, axis=0)
    keep = valid[:, None] & (ranks >= k) & (ranks < m - k)
    num = (w[:, None] * keep * u).sum(axis=0)
    den = (w[:, None] * keep).sum(axis=0)
    return num / jnp.maximum(den, TINY)


def trimmed_mean_sharded(updates: jnp.ndarray, weights: jnp.ndarray, *,
                         mesh: Mesh, axis: str = "clients",
                         trim: float = 0.2) -> jnp.ndarray:
    """``trimmed_mean`` over a client-sharded buffer -> replicated (D,).

    The per-coordinate order statistics need every client's value, so
    the rows are ``all_gather``ed and the sort runs replicated on each
    device — redundant compute, zero extra syncs.
    """
    def body(w_blk, u_blk):
        wg = jax.lax.all_gather(w_blk, axis, tiled=True)
        ug = jax.lax.all_gather(u_blk, axis, tiled=True)
        return trimmed_mean(ug, wg, trim=trim)

    return shard_map(body, mesh=mesh, in_specs=(P(axis), P(axis, None)),
                     out_specs=P(), check_rep=False)(weights, updates)


def masked_median(x: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Lower median of ``x`` over ``valid`` entries (0.0 when none)."""
    m = valid.sum()
    order = jnp.sort(jnp.where(valid, x, jnp.inf))
    i = jnp.clip((m - 1) // 2, 0, x.shape[0] - 1)
    return jnp.where(m > 0, order[i], 0.0)
