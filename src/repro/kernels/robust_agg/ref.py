"""Numpy oracles for the robust aggregation rules.

``geometric_median_ref`` is the smoothed Weiszfeld iteration of RFA
(Pillutla et al., arXiv 1912.13445, Algorithm 1): starting from the
weighted mean, each iteration reweights every client by
``w_c / max(nu, ||u_c - z||)`` and recomputes the weighted average.  A
*fixed* static iteration count keeps the device version a straight-line
jit; the reference mirrors that exactly (no convergence test) so the
device kernels can be compared iteration-for-iteration.

``trimmed_mean_ref`` is the coordinate-wise trimmed mean over the valid
(weight > 0) clients: per coordinate, the ``k = floor(trim * m)``
smallest and largest valid values are discarded and the rest average
with their aggregation weights.
"""
from __future__ import annotations

import numpy as np

TINY = 1e-30


def geometric_median_ref(updates: np.ndarray, weights: np.ndarray,
                         iters: int = 6, eps: float = 1e-6) -> np.ndarray:
    """Smoothed Weiszfeld over (C, D) rows -> (D,).  float64 accumulate."""
    u = np.asarray(updates, np.float64)
    w = np.asarray(weights, np.float64)
    z = (w / max(w.sum(), TINY)) @ u
    for _ in range(int(iters)):
        dist = np.linalg.norm(u - z[None, :], axis=1)
        beta = np.where(w > 0, w / np.maximum(dist, eps), 0.0)
        z = (beta / max(beta.sum(), TINY)) @ u
    return z


def trimmed_mean_ref(updates: np.ndarray, weights: np.ndarray,
                     trim: float = 0.2) -> np.ndarray:
    """Coordinate-wise weighted trimmed mean over (C, D) rows -> (D,)."""
    u = np.asarray(updates, np.float64)
    w = np.asarray(weights, np.float64)
    valid = w > 0
    m = int(valid.sum())
    if m == 0:
        return np.zeros(u.shape[1])
    k = min(int(np.floor(trim * m + 1e-6)), max((m - 1) // 2, 0))
    out = np.zeros(u.shape[1])
    for d in range(u.shape[1]):
        col = u[valid, d]
        wv = w[valid]
        order = np.argsort(col, kind="stable")
        keep = order[k:m - k]
        out[d] = (wv[keep] @ col[keep]) / max(wv[keep].sum(), TINY)
    return out
