"""Pallas TPU kernel: WKV6 recurrence, chunk-resident state.

Grid (B, H, nc) — chunks innermost; the (D, D) per-head state lives in VMEM
fp32 scratch across chunks.  Within a chunk the exact per-timestep
recurrence runs in a fori_loop over VMEM-resident (c, D) tiles: each step is
an outer product k_t⊗v_t (rank-1 MXU update) + a VPU decay multiply —
this is the TPU-idiomatic shape for data-dependent per-channel decays that
break the plain-matmul chunk form (see DESIGN.md §4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
                y_ref, sf_ref, state_ref, *, chunk: int, n_chunks: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)      # (c, D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = jnp.exp(lw_ref[0, 0].astype(jnp.float32))
    u = u_ref[0].astype(jnp.float32)         # (D,)

    def step(t, S):
        kt = k[t]                            # (D,)
        vt = v[t]
        a = kt[:, None] * vt[None, :]        # (D, D) rank-1
        y = jax.lax.dot_general(
            (r[t] * u)[None, :], a, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[0] + jax.lax.dot_general(
            r[t][None, :], S, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[0]
        y_ref[0, 0, t] = y.astype(y_ref.dtype)
        return w[t][:, None] * S + a

    S = jax.lax.fori_loop(0, chunk, step, state_ref[...])
    state_ref[...] = S

    @pl.when(j == n_chunks - 1)
    def _done():
        sf_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan_pallas(r, k, v, logw, u, s0, *, chunk: int = 64,
                      interpret: bool = False):
    """r,k,v,logw: (B,H,S,D); u: (H,D); s0: (B,H,D,D) fp32."""
    B, H, S, D = r.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        widths = ((0, 0), (0, 0), (0, pad), (0, 0))
        r = jnp.pad(r, widths)
        k = jnp.pad(k, widths)          # k=0 ⇒ no state contribution
        v = jnp.pad(v, widths)
        logw = jnp.pad(logw, widths)    # logw=0 ⇒ identity decay
    Sp = S + pad
    nc = Sp // c

    kernel = functools.partial(_wkv_kernel, chunk=c, n_chunks=nc)
    y, sf = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, c, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, c, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, c, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, c, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, D), lambda b, h, j: (h, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sp, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, s0)
    return y[:, :, :S], sf
