"""Public wrapper for the WKV6 kernel: model layout (B, S, H, D) adapter.

``wkv_kernel_adapter`` plugs directly into ``repro.models.rwkv.time_mix``'s
``kernel=`` hook (same contract as ``wkv_recurrence``).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.rwkv6_scan.kernel import rwkv6_scan_pallas
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref


def rwkv6_scan(r, k, v, logw, u, s0: Optional[jnp.ndarray] = None, *,
               chunk: int = 64, impl: str = "pallas_interpret"):
    """Kernel layout (B,H,S,D) in/out."""
    B, H, S, D = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, D, D), jnp.float32)
    if impl == "xla":
        return rwkv6_scan_ref(r, k, v, logw, u, s0)
    return rwkv6_scan_pallas(r, k, v, logw, u, s0, chunk=chunk,
                             interpret=(impl == "pallas_interpret"))


def wkv_kernel_adapter(impl: str = "pallas_interpret", chunk: int = 64):
    """Returns fn(r,k,v,logw,u,state) in model layout (B,S,H,D)."""
    def fn(r, k, v, logw, u, state):
        rk = jnp.moveaxis(r, 1, 2)
        kk = jnp.moveaxis(k, 1, 2)
        vk = jnp.moveaxis(v, 1, 2)
        lw = jnp.moveaxis(logw, 1, 2)
        y, sf = rwkv6_scan(rk, kk, vk, lw, u, state, chunk=chunk, impl=impl)
        return jnp.moveaxis(y, 1, 2), sf
    return fn
