"""Pure-jnp oracle: exact WKV6 recurrence (kernel layout (B, H, S, D))."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, logw, u, s0: Optional[jnp.ndarray] = None):
    """r,k,v,logw: (B,H,S,D); u: (H,D); s0: (B,H,D,D) fp32.

    y_t = r_t · (S_{t-1} + diag(u)·k_t v_tᵀ);  S_t = diag(w_t)·S_{t-1}
                                                     + k_t v_tᵀ
    Returns y (B,H,S,D) fp32 and the final state.
    """
    B, H, S, D = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, D, D), jnp.float32)
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    wf = jnp.exp(logw.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp                       # (B,H,D)
        a = jnp.einsum("bhi,bhj->bhij", kt, vt)
        y = jnp.einsum("bhi,bhij->bhj", rt, S + uf[None, :, :, None] * a)
        return wt[..., None] * S + a, y

    xs = tuple(jnp.moveaxis(t, 2, 0) for t in (rf, kf, vf, wf))
    SF, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 2), SF
