"""Pallas TPU kernel: chunked Mamba2 SSD scan.

Grid (B, H, nc) — chunks innermost; the (P, N) state carries in VMEM fp32
scratch across chunk iterations.  Intra-chunk work is three MXU matmuls
((c,N)x(N,c), (c,c)x(c,P), (c,N)^T x (c,P)); the per-chunk decay vectors
live in VREGs.  The wrapper pads S to chunk multiples with dt = 0 (identity
decay, zero contribution).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
                y_ref, hf_ref, state_ref, *, n_chunks: int, chunk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        state_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)           # (c, P)
    dt = dt_ref[0, 0].astype(jnp.float32)         # (c,)
    A = a_ref[0]                                  # scalar (per head)
    Bm = b_ref[0, 0].astype(jnp.float32)          # (c, N)
    Cm = c_ref[0, 0].astype(jnp.float32)          # (c, N)

    a = dt * A                                    # (c,) log-decay, <= 0
    seg = jnp.cumsum(a)                           # (c,)
    state = state_ref[...]                        # (P, N)

    # intra-chunk: M[i,l] = (C_i · B_l) exp(seg_i - seg_l) [l <= i]
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (c, c)
    dseg = seg[:, None] - seg[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ll = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    M = jnp.where(ll <= ii, cb * jnp.exp(dseg), 0.0)
    xdt = x * dt[:, None]                         # (c, P)
    y = jax.lax.dot_general(M, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: y += exp(seg_i) * C_i · state
    cs = jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (c, P)
    y = y + jnp.exp(seg)[:, None] * cs
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: state' = exp(seg_last)·state + Σ_l w_l · x_l ⊗ B_l
    w = jnp.exp(seg[-1] - seg)                    # (c,)
    dstate = jax.lax.dot_general(xdt, Bm * w[:, None],
                                 (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    state_ref[...] = jnp.exp(seg[-1]) * state + dstate

    @pl.when(j == n_chunks - 1)
    def _done():
        hf_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan_pallas(x, dt, A, Bm, Cm, h0, *, chunk: int = 128,
                    interpret: bool = False):
    """x: (B,H,S,P); dt: (B,H,S); A: (H,); Bm,Cm: (B,H,S,N);
    h0: (B,H,P,N) fp32.  Returns (y (B,H,S,P), h_final (B,H,P,N))."""
    B, H, S, P = x.shape
    N = Bm.shape[-1]
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad)))   # dt=0: no-op steps
        Bm = jnp.pad(Bm, ((0, 0), (0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // c

    kernel = functools.partial(_ssd_kernel, n_chunks=nc, chunk=c)
    y, hf = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, c, P), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, c), lambda b, h, j: (b, h, j)),
            pl.BlockSpec((1,), lambda b, h, j: (h,)),
            pl.BlockSpec((1, 1, c, N), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, c, N), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, P), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sp, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, Cm, h0)
    return y[:, :, :S], hf
