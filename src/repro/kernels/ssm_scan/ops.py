"""Public wrapper: model layout + group expansion for the SSD kernel."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.ssm_scan.kernel import ssm_scan_pallas
from repro.kernels.ssm_scan.ref import ssm_scan_ref


def ssm_scan(x, dt, A, Bm, Cm, h0: Optional[jnp.ndarray] = None, *,
             chunk: int = 128, impl: str = "pallas_interpret"):
    """Model layout: x (B,S,H,P); dt (B,S,H); A (H,); Bm/Cm (B,S,G,N).

    Returns y (B,S,H,P) fp32 and final state (B,H,P,N) fp32.
    """
    B, S, H, P = x.shape
    G = Bm.shape[2]
    rep = H // G
    xk = jnp.moveaxis(x, 1, 2)                     # (B,H,S,P)
    dtk = jnp.moveaxis(dt, 1, 2)                   # (B,H,S)
    Bk = jnp.repeat(jnp.moveaxis(Bm, 1, 2), rep, axis=1)
    Ck = jnp.repeat(jnp.moveaxis(Cm, 1, 2), rep, axis=1)
    if h0 is None:
        h0 = jnp.zeros((B, H, P, Bm.shape[-1]), jnp.float32)
    if impl == "xla":
        y, hf = ssm_scan_ref(xk, dtk, A, Bk, Ck, h0)
    else:
        y, hf = ssm_scan_pallas(xk, dtk, A, Bk, Ck, h0, chunk=chunk,
                                interpret=(impl == "pallas_interpret"))
    return jnp.moveaxis(y, 1, 2), hf
