"""Pure-jnp oracle: per-timestep Mamba2 SSD recurrence (exact, sequential).

h_t = exp(dt_t · A_h) · h_{t-1} + dt_t · x_t ⊗ B_t ;   y_t = C_t · h_t
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def ssm_scan_ref(x, dt, A, Bm, Cm, h0: Optional[jnp.ndarray] = None):
    """x: (B, H, S, P); dt: (B, H, S); A: (H,) negative;
    Bm, Cm: (B, H, S, N) (groups pre-expanded to heads).
    Returns y (B, H, S, P) fp32 and final state (B, H, P, N) fp32."""
    B, H, S, P = x.shape
    N = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                      # (B,H,P),(B,H),(B,H,N)
        da = jnp.exp(dtt * Af[None, :])            # (B,H)
        h = da[..., None, None] * h + jnp.einsum(
            "bhp,bhn->bhpn", xt * dtt[..., None], bt)
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    xs = (jnp.moveaxis(xf, 2, 0), jnp.moveaxis(dtf, 2, 0),
          jnp.moveaxis(Bf, 2, 0), jnp.moveaxis(Cf, 2, 0))
    hF, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 2), hF
