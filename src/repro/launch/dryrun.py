from repro.launch.mesh import force_host_platform_device_count
force_host_platform_device_count(512)
# ^ MUST be the first two lines (before any jax import): the dry-run builds
# 512 placeholder host devices so jax.make_mesh can realize the production
# meshes.  Smoke tests and benchmarks never import this module and keep
# seeing 1 device.

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

For each combination this:
  1. builds the model's abstract params / optimizer state / caches
     (ShapeDtypeStruct stand-ins — nothing is allocated),
  2. jits the FLUDE train step (train_4k), prefill step (prefill_32k) or
     decode step (decode_32k / long_500k) with the production shardings,
  3. ``.lower().compile()`` — a failure here is a sharding bug,
  4. records memory_analysis / cost_analysis / roofline terms into
     results/dryrun/<arch>__<shape>__<mesh>.json (resumable).

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k \
      --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--force]
"""
import argparse
import json
import os
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.configs.base import TrainConfig
from repro.fl import cross_silo
from repro.launch.mesh import make_production_mesh, n_silos
from repro.models import ExecConfig, build_model, input_specs, \
    supports_shape
from repro.models import layers as PL
from repro.optim.optimizers import make_optimizer
from repro.roofline.analysis import build_roofline, model_flops
from repro.roofline.hlo import analyze_hlo_text, compiled_cost_analysis
from repro.sharding import partitioning as SP

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _exec_cfg(cfg, shape, mesh, rules, silos, overrides=None):
    kw = dict(mesh=mesh, rules=rules, moe_groups=silos)
    kw.update(overrides or {})
    return ExecConfig(**kw)


def _microbatches(cfg, shape, n_silo):
    """Per-silo microbatching keeps live activations bounded (§Perf)."""
    per_silo = max(shape.global_batch // n_silo, 1)
    target = 4 if cfg.d_model <= 8192 else 1
    if cfg.moe is not None and cfg.moe.num_experts >= 64:
        target = 1          # (T', E, C') dispatch tensors scale with E
    mb = max(per_silo // target, 1)
    while shape.global_batch % (mb) != 0 or \
            (shape.global_batch // mb) % 1 != 0:
        mb -= 1
    # microbatch count must divide the global batch
    while shape.global_batch % mb != 0:
        mb -= 1
    return mb


def lower_one(arch: str, shape_name: str, mesh_name: str,
              exec_overrides=None, microbatches=None, save_hlo=False):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    model = build_model(cfg)
    rules = SP.make_rules(cfg, mesh)
    pspecs = SP.param_shardings(model.specs, mesh, rules)
    silos = n_silos(mesh)
    ecfg = _exec_cfg(cfg, shape, mesh, rules, silos, exec_overrides)

    t0 = time.time()
    # >=150B params: bf16 optimizer moments + grad accumulators, else fp32
    # (hardware adaptation — fp32 Adam state for 405B alone exceeds a
    # 256-chip v5e pod; see DESIGN.md §3 / EXPERIMENTS.md §Perf)
    tc = TrainConfig()
    if model.param_count() > 1.5e11:
        tc = TrainConfig(moment_dtype="bfloat16", accum_dtype="bfloat16")
    if shape.kind == "train":
        opt = make_optimizer(tc)
        state = cross_silo.abstract_train_state(model, opt)
        # optimizer moments share the param shardings; scalars replicate
        from repro.optim.optimizers import OptState
        state_sh = cross_silo.TrainState(
            params=pspecs,
            opt_state=OptState(pspecs, pspecs, NamedSharding(mesh, P())),
            step=NamedSharding(mesh, P()),
        )
        batch = input_specs(cfg, shape)
        batch_sh = SP.batch_shardings(batch, mesh)
        w = jax.ShapeDtypeStruct((silos,), jnp.float32)
        w_sh = NamedSharding(mesh, P())
        mb = microbatches if microbatches is not None else \
            _microbatches(cfg, shape, silos)
        step_fn = cross_silo.make_train_step(
            model, tc, silos, ecfg, microbatches=mb)
        jitted = jax.jit(step_fn,
                         in_shardings=(state_sh, batch_sh, w_sh),
                         donate_argnums=(0,))
        args = (state, batch, w)
    elif shape.kind == "prefill":
        batch = input_specs(cfg, shape)
        batch_sh = SP.batch_shardings(batch, mesh)
        step_fn = cross_silo.make_prefill_step(model, ecfg)
        # output cache must be sharded like the decode-input cache —
        # otherwise XLA replicates the (L, B, S, Hkv, D) buffers
        out_abs = jax.eval_shape(step_fn, model.abstract_params(), batch)
        out_sh = (SP.batch_shardings(out_abs[0], mesh)
                  if out_abs[0] is not None else None,
                  SP.cache_shardings(out_abs[1], mesh))
        jitted = jax.jit(step_fn, in_shardings=(pspecs, batch_sh),
                         out_shardings=out_sh)
        args = (model.abstract_params(), batch)
    else:  # decode
        inp = input_specs(cfg, shape)
        cache = inp["cache"]
        cache_sh = SP.cache_shardings(cache, mesh)
        tok_sh = SP.batch_shardings(
            {"tokens": inp["tokens"], "positions": inp["positions"]}, mesh)
        step_fn = cross_silo.make_decode_step(model)
        jitted = jax.jit(step_fn,
                         in_shardings=(pspecs, tok_sh["tokens"],
                                       tok_sh["positions"], cache_sh),
                         donate_argnums=(3,))
        args = (model.abstract_params(), inp["tokens"], inp["positions"],
                cache)

    with mesh:
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    ca = compiled_cost_analysis(compiled)
    hlo_text = compiled.as_text()
    cost = analyze_hlo_text(hlo_text)

    n_active = model.active_param_count()
    mflops = model_flops(cfg, shape, n_active, shape.kind)
    roof = build_roofline(arch, shape_name, mesh_name, "", mesh.size,
                          mflops, cost=cost)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_devices": mesh.size,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_gb": mem.argument_size_in_bytes / 2**30,
            "output_gb": mem.output_size_in_bytes / 2**30,
            "temp_gb": mem.temp_size_in_bytes / 2**30,
            "peak_gb": (mem.argument_size_in_bytes
                        + mem.temp_size_in_bytes) / 2**30,
        },
        "xla_cost_analysis": {k: ca.get(k) for k in
                              ("flops", "bytes accessed")},
        "roofline": roof.to_dict(),
        "microbatches": microbatches,
        "params_total": model.param_count(),
        "params_active": n_active,
        "hlo_bytes": len(hlo_text),
    }
    if save_hlo:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        with open(os.path.join(
                RESULTS_DIR, f"{arch}__{shape_name}__{mesh_name}.hlo.txt"),
                "w") as f:
            f.write(hlo_text)
    return rec


def result_path(arch, shape, mesh_name):
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_name}.json")


def run_matrix(archs, shapes, meshes, force=False, save_hlo=False,
               exec_overrides=None):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    results = []
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            shape = INPUT_SHAPES[shape_name]
            if not supports_shape(cfg, shape):
                rec = {"arch": arch, "shape": shape_name, "mesh": "-",
                       "skipped": "needs sub-quadratic attention "
                                  "(see DESIGN.md §5)"}
                print(f"SKIP  {arch} × {shape_name}: full attention")
                path = result_path(arch, shape_name, "skip")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                continue
            for mesh_name in meshes:
                path = result_path(arch, shape_name, mesh_name)
                if os.path.exists(path) and not force:
                    print(f"HAVE  {arch} × {shape_name} × {mesh_name}")
                    continue
                print(f"RUN   {arch} × {shape_name} × {mesh_name} ...",
                      flush=True)
                try:
                    rec = lower_one(arch, shape_name, mesh_name,
                                    exec_overrides=exec_overrides,
                                    save_hlo=save_hlo)
                    r = rec["roofline"]
                    print(f"  ok: compile {rec['compile_s']}s, "
                          f"peak {rec['memory']['peak_gb']:.1f} GB/dev, "
                          f"dominant={r['dominant']} "
                          f"(c={r['compute_s']:.3g}s m={r['memory_s']:.3g}s "
                          f"coll={r['collective_s']:.3g}s)", flush=True)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "error": str(e)[:2000],
                           "traceback": traceback.format_exc()[-4000:]}
                    print(f"  FAIL: {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                results.append(rec)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    run_matrix(archs, shapes, meshes, force=args.force,
               save_hlo=args.save_hlo)


if __name__ == "__main__":
    main()
