"""Production mesh builders (TPU v5e pods).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; callers (dryrun / train / serve) decide when the
mesh is built.  Dry-runs must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import — ``repro.launch.dryrun`` does this in its first two lines.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips; two pods: (2, 16, 16) = 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(min(model, n // data), 1)
    return jax.make_mesh((data, model), ("data", "model"))


def n_silos(mesh) -> int:
    """FL silos = product of the (pod, data) axes."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("pod", 1) * sizes.get("data", 1)
