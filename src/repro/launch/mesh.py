"""Mesh builders: production pods (cross-silo) and the fleet client mesh.

This module imports NO jax at module scope, so it can be imported *before*
jax to request forced host devices: ``force_host_platform_device_count(n)``
edits ``XLA_FLAGS`` and raises if jax was already initialized (the flag is
read once at backend creation — setting it later silently does nothing,
which is exactly the doc-only folklore this helper replaces).  Benchmarks
and tests that want a multi-device fleet on CPU call it first, then import
jax / build the mesh::

    from repro.launch.mesh import force_host_platform_device_count
    force_host_platform_device_count(8)          # before any jax import
    from repro.launch.mesh import make_fleet_mesh
    mesh = make_fleet_mesh(8)                    # ("clients",) axis

``make_production_mesh`` / ``make_host_mesh`` / ``make_fleet_mesh`` are
FUNCTIONS so importing this module never touches jax device state; callers
(dryrun / train / serve / FleetEngine) decide when the mesh is built.
"""
from __future__ import annotations

import os
import re
import sys
from typing import Optional

_FORCE_RE = re.compile(r"--xla_force_host_platform_device_count=\d+")


def force_host_platform_device_count(n: int) -> None:
    """Request ``n`` host platform devices — call before jax *initializes*.

    Appends/rewrites ``--xla_force_host_platform_device_count`` in
    ``XLA_FLAGS``.  The flag is read once, when the CPU client is created
    (the first jax computation / ``jax.devices()`` call), not at import —
    so the env edit happens unconditionally, and when jax is already
    loaded the device count is probed afterwards: if the backend had
    already been created with the old flags this raises instead of
    silently handing back a wrong-sized fleet.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    new = f"--xla_force_host_platform_device_count={n}"
    if _FORCE_RE.search(flags):
        flags = _FORCE_RE.sub(new, flags)
    else:
        flags = (flags + " " + new).strip()
    os.environ["XLA_FLAGS"] = flags
    if "jax" in sys.modules:
        import jax  # initializes the backend NOW if it wasn't yet
        if len(jax.devices()) != n:
            raise RuntimeError(
                f"force_host_platform_device_count({n}) called after jax "
                f"was initialized ({len(jax.devices())} device(s)); set "
                f"it before the first jax use, or spawn a subprocess "
                f"(see tests/test_mesh_engine.py)")


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips; two pods: (2, 16, 16) = 512."""
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    import jax
    n = len(jax.devices())
    data = min(data, n)
    model = max(min(model, n // data), 1)
    return jax.make_mesh((data, model), ("data", "model"))


def make_fleet_mesh(num_devices: Optional[int] = None):
    """1-D ``("clients",)`` mesh for the cross-device FL round path.

    The fleet's stacked client pytree, the packed (C, D) aggregation
    buffer, and all (N,) per-client state shard over this axis (see
    ``repro.sharding.partitioning.fleet_*``).  ``num_devices=None`` takes
    every visible device; asking for more than exist raises.
    """
    import jax
    avail = len(jax.devices())
    n = avail if num_devices is None else int(num_devices)
    if n < 1 or n > avail:
        raise ValueError(f"make_fleet_mesh({num_devices}): {avail} "
                         f"device(s) visible")
    return jax.make_mesh((n,), ("clients",))


def n_silos(mesh) -> int:
    """FL silos = product of the (pod, data) axes."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("pod", 1) * sizes.get("data", 1)
