"""Multi-host initialization glue for real TPU pod deployments.

On an actual v5e pod slice (or two — the multi-pod mesh), each host process
calls ``init_multihost()`` before any jax API; jax.distributed wires the
hosts into one logical runtime and ``make_production_mesh`` then sees all
512 chips.  On single-host / CPU environments this is a no-op, so every
entry point can call it unconditionally.

Typical GKE/GCE launch (one process per host):

    COORDINATOR=$(hostname -i):8476 \
    NUM_PROCESSES=64 PROCESS_ID=${TPU_WORKER_ID} \
    python -m repro.launch.train --arch llama3-405b ...

The dry-run never uses this module — it simulates 512 devices on one host.
"""
from __future__ import annotations

import os


def init_multihost(coordinator: str | None = None,
                   num_processes: int | None = None,
                   process_id: int | None = None) -> bool:
    """Initialize jax.distributed from args or environment.

    Env fallbacks: COORDINATOR / JAX_COORDINATOR_ADDRESS,
    NUM_PROCESSES / JAX_NUM_PROCESSES, PROCESS_ID / JAX_PROCESS_ID (also
    TPU_WORKER_ID).  Returns True if distributed init ran.
    """
    coordinator = coordinator or os.environ.get(
        "COORDINATOR") or os.environ.get("JAX_COORDINATOR_ADDRESS")
    if not coordinator:
        return False
    num_processes = num_processes or int(
        os.environ.get("NUM_PROCESSES")
        or os.environ.get("JAX_NUM_PROCESSES") or 1)
    process_id = process_id if process_id is not None else int(
        os.environ.get("PROCESS_ID")
        or os.environ.get("JAX_PROCESS_ID")
        or os.environ.get("TPU_WORKER_ID") or 0)
    import jax
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)
    return True


def host_local_batch_slice(global_batch: int):
    """The slice of the global batch this host feeds (process-sharded
    host-offload pattern: every host materializes only its slice and
    ``jax.make_array_from_process_local_data`` assembles the global)."""
    import jax
    per = global_batch // jax.process_count()
    lo = per * jax.process_index()
    return slice(lo, lo + per)
