"""Batched serving driver: prefill a request batch, decode N tokens.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
      --batch 4 --prompt-len 64 --decode-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import restore_like
from repro.configs import get_config
from repro.models import ExecConfig, build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="flude-paper")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-tokens", type=int, default=32)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    from repro.launch.multihost import init_multihost
    init_multihost()     # no-op off-pod; wires jax.distributed on pods

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    if args.ckpt:
        params = restore_like(args.ckpt, params)
    print(f"serving {cfg.name}: {model.param_count() / 1e6:.1f}M params, "
          f"batch={args.batch}")

    B, S = args.batch, args.prompt_len
    rng = jax.random.key(args.seed + 1)
    if cfg.encdec is not None:
        batch = {"frames": jax.random.normal(rng, (B, S, cfg.d_model))}
    else:
        batch = {"tokens": jax.random.randint(rng, (B, S), 0,
                                              cfg.vocab_size)}
        if cfg.vision is not None:
            batch["image_embeds"] = jax.random.normal(
                rng, (B, cfg.vision.num_image_tokens,
                      cfg.vision.patch_embed_dim))

    ecfg = ExecConfig()
    cap = S + args.decode_tokens + 1
    prefill = jax.jit(lambda p, b: model.prefill(p, b, ecfg, max_len=cap))
    decode = jax.jit(model.decode_step, donate_argnums=(3,))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(cache)
    t_prefill = time.time() - t0
    print(f"prefill: {B}×{S} tokens in {t_prefill * 1e3:.1f} ms "
          f"({B * S / t_prefill:.0f} tok/s)")

    if logits is not None:
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    else:
        tok = jnp.zeros((B, 1), jnp.int32)       # enc-dec BOS
    out_tokens = [tok]
    t0 = time.time()
    base = 0 if cfg.encdec is not None else S
    for k in range(args.decode_tokens):
        pos = jnp.full((B, 1), base + k, jnp.int32)
        logits, cache = decode(params, tok, pos, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"decode: {args.decode_tokens} steps × batch {B} in "
          f"{dt * 1e3:.1f} ms ({B * args.decode_tokens / dt:.0f} tok/s)")
    ids = jnp.concatenate(out_tokens, 1)
    print("sampled ids (first request):", ids[0, :16].tolist())


if __name__ == "__main__":
    main()
