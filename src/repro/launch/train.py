"""FLUDE cross-silo LM training driver (single host or host-mesh).

Runs real federated rounds: each round the FLUDE server (Algorithms 1–2)
selects silos, the fleet simulator draws failures, and the compiled
cross-silo step trains the causal LM with the resulting per-silo weights.
Silo sample offsets realize cache-resume at the data level (DESIGN.md §3).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch flude-paper \
      --rounds 200 --silos 8
  PYTHONPATH=src python -m repro.launch.train --arch flude-paper \
      --scale 100m --rounds 300        # ~100M-param end-to-end driver
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.checkpoint.checkpointer import save
from repro.configs import get_config
from repro.configs.base import FLConfig, TrainConfig
from repro.data.synthetic import lm_dataset
from repro.fl import cross_silo
from repro.fl.simulator import Fleet, SimConfig
from repro.models import build_model
from repro.optim.optimizers import make_optimizer

SCALES = {
    # ~100M-param config for the end-to-end driver (paper kind: training)
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 d_ff=3072, vocab_size=32000, head_dim=64),
    "10m": dict(num_layers=6, d_model=384, num_heads=6, num_kv_heads=2,
                d_ff=1536, vocab_size=8192, head_dim=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="flude-paper")
    ap.add_argument("--scale", default=None, choices=[None, "10m", "100m"])
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--silos", type=int, default=8)
    ap.add_argument("--batch-per-silo", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--undep", type=float, default=0.4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()
    from repro.launch.multihost import init_multihost
    init_multihost()     # no-op off-pod; wires jax.distributed on pods

    cfg = get_config(args.arch)
    if args.scale:
        cfg = dataclasses.replace(
            cfg, name=f"{cfg.name}-{args.scale}",
            param_dtype="float32", compute_dtype="float32",
            **SCALES[args.scale])
    model = build_model(cfg)
    print(f"arch={cfg.name} params={model.param_count() / 1e6:.1f}M "
          f"silos={args.silos}")

    n = args.silos
    tc = TrainConfig(learning_rate=args.lr, warmup_steps=20,
                     total_steps=args.rounds)
    opt = make_optimizer(tc)
    params = model.init(jax.random.key(args.seed))
    state = cross_silo.TrainState(params, opt.init(params),
                                  jnp.zeros((), jnp.int32))
    step = jax.jit(cross_silo.make_train_step(model, tc, n),
                   donate_argnums=(0,))

    # federated data: one shard per silo
    data = lm_dataset(n, vocab_size=cfg.vocab_size,
                      seq_len=args.seq_len, n_seq=64, seed=args.seed)
    tokens = jnp.asarray(data.tokens)           # (n, n_seq, S+1)

    # FLUDE server state over silos + fleet simulator
    fl_cfg = FLConfig(num_clients=n, clients_per_round=max(n // 2, 2),
                      local_steps=1)
    sim = SimConfig(num_clients=n, seed=args.seed,
                    undep_means=(args.undep,) * 3)
    fleet = Fleet(sim)
    fstate = core.init_state(fl_cfg)
    caches = core.init_caches({"offset": jnp.zeros(())}, n)

    rng = jax.random.key(args.seed + 1)
    offsets = np.zeros(n, np.int64)             # data-level cache resume
    t0 = time.time()
    for rnd in range(args.rounds):
        rng, k1 = jax.random.split(rng)
        online = fleet.online_mask()
        plan = core.plan_round(fstate, caches, jnp.asarray(online),
                               fl_cfg, k1)
        selected = np.asarray(plan.selected)
        fail = fleet.failure_draw(np.where(selected, 1.0, 0.0)) & selected
        received = selected & ~fail

        # per-silo batch from each silo's shard (resume offsets)
        bps = args.batch_per_silo
        batch_tok = []
        for i in range(n):
            idx = (offsets[i] + np.arange(bps)) % tokens.shape[1]
            batch_tok.append(np.asarray(tokens[i, idx]))
            if received[i]:
                offsets[i] += bps
        bt = jnp.asarray(np.concatenate(batch_tok, 0))   # (n·bps, S+1)
        batch = {"tokens": bt[:, :-1], "labels": bt[:, 1:]}

        w = core.aggregation_weights(jnp.asarray(received))
        state, metrics = step(state, batch, w.astype(jnp.float32))
        fstate = core.update_after_round(fstate, plan,
                                         jnp.asarray(received), fl_cfg)
        if rnd % args.log_every == 0 or rnd == args.rounds - 1:
            print(f"round {rnd:4d} loss {float(metrics['loss']):.4f} "
                  f"selected {int(selected.sum())} received "
                  f"{int(received.sum())} eps {float(fstate.epsilon):.2f} "
                  f"({time.time() - t0:.0f}s)", flush=True)

    if args.ckpt:
        os.makedirs(os.path.dirname(args.ckpt) or ".", exist_ok=True)
        save(args.ckpt, state.params)
        print("checkpoint saved to", args.ckpt)
    return state


if __name__ == "__main__":
    main()
