from repro.models.model import Model, build_model, input_specs, supports_shape
from repro.models.transformer import ExecConfig
