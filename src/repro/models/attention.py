"""Attention substrate: GQA (+ sliding window, qkv bias), MLA, KV caches.

Layouts
-------
q:      (B, S, Hkv, G, D)   — G = query-group size = Hq // Hkv
k, v:   (B, S, Hkv, D)
cache:  KVCache with k/v of (B, S_max, Hkv, D) (ring-buffered for SWA)

The train/prefill path is a chunked online-softmax (flash-style) written in
pure lax.scan so that the dry-run never materializes (S, S) score tensors.
The Pallas TPU kernel (repro.kernels.flash_attention) implements the same
contract for the real-hardware path; tests cross-check all implementations.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array          # (B, S_cache, Hkv, D)
    v: jax.Array          # (B, S_cache, Hkv, D)
    length: jax.Array     # (B,) valid prefix length (== insert position)


class MLACache(NamedTuple):
    c_kv: jax.Array       # (B, S_cache, kv_lora)
    k_rope: jax.Array     # (B, S_cache, rope_dim)
    length: jax.Array     # (B,)


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------

def gqa_spec(cfg, layered: Optional[int] = None):
    d, hk = cfg.d_model, cfg.num_kv_heads
    g = cfg.num_heads // hk
    hd = cfg.resolved_head_dim
    dt = L.cfg_dtype(cfg.param_dtype)

    def w(shape, axes, init="normal", scale=1.0, fan_in=None):
        if layered is not None:
            shape = (layered,) + shape
            axes = ("layers",) + axes
        return L.ParamSpec(shape, dt, axes, init, scale, fan_in=fan_in)

    # explicit fan_in: the shape heuristic reads dim -2, which for these
    # multi-dim projections is a head axis, not the contraction size —
    # mis-scaled init saturates the score softmax
    p = {
        "wq": w((d, hk, g, hd), ("embed", "kv_heads", "q_group", "head_dim"),
                fan_in=d),
        "wk": w((d, hk, hd), ("embed", "kv_heads", "head_dim"), fan_in=d),
        "wv": w((d, hk, hd), ("embed", "kv_heads", "head_dim"), fan_in=d),
        "wo": w((hk, g, hd, d), ("kv_heads", "q_group", "head_dim", "embed"),
                fan_in=hk * g * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = w((hk, g, hd), ("kv_heads", "q_group", "head_dim"), "zeros")
        p["bk"] = w((hk, hd), ("kv_heads", "head_dim"), "zeros")
        p["bv"] = w((hk, hd), ("kv_heads", "head_dim"), "zeros")
    return p


def mla_spec(cfg, layered: Optional[int] = None):
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    dt = L.cfg_dtype(cfg.param_dtype)

    def w(shape, axes, init="normal", fan_in=None):
        if layered is not None:
            shape = (layered,) + shape
            axes = ("layers",) + axes
        return L.ParamSpec(shape, dt, axes, init, 1.0, fan_in=fan_in)

    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        # query low-rank path
        "w_dq": w((d, m.q_lora_rank), ("embed", "lora")),
        "q_norm": w((m.q_lora_rank,), ("lora",), "ones"),
        "w_uq": w((m.q_lora_rank, h, qk_dim), ("lora", "heads", "head_dim"),
                  fan_in=m.q_lora_rank),
        # kv low-rank path (+ shared rope key)
        "w_dkv": w((d, m.kv_lora_rank + m.qk_rope_head_dim),
                   ("embed", "lora")),
        "kv_norm": w((m.kv_lora_rank,), ("lora",), "ones"),
        "w_uk": w((m.kv_lora_rank, h, m.qk_nope_head_dim),
                  ("lora", "heads", "head_dim"), fan_in=m.kv_lora_rank),
        "w_uv": w((m.kv_lora_rank, h, m.v_head_dim),
                  ("lora", "heads", "head_dim"), fan_in=m.kv_lora_rank),
        "wo": w((h, m.v_head_dim, d), ("heads", "head_dim", "embed"),
                fan_in=h * m.v_head_dim),
    }


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (train / prefill), pure XLA
# ---------------------------------------------------------------------------

def _block(q, k, v, bias):
    """q: (B,Bq,Hk,G,D) k/v: (B,Bk,Hk,D) bias: (Bq,Bk) -> partial softmax."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                   preferred_element_type=jnp.float32)
    s = s + bias[None, None, None]
    m = s.max(-1)                                           # (B,Hk,G,Bq)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return m, l, o


def chunked_attention(q, k, v, *, causal: bool, window: Optional[int],
                      scale: float, q_offset=0,
                      q_chunk: int = 512, k_chunk: int = 512,
                      unroll_causal: bool = False, ecfg=None):
    """Flash-style attention via nested scans.

    q: (B, Sq, Hk, G, D); k, v: (B, Sk, Hk, D).  ``q_offset`` is the absolute
    position of q[0] relative to k[0] (for prefill-continuation).  Returns
    (B, Sq, Hk, G, D).
    """
    B, Sq, Hk, G, D = q.shape
    Dv = v.shape[-1]
    Sk = k.shape[1]
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    assert Sq % q_chunk == 0 and Sk % k_chunk == 0
    nq, nk = Sq // q_chunk, Sk // k_chunk
    q = (q * scale).reshape(B, nq, q_chunk, Hk, G, D)

    q_pos = jnp.arange(q_chunk)
    k_pos = jnp.arange(k_chunk)

    def kv_bias(iq, jk):
        """(Bq, Bk) additive mask bias for q block iq vs kv block jk."""
        qp = q_offset + iq * q_chunk + q_pos[:, None]
        kp = jk * k_chunk + k_pos[None, :]
        ok = jnp.ones((q_chunk, k_chunk), bool)
        if causal:
            ok &= kp <= qp
        if window is not None:
            ok &= kp > qp - window
        return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)

    def one_q_block(qi, iq, jks, valids=None):
        """Online softmax over the given kv block indices."""
        m0 = jnp.full((B, Hk, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hk, G, q_chunk), jnp.float32)
        o0 = jnp.zeros((B, Hk, G, q_chunk, Dv), jnp.float32)
        if valids is None:
            valids = jnp.ones(jks.shape, bool)

        def body(carry, jk_valid):
            jk, valid = jk_valid
            m, l, o = carry
            kb = jax.lax.dynamic_slice_in_dim(k, jk * k_chunk, k_chunk, 1)
            vb = jax.lax.dynamic_slice_in_dim(v, jk * k_chunk, k_chunk, 1)
            # pin the block layout INSIDE the loop: scan-carry shardings
            # are otherwise XLA's choice, and it picked head_dim-contraction
            # sharding (a per-block score psum — §Perf llama3 iteration 2)
            kb = L.shard_act(kb, ("batch", None, "kv_heads", "head_dim"),
                             ecfg)
            vb = L.shard_act(vb, ("batch", None, "kv_heads", "head_dim"),
                             ecfg)
            qb = L.shard_act(qi, ("batch", None, "kv_heads", "q_group",
                                  "head_dim"), ecfg)
            bias = kv_bias(iq, jk) + jnp.where(valid, 0.0, NEG_INF)
            mb, lb, ob = _block(qb, kb, vb, bias)
            m_new = jnp.maximum(m, mb)
            a1 = jnp.exp(m - m_new)
            a2 = jnp.exp(mb - m_new)
            return (m_new, l * a1 + lb * a2,
                    o * a1[..., None] + ob * a2[..., None]), None

        # remat the block: otherwise backward saves per-iteration (Bq, Bk)
        # score tensors for every kv block (O(S²) residuals)
        (m, l, o), _ = jax.lax.scan(jax.remat(body), (m0, l0, o0),
                                    (jks, valids))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        # (B,Hk,G,Bq,D) -> (B,Bq,Hk,G,D)
        return jnp.transpose(o, (0, 3, 1, 2, 4)).astype(v.dtype)

    if window is not None and causal:
        # banded: a q block [qlo, qhi] needs kv positions
        # (qlo - window, qhi] — at most ceil((q_chunk + window)/k_chunk)+1
        # kv blocks.  Out-of-range block indices are masked (NOT clamped:
        # clamping would double-visit block 0 and skew the softmax).
        wblocks = min(-(-(q_chunk + window) // k_chunk) + 1, nk)

        def per_q(carry, iq):
            qi = jax.lax.dynamic_index_in_dim(q, iq, 1, keepdims=False)
            last = (q_offset + (iq + 1) * q_chunk - 1) // k_chunk
            raw = last - jnp.arange(wblocks)[::-1]
            valids = (raw >= 0) & (raw <= nk - 1)
            jks = jnp.clip(raw, 0, nk - 1)
            return carry, one_q_block(qi, iq, jks, valids)

        _, out = jax.lax.scan(per_q, None, jnp.arange(nq))
    elif causal and unroll_causal:
        # unrolled causal pruning: q block i only visits kv blocks <= i
        outs = []
        for i in range(nq):
            last = (q_offset + (i + 1) * q_chunk - 1) // k_chunk
            outs.append(one_q_block(q[:, i], i, jnp.arange(last + 1)))
        out = jnp.stack(outs, 0)
    else:
        def per_q(carry, iq):
            qi = jax.lax.dynamic_index_in_dim(q, iq, 1, keepdims=False)
            return carry, one_q_block(qi, iq, jnp.arange(nk))

        _, out = jax.lax.scan(per_q, None, jnp.arange(nq))

    # out: (nq, B, Bq, Hk, G, Dv) -> (B, Sq, Hk, G, Dv)
    return jnp.transpose(out, (1, 0, 2, 3, 4, 5)).reshape(B, Sq, Hk, G, Dv)


def dense_attention(q, k, v, *, causal, window, scale, q_offset=0):
    """Naive dense reference (tests / tiny shapes only)."""
    B, Sq, Hk, G, D = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q * scale, k,
                   preferred_element_type=jnp.float32)
    qp = q_offset + jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), v)
    return jnp.transpose(o, (0, 3, 1, 2, 4))


# ---------------------------------------------------------------------------
# GQA block forward
# ---------------------------------------------------------------------------

def _project_qkv(p, x, cfg):
    dt = x.dtype
    q = jnp.einsum("bsd,dhgk->bshgk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def gqa_forward(p, x, positions, cfg, *, causal: bool = True,
                q_chunk: int = 512, k_chunk: int = 512,
                unroll_causal: bool = False, impl: str = "chunked",
                ecfg=None):
    """Full-sequence attention (train / encoder / prefill).

    x: (B, S, d); positions: (B, S) absolute positions.
    """
    q, k, v = _project_qkv(p, x, cfg)
    q = L.shard_act(q, ("batch", None, "kv_heads", "q_group", "head_dim"),
                    ecfg)
    k = L.shard_act(k, ("batch", None, "kv_heads", "head_dim"), ecfg)
    v = L.shard_act(v, ("batch", None, "kv_heads", "head_dim"), ecfg)
    q = L.apply_rope(q.reshape(q.shape[:2] + (-1, q.shape[-1])),
                     positions, cfg.rope_theta).reshape(q.shape)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    scale = cfg.resolved_head_dim ** -0.5
    if impl == "dense":
        o = dense_attention(q, k, v, causal=causal,
                            window=cfg.sliding_window, scale=scale)
    else:
        o = chunked_attention(q, k, v, causal=causal,
                              window=cfg.sliding_window, scale=scale,
                              q_chunk=q_chunk, k_chunk=k_chunk,
                              unroll_causal=unroll_causal, ecfg=ecfg)
    return jnp.einsum("bshgk,hgkd->bsd", o, p["wo"].astype(x.dtype))


def gqa_prefill(p, x, positions, cfg, cache: KVCache, ecfg=None, **kw):
    """Prefill: run full attention AND fill the cache.

    k/v are pinned to the attention-core sharding (replicated over model
    for GQA archs whose kv_heads don't divide the model axis) so the
    decode cache's head_dim sharding cannot propagate INTO the attention
    contraction — that propagation forced a per-block score psum measured
    at 5.2e3 s of wire time on llama3 prefill_32k (§Perf llama3 it.1).
    The reshard happens once at the cache write instead.
    """
    q, k, v = _project_qkv(p, x, cfg)
    q = L.shard_act(q, ("batch", None, "kv_heads", "q_group", "head_dim"),
                    ecfg)
    k = L.shard_act(k, ("batch", None, "kv_heads", "head_dim"), ecfg)
    v = L.shard_act(v, ("batch", None, "kv_heads", "head_dim"), ecfg)
    q = L.apply_rope(q.reshape(q.shape[:2] + (-1, q.shape[-1])),
                     positions, cfg.rope_theta).reshape(q.shape)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    scale = cfg.resolved_head_dim ** -0.5
    o = chunked_attention(q, k, v, causal=True, window=cfg.sliding_window,
                          scale=scale, ecfg=ecfg, **kw)
    out = jnp.einsum("bshgk,hgkd->bsd", o, p["wo"].astype(x.dtype))
    S = x.shape[1]
    Sc = cache.k.shape[1]
    if Sc >= S:
        newk = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), 0, 1)
        newv = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), 0, 1)
    else:   # ring cache smaller than prompt (SWA): keep the tail, placed
        # at ring index p mod Sc (decode's slotting discipline)
        newk = jnp.roll(k[:, S - Sc:], S % Sc, axis=1).astype(cache.k.dtype)
        newv = jnp.roll(v[:, S - Sc:], S % Sc, axis=1).astype(cache.v.dtype)
    return out, KVCache(newk, newv, jnp.full_like(cache.length, S))


def gqa_decode_step(p, x, positions, cfg, cache: KVCache):
    """One-token decode: x (B, 1, d), positions (B, 1) absolute.

    The cache is a ring buffer of size S_cache; for SWA archs S_cache ==
    sliding_window so the 500k-context decode stays O(window).
    """
    q, k, v = _project_qkv(p, x, cfg)
    q = L.apply_rope(q.reshape(q.shape[:2] + (-1, q.shape[-1])),
                     positions, cfg.rope_theta).reshape(q.shape)
    k = L.apply_rope(k, positions, cfg.rope_theta)

    B, _, Hk, D = k.shape
    Sc = cache.k.shape[1]
    slot = (cache.length % Sc)[:, None, None, None]          # (B,1,1,1)
    oh = (jnp.arange(Sc)[None, :, None, None] == slot)
    newk = jnp.where(oh, k.astype(cache.k.dtype), cache.k)
    newv = jnp.where(oh, v.astype(cache.v.dtype), cache.v)

    # positions of cache slots (ring-aware), for masking + rope already baked
    slot_idx = jnp.arange(Sc)[None, :]                       # (1, Sc)
    n_written = jnp.minimum(cache.length[:, None] + 1, Sc)   # (B,1)
    # valid if the slot has been written
    wrapped = (cache.length[:, None] + 1) > Sc
    valid = jnp.where(wrapped, jnp.ones((B, Sc), bool),
                      slot_idx < n_written)

    s = jnp.einsum("bqhgd,bkhd->bhgqk", q * (cfg.resolved_head_dim ** -0.5),
                   newk.astype(q.dtype), preferred_element_type=jnp.float32)
    s = jnp.where(valid[:, None, None, None], s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", prob.astype(newv.dtype),
                   newv.astype(q.dtype))
    o = jnp.transpose(o, (0, 3, 1, 2, 4))
    out = jnp.einsum("bshgk,hgkd->bsd", o, p["wo"].astype(x.dtype))
    return out, KVCache(newk, newv, cache.length + 1)


def init_kv_cache(cfg, batch: int, max_len: int, filled: bool = False):
    Sc = max_len if cfg.sliding_window is None else min(
        max_len, cfg.sliding_window)
    dt = L.cfg_dtype(cfg.param_dtype)
    hd = cfg.resolved_head_dim
    shape = (batch, Sc, cfg.num_kv_heads, hd)
    length = jnp.full((batch,), max_len if filled else 0, jnp.int32)
    return KVCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt), length)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 Multi-head Latent Attention)
# ---------------------------------------------------------------------------

def _mla_qkv(p, x, positions, cfg):
    m = cfg.mla
    dt = x.dtype
    cq = jnp.einsum("bsd,dr->bsr", x, p["w_dq"].astype(dt))
    cq = _rms(cq, p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"].astype(dt))
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(dt))
    c_kv, k_rope = jnp.split(ckv_full, [m.kv_lora_rank], axis=-1)
    c_kv = _rms(c_kv, p["kv_norm"])
    k_rope = L.apply_rope(k_rope[:, :, None, :], positions,
                          cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def _rms(x, scale, eps=1e-5):
    xf = x.astype(jnp.float32)
    out = xf * jax.lax.rsqrt((xf ** 2).mean(-1, keepdims=True) + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def mla_forward(p, x, positions, cfg, *, q_chunk=512, k_chunk=512,
                unroll_causal=False, impl="chunked"):
    """MLA attention via decompression into per-head K/V (train/prefill)."""
    m = cfg.mla
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, positions, cfg)
    dt = x.dtype
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"].astype(dt))
    val = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"].astype(dt))
    B, S, H, _ = k_nope.shape
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    # treat as MHA: Hk = H, G = 1; pad v to qk dim not needed — attention
    # core supports distinct v dim via separate einsum, so call _core directly
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    scale = qk_dim ** -0.5
    q5 = q[:, :, :, None, :]                              # (B,S,H,1,Dqk)
    if impl == "dense":
        o = dense_attention(q5, k, val, causal=True, window=None, scale=scale)
    else:
        o = chunked_attention(q5, k, val, causal=True, window=None,
                              scale=scale, q_chunk=q_chunk, k_chunk=k_chunk,
                              unroll_causal=unroll_causal)
    o = o[:, :, :, 0, :]                                  # (B,S,H,Dv)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))


def mla_prefill(p, x, positions, cfg, cache: MLACache, **kw):
    out = mla_forward(p, x, positions, cfg, **kw)
    _, _, c_kv, k_rope = _mla_qkv(p, x, positions, cfg)
    S = x.shape[1]
    new = MLACache(
        jax.lax.dynamic_update_slice_in_dim(
            cache.c_kv, c_kv.astype(cache.c_kv.dtype), 0, 1),
        jax.lax.dynamic_update_slice_in_dim(
            cache.k_rope, k_rope.astype(cache.k_rope.dtype), 0, 1),
        jnp.full_like(cache.length, S))
    return out, new


def mla_decode_step(p, x, positions, cfg, cache: MLACache):
    """One-token decode against the *compressed* cache (MLA's raison d'être).

    Scores are computed in latent space: q_nope is absorbed through w_uk so
    the per-token cache stays (kv_lora + rope_dim) wide.
    """
    m = cfg.mla
    dt = x.dtype
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(p, x, positions, cfg)

    B = x.shape[0]
    Sc = cache.c_kv.shape[1]
    slot = (cache.length % Sc)[:, None, None]
    oh = (jnp.arange(Sc)[None, :, None] == slot)
    c_kv = jnp.where(oh, c_kv_new.astype(cache.c_kv.dtype), cache.c_kv)
    k_rope = jnp.where(oh, k_rope_new.astype(cache.k_rope.dtype),
                       cache.k_rope)

    # absorb: q_lat[b,h,r] = sum_k q_nope[b,h,k] * w_uk[r,h,k]
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"].astype(dt))
    s_nope = jnp.einsum("bshr,btr->bhst", q_lat, c_kv.astype(dt),
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bshk,btk->bhst", q_rope, k_rope.astype(dt),
                        preferred_element_type=jnp.float32)
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    s = (s_nope + s_rope) * (qk_dim ** -0.5)
    valid = jnp.arange(Sc)[None, :] < jnp.minimum(
        cache.length[:, None] + 1, Sc)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1).astype(dt)
    # o_lat[b,h,r] then decompress through w_uv
    o_lat = jnp.einsum("bhst,btr->bshr", prob, c_kv.astype(dt))
    o = jnp.einsum("bshr,rhk->bshk", o_lat, p["w_uv"].astype(dt))
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))
    return out, MLACache(c_kv, k_rope, cache.length + 1)


def init_mla_cache(cfg, batch: int, max_len: int, filled: bool = False):
    m = cfg.mla
    dt = L.cfg_dtype(cfg.param_dtype)
    length = jnp.full((batch,), max_len if filled else 0, jnp.int32)
    return MLACache(
        jnp.zeros((batch, max_len, m.kv_lora_rank), dt),
        jnp.zeros((batch, max_len, m.qk_rope_head_dim), dt),
        length)
