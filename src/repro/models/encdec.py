"""Whisper-style encoder-decoder (audio backbone; conv frontend is a stub).

``input_specs()`` provides precomputed frame embeddings (B, S_frames, d) —
the mel-spectrogram + 2×conv1d feature extractor carve-out.  The encoder uses
fixed sinusoidal positions (as whisper does); the decoder uses learned
positional embeddings over ``max_target_len``.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as A
from repro.models import layers as L


class EncDecCache(NamedTuple):
    self_kv: Any        # stacked per-decoder-layer KVCache (self attention)
    cross_k: jax.Array  # (Ldec, B, S_enc, Hkv, D)
    cross_v: jax.Array
    length: jax.Array


def _enc_block_spec(cfg, layered):
    return {
        "norm1": L.norm_spec(cfg, cfg.d_model, layered=layered),
        "attn": A.gqa_spec(cfg, layered=layered),
        "norm2": L.norm_spec(cfg, cfg.d_model, layered=layered),
        "mlp": L.mlp_spec(cfg, cfg.d_model, cfg.d_ff, layered=layered),
    }


def _dec_block_spec(cfg, layered):
    return {
        "norm1": L.norm_spec(cfg, cfg.d_model, layered=layered),
        "self_attn": A.gqa_spec(cfg, layered=layered),
        "norm_x": L.norm_spec(cfg, cfg.d_model, layered=layered),
        "cross_attn": A.gqa_spec(cfg, layered=layered),
        "norm2": L.norm_spec(cfg, cfg.d_model, layered=layered),
        "mlp": L.mlp_spec(cfg, cfg.d_model, cfg.d_ff, layered=layered),
    }


def build_encdec_spec(cfg):
    e = cfg.encdec
    dt = L.cfg_dtype(cfg.param_dtype)
    return {
        "embed": L.ParamSpec((cfg.vocab_size, cfg.d_model), dt,
                             ("vocab", "embed"), "embed", 0.02),
        "dec_pos": L.ParamSpec((e.max_target_len, cfg.d_model), dt,
                               (None, "embed"), "embed", 0.02),
        "enc_blocks": _enc_block_spec(cfg, e.num_encoder_layers),
        "enc_norm": L.norm_spec(cfg, cfg.d_model),
        "dec_blocks": _dec_block_spec(cfg, e.num_decoder_layers),
        "final_norm": L.norm_spec(cfg, cfg.d_model),
    }


def _sinusoid(S, d):
    pos = np.arange(S)[:, None]
    dim = np.arange(d // 2)[None, :]
    inv = 1.0 / (10000 ** (dim / max(d // 2 - 1, 1)))
    ang = pos * inv
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], -1),
                       jnp.float32)


def encode(params, frames, cfg, exec_cfg):
    """frames: (B, S_enc, d) stub conv features -> encoder hidden states."""
    B, S, _ = frames.shape
    x = frames.astype(L.cfg_dtype(cfg.compute_dtype))
    x = x + _sinusoid(S, cfg.d_model).astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body_fn(x, p_l):
        h = L.apply_norm(p_l["norm1"], x, cfg)
        x = x + A.gqa_forward(p_l["attn"], h, positions, cfg, causal=False,
                              q_chunk=exec_cfg.q_chunk,
                              k_chunk=exec_cfg.k_chunk,
                              impl=exec_cfg.attn_impl)
        h = L.apply_norm(p_l["norm2"], x, cfg)
        return x + L.apply_mlp(p_l["mlp"], h, cfg), None

    body = jax.remat(body_fn) if cfg.remat else body_fn
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.apply_norm(params["enc_norm"], x, cfg)


def _cross_kv(p, enc_out, cfg):
    dt = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt))
    return k, v


def _cross_attend(p, x, k, v, cfg, exec_cfg):
    dt = x.dtype
    q = jnp.einsum("bsd,dhgk->bshgk", x, p["wq"].astype(dt))
    scale = cfg.resolved_head_dim ** -0.5
    o = A.chunked_attention(q, k, v, causal=False, window=None, scale=scale,
                            q_chunk=exec_cfg.q_chunk,
                            k_chunk=exec_cfg.k_chunk) \
        if exec_cfg.attn_impl == "chunked" else \
        A.dense_attention(q, k, v, causal=False, window=None, scale=scale)
    return jnp.einsum("bshgk,hgkd->bsd", o, p["wo"].astype(dt))


def decode_train(params, enc_out, dec_tokens, cfg, exec_cfg):
    """Teacher-forced decoder pass -> logits (B, S_dec, V)."""
    B, Sd = dec_tokens.shape
    x = jnp.take(params["embed"], dec_tokens, axis=0).astype(enc_out.dtype)
    x = x + params["dec_pos"][:Sd].astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(Sd)[None], (B, Sd))

    def body_fn(x, p_l):
        h = L.apply_norm(p_l["norm1"], x, cfg)
        x = x + A.gqa_forward(p_l["self_attn"], h, positions, cfg,
                              causal=True, q_chunk=min(exec_cfg.q_chunk, Sd),
                              k_chunk=min(exec_cfg.k_chunk, Sd),
                              impl=exec_cfg.attn_impl)
        h = L.apply_norm(p_l["norm_x"], x, cfg)
        k, v = _cross_kv(p_l["cross_attn"], enc_out, cfg)
        x = x + _cross_attend(p_l["cross_attn"], h, k, v, cfg, exec_cfg)
        h = L.apply_norm(p_l["norm2"], x, cfg)
        return x + L.apply_mlp(p_l["mlp"], h, cfg), None

    body = jax.remat(body_fn) if cfg.remat else body_fn
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = L.apply_norm(params["final_norm"], x, cfg)
    return x @ params["embed"].astype(x.dtype).T


def encdec_loss(params, batch, cfg, exec_cfg, per_example=False):
    enc_out = encode(params, batch["frames"], cfg, exec_cfg)
    logits = decode_train(params, enc_out, batch["dec_tokens"], cfg,
                          exec_cfg)
    labels = batch["dec_labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    if per_example:
        tok = jnp.maximum(mask.sum(-1), 1.0)
        ce = -(ll * mask).sum(-1) / tok
        return ce.mean(), {"ce_per_example": ce}
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = -(ll * mask).sum() / denom
    return ce, {"ce": ce}


def init_encdec_cache(cfg, batch: int, enc_len: int, filled: bool = False):
    e = cfg.encdec
    dt = L.cfg_dtype(cfg.param_dtype)
    hd = cfg.resolved_head_dim
    Ld = e.num_decoder_layers
    kv = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[A.KVCache(
            jnp.zeros((batch, e.max_target_len, cfg.num_kv_heads, hd), dt),
            jnp.zeros((batch, e.max_target_len, cfg.num_kv_heads, hd), dt),
            jnp.zeros((batch,), jnp.int32))
          for _ in range(Ld)])
    cross = jnp.zeros((Ld, batch, enc_len, cfg.num_kv_heads, hd), dt)
    length = jnp.full((batch,), enc_len if filled else 0, jnp.int32)
    return EncDecCache(kv, cross, cross, length)


def encdec_prefill(params, batch, cfg, exec_cfg):
    """Encode audio + precompute cross K/V; decoder cache starts empty."""
    enc_out = encode(params, batch["frames"], cfg, exec_cfg)

    def per_layer(carry, p_l):
        k, v = _cross_kv(p_l["cross_attn"], enc_out, cfg)
        return carry, (k, v)

    _, (ck, cv) = jax.lax.scan(per_layer, None, params["dec_blocks"])
    B, S_enc = enc_out.shape[0], enc_out.shape[1]
    cache = init_encdec_cache(cfg, B, S_enc)
    return EncDecCache(cache.self_kv, ck, cv,
                       jnp.full((B,), S_enc, jnp.int32))


def encdec_decode_step(params, tokens, positions, cache: EncDecCache, cfg):
    """One decoder token against cached cross K/V.  tokens: (B, 1)."""
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        L.cfg_dtype(cfg.compute_dtype))
    pos_emb = jnp.take(params["dec_pos"], positions[:, 0], axis=0)
    x = x + pos_emb[:, None, :].astype(x.dtype)

    def body(x, inputs):
        p_l, kv_l, ck_l, cv_l = inputs
        h = L.apply_norm(p_l["norm1"], x, cfg)
        o, kv_l = A.gqa_decode_step(p_l["self_attn"], h, positions, cfg,
                                    kv_l)
        x = x + o
        h = L.apply_norm(p_l["norm_x"], x, cfg)
        x = x + _cross_attend_cached(p_l["cross_attn"], h, ck_l, cv_l, cfg)
        h = L.apply_norm(p_l["norm2"], x, cfg)
        return x + L.apply_mlp(p_l["mlp"], h, cfg), kv_l

    x, new_kv = jax.lax.scan(
        body, x, (params["dec_blocks"], cache.self_kv,
                  cache.cross_k, cache.cross_v))
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = x @ params["embed"].astype(x.dtype).T
    return logits, cache._replace(self_kv=new_kv)


def _cross_attend_cached(p, x, k, v, cfg):
    dt = x.dtype
    q = jnp.einsum("bsd,dhgk->bshgk", x, p["wq"].astype(dt))
    scale = cfg.resolved_head_dim ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q * scale, k.astype(dt),
                   preferred_element_type=jnp.float32)
    prob = jax.nn.softmax(s, axis=-1).astype(dt)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", prob, v.astype(dt))
    o = jnp.transpose(o, (0, 3, 1, 2, 4))
    return jnp.einsum("bshgk,hgkd->bsd", o, p["wo"].astype(dt))
