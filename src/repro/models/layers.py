"""Shared model substrate: param specs, norms, MLPs, rotary embeddings.

Parameters are described by ``ParamSpec`` metadata trees (shape, dtype,
logical axes, init law).  ``init_params`` materializes values;
``abstract_params`` produces ``ShapeDtypeStruct`` stand-ins for the dry-run;
``repro.sharding`` maps logical axes to mesh axes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    dtype: Any
    axes: Tuple[Optional[str], ...]   # logical axis names, len == len(shape)
    init: str = "normal"              # normal | zeros | ones | embed
    scale: float = 1.0                # multiplier on the default fan-in scale
    fan_in: Optional[int] = None      # explicit fan-in (contraction size);
                                      # None = shape heuristic (2D/stacked-3D)

    def __post_init__(self):
        assert len(self.axes) == len(self.shape), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def spec_tree_map(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def abstract_params(specs):
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return spec_tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs)


def _init_one(spec: ParamSpec, key) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        std = 1.0 * spec.scale
        return (jax.random.normal(key, spec.shape, jnp.float32) * std
                ).astype(spec.dtype)
    # fan-in scaled normal
    if spec.fan_in is not None:
        fan_in = spec.fan_in
    else:
        fan_in = spec.shape[0] if len(spec.shape) >= 2 \
            else max(spec.shape[-1], 1)
        if len(spec.shape) >= 3:   # stacked/layered weights: fan-in is dim -2
            fan_in = spec.shape[-2]
    std = spec.scale / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * std
            ).astype(spec.dtype)


def init_params(specs, rng):
    """Materialize a param tree from a spec tree (per-leaf folded rng)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_one(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def param_axes(specs):
    """Tree of logical-axis tuples, mirroring the param tree."""
    return spec_tree_map(lambda s: s.axes, specs)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_spec(cfg, d: int, layered: Optional[int] = None):
    shape, axes = (d,), ("embed",)
    if layered is not None:
        shape, axes = (layered, d), ("layers", "embed")
    p = {"scale": ParamSpec(shape, cfg_dtype(cfg.param_dtype), axes, "ones")}
    if cfg.norm == "layernorm":
        p["bias"] = ParamSpec(shape, cfg_dtype(cfg.param_dtype), axes, "zeros")
    return p


def apply_norm(p, x, cfg, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(
            jnp.float32)
    else:
        var = (xf ** 2).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def cfg_dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------

def dense_spec(cfg, din: int, dout: int, axes, *, bias: bool = False,
               layered: Optional[int] = None, scale: float = 1.0,
               init: str = "normal"):
    dt = cfg_dtype(cfg.param_dtype)
    shape, ax = (din, dout), tuple(axes)
    if layered is not None:
        shape, ax = (layered, din, dout), ("layers",) + tuple(axes)
    out = {"w": ParamSpec(shape, dt, ax, init, scale)}
    if bias:
        bshape = (dout,) if layered is None else (layered, dout)
        bax = (axes[-1],) if layered is None else ("layers", axes[-1])
        out["b"] = ParamSpec(bshape, dt, bax, "zeros")
    return out


def apply_dense(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def mlp_spec(cfg, d: int, d_ff: int, layered: Optional[int] = None,
             in_axis: str = "embed", ff_axis: str = "mlp"):
    p = {}
    if cfg.mlp_act == "silu_glu":
        p["wi"] = dense_spec(cfg, d, d_ff, (in_axis, ff_axis), layered=layered)
        p["wg"] = dense_spec(cfg, d, d_ff, (in_axis, ff_axis), layered=layered)
    else:
        p["wi"] = dense_spec(cfg, d, d_ff, (in_axis, ff_axis), layered=layered)
    p["wo"] = dense_spec(cfg, d_ff, d, (ff_axis, in_axis), layered=layered)
    return p


def apply_mlp(p, x, cfg):
    if cfg.mlp_act == "silu_glu":
        h = jax.nn.silu(apply_dense(p["wi"], x)) * apply_dense(p["wg"], x)
    elif cfg.mlp_act == "gelu":
        h = jax.nn.gelu(apply_dense(p["wi"], x))
    elif cfg.mlp_act == "relu2":
        h = jnp.square(jax.nn.relu(apply_dense(p["wi"], x)))
    else:
        raise ValueError(cfg.mlp_act)
    return apply_dense(p["wo"], h)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))            # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                        # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def shard_act(x, axes, exec_cfg):
    """with_sharding_constraint via logical activation axes.

    "batch" maps to the (pod, data) mesh axes; weight-style axes resolve via
    ``exec_cfg.rules``.  No-op when exec_cfg carries no mesh (smoke tests,
    single-device runs).
    """
    if exec_cfg is None or getattr(exec_cfg, "mesh", None) is None \
            or getattr(exec_cfg, "rules", None) is None:
        return x
    from jax.sharding import NamedSharding
    from repro.sharding.partitioning import fsdp_axes, spec_for_axes
    rules = dict(exec_cfg.rules)
    rules["batch"] = fsdp_axes(exec_cfg.mesh)
    spec = spec_for_axes(tuple(axes), rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(exec_cfg.mesh, spec))
