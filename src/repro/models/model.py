"""Unified model API + per-(arch, shape) input specs for the dry-run.

``Model`` wraps spec building, init, loss, prefill and decode for every
assigned architecture.  ``input_specs(cfg, shape)`` returns
ShapeDtypeStruct stand-ins for every input of the step that the dry-run
lowers (train / prefill / decode) — weak-type-correct, shardable, and never
allocating device memory.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import encdec as E
from repro.models import layers as L
from repro.models import transformer as T


class Model:
    """Functional model handle: specs + pure apply functions."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        if cfg.encdec is not None:
            self.specs = E.build_encdec_spec(cfg)
        else:
            self.specs = T.build_spec(cfg)

    # -- params ------------------------------------------------------------
    def init(self, rng) -> Dict[str, Any]:
        return L.init_params(self.specs, rng)

    def abstract_params(self):
        return L.abstract_params(self.specs)

    def param_axes(self):
        return L.param_axes(self.specs)

    def param_count(self) -> int:
        import numpy as np
        leaves = jax.tree.leaves(L.abstract_params(self.specs))
        return int(sum(np.prod(l.shape) for l in leaves))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        cfg = self.cfg
        if cfg.moe is None:
            return self.param_count()
        import numpy as np
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                L.abstract_params(self.specs))[0]:
            n = int(np.prod(leaf.shape))
            keys = [getattr(k, "key", str(k)) for k in path]
            if any(k in ("wi", "wg", "wo") for k in keys) and \
                    any(k == "moe" for k in keys) and \
                    not any(k == "shared" for k in keys):
                n = n * cfg.moe.top_k // cfg.moe.num_experts
            total += n
        return total

    # -- steps ---------------------------------------------------------------
    def loss(self, params, batch, exec_cfg=T.ExecConfig(),
             per_example: bool = False):
        if self.cfg.encdec is not None:
            return E.encdec_loss(params, batch, self.cfg, exec_cfg,
                                 per_example=per_example)
        return T.lm_loss(params, batch, self.cfg, exec_cfg,
                         per_example=per_example)

    def logits(self, params, batch, exec_cfg=T.ExecConfig()):
        if self.cfg.encdec is not None:
            enc = E.encode(params, batch["frames"], self.cfg, exec_cfg)
            return E.decode_train(params, enc, batch["dec_tokens"],
                                  self.cfg, exec_cfg)
        return T.forward(params, batch, self.cfg, exec_cfg)[0]

    def prefill(self, params, batch, exec_cfg=T.ExecConfig(),
                max_len=None):
        if self.cfg.encdec is not None:
            return None, E.encdec_prefill(params, batch, self.cfg, exec_cfg)
        return T.prefill(params, batch, self.cfg, exec_cfg,
                         max_len=max_len)

    def decode_step(self, params, tokens, positions, cache):
        if self.cfg.encdec is not None:
            return E.encdec_decode_step(params, tokens, positions, cache,
                                        self.cfg)
        return T.decode_step(params, tokens, positions, cache, self.cfg)

    def init_cache(self, batch: int, max_len: int, filled: bool = False):
        if self.cfg.encdec is not None:
            return E.init_encdec_cache(self.cfg, batch, max_len, filled)
        return T.init_cache(self.cfg, batch, max_len, filled)

    def abstract_cache(self, batch: int, max_len: int):
        return jax.eval_shape(
            lambda: self.init_cache(batch, max_len, filled=True))


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


# ---------------------------------------------------------------------------
# Dry-run input specs
# ---------------------------------------------------------------------------

def supports_shape(cfg: ModelConfig, shape: InputShape) -> bool:
    """long_500k needs sub-quadratic context (SSM / hybrid / SWA)."""
    if shape.name != "long_500k":
        return True
    if cfg.arch_type in ("ssm", "hybrid"):
        return True
    return cfg.sliding_window is not None


def input_specs(cfg: ModelConfig, shape: InputShape,
                n_silos: int = 1) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of the lowered step."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok(b, s):
        return jax.ShapeDtypeStruct((b, s), i32)

    if shape.kind == "train":
        if cfg.encdec is not None:
            e = cfg.encdec
            return {
                "frames": jax.ShapeDtypeStruct(
                    (B, S, cfg.d_model), L.cfg_dtype(cfg.compute_dtype)),
                "dec_tokens": tok(B, e.max_target_len),
                "dec_labels": tok(B, e.max_target_len),
            }
        batch = {"tokens": tok(B, S), "labels": tok(B, S)}
        if cfg.vision is not None:
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vision.num_image_tokens, cfg.vision.patch_embed_dim),
                L.cfg_dtype(cfg.compute_dtype))
        return batch

    if shape.kind == "prefill":
        if cfg.encdec is not None:
            return {"frames": jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), L.cfg_dtype(cfg.compute_dtype))}
        batch = {"tokens": tok(B, S)}
        if cfg.vision is not None:
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vision.num_image_tokens, cfg.vision.patch_embed_dim),
                L.cfg_dtype(cfg.compute_dtype))
        return batch

    # decode: one new token against a filled cache of length S
    model = Model(cfg)
    cache = model.abstract_cache(B, S)
    return {
        "tokens": tok(B, 1),
        "positions": jax.ShapeDtypeStruct((B, 1), i32),
        "cache": cache,
    }
