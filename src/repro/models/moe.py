"""Mixture-of-Experts block: top-k router + GShard-style capacity dispatch.

Expert-parallel sharding: the ``expert`` logical axis maps to the ``model``
mesh axis when num_experts is divisible by it (deepseek-v2: 160 experts), else
experts are replicated and the ``expert_mlp`` axis is sharded (mixtral: 8
experts).  Dispatch/combine einsums lower to all-to-alls under pjit when the
token and expert axes live on different mesh axes.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import shard_act


def moe_spec(cfg, layered: Optional[int] = None):
    m = cfg.moe
    d = cfg.d_model
    eff = m.expert_d_ff or cfg.d_ff
    dt = L.cfg_dtype(cfg.param_dtype)
    glu = cfg.mlp_act == "silu_glu"

    def w(shape, axes, init="normal"):
        if layered is not None:
            shape = (layered,) + shape
            axes = ("layers",) + axes
        return L.ParamSpec(shape, dt, axes, init)

    p = {
        "router": w((d, m.num_experts), ("embed", "expert_gate")),
        "wi": w((m.num_experts, d, eff), ("expert", "embed", "expert_mlp")),
        "wo": w((m.num_experts, eff, d), ("expert", "expert_mlp", "embed")),
    }
    if glu:
        p["wg"] = w((m.num_experts, d, eff),
                    ("expert", "embed", "expert_mlp"))
    if m.num_shared_experts:
        sff = (m.shared_d_ff or eff) * m.num_shared_experts
        p["shared"] = L.mlp_spec(cfg, d, sff, layered=layered,
                                 ff_axis="mlp")
    return p


def _act(cfg, h, g=None):
    if cfg.mlp_act == "silu_glu":
        return jax.nn.silu(h) * g
    if cfg.mlp_act == "gelu":
        return jax.nn.gelu(h)
    return jnp.square(jax.nn.relu(h))


def moe_forward(p, x, cfg, exec_cfg=None):
    """x: (B, S, d) -> (B, S, d), plus aux load-balance loss.

    GShard-style grouped dispatch: tokens are split into G groups (one per
    data shard / FL silo), each with a *local* expert capacity
    C' = T'·k/E·cf.  Dispatch tensors are (G, T', E, C') — G× smaller than
    the ungrouped form (which peaked at 21 GB/device on mixtral train_4k) —
    and the expert einsum lowers to the canonical all-to-all when groups
    live on the data axis and experts on the model axis.  Tokens over local
    capacity are dropped (contribute zero), matching the reference systems.
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    G = getattr(exec_cfg, "moe_groups", 1) if exec_cfg is not None else 1
    # auto-scale groups so T' stays bounded: dispatch/expert buffers are
    # O(T'·k·cf) per group — unbounded T' (e.g. 1M-token prefill) blew the
    # einsum dispatch up to 30 TB/device (EXPERIMENTS.md §Perf mixtral it.1)
    G = max(G, T // 4096)
    while T % G != 0:
        G -= 1
    Tl = T // G
    dt = x.dtype
    xt = x.reshape(G, Tl, d)
    dispatch_impl = getattr(exec_cfg, "moe_dispatch", "gather") \
        if exec_cfg is not None else "gather"

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # (G, T', E)

    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)       # (G, T', k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    E = m.num_experts
    cap = int(max(1, round(Tl * m.top_k / E * m.capacity_factor)))
    if Tl <= 128:
        # decode / tiny batches: full capacity (drops would corrupt the
        # single-token step; cost is negligible at this size)
        cap = Tl

    # position of each (token, slot) within its expert queue (per group)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)     # (G,T',k,E)
    flat = onehot.reshape(G, Tl * m.top_k, E)
    pos = jnp.cumsum(flat, axis=1) - 1                        # (G,T'k,E)
    pos = (pos * flat).sum(-1).reshape(G, Tl, m.top_k)        # (G,T',k)
    keep = pos < cap

    if dispatch_impl == "gather":
        # sort-free gather/scatter dispatch: O(T·k·d) data movement, zero
        # matmul flops — replaces the O(T·E·C·d) one-hot einsums that
        # dominated the MoE rooflines (beyond-paper optimization; see
        # EXPERIMENTS.md §Perf deepseek/mixtral iterations).
        g_ids = jnp.arange(G)[:, None, None]
        tok_ids = jnp.broadcast_to(jnp.arange(Tl)[None, :, None],
                                   (G, Tl, m.top_k))
        safe_pos = jnp.where(keep, pos, cap)          # overflow slot
        # slot tables (G, E, C'+1): token index + validity per expert slot
        idx = jnp.zeros((G, E, cap + 1), jnp.int32).at[
            g_ids, gate_idx, safe_pos].set(tok_ids.astype(jnp.int32),
                                           mode="drop")[..., :cap]
        slot_ok = jnp.zeros((G, E, cap + 1), bool).at[
            g_ids, gate_idx, safe_pos].set(True, mode="drop")[..., :cap]
        # gather expert inputs: (G, E, C', d) -> (E, G, C', d)
        xin = jnp.take_along_axis(
            xt, idx.reshape(G, E * cap)[..., None], axis=1
        ).reshape(G, E, cap, d) * slot_ok[..., None].astype(dt)
        xin = jnp.swapaxes(xin, 0, 1)
    else:
        # reference one-hot einsum dispatch (GShard formulation)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                                dtype=dt)[..., :cap]          # (G,T',k,C')
        disp = jnp.einsum("gtke,gtkc->gtec", onehot.astype(dt), pos_oh)
        xin = jnp.einsum("gtec,gtd->egcd", disp, xt)

    xin = shard_act(xin, ("expert", "batch", None, None), exec_cfg)
    h = jnp.einsum("egcd,edf->egcf", xin, p["wi"].astype(dt))
    g = (jnp.einsum("egcd,edf->egcf", xin, p["wg"].astype(dt))
         if "wg" in p else None)
    h = _act(cfg, h, g)
    h = shard_act(h, ("expert", "batch", None, "expert_mlp"), exec_cfg)
    eout = jnp.einsum("egcf,efd->egcd", h, p["wo"].astype(dt))
    eout = shard_act(eout, ("expert", "batch", None, None), exec_cfg)

    if dispatch_impl == "gather":
        # combine: gather each (token, k) slot's expert output
        flat = jnp.swapaxes(eout, 0, 1).reshape(G, E * cap, d)
        slot = (gate_idx * cap + safe_pos).reshape(G, Tl * m.top_k)
        vals = jnp.take_along_axis(
            flat, jnp.minimum(slot, E * cap - 1)[..., None], axis=1
        ).reshape(G, Tl, m.top_k, d)
        w_tk = (gate_vals * keep).astype(jnp.float32)
        out = jnp.einsum("gtkd,gtk->gtd", vals.astype(jnp.float32),
                         w_tk).astype(dt)
    else:
        comb = jnp.einsum("gtke,gtkc,gtk->gtec",
                          onehot.astype(jnp.float32),
                          pos_oh.astype(jnp.float32),
                          gate_vals * keep).astype(dt)
        out = jnp.einsum("gtec,egcd->gtd", comb, eout)

    if m.num_shared_experts:
        out = out + L.apply_mlp(p["shared"], xt, cfg)

    # load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    frac = onehot[:, :, 0, :].astype(jnp.float32).mean((0, 1))
    pmean = probs.mean((0, 1))
    aux = E * jnp.sum(frac * pmean) * m.router_aux_weight
    return out.reshape(B, S, d), aux
