"""RWKV6 (Finch) block: time-mix with data-dependent decay + channel-mix.

Attention-free: per-head (D, D) state evolved by a per-channel decay
``w_t = exp(-exp(w_raw_t))`` that depends on the input (the paper's "data-
dependent decay").  The XLA path runs the exact per-timestep recurrence with
a lax.scan carrying fp32 state; the Pallas kernel (repro.kernels.rwkv6_scan)
runs the same recurrence chunk-resident in VMEM.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L


class RWKVState(NamedTuple):
    shift_tmix: jax.Array   # (B, d) previous token input to time-mix
    shift_cmix: jax.Array   # (B, d) previous token input to channel-mix
    wkv: jax.Array          # (B, H, D, D) fp32 state
    length: jax.Array       # (B,)


_MIX_NAMES = ("r", "k", "v", "g", "w")


def rwkv_spec(cfg, layered: Optional[int] = None):
    r = cfg.rwkv
    d = cfg.d_model
    H = d // r.head_dim
    dt = L.cfg_dtype(cfg.param_dtype)

    def w(shape, axes, init="normal", scale=1.0):
        if layered is not None:
            shape = (layered,) + shape
            axes = ("layers",) + axes
        return L.ParamSpec(shape, dt, axes, init, scale)

    return {
        # time-mix
        "mu_x": w((d,), ("embed",), "zeros"),
        "mu": w((5, d), ("mix5", "embed"), "zeros"),
        "lora_a": w((d, 5 * r.decay_lora_rank), ("embed", "lora")),
        "lora_b": w((5, r.decay_lora_rank, d), ("mix5", "lora", "embed"),
                    "zeros"),
        "w_r": w((d, d), ("embed", "heads_x_dim")),
        "w_k": w((d, d), ("embed", "heads_x_dim")),
        "w_v": w((d, d), ("embed", "heads_x_dim")),
        "w_g": w((d, d), ("embed", "heads_x_dim")),
        "w0": w((d,), ("heads_x_dim",), "zeros"),
        "w_lora_a": w((d, r.decay_lora_rank), ("embed", "lora")),
        "w_lora_b": w((r.decay_lora_rank, d), ("lora", "heads_x_dim"),
                      "zeros"),
        "u_bonus": w((d,), ("heads_x_dim",), "zeros"),
        "ln_x": w((d,), ("heads_x_dim",), "ones"),
        "w_o": w((d, d), ("heads_x_dim", "embed")),
        # channel-mix
        "cm_mu_k": w((d,), ("embed",), "zeros"),
        "cm_mu_r": w((d,), ("embed",), "zeros"),
        "cm_wk": w((d, cfg.d_ff), ("embed", "mlp")),
        "cm_wv": w((cfg.d_ff, d), ("mlp", "embed")),
        "cm_wr": w((d, d), ("embed", "embed_out")),
    }


def _token_shift(x, prev):
    """shifted[t] = x[t-1]; shifted[0] = prev (or 0)."""
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if prev is not None:
        shifted = shifted.at[:, 0].set(prev.astype(shifted.dtype))
    return shifted


def _ddlerp(p, x, xx):
    """RWKV6 data-dependent token-shift interpolation -> 5 mixed inputs."""
    base = x + xx * p["mu_x"].astype(x.dtype)
    lora = jnp.tanh(base @ p["lora_a"].astype(x.dtype))
    B, S, _ = x.shape
    rank = p["lora_b"].shape[1]
    lora = lora.reshape(B, S, 5, rank)
    delta = jnp.einsum("bsmr,mrd->bsmd", lora, p["lora_b"].astype(x.dtype))
    mix = p["mu"].astype(x.dtype)[None, None] + delta       # (B,S,5,d)
    return x[:, :, None, :] + xx[:, :, None, :] * mix       # (B,S,5,d)


def wkv_recurrence(r, k, v, logw, u, state):
    """Exact WKV6 recurrence.

    r,k,v: (B, S, H, D); logw: (B, S, H, D) (log of decay, <= 0);
    u: (H, D) bonus; state: (B, H, D, D) fp32.
    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1}
                                                      + k_t v_t^T
    Returns y (B, S, H, D) fp32 and the final state.
    """
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    wf = jnp.exp(logw.astype(jnp.float32))

    def step(S, inp):
        rt, kt, vt, wt = inp                                # (B,H,D)
        a = jnp.einsum("bhi,bhj->bhij", kt, vt)             # k ⊗ v
        y = jnp.einsum("bhi,bhij->bhj", rt, S + u[None, :, :, None] * a)
        S_new = wt[..., None] * S + a
        return S_new, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, wf))
    SF, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), SF


def wkv_chunked(r, k, v, logw, u, state, chunk: int = 64):
    """Chunked WKV6 — the XLA-path analogue of the Pallas kernel.

    Per chunk of length c, with S₀ the carried state and within-chunk
    cumulative log-decays cums_t = Σ_{s≤t} logw_s (all ≤ 0):

      y_t = r_t·diag(e^{cums_{t-1}})·S₀                       (inter)
            + Σ_{j<t} (r_t ⊙ e^{cums_{t-1}-cums_j})·k_j v_jᵀ  (intra)
            + (r_t ⊙ u)·k_t v_tᵀ                              (bonus)
      S' = diag(e^{cums_last})·S₀ + Σ_j diag(e^{cums_last-cums_j}) k_j v_jᵀ

    Every exponent is ≤ 0, so no overflow — unlike the matmul
    factorization e^{cums_{t-1}}·e^{-cums_j}.  The (c, c, D) decay tensor
    is the price; at c = 64, D = 64 it is VMEM/cache-sized.  HBM state
    traffic drops from per-STEP to per-CHUNK (×c less) — the rwkv6
    train_4k §Perf iteration.
    """
    B, S, H, D = r.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        r = jnp.pad(r, widths)
        k = jnp.pad(k, widths)       # k = 0 ⇒ no contribution
        v = jnp.pad(v, widths)
        logw = jnp.pad(logw, widths)  # logw = 0 ⇒ identity decay
    Sp = S + pad
    nc = Sp // c

    def resh(t):
        return jnp.moveaxis(
            t.astype(jnp.float32).reshape(B, nc, c, H, D), 1, 0)

    rs, ks, vs, lws = resh(r), resh(k), resh(v), resh(logw)
    uf = u.astype(jnp.float32)

    def body(S0, inp):
        rc, kc, vc, lwc = inp                       # (B, c, H, D)
        cums = jnp.cumsum(lwc, axis=1)              # (B, c, H, D)
        # inter-chunk: decay up to t-1 = cums shifted right by one
        cums_prev = jnp.pad(cums, ((0, 0), (1, 0), (0, 0), (0, 0)))[:, :-1]
        y_inter = jnp.einsum("bthi,bhij->bthj", rc * jnp.exp(cums_prev), S0)
        # intra-chunk: A[t,j,i] = r_t k_j e^{cums_{t-1}-cums_j}, j < t.
        # The exponent is computed as ONE difference (≤ 0 for valid j<t):
        # the e^{cums_{t-1}}·e^{-cums_j} product form overflows.
        diff = cums_prev[:, :, None] - cums[:, None]       # (B,t,j,H,D)
        mask = jnp.tril(jnp.ones((c, c), bool), -1)        # strict lower
        dd = jnp.exp(jnp.where(mask[None, :, :, None, None], diff, -1e30))
        A = jnp.einsum("bthi,bjhi,btjhi->bthj", rc, kc, dd)
        y_intra = jnp.einsum("bthj,bjhd->bthd", A, vc)
        # bonus diagonal: (r_t ⊙ u)·k_t scales v_t
        y_bonus = (rc * uf[None, None] * kc).sum(-1, keepdims=True) * vc
        # state update
        last = cums[:, -1:]                          # (B,1,H,D)
        wsuf = jnp.exp(last - cums)                  # decay after step j
        dS = jnp.einsum("bjhi,bjhd->bhid", kc * wsuf, vc)
        S_new = jnp.exp(last[:, 0])[..., None] * S0 + dS
        return S_new, y_inter + y_intra + y_bonus

    SF, ys = jax.lax.scan(body, state.astype(jnp.float32),
                          (rs, ks, vs, lws))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Sp, H, D)[:, :S]
    return y, SF


def time_mix(p, x, cfg, state: Optional[RWKVState], *, kernel=None):
    r_cfg = cfg.rwkv
    d = cfg.d_model
    H, D = d // r_cfg.head_dim, r_cfg.head_dim
    B, S, _ = x.shape
    prev = state.shift_tmix if state is not None else None
    xx = _token_shift(x, prev) - x
    mixed = _ddlerp(p, x, xx)                                # (B,S,5,d)
    xr, xk, xv, xg, xw = [mixed[:, :, i] for i in range(5)]
    r = (xr @ p["w_r"].astype(x.dtype)).reshape(B, S, H, D)
    k = (xk @ p["w_k"].astype(x.dtype)).reshape(B, S, H, D)
    v = (xv @ p["w_v"].astype(x.dtype)).reshape(B, S, H, D)
    g = jax.nn.silu(xg @ p["w_g"].astype(x.dtype))
    w_raw = (p["w0"].astype(jnp.float32)
             + (jnp.tanh(xw @ p["w_lora_a"].astype(x.dtype))
                @ p["w_lora_b"].astype(x.dtype)).astype(jnp.float32))
    logw = -jnp.exp(w_raw).reshape(B, S, H, D)               # log decay <= 0
    u = p["u_bonus"].astype(jnp.float32).reshape(H, D)
    s0 = (state.wkv if state is not None
          else jnp.zeros((B, H, D, D), jnp.float32))
    if kernel is not None:
        y, sF = kernel(r, k, v, logw, u, s0)
    elif S > 64:
        # chunked form: per-chunk (not per-step) state traffic — the
        # rwkv6 §Perf iteration; exact per-step recurrence for short seqs
        y, sF = wkv_chunked(r, k, v, logw, u, s0, chunk=64)
    else:
        y, sF = wkv_recurrence(r, k, v, logw, u, s0)
    # per-head group norm
    y = y.reshape(B, S, H, D)
    mu = y.mean(-1, keepdims=True)
    var = ((y - mu) ** 2).mean(-1, keepdims=True)
    y = ((y - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, S, d)
    y = (y * p["ln_x"].astype(jnp.float32)).astype(x.dtype) * g
    out = y @ p["w_o"].astype(x.dtype)
    return out, sF


def channel_mix(p, x, state: Optional[RWKVState]):
    prev = state.shift_cmix if state is not None else None
    xx = _token_shift(x, prev) - x
    xk = x + xx * p["cm_mu_k"].astype(x.dtype)
    xr = x + xx * p["cm_mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["cm_wk"].astype(x.dtype)))
    kv = k @ p["cm_wv"].astype(x.dtype)
    return jax.nn.sigmoid(xr @ p["cm_wr"].astype(x.dtype)) * kv


def rwkv_block(p, x, cfg, norm1, norm2, state: Optional[RWKVState] = None,
               return_state: bool = False, kernel=None):
    """Full RWKV6 block (time-mix + channel-mix, pre-norm residual)."""
    h = L.apply_norm(norm1, x, cfg)
    tm, sF = time_mix(p, h, cfg, state, kernel=kernel)
    x = x + tm
    h2 = L.apply_norm(norm2, x, cfg)
    x = x + channel_mix(p, h2, state)
    if return_state:
        new_state = RWKVState(h[:, -1, :], h2[:, -1, :], sF,
                              (state.length + x.shape[1]) if state is not None
                              else jnp.full((x.shape[0],), x.shape[1],
                                            jnp.int32))
        return x, new_state
    return x


def init_rwkv_state(cfg, batch: int):
    d = cfg.d_model
    H, D = d // cfg.rwkv.head_dim, cfg.rwkv.head_dim
    dt = L.cfg_dtype(cfg.param_dtype)
    return RWKVState(
        jnp.zeros((batch, d), dt), jnp.zeros((batch, d), dt),
        jnp.zeros((batch, H, D, D), jnp.float32),
        jnp.zeros((batch,), jnp.int32))
