"""Mamba2 (SSD — state-space duality) block, chunked-parallel + decode step.

Used by zamba2 (hybrid).  The chunked form computes intra-chunk contributions
with MXU-friendly masked matmuls and carries the (H, P, N) SSM state across
chunks with a lax.scan — the same decomposition the Pallas kernel
(repro.kernels.ssm_scan) implements with explicit VMEM tiles.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L


class SSMState(NamedTuple):
    conv: jax.Array    # (B, K-1, conv_channels) rolling conv input window
    ssm: jax.Array     # (B, H, P, N) state
    length: jax.Array  # (B,)


def ssm_spec(cfg, layered: Optional[int] = None):
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    H = d_inner // s.head_dim
    G = s.n_groups
    conv_ch = d_inner + 2 * G * s.d_state
    dt = L.cfg_dtype(cfg.param_dtype)

    def w(shape, axes, init="normal", scale=1.0):
        if layered is not None:
            shape = (layered,) + shape
            axes = ("layers",) + axes
        return L.ParamSpec(shape, dt, axes, init, scale)

    return {
        # fused input projection: [z, x, B, C, dt]
        "w_in": w((d, d_inner + conv_ch + H), ("embed", "ssm_in")),
        "conv_w": w((s.conv_kernel, conv_ch), ("conv", "ssm_conv"),
                    scale=1.0),
        "conv_b": w((conv_ch,), ("ssm_conv",), "zeros"),
        "a_log": w((H,), ("heads",), "zeros"),   # A = -exp(a_log)
        "d_skip": w((H,), ("heads",), "ones"),
        "dt_bias": w((H,), ("heads",), "zeros"),
        "norm": w((d_inner,), ("ssm_inner",), "ones"),
        "w_out": w((d_inner, d), ("ssm_inner", "embed")),
    }


def _split_proj(p, x, cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    G, N = s.n_groups, s.d_state
    H = d_inner // s.head_dim
    zxbcdt = x @ p["w_in"].astype(x.dtype)
    z, xconv, dt_raw = jnp.split(
        zxbcdt, [d_inner, d_inner + d_inner + 2 * G * N], axis=-1)
    return z, xconv, dt_raw, (d_inner, G, N, H)


def _causal_conv(xconv, p, cfg):
    """Depthwise causal conv1d via K shifted adds (K=4: cheap, fusable)."""
    K = cfg.ssm.conv_kernel
    w = p["conv_w"].astype(xconv.dtype)
    out = jnp.zeros_like(xconv)
    for i in range(K):
        shift = K - 1 - i
        shifted = jnp.pad(xconv, ((0, 0), (shift, 0), (0, 0)))[
            :, :xconv.shape[1]]
        out = out + shifted * w[i]
    return jax.nn.silu(out + p["conv_b"].astype(xconv.dtype))


def _ssd_chunked(xh, dtv, A, Bm, Cm, h0=None, chunk=256):
    """Chunked SSD scan.

    xh:  (B, S, H, P)  input heads
    dtv: (B, S, H)     positive step sizes
    A:   (H,)          negative decay rates
    Bm:  (B, S, G, N)  input matrices (groups broadcast over heads)
    Cm:  (B, S, G, N)  output matrices
    h0:  optional initial state (B, H, P, N)
    Returns y (B, S, H, P) and final state (B, H, P, N).
    """
    B, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        # dt = 0 on padded steps: identity decay, zero contribution
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtv = jnp.pad(dtv, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S_out = S
    S = S + pad
    nc = S // chunk

    xc = xh.reshape(B, nc, chunk, H, P)
    dtc = dtv.reshape(B, nc, chunk, H).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, chunk, G, N)
    Cc = Cm.reshape(B, nc, chunk, G, N)
    a = dtc * A.astype(jnp.float32)                     # (B,nc,c,H) negative
    seg = jnp.cumsum(a, axis=2)                         # within-chunk cumsum

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def body(h, inputs):
        xk, dtk, Bk, Ck, ak, segk = inputs              # chunk k slices
        # expand groups over heads
        Bh = jnp.repeat(Bk, rep, axis=2)                # (B,c,H,N)
        Ch = jnp.repeat(Ck, rep, axis=2)
        # intra-chunk: M[i,j] = (C_i . B_j) exp(seg_i - seg_j) [j <= i]
        cb = jnp.einsum("bihn,bjhn->bhij", Ch.astype(jnp.float32),
                        Bh.astype(jnp.float32))
        dseg = segk[:, :, None, :] - segk[:, None, :, :]  # (B,i,j,H)
        dseg = jnp.transpose(dseg, (0, 3, 1, 2))          # (B,H,i,j)
        mask = jnp.tril(jnp.ones((segk.shape[1], segk.shape[1]), bool))
        M = jnp.where(mask, cb * jnp.exp(dseg), 0.0)
        xdt = xk.astype(jnp.float32) * dtk[..., None]     # (B,c,H,P)
        y_intra = jnp.einsum("bhij,bjhp->bihp", M, xdt)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bihn,bhpn,bih->bihp", Ch.astype(jnp.float32),
                             h, jnp.exp(segk))
        # state update: h' = exp(seg_last) h + sum_j exp(seg_last - seg_j)
        #                                          dt_j x_j B_j^T
        seg_last = segk[:, -1:, :]                        # (B,1,H)
        w = jnp.exp(seg_last - segk)                      # (B,c,H)
        dh = jnp.einsum("bjhp,bjhn,bjh->bhpn", xdt, Bh.astype(jnp.float32),
                        w)
        h_new = jnp.exp(seg_last[:, 0, :])[:, :, None, None] * h + dh
        return h_new, (y_intra + y_inter)

    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
          jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0),
          jnp.moveaxis(a, 1, 0), jnp.moveaxis(seg, 1, 0))
    hF, ys = jax.lax.scan(body, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)[:, :S_out]
    return y.astype(xh.dtype), hF


def ssm_forward(p, x, cfg, state: Optional[SSMState] = None,
                return_state: bool = False):
    """Full-sequence Mamba2 block.  x: (B, S, d)."""
    s = cfg.ssm
    z, xconv, dt_raw, (d_inner, G, N, H) = _split_proj(p, x, cfg)
    xconv = _causal_conv(xconv, p, cfg)
    xh, Bm, Cm = jnp.split(xconv, [d_inner, d_inner + G * N], axis=-1)
    B_, S_ = x.shape[0], x.shape[1]
    xh = xh.reshape(B_, S_, H, s.head_dim)
    Bm = Bm.reshape(B_, S_, G, N)
    Cm = Cm.reshape(B_, S_, G, N)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    h0 = state.ssm if state is not None else None
    y, hF = _ssd_chunked(xh, dtv, A, Bm, Cm, h0=h0, chunk=s.chunk_size)
    y = y + xh * p["d_skip"].astype(xh.dtype)[None, None, :, None]
    y = y.reshape(B_, S_, d_inner)
    y = _gated_norm(y, z, p)
    out = y @ p["w_out"].astype(x.dtype)
    if return_state:
        K = s.conv_kernel
        # raw (pre-activation) conv-input tail becomes the rolling window
        _, xconv_raw, _, _ = _split_proj(p, x, cfg)
        conv_state = xconv_raw[:, -(K - 1):, :]
        st = SSMState(conv_state.astype(x.dtype), hF,
                      jnp.full((B_,), S_, jnp.int32))
        return out, st
    return out


def _gated_norm(y, z, p, eps=1e-5):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    yf = yf * jax.lax.rsqrt((yf ** 2).mean(-1, keepdims=True) + eps)
    return (yf * p["norm"].astype(jnp.float32)).astype(y.dtype)


def ssm_decode_step(p, x, cfg, state: SSMState):
    """One-token decode.  x: (B, 1, d)."""
    s = cfg.ssm
    z, xconv_new, dt_raw, (d_inner, G, N, H) = _split_proj(p, x, cfg)
    K = s.conv_kernel
    # conv over the rolling window [state.conv, xconv_new]
    win = jnp.concatenate([state.conv, xconv_new], axis=1)    # (B, K, C)
    w = p["conv_w"].astype(win.dtype)
    conv_out = jnp.einsum("bkc,kc->bc", win[:, -K:], w) \
        + p["conv_b"].astype(win.dtype)
    conv_out = jax.nn.silu(conv_out)[:, None, :]              # (B,1,C)
    xh, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + G * N], axis=-1)
    B_ = x.shape[0]
    xh = xh.reshape(B_, H, s.head_dim)
    Bm = Bm.reshape(B_, G, N)
    Cm = Cm.reshape(B_, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)
    Ch = jnp.repeat(Cm, rep, axis=1)
    dtv = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dtv * A)                                      # (B,H)
    xdt = xh.astype(jnp.float32) * dtv[..., None]              # (B,H,P)
    h_new = (da[..., None, None] * state.ssm
             + jnp.einsum("bhp,bhn->bhpn", xdt, Bh.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Ch.astype(jnp.float32))
    y = y.astype(x.dtype) + xh * p["d_skip"].astype(xh.dtype)[None, :, None]
    y = y.reshape(B_, 1, d_inner)
    y = _gated_norm(y, z, p)
    out = y @ p["w_out"].astype(x.dtype)
    new_conv = jnp.concatenate([state.conv, xconv_new], axis=1)[:, 1:]
    return out, SSMState(new_conv, h_new, state.length + 1)


def init_ssm_state(cfg, batch: int):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    dt = L.cfg_dtype(cfg.param_dtype)
    return SSMState(
        jnp.zeros((batch, s.conv_kernel - 1, conv_ch), dt),
        jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32),
        jnp.zeros((batch,), jnp.int32))
