"""Unified causal-LM stacks: dense / MoE / hybrid (zamba2) / RWKV / VLM.

All stacks are scan-over-layers with optional remat; decode carries stacked
per-layer caches through the same scan.  Whisper (enc-dec) lives in
``repro.models.encdec``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rwkv as R
from repro.models import ssm as S


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """Runtime execution knobs (closure-captured; not a jit arg)."""
    attn_impl: str = "chunked"       # chunked | dense
    q_chunk: int = 512
    k_chunk: int = 512
    unroll_causal: bool = False      # causal block pruning (bigger HLO)
    scan_layers: Optional[bool] = None   # override cfg.scan_layers
    remat: Optional[bool] = None
    seq_shard_resid: bool = False    # Megatron-SP: shard residual seq dim
                                     # over "model" (saves remat residuals)
    moe_groups: int = 1              # GShard dispatch groups (= n_silos)
    moe_dispatch: str = "gather"     # gather | einsum (reference)
    # activation sharding: mesh + logical rules (None = no constraints)
    mesh: Any = None
    rules: Any = None


from repro.models.layers import shard_act  # noqa: E402


def _scan_layers(cfg, exec_cfg):
    v = exec_cfg.scan_layers
    return cfg.scan_layers if v is None else v


def _remat(cfg, exec_cfg):
    v = exec_cfg.remat
    return cfg.remat if v is None else v


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------

def _block_spec(cfg, layered):
    """One decoder block (attention or MLA) + (MLP or MoE)."""
    p = {"norm1": _lnorm(cfg, layered), "norm2": _lnorm(cfg, layered)}
    if cfg.attention == "mla":
        p["attn"] = A.mla_spec(cfg, layered=layered)
    else:
        p["attn"] = A.gqa_spec(cfg, layered=layered)
    if cfg.moe is not None:
        p["moe"] = M.moe_spec(cfg, layered=layered)
    else:
        p["mlp"] = L.mlp_spec(cfg, cfg.d_model, cfg.d_ff, layered=layered)
    return p


def _lnorm(cfg, layered):
    return L.norm_spec(cfg, cfg.d_model, layered=layered)


def build_spec(cfg) -> Dict[str, Any]:
    dt = L.cfg_dtype(cfg.param_dtype)
    spec: Dict[str, Any] = {
        "embed": L.ParamSpec((cfg.vocab_size, cfg.d_model), dt,
                             ("vocab", "embed"), "embed", 0.02),
        "final_norm": L.norm_spec(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = L.ParamSpec((cfg.d_model, cfg.vocab_size), dt,
                                      ("embed", "vocab"), "normal")
    Lr = cfg.num_layers if cfg.scan_layers else None

    if cfg.arch_type == "hybrid":
        spec["mamba_norm"] = L.norm_spec(cfg, cfg.d_model,
                                         layered=cfg.num_layers)
        spec["mamba"] = S.ssm_spec(cfg, layered=cfg.num_layers)
        spec["shared_attn"] = {
            "norm1": _lnorm(cfg, None),
            "attn": A.gqa_spec(cfg, layered=None),
            "norm2": _lnorm(cfg, None),
            "mlp": L.mlp_spec(cfg, cfg.d_model, cfg.d_ff),
        }
    elif cfg.rwkv is not None:
        spec["blocks"] = {
            "norm1": _lnorm(cfg, Lr), "norm2": _lnorm(cfg, Lr),
            "rwkv": R.rwkv_spec(cfg, layered=Lr),
        }
        spec["ln0"] = L.norm_spec(cfg, cfg.d_model)   # rwkv pre-embedding LN
    else:
        spec["blocks"] = _block_spec(cfg, Lr)
    if cfg.vision is not None:
        spec["vis_proj"] = L.dense_spec(
            cfg, cfg.vision.patch_embed_dim, cfg.d_model,
            ("vis_patch", "embed"))
    return spec


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg, batch=None):
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        L.cfg_dtype(cfg.compute_dtype))
    if cfg.vision is not None and batch is not None \
            and "image_embeds" in batch:
        n = cfg.vision.num_image_tokens
        img = L.apply_dense(params["vis_proj"],
                            batch["image_embeds"].astype(x.dtype))
        x = jnp.concatenate([img, x[:, n:]], axis=1)
    return x


def lm_head(params, x, cfg):
    if cfg.tie_embeddings:
        w = params["embed"].astype(x.dtype).T
    else:
        w = params["lm_head"].astype(x.dtype)
    return x @ w


# ---------------------------------------------------------------------------
# Dense / MoE blocks
# ---------------------------------------------------------------------------

def _attn_fwd(p, x, positions, cfg, exec_cfg):
    kw = dict(q_chunk=exec_cfg.q_chunk, k_chunk=exec_cfg.k_chunk,
              unroll_causal=exec_cfg.unroll_causal, impl=exec_cfg.attn_impl)
    if cfg.attention == "mla":
        return A.mla_forward(p, x, positions, cfg, **kw)
    return A.gqa_forward(p, x, positions, cfg, ecfg=exec_cfg, **kw)


def _resid_axes(exec_cfg):
    return ("batch", "seq_sp" if exec_cfg.seq_shard_resid else None, None)


def block_forward(p, x, positions, cfg, exec_cfg):
    """Returns (x, aux)."""
    h = L.apply_norm(p["norm1"], x, cfg)
    x = x + _attn_fwd(p["attn"], h, positions, cfg, exec_cfg)
    x = shard_act(x, _resid_axes(exec_cfg), exec_cfg)
    h = L.apply_norm(p["norm2"], x, cfg)
    if cfg.moe is not None:
        y, aux = M.moe_forward(p["moe"], h, cfg, exec_cfg)
    else:
        y, aux = L.apply_mlp(p["mlp"], h, cfg), 0.0
    return shard_act(x + y, _resid_axes(exec_cfg), exec_cfg), aux


def block_decode(p, x, positions, cfg, cache):
    h = L.apply_norm(p["norm1"], x, cfg)
    if cfg.attention == "mla":
        o, cache = A.mla_decode_step(p["attn"], h, positions, cfg, cache)
    else:
        o, cache = A.gqa_decode_step(p["attn"], h, positions, cfg, cache)
    x = x + o
    h = L.apply_norm(p["norm2"], x, cfg)
    if cfg.moe is not None:
        y, _ = M.moe_forward(p["moe"], h, cfg)
    else:
        y = L.apply_mlp(p["mlp"], h, cfg)
    return x + y, cache


def block_prefill(p, x, positions, cfg, cache, exec_cfg):
    h = L.apply_norm(p["norm1"], x, cfg)
    kw = dict(q_chunk=exec_cfg.q_chunk, k_chunk=exec_cfg.k_chunk)
    if cfg.attention == "mla":
        o, cache = A.mla_prefill(p["attn"], h, positions, cfg, cache, **kw)
    else:
        o, cache = A.gqa_prefill(p["attn"], h, positions, cfg, cache,
                                 ecfg=exec_cfg, **kw)
    x = x + o
    h = L.apply_norm(p["norm2"], x, cfg)
    if cfg.moe is not None:
        y, _ = M.moe_forward(p["moe"], h, cfg)
    else:
        y = L.apply_mlp(p["mlp"], h, cfg)
    return x + y, cache


# ---------------------------------------------------------------------------
# Stacks (train forward)
# ---------------------------------------------------------------------------

def _hybrid_segments(cfg):
    """zamba2 layer plan: shared attn before layers 0, k, 2k, ..."""
    k = cfg.hybrid.attn_every
    bounds = list(range(0, cfg.num_layers, k)) + [cfg.num_layers]
    return [(bounds[i], bounds[i + 1]) for i in range(len(bounds) - 1)]


def _shared_attn_block(p, x, positions, cfg, exec_cfg):
    h = L.apply_norm(p["norm1"], x, cfg)
    x = x + A.gqa_forward(
        p["attn"], h, positions, cfg,
        q_chunk=exec_cfg.q_chunk, k_chunk=exec_cfg.k_chunk,
        unroll_causal=exec_cfg.unroll_causal, impl=exec_cfg.attn_impl)
    h = L.apply_norm(p["norm2"], x, cfg)
    return x + L.apply_mlp(p["mlp"], h, cfg)


def forward(params, batch, cfg, exec_cfg=ExecConfig()):
    """Full train/eval forward -> (logits, aux_loss)."""
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    positions = batch.get("positions",
                          jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq)))
    x = embed_tokens(params, tokens, cfg, batch)
    x = shard_act(x, _resid_axes(exec_cfg), exec_cfg)

    if cfg.arch_type == "hybrid":
        x = _hybrid_forward(params, x, positions, cfg, exec_cfg)
        aux = 0.0
    elif cfg.rwkv is not None:
        x = _rwkv_forward(params, x, cfg, exec_cfg)
        aux = 0.0
    else:
        x, aux = _dense_forward(params, x, positions, cfg, exec_cfg)

    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = lm_head(params, x, cfg)
    return shard_act(logits, ("batch", None, "vocab"), exec_cfg), aux


def _dense_forward(params, x, positions, cfg, exec_cfg):
    def body_fn(carry, p_l):
        x, aux = carry
        x, a = block_forward(p_l, x, positions, cfg, exec_cfg)
        return (x, aux + a), None

    body = jax.remat(body_fn) if _remat(cfg, exec_cfg) else body_fn
    if _scan_layers(cfg, exec_cfg):
        (x, aux), _ = jax.lax.scan(body, (x, 0.0), params["blocks"])
    else:
        aux = 0.0
        for i in range(cfg.num_layers):
            p_l = jax.tree.map(lambda a: a[i], params["blocks"])
            (x, aux), _ = body((x, aux), p_l)
    return x, aux


def _hybrid_forward(params, x, positions, cfg, exec_cfg):
    segs = _hybrid_segments(cfg)

    def mamba_body_fn(x, inputs):
        norm_p, mamba_p = inputs
        h = L.apply_norm(norm_p, x, cfg)
        return x + S.ssm_forward(mamba_p, h, cfg), None

    body = (jax.remat(mamba_body_fn) if _remat(cfg, exec_cfg)
            else mamba_body_fn)
    for (lo, hi) in segs:
        x = _shared_attn_block(params["shared_attn"], x, positions, cfg,
                               exec_cfg)
        seg_norm = jax.tree.map(lambda a: a[lo:hi], params["mamba_norm"])
        seg_mamba = jax.tree.map(lambda a: a[lo:hi], params["mamba"])
        x, _ = jax.lax.scan(body, x, (seg_norm, seg_mamba))
    return x


def _rwkv_forward(params, x, cfg, exec_cfg):
    x = L.apply_norm(params["ln0"], x, cfg)

    def body_fn(x, p_l):
        return R.rwkv_block(p_l["rwkv"], x, cfg, p_l["norm1"],
                            p_l["norm2"]), None

    body = jax.remat(body_fn) if _remat(cfg, exec_cfg) else body_fn
    if _scan_layers(cfg, exec_cfg):
        x, _ = jax.lax.scan(body, x, params["blocks"])
    else:
        for i in range(cfg.num_layers):
            p_l = jax.tree.map(lambda a: a[i], params["blocks"])
            x, _ = body(x, p_l)
    return x


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(params, batch, cfg, exec_cfg=ExecConfig(),
            per_example: bool = False):
    """Next-token CE.  labels < 0 are masked.  Returns (loss, metrics)."""
    logits, aux = forward(params, batch, cfg, exec_cfg)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    # label pick via fused iota-compare (gather on a model-sharded vocab
    # axis would force an all-gather of the logits)
    pick = (jax.lax.broadcasted_iota(jnp.int32, lp.shape, lp.ndim - 1)
            == jnp.maximum(labels, 0)[..., None])
    ll = jnp.sum(jnp.where(pick, lp, 0.0), axis=-1)
    if per_example:
        tok = jnp.maximum(mask.sum(-1), 1.0)
        ce = -(ll * mask).sum(-1) / tok                  # (B,)
        loss = ce.mean() + aux
        return loss, {"ce_per_example": ce, "aux": aux}
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = -(ll * mask).sum() / denom
    return ce + aux, {"ce": ce, "aux": aux,
                      "acc": ((logits.argmax(-1) == labels) * mask).sum()
                      / denom}


# ---------------------------------------------------------------------------
# Decode (serve) paths
# ---------------------------------------------------------------------------

class DecodeCache(NamedTuple):
    layers: Any            # stacked per-layer cache pytree
    extra: Any             # hybrid: stacked shared-attn caches; else None


def init_cache(cfg, batch: int, max_len: int, filled: bool = False):
    if cfg.arch_type == "hybrid":
        n_app = len(_hybrid_segments(cfg))
        attn = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[A.init_kv_cache(cfg, batch, max_len, filled)
              for _ in range(n_app)])
        ssm = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[S.init_ssm_state(cfg, batch)
                             for _ in range(cfg.num_layers)])
        if filled:
            ssm = ssm._replace(
                length=jnp.full_like(ssm.length, max_len))
        return DecodeCache(ssm, attn)
    if cfg.rwkv is not None:
        st = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[R.init_rwkv_state(cfg, batch)
                            for _ in range(cfg.num_layers)])
        if filled:
            st = st._replace(length=jnp.full_like(st.length, max_len))
        return DecodeCache(st, None)
    mk = (A.init_mla_cache if cfg.attention == "mla" else A.init_kv_cache)
    kv = jax.tree.map(lambda *xs: jnp.stack(xs),
                      *[mk(cfg, batch, max_len, filled)
                        for _ in range(cfg.num_layers)])
    return DecodeCache(kv, None)


def decode_step(params, tokens, positions, cache: DecodeCache, cfg):
    """One-token decode.  tokens: (B, 1); positions: (B, 1) absolute."""
    x = embed_tokens(params, tokens, cfg)

    if cfg.arch_type == "hybrid":
        segs = _hybrid_segments(cfg)
        new_attn = []
        ssm_st = cache.layers

        def mamba_body(x, inputs):
            norm_p, mamba_p, st = inputs
            h = L.apply_norm(norm_p, x, cfg)
            o, st = S.ssm_decode_step(mamba_p, h, cfg, st)
            return x + o, st

        for si, (lo, hi) in enumerate(segs):
            attn_c = jax.tree.map(lambda a: a[si], cache.extra)
            h = L.apply_norm(params["shared_attn"]["norm1"], x, cfg)
            o, attn_c = A.gqa_decode_step(params["shared_attn"]["attn"], h,
                                          positions, cfg, attn_c)
            x = x + o
            h = L.apply_norm(params["shared_attn"]["norm2"], x, cfg)
            x = x + L.apply_mlp(params["shared_attn"]["mlp"], h, cfg)
            new_attn.append(attn_c)
            seg = lambda t: jax.tree.map(lambda a: a[lo:hi], t)
            x, st_seg = jax.lax.scan(
                mamba_body, x, (seg(params["mamba_norm"]),
                                seg(params["mamba"]), seg(ssm_st)))
            ssm_st = jax.tree.map(
                lambda full, part: jax.lax.dynamic_update_slice_in_dim(
                    full, part.astype(full.dtype), lo, 0), ssm_st, st_seg)
        attn_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *new_attn)
        x = L.apply_norm(params["final_norm"], x, cfg)
        return lm_head(params, x, cfg), DecodeCache(ssm_st, attn_stack)

    if cfg.rwkv is not None:
        x = L.apply_norm(params["ln0"], x, cfg)

        def body(x, inputs):
            p_l, st = inputs
            h = L.apply_norm(p_l["norm1"], x, cfg)
            tm, wkv = R.time_mix(p_l["rwkv"], h, cfg, st)
            x = x + tm
            h2 = L.apply_norm(p_l["norm2"], x, cfg)
            x = x + R.channel_mix(p_l["rwkv"], h2, st)
            new_st = R.RWKVState(h[:, -1], h2[:, -1], wkv, st.length + 1)
            return x, new_st

        x, new_states = jax.lax.scan(body, x, (params["blocks"],
                                               cache.layers))
        x = L.apply_norm(params["final_norm"], x, cfg)
        return lm_head(params, x, cfg), DecodeCache(new_states, None)

    def body(x, inputs):
        p_l, c_l = inputs
        x, c_l = block_decode(p_l, x, positions, cfg, c_l)
        return x, c_l

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache.layers))
    x = L.apply_norm(params["final_norm"], x, cfg)
    return lm_head(params, x, cfg), DecodeCache(new_cache, None)


def prefill(params, batch, cfg, exec_cfg=ExecConfig(), max_len=None):
    """Prompt prefill: returns (last-position logits, filled cache).

    ``max_len`` sets the cache capacity (>= prompt length) so subsequent
    decode steps have headroom; defaults to the prompt length (the dry-run
    decode shapes supply their own filled caches).
    """
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    max_len = max_len or Sq
    positions = batch.get("positions",
                          jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq)))
    x = embed_tokens(params, tokens, cfg, batch)

    if cfg.arch_type == "hybrid":
        segs = _hybrid_segments(cfg)
        attn_caches, ssm_states = [], []

        def mamba_body(x, inputs):
            norm_p, mamba_p = inputs
            h = L.apply_norm(norm_p, x, cfg)
            o, st = S.ssm_forward(mamba_p, h, cfg, return_state=True)
            return x + o, st

        for si, (lo, hi) in enumerate(segs):
            c0 = A.init_kv_cache(cfg, B, max_len)
            h = L.apply_norm(params["shared_attn"]["norm1"], x, cfg)
            o, c = A.gqa_prefill(params["shared_attn"]["attn"], h,
                                 positions, cfg, c0, ecfg=exec_cfg,
                                 q_chunk=exec_cfg.q_chunk,
                                 k_chunk=exec_cfg.k_chunk)
            x = x + o
            h = L.apply_norm(params["shared_attn"]["norm2"], x, cfg)
            x = x + L.apply_mlp(params["shared_attn"]["mlp"], h, cfg)
            attn_caches.append(c)
            seg = lambda t: jax.tree.map(lambda a: a[lo:hi], t)
            x, st_seg = jax.lax.scan(
                mamba_body, x, (seg(params["mamba_norm"]),
                                seg(params["mamba"])))
            ssm_states.append(st_seg)
        ssm = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *ssm_states)
        attn = jax.tree.map(lambda *xs: jnp.stack(xs), *attn_caches)
        x = L.apply_norm(params["final_norm"], x, cfg)
        return lm_head(params, x[:, -1:], cfg), DecodeCache(ssm, attn)

    if cfg.rwkv is not None:
        x = L.apply_norm(params["ln0"], x, cfg)

        def body(x, p_l):
            x, st = R.rwkv_block(p_l["rwkv"], x, cfg, p_l["norm1"],
                                 p_l["norm2"], return_state=True)
            return x, st

        x, states = jax.lax.scan(body, x, params["blocks"])
        x = L.apply_norm(params["final_norm"], x, cfg)
        return lm_head(params, x[:, -1:], cfg), DecodeCache(states, None)

    def body(x, inputs):
        p_l, c_l = inputs
        x, c_l = block_prefill(p_l, x, positions, cfg, c_l, exec_cfg)
        return x, c_l

    cache0 = init_cache(cfg, B, max_len).layers
    x, cache = jax.lax.scan(body, x, (params["blocks"], cache0))
    x = L.apply_norm(params["final_norm"], x, cfg)
    return lm_head(params, x[:, -1:], cfg), DecodeCache(cache, None)
