"""Observability for the fleet round path (see DESIGN notes in each
module):

* ``repro.obs.metrics`` — ``@register_metric`` device-metric registry;
  per-round reductions fused into one extra jitted dispatch that rides
  the pipelined round ledger (zero added host syncs).
* ``repro.obs.trace`` — host span tracer with Chrome/Perfetto
  ``trace_event`` export.
* ``repro.obs.sink`` — JSONL / in-memory event sinks.
* ``repro.obs.telemetry`` — the ``Telemetry`` session object
  ``FleetEngine.run(telemetry=...)`` consumes.
* ``repro.obs.report`` — ``python -m repro.obs.report run.jsonl`` run
  summary CLI.
"""
from repro.obs.metrics import (available_metrics, make_metrics_fn,
                               metrics_for, register_metric)
from repro.obs.sink import JsonlSink, MemorySink, TeeSink
from repro.obs.telemetry import Telemetry
from repro.obs.trace import NULL_TRACER, NullTracer, Tracer
