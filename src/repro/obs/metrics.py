"""Device metrics registry: per-round scalars fused into one dispatch.

Each ``@register_metric`` entry is a pure jnp reduction over the round's
device-resident context (plan masks, receive mask, losses, finish times,
cache metadata, the stacked trainer output and the pre-step global
model).  ``make_metrics_fn`` selects the metrics whose declared needs
the engine's active round path can supply at the configured level and
fuses them into a *single* jitted dispatch whose outputs are device
scalars (or small fixed-size vectors) — the engine pushes the handles
through the round ledger, so metric values ride the existing pipelined
readback and add **zero** per-round host syncs.  With
``FLConfig.telemetry=None`` the factory is never called and the round
path is bit-for-bit (and dispatch-count) identical to an uninstrumented
engine.

Context keys (the engine supplies the subset its path produces; every
per-client array is the (N,) fleet view, ``rows``/``rows_mask`` are the
stacked trainer rows — (N, ...) full scan or (X, ...) cohort block):

``selected, distribute, resume, online, received, fail`` — (N,) bool
masks; ``losses`` — (N,) mean local loss; ``times`` — (N,) finish
times (inf = no upload); ``progress, stamp`` — (N,) C3 cache metadata
*before* the server step (post plan-side expiry); ``stamp_pre_expire``
— (N,) stamps before the discard-mode expiry (discard runs only);
``rule_state`` — (N,) robust-aggregation state (stateful rules);
``rows, rows_mask, global`` — stacked client params, their receive
mask, and the pre-step global model; ``rnd`` — the round index.

Static keys (``make_metrics_fn(static=...)``): ``num_clients``,
``cohort_size`` (None on the full scan), ``local_steps``,
``staleness_edges``, and optionally ``rows_bound`` — the round's
static selection bound, letting O(rows · D) metrics gather the
received rows into a compact block before reducing (the full-scan
``rows`` view is fleet-sized).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import partitioning as SP

LEVELS = ("basic", "full")
_RANK = {lvl: i for i, lvl in enumerate(LEVELS)}

# default staleness-histogram bucket edges (rounds since cache write);
# bucket b counts edges[b] <= staleness < edges[b+1], last bucket open
STALENESS_EDGES = (0, 1, 2, 4, 8, 16)


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    name: str
    level: str
    needs: Tuple[str, ...]           # ctx keys (+ static availability
    fn: Callable                     # flags like "cohort_size")


_REGISTRY: Dict[str, MetricSpec] = {}


def register_metric(name: str, *, level: str = "basic",
                    needs: Sequence[str] = (),
                    allow_override: bool = False):
    """Register ``fn(ctx, static) -> {column: device scalar/vector}``.

    ``level`` gates when the metric compiles in (``"basic"`` runs at
    both levels, ``"full"`` only at full); ``needs`` lists the context
    keys the reduction reads — the engine's round path advertises what
    it can supply and metrics with unmet needs are skipped, never
    traced.
    """
    if level not in LEVELS:
        raise ValueError(f"metric level must be one of {LEVELS}, got "
                         f"{level!r}")

    def deco(fn):
        if name in _REGISTRY and not allow_override:
            raise ValueError(f"metric {name!r} already registered")
        _REGISTRY[name] = MetricSpec(name, level, tuple(needs), fn)
        return fn

    return deco


def available_metrics():
    return sorted(_REGISTRY)


def metrics_for(level: str, available) -> Tuple[MetricSpec, ...]:
    """Registered metrics active at ``level`` whose needs ``available``
    (a set of ctx keys + static availability flags) satisfies."""
    if level not in LEVELS:
        raise ValueError(f"telemetry level must be one of {LEVELS}, got "
                         f"{level!r}")
    avail = set(available)
    return tuple(s for _, s in sorted(_REGISTRY.items())
                 if _RANK[s.level] <= _RANK[level]
                 and set(s.needs) <= avail)


def make_metrics_fn(level: str, available, static: dict, mesh=None):
    """Fuse the active metrics into one jitted dispatch.

    Returns ``(fn, needed)``: ``fn(ctx) -> {column: device value}`` and
    the tuple of ctx keys the engine must supply (the union of the
    selected metrics' needs, minus static flags).  Returns
    ``(None, ())`` when no metric applies.
    """
    specs = metrics_for(level, available)
    if not specs:
        return None, ()
    needed = tuple(sorted({k for s in specs for k in s.needs
                           if k not in static}))

    @jax.jit
    def metrics_fn(ctx):
        out = {}
        for spec in specs:
            vals = spec.fn(ctx, static)
            dup = set(vals) & set(out)
            if dup:
                raise ValueError(f"metric {spec.name!r} re-emits "
                                 f"columns {sorted(dup)}")
            out.update(vals)
        # metric outputs are replicated reductions — pin that under the
        # client mesh so readback never gathers
        return SP.replicated_constraint(out, mesh)

    return metrics_fn, needed


# ---------------------------------------------------------------------------
# Masked-reduction helpers (shared numpy-oracle-friendly definitions)
# ---------------------------------------------------------------------------

def _count(mask):
    return jnp.sum(mask.astype(jnp.int32))


def _masked_mean_max(values, mask):
    """Mean/max of ``values`` over ``mask`` rows (0.0 when empty)."""
    n = jnp.sum(mask.astype(values.dtype))
    got = jnp.where(mask, values, 0.0)
    return jnp.sum(got) / jnp.maximum(n, 1.0), jnp.max(got)


# ---------------------------------------------------------------------------
# Built-in metrics
# ---------------------------------------------------------------------------

@register_metric("counts", needs=("selected", "received", "fail",
                                  "online", "distribute"))
def _counts(ctx, static):
    """Fleet participation counters (Alg. 2 accounting)."""
    return {
        "selected_count": _count(ctx["selected"]),
        "received_count": _count(ctx["received"]),
        "interrupted_count": _count(ctx["fail"]),
        "online_count": _count(ctx["online"]),
        "download_count": _count(ctx["distribute"] & ctx["online"]),
    }


@register_metric("local_loss", needs=("losses", "received"))
def _local_loss(ctx, static):
    """Mean/max local training loss over the uploads the server saw."""
    mean, mx = _masked_mean_max(ctx["losses"], ctx["received"])
    return {"local_loss_mean": mean, "local_loss_max": mx}


@register_metric("round_time", needs=("times", "received"))
def _round_time(ctx, static):
    """Mean/max finish time of received uploads (why was it slow?)."""
    mean, mx = _masked_mean_max(ctx["times"], ctx["received"])
    return {"finish_time_mean": mean, "finish_time_max": mx}


@register_metric("cache", needs=("stamp", "resume", "selected"))
def _cache(ctx, static):
    """C3 cache residency + hits (resumed-from-cache selections)."""
    return {
        "cache_rows": _count(ctx["stamp"] >= 0),
        "cache_hit_count": _count(ctx["resume"] & ctx["selected"]),
    }


@register_metric("cohort_fill", needs=("selected", "cohort_size"))
def _cohort_fill(ctx, static):
    """Fraction of the static (X,) cohort block the round used."""
    x = static["cohort_size"]
    return {"cohort_fill": _count(ctx["selected"]) / jnp.float32(x)}


@register_metric("cache_expired", level="full",
                 needs=("stamp", "stamp_pre_expire"))
def _cache_expired(ctx, static):
    """Rows the discard-mode staleness bound pruned this round."""
    dead = (ctx["stamp_pre_expire"] >= 0) & (ctx["stamp"] < 0)
    return {"cache_expired_count": _count(dead)}


@register_metric("staleness_hist", level="full", needs=("stamp", "rnd"))
def _staleness_hist(ctx, static):
    """Histogram of live cache-row staleness (rounds since write)."""
    edges = static["staleness_edges"]
    stamp = ctx["stamp"]
    live = stamp >= 0
    s = ctx["rnd"] - stamp
    buckets = []
    for b, lo in enumerate(edges):
        hi = edges[b + 1] if b + 1 < len(edges) else None
        m = live & (s >= lo)
        if hi is not None:
            m = m & (s < hi)
        buckets.append(_count(m))
    return {"staleness_hist": jnp.stack(buckets)}


@register_metric("trust_quantiles", level="full", needs=("rule_state",))
def _trust_quantiles(ctx, static):
    """Quartiles + extremes of the per-client robust-rule trust state."""
    state = ctx["rule_state"].astype(jnp.float32)
    q = jnp.quantile(state, jnp.array([0.25, 0.5, 0.75], jnp.float32))
    return {"trust_quartiles": q,
            "trust_min": jnp.min(state), "trust_max": jnp.max(state)}


@register_metric("update_norm", level="full",
                 needs=("rows", "rows_mask", "global"))
def _update_norm(ctx, static):
    """Per-upload delta-norm stats and their residual around the plain
    received-mean delta (dispersion the robust rules act on).

    This is the one metric whose input is O(rows · D), so it keeps the
    reductions off the fleet-sized stack: when the engine advertises
    ``rows_bound`` (the round's static selection bound) below the
    fleet view's leading dim, the received rows are first gathered
    into a compact (K, ...) block — the full-scan path then reads K
    rows instead of all N.  The residual around the received-mean row
    expands as ``||d - m||² = ||d||² - 2⟨d, m⟩ + ||m||²`` so each
    leaf's delta block is built once and every reduction is a fused
    product over it."""
    rows, mask = ctx["rows"], ctx["rows_mask"]
    g = ctx["global"]
    lead = jax.tree.leaves(rows)[0].shape[0]
    bound = static.get("rows_bound")
    if bound is not None and bound < lead:
        idx = jnp.flatnonzero(mask, size=bound, fill_value=lead)
        rows = jax.tree.map(
            lambda r: jnp.take(r, jnp.minimum(idx, lead - 1), axis=0),
            rows)
        mask = idx < lead
    cnt = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
    maskf = mask.astype(jnp.float32)
    sq, dots, msq = 0.0, 0.0, 0.0
    for r, gl in zip(jax.tree.leaves(rows), jax.tree.leaves(g)):
        d = (r - gl).reshape(r.shape[0], -1).astype(jnp.float32)
        sq = sq + jnp.einsum("nd,nd->n", d, d)
        md = maskf @ d / cnt                   # masked mean delta m
        dots = dots + d @ md
        msq = msq + jnp.sum(md * md)
    norms = jnp.sqrt(sq)
    n_mean, n_max = _masked_mean_max(norms, mask)
    resid = jnp.sqrt(jnp.maximum(sq - 2.0 * dots + msq, 0.0))
    r_mean, r_max = _masked_mean_max(resid, mask)
    return {"update_norm_mean": n_mean, "update_norm_max": n_max,
            "agg_residual_mean": r_mean, "agg_residual_max": r_max}
