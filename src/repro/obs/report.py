"""Render a run summary from a telemetry JSONL file.

Usage::

    python -m repro.obs.report run.jsonl [--run INDEX] [--json]

Reads the ``run_start`` / ``round`` / ``run_end`` event stream a
``Telemetry(jsonl=...)`` session appended (``repro.obs.sink``) and
prints, for one run (default: the last):

* the host round-time breakdown (per-span totals from the tracer),
* comm / wall-clock totals and cache residency,
* per-metric stats with a unicode sparkline over rounds.

``--json`` dumps the parsed summary as JSON instead (CI assertions).
Exits non-zero only on unreadable input.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

SPARK = "▁▂▃▄▅▆▇█"


def parse_runs(path: str) -> List[dict]:
    """Group the JSONL event stream into runs.

    Each run is ``{"start": {...}|None, "rounds": [...], "end":
    {...}|None}``; events before the first ``run_start`` open an
    implicit run so truncated files still render.
    """
    runs: List[dict] = []

    def fresh(start=None):
        runs.append({"start": start, "rounds": [], "end": None})

    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{lineno}: bad JSON line "
                                 f"({e})") from e
            kind = ev.get("kind")
            if kind == "run_start":
                fresh(ev)
            else:
                if not runs:
                    fresh()
                if kind == "round":
                    runs[-1]["rounds"].append(ev)
                elif kind == "run_end":
                    runs[-1]["end"] = ev
    return runs


def sparkline(values, width: int = 32) -> str:
    vals = [float(v) for v in values]
    if not vals:
        return ""
    if len(vals) > width:                    # resample to `width` cells
        step = len(vals) / width
        vals = [vals[int(i * step)] for i in range(width)]
    lo, hi = min(vals), max(vals)
    span = (hi - lo) or 1.0
    return "".join(SPARK[int((v - lo) / span * (len(SPARK) - 1))]
                   for v in vals)


def _fmt(v, nd=3):
    if isinstance(v, float):
        return f"{v:.{nd}g}"
    return str(v)


def _metric_series(rounds: List[dict]) -> dict:
    """Column -> list over rounds, for the non-History metric columns."""
    skip = {"kind", "round", "evaluated"}
    series: dict = {}
    for ev in rounds:
        for k, v in ev.items():
            if k in skip:
                continue
            series.setdefault(k, []).append(v)
    return series


def summarize(run: dict) -> dict:
    """Parsed summary of one run (what ``--json`` prints)."""
    start = run["start"] or {}
    end = run["end"] or {}
    rounds = run["rounds"]
    out = {"policy": start.get("policy"),
           "num_clients": start.get("num_clients"),
           "level": start.get("level"),
           "rounds": end.get("rounds", len(rounds)),
           "final_acc": end.get("final_acc"),
           "comm_mb": end.get("comm_mb"),
           "wall_clock": end.get("wall_clock"),
           "spans": end.get("spans", {}),
           "transfer_stats": end.get("transfer_stats"),
           "metrics": {}}
    for name, vals in _metric_series(rounds).items():
        flat = [v for v in vals if isinstance(v, (int, float))
                and v == v]                          # scalar, non-NaN
        if len(flat) == len(vals) and flat:
            s = sorted(flat)
            out["metrics"][name] = {
                "last": flat[-1], "min": s[0], "max": s[-1],
                "median": s[len(s) // 2], "n": len(flat)}
        elif vals:
            out["metrics"][name] = {"last": vals[-1], "n": len(vals)}
    return out


def render(run: dict, file=None) -> None:
    file = file or sys.stdout
    p = lambda *a: print(*a, file=file)   # noqa: E731
    s = summarize(run)
    rounds = run["rounds"]
    p(f"run: policy={s['policy']} clients={s['num_clients']} "
      f"level={s['level']} rounds={s['rounds']}")
    if s["final_acc"] is not None:
        p(f"final: acc={_fmt(s['final_acc'])} "
          f"comm={_fmt(s['comm_mb'])} MB "
          f"wall={_fmt(s['wall_clock'])} s (simulated)")

    spans = s["spans"]
    if spans:
        p("\nround-time breakdown (host seams, wall seconds):")
        total = sum(v["total_s"] for v in spans.values())
        p(f"  {'span':<18} {'calls':>6} {'total_s':>9} {'mean_ms':>9} "
          f"{'share':>6}")
        for name, v in sorted(spans.items(),
                              key=lambda kv: -kv[1]["total_s"]):
            p(f"  {name:<18} {v['count']:>6} {v['total_s']:>9.4f} "
              f"{v['mean_s'] * 1e3:>9.3f} "
              f"{v['total_s'] / total * 100 if total else 0:>5.1f}%")

    ts = s["transfer_stats"]
    if ts:
        p("\ncache stream: "
          f"d2h={ts.get('d2h_async', 0)}x/{ts.get('d2h_bytes', 0)}B "
          f"h2d={ts.get('h2d_async', 0)}x/{ts.get('h2d_bytes', 0)}B "
          f"sync_copies={ts.get('sync_copies', 0)}")

    if s["metrics"]:
        p("\nper-round metrics:")
        p(f"  {'metric':<20} {'last':>10} {'min':>10} {'median':>10} "
          f"{'max':>10}  trend")
        series = _metric_series(rounds)
        for name in sorted(s["metrics"]):
            m = s["metrics"][name]
            if "min" in m:
                p(f"  {name:<20} {_fmt(m['last']):>10} "
                  f"{_fmt(m['min']):>10} {_fmt(m['median']):>10} "
                  f"{_fmt(m['max']):>10}  {sparkline(series[name])}")
            else:
                p(f"  {name:<20} last={m['last']}")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("jsonl", help="telemetry JSONL file")
    ap.add_argument("--run", type=int, default=-1,
                    help="run index in the file (default: last)")
    ap.add_argument("--json", action="store_true",
                    help="print the parsed summary as JSON")
    args = ap.parse_args(argv)
    try:
        runs = parse_runs(args.jsonl)
    except (OSError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    if not runs:
        print(f"error: no telemetry events in {args.jsonl}",
              file=sys.stderr)
        return 1
    try:
        run = runs[args.run]
    except IndexError:
        print(f"error: run index {args.run} out of range "
              f"({len(runs)} runs)", file=sys.stderr)
        return 1
    if args.json:
        print(json.dumps(summarize(run), indent=1, default=float))
    else:
        render(run)
    return 0


if __name__ == "__main__":
    sys.exit(main())
