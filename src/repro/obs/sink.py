"""Telemetry event sinks.

Events are flat JSON-serializable dicts with a ``kind`` discriminator:

* ``run_start`` — one per ``FleetEngine.run``: policy, fleet size,
  telemetry level, config digest.
* ``round``     — one per resolved round: the History row plus every
  registered device metric (read back through the round ledger, so
  emission follows the pipelined resolve cadence, not the round itself).
* ``run_end``   — run totals: rounds, final accuracy, cumulative
  comm/time, per-span host-time summary and the engine's transfer
  counters.

``JsonlSink`` appends one JSON line per event (the ``repro.obs.report``
CLI input format); ``MemorySink`` buffers events in a list (tests,
programmatic consumers).
"""
from __future__ import annotations

import json
from typing import List, Optional


class JsonlSink:
    """One JSON object per line, appended to ``path``."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "a")

    def emit(self, event: dict) -> None:
        self._f.write(json.dumps(event, default=float) + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


class MemorySink:
    """In-process event buffer."""

    def __init__(self):
        self.events: List[dict] = []

    def emit(self, event: dict) -> None:
        self.events.append(event)

    def close(self) -> None:
        pass


class TeeSink:
    """Fan one event stream out to several sinks."""

    def __init__(self, *sinks):
        self.sinks = [s for s in sinks if s is not None]

    def emit(self, event: dict) -> None:
        for s in self.sinks:
            s.emit(event)

    def close(self) -> None:
        for s in self.sinks:
            s.close()
