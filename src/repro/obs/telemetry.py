"""Telemetry session object threaded through ``FleetEngine.run``.

``Telemetry`` bundles the three observability layers behind one handle:

* ``level`` — which device metrics compile in (``"basic"`` |
  ``"full"``, see ``repro.obs.metrics``).  The engine fuses them into
  one extra jitted dispatch per round whose scalar outputs ride the
  pipelined round ledger — no per-round host sync is added.
* ``tracer`` — host span tracing of the dispatch seams
  (``repro.obs.trace``); ``trace=`` saves the Chrome/Perfetto
  ``trace_event`` JSON at run end.
* ``sink`` — the event stream (``run_start`` / ``round`` / ``run_end``
  dicts).  ``jsonl=`` appends to a JSONL file (the
  ``python -m repro.obs.report`` input); events are always buffered in
  ``last_events`` too.

``profile_dir`` + ``profile_rounds=(start, stop)`` additionally capture
a ``jax.profiler`` device trace for that round window.

Typical use::

    tel = Telemetry(level="full", jsonl="run.jsonl",
                    trace="run.trace.json")
    hist = engine.run("flude", telemetry=tel)
    # -> python -m repro.obs.report run.jsonl
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.obs.sink import JsonlSink, MemorySink, TeeSink
from repro.obs.trace import Tracer
from repro.obs import metrics as _metrics


class Telemetry:
    def __init__(self, level: str = "full", jsonl: Optional[str] = None,
                 trace: Optional[str] = None,
                 profile_dir: Optional[str] = None,
                 profile_rounds: Optional[Tuple[int, int]] = None):
        if level not in _metrics.LEVELS:
            raise ValueError(
                f"telemetry level must be one of {_metrics.LEVELS}, got "
                f"{level!r}")
        self.level = level
        self.tracer = Tracer()
        self.trace_path = trace
        self._memory = MemorySink()
        self.sink = TeeSink(self._memory,
                            JsonlSink(jsonl) if jsonl else None)
        self.profile_dir = profile_dir
        self.profile_rounds = profile_rounds
        self._profiling = False
        self._run_mark = 0

    @property
    def last_events(self):
        """Events of the most recent run (memory buffer)."""
        return self._memory.events[self._run_mark:]

    # -- engine protocol ----------------------------------------------------

    def open_run(self, meta: dict) -> None:
        self._run_mark = len(self._memory.events)
        self.tracer.reset()
        self.sink.emit({"kind": "run_start", "level": self.level, **meta})

    def record_round(self, row: dict) -> None:
        self.sink.emit({"kind": "round", **row})

    def maybe_profile(self, rnd: int) -> None:
        """Start/stop the optional ``jax.profiler`` window at ``rnd``."""
        if self.profile_dir is None or self.profile_rounds is None:
            return
        start, stop = self.profile_rounds
        if rnd == start and not self._profiling:
            import jax
            jax.profiler.start_trace(self.profile_dir)
            self._profiling = True
        elif rnd > stop and self._profiling:
            self._stop_profile()

    def _stop_profile(self) -> None:
        if self._profiling:
            import jax
            jax.profiler.stop_trace()
            self._profiling = False

    def close_run(self, summary: dict) -> None:
        self._stop_profile()
        self.sink.emit({"kind": "run_end",
                        "spans": self.tracer.summary(), **summary})
        if self.trace_path is not None:
            self.tracer.save(self.trace_path)

    def close(self) -> None:
        self._stop_profile()
        self.sink.close()
