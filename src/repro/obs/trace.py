"""Host span tracing for the fleet round path.

The engine's per-round host work is a short sequence of *dispatch* seams
— plan, fused trainer, round cut, server step, ledger resolve, cache
stream, eval — and :class:`Tracer` wraps each in a lightweight span
(``time.perf_counter`` pairs, one appended tuple per span).  Because the
round path is asynchronous, a span measures the *host-side* cost of its
seam (argument prep + dispatch + any blocking read it performs), which
is exactly the budget the zero-per-round-host-sync invariant protects;
device-side compute is captured separately via the optional
``jax.profiler`` window (see :class:`repro.obs.telemetry.Telemetry`).

Spans export as Chrome ``trace_event`` JSON (``save``) loadable in
Perfetto / ``chrome://tracing``, and aggregate into a per-name summary
(``summary``) that the report CLI renders as the round-time breakdown.

``NULL_TRACER`` is the disabled path: ``span`` returns a shared no-op
context manager, so instrumented code needs no branches and the default
(telemetry off) path pays a single attribute lookup per seam.
"""
from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional, Tuple


class Span:
    """One timed section; also usable as ``with tracer.span(..) as sp``
    for its ``seconds`` reading (the benchmark clock)."""

    __slots__ = ("_tracer", "name", "args", "t0", "t1")

    def __init__(self, tracer: "Tracer", name: str, args: Optional[dict]):
        self._tracer = tracer
        self.name = name
        self.args = args
        self.t0 = 0.0
        self.t1 = 0.0

    def __enter__(self) -> "Span":
        self.t0 = self._tracer._clock()
        return self

    def __exit__(self, *exc) -> None:
        self.t1 = self._tracer._clock()
        self._tracer._record(self)

    @property
    def seconds(self) -> float:
        return self.t1 - self.t0


class Tracer:
    """Append-only span/instant/counter recorder with a perf_counter
    clock; timestamps are relative to tracer construction (reset)."""

    def __init__(self):
        self._clock = time.perf_counter
        self.reset()

    def reset(self) -> None:
        self._epoch = self._clock()
        # (name, ts_us, dur_us, args) — dur_us None for instants,
        # args holding values for counters (ph "C")
        self.events: List[Tuple[str, float, Optional[float], Any]] = []
        self._counters: set = set()

    # -- recording ----------------------------------------------------------

    def span(self, name: str, **args) -> Span:
        return Span(self, name, args or None)

    def _record(self, sp: Span) -> None:
        self.events.append((sp.name, (sp.t0 - self._epoch) * 1e6,
                            (sp.t1 - sp.t0) * 1e6, sp.args))

    def instant(self, name: str, **args) -> None:
        self.events.append((name, (self._clock() - self._epoch) * 1e6,
                            None, args or None))

    def counter(self, name: str, **values) -> None:
        self._counters.add(name)
        self.events.append((name, (self._clock() - self._epoch) * 1e6,
                            None, values))

    # -- aggregation / export -----------------------------------------------

    def summary(self) -> Dict[str, dict]:
        """Per-span-name aggregate: count, total/mean/max seconds."""
        out: Dict[str, dict] = {}
        for name, _ts, dur, _args in self.events:
            if dur is None:
                continue
            s = out.setdefault(name, {"count": 0, "total_s": 0.0,
                                      "max_s": 0.0})
            s["count"] += 1
            s["total_s"] += dur * 1e-6
            s["max_s"] = max(s["max_s"], dur * 1e-6)
        for s in out.values():
            s["mean_s"] = s["total_s"] / s["count"]
        return out

    def to_chrome(self) -> dict:
        """Chrome ``trace_event`` JSON (Perfetto-loadable)."""
        evs = [{"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
                "args": {"name": "fleet-engine host"}}]
        for name, ts, dur, args in self.events:
            ev = {"name": name, "pid": 0, "tid": 0, "ts": ts, "cat": "fl"}
            if name in self._counters:
                ev.update(ph="C", args=args or {})
            elif dur is None:
                ev.update(ph="i", s="t")
                if args:
                    ev["args"] = args
            else:
                ev.update(ph="X", dur=dur)
                if args:
                    ev["args"] = args
            evs.append(ev)
        return {"traceEvents": evs, "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None

    seconds = 0.0


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: every operation is a no-op (shared span)."""

    events: List = []

    def span(self, name: str, **args) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, **args) -> None:
        pass

    def counter(self, name: str, **values) -> None:
        pass

    def summary(self) -> dict:
        return {}

    def reset(self) -> None:
        pass


NULL_TRACER = NullTracer()
