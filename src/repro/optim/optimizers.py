"""Pytree optimizers (no external deps): SGD, momentum, Adam(W) + schedules.

Moments are kept in fp32 regardless of param dtype; updates are computed in
fp32 and cast back.  API mirrors optax minimally:

    opt = make_optimizer(train_cfg)
    state = opt.init(params)
    params, state = opt.step(params, grads, state, step)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class OptState(NamedTuple):
    mu: Any            # first moment (or momentum buffer); None-like zeros
    nu: Any            # second moment (adam only)
    count: jax.Array


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    step: Callable[..., Any]


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  floor_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        wu = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = floor_frac + (1 - floor_frac) * 0.5 * (1 +
                                                     jnp.cos(jnp.pi * prog))
        return base_lr * wu * cos
    return lr


def make_optimizer(cfg: TrainConfig,
                   lr_fn: Optional[Callable] = None) -> Optimizer:
    if lr_fn is None:
        lr_fn = warmup_cosine(cfg.learning_rate, cfg.warmup_steps,
                              cfg.total_steps)
    kind = cfg.optimizer

    mdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[
        cfg.moment_dtype]

    def f32_zeros(params):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, mdt), params)

    def init(params):
        if kind in ("adam", "adamw"):
            return OptState(f32_zeros(params), f32_zeros(params),
                            jnp.zeros((), jnp.int32))
        if kind == "momentum":
            return OptState(f32_zeros(params), None,
                            jnp.zeros((), jnp.int32))
        return OptState(None, None, jnp.zeros((), jnp.int32))

    def step(params, grads, state: OptState, *, lr_scale=1.0):
        count = state.count + 1
        lr = lr_fn(count) * lr_scale
        if cfg.grad_clip:
            grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        if kind in ("adam", "adamw"):
            b1, b2, eps = cfg.beta1, cfg.beta2, 1e-8
            mu = jax.tree.map(
                lambda m, g: (b1 * m.astype(jnp.float32)
                              + (1 - b1) * g).astype(mdt), state.mu, g32)
            nu = jax.tree.map(
                lambda v, g: (b2 * v.astype(jnp.float32)
                              + (1 - b2) * g * g).astype(mdt),
                state.nu, g32)
            c = count.astype(jnp.float32)
            bc1 = 1 - b1 ** c
            bc2 = 1 - b2 ** c

            def upd(p, m, v):
                m = m.astype(jnp.float32)
                v = v.astype(jnp.float32)
                u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
                if kind == "adamw" and p.ndim >= 2:
                    u = u + cfg.weight_decay * p.astype(jnp.float32)
                return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

            new_params = jax.tree.map(upd, params, mu, nu)
            return new_params, OptState(mu, nu, count)

        if kind == "momentum":
            mu = jax.tree.map(lambda m, g: 0.9 * m + g, state.mu, g32)
            new_params = jax.tree.map(
                lambda p, m: (p.astype(jnp.float32) - lr * m
                              ).astype(p.dtype), params, mu)
            return new_params, OptState(mu, None, count)

        # plain sgd
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g).astype(p.dtype),
            params, g32)
        return new_params, OptState(None, None, count)

    return Optimizer(init=init, step=step)
