"""Three-term roofline from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_wire_bytes / (chips × links × link_bw)

HLO_FLOPs / HLO_bytes / collective bytes come from the post-SPMD per-device
module via ``repro.roofline.hlo`` (trip-count aware), so the three terms are
already per-device; "chips ×" in the denominators is absorbed.

Hardware: TPU v5e — 197 TFLOP/s bf16/chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.roofline.hlo import HloCost, analyze_hlo_text

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (count links ~= 1 effective)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_wire_bytes: float
    collective_breakdown: Dict[str, float]
    model_flops_total: float          # 6·N·D (dense) / 6·N_active·D (MoE)
    n_devices: int
    notes: list

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_wire_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs (across devices): catches remat and
        masked-block waste.  >1 is impossible; ≪1 means redundant compute."""
        total = self.flops_per_device * self.n_devices
        return self.model_flops_total / total if total else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "collective_wire_bytes": self.collective_wire_bytes,
            "collective_breakdown": self.collective_breakdown,
            "model_flops_total": self.model_flops_total,
            "n_devices": self.n_devices,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_fraction": self.useful_flops_fraction,
            "notes": self.notes,
        }


def model_flops(cfg, shape, n_params_active: int, kind: str) -> float:
    """Reference useful FLOPs: 6·N·tokens for a train step, 2·N·tokens for
    prefill, 2·N·batch for one decode step."""
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_params_active * tokens
    return 2.0 * n_params_active * shape.global_batch   # decode: 1 token


def build_roofline(arch: str, shape_name: str, mesh_name: str,
                   hlo_text: str, n_devices: int,
                   model_flops_total: float,
                   cost: Optional[HloCost] = None) -> Roofline:
    if cost is None:
        cost = analyze_hlo_text(hlo_text)
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name,
        flops_per_device=cost.flops,
        bytes_per_device=cost.bytes,
        collective_bytes_per_device=cost.total_collective_bytes,
        collective_wire_bytes=cost.collective_wire_bytes,
        collective_breakdown=dict(cost.collective_bytes),
        model_flops_total=model_flops_total,
        n_devices=n_devices,
        notes=list(cost.notes),
    )


def format_table(rows) -> str:
    """Markdown table for EXPERIMENTS.md §Roofline."""
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s "
           "| dominant | useful-FLOP frac |\n"
           "|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.4g} | {r['memory_s']:.4g} "
            f"| {r['collective_s']:.4g} | **{r['dominant']}** "
            f"| {r['useful_flops_fraction']:.3f} |")
    return "\n".join(lines)
