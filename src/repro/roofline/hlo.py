"""HLO-text analyzer: FLOPs / HBM traffic / collective bytes with while-loop
trip-count multiplication.

Why not ``compiled.cost_analysis()``?  XLA's cost analysis does NOT multiply
while-loop bodies by their trip count, so any scan-over-layers model is
undercounted by ~num_layers× (verified: a 126-layer train step reported
77 TFLOP instead of ~2.4 EFLOP).  This walker parses the *post-SPMD*
optimized HLO (per-device shapes), recovers trip counts from while
conditions, and aggregates:

  * flops: dot ops exactly (2·prod(out)·prod(contracting)), elementwise 1/elem
  * bytes: per materializing op — operands + outputs (fusion internals are
    in-register and not counted)
  * collective bytes: per op type, with wire-byte estimates from replica
    group sizes
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
    # fp8 fnuz variants + sub-byte ints (stored 1 byte/elem in HBM,
    # matching the s4/u4 convention above)
    "f8e4m3fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1, "f8e3m4": 1,
    "f8e4m3": 1, "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_SIMPLE_TYPE_RE = re.compile(r"^([a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s+")
_OPCODE_RE = re.compile(r"^\s*([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")


def _parse_instr_line(line: str):
    """-> (name, type_str, opcode, rest) or None.

    Handles tuple types containing nested parens/braces and /*index=N*/
    comments (large while carries), which defeat a single regex.
    """
    m = _ASSIGN_RE.match(line)
    if not m:
        return None
    name, tail = m.groups()
    if tail.startswith("("):            # tuple type: scan matching paren
        depth = 0
        end = -1
        for idx, ch in enumerate(tail):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = idx
                    break
        if end < 0:
            return None
        type_str, remainder = tail[:end + 1], tail[end + 1:]
    else:
        mt = _SIMPLE_TYPE_RE.match(tail)
        if not mt:
            return None
        type_str = mt.group(1)
        remainder = tail[mt.end():]
    mo = _OPCODE_RE.match(remainder)
    if not mo:
        return None
    return name, type_str, mo.group(1), mo.group(2)

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "rsqrt", "sqrt", "power", "atan2", "and", "or", "xor", "not",
    "select", "compare", "floor", "ceil", "round-nearest-afz", "sign",
    "cosine", "sine", "clamp", "remainder", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "erf", "logistic",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _parse_shape(s: str) -> Tuple[int, int]:
    """-> (num_elements, bytes); tuples are summed."""
    total_el, total_by = 0, 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            n = int(np.prod([int(d) for d in dims.split(",")]))
        total_el += n
        total_by += n * _DTYPE_BYTES[dt]
    return total_el, total_by


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str              # operands + attrs (raw tail of the line)

    @property
    def out_elems(self):
        return _parse_shape(self.type_str)[0]

    @property
    def out_bytes(self):
        return _parse_shape(self.type_str)[1]


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    table: Dict[str, str] = field(default_factory=dict)  # name -> type_str


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_wire_bytes: float = 0.0
    notes: List[str] = field(default_factory=list)

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) \
                + v * mult
        self.collective_wire_bytes += other.collective_wire_bytes * mult

    @property
    def total_collective_bytes(self):
        return sum(self.collective_bytes.values())


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_marker = None
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry_marker = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        parsed = _parse_instr_line(line)
        if parsed:
            name, tstr, opcode, rest = parsed
            cur.instrs.append(Instr(name, tstr, opcode, rest))
            cur.table[name] = tstr
    if entry_marker:
        comps["__entry__"] = comps[entry_marker]
    return comps


def _called(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", rest)
    return m.group(1) if m else None


def _group_size(rest: str, default: int = 1) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", rest)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    return default


def _trip_count(while_rest: str,
                cond: Optional[Computation]) -> Tuple[int, bool]:
    """Trip count: XLA's known_trip_count annotation, else condition
    heuristic (constant vs induction-var compare)."""
    m = re.search(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)', while_rest)
    if m:
        return max(int(m.group(1)), 1), True
    if cond is None:
        return 1, False
    consts = {}
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", "constant(" + ins.rest)
            if m:
                consts[ins.name] = int(m.group(1))
    for ins in cond.instrs:
        if ins.opcode in ("compare", "fusion"):
            mdir = re.search(r"direction=(\w+)", ins.rest)
            ops = re.findall(r"%([\w.\-]+)", ins.rest.split(")")[0])
            cvals = [consts[o] for o in ops if o in consts]
            if ins.opcode == "fusion" and cvals and not mdir:
                mdir = re.match(r"(?s).*direction=LT.*", ins.rest) and \
                    re.match(r"(LT)", "LT")
            if mdir and cvals:
                d = mdir.group(1) if hasattr(mdir, "group") else "LT"
                c = max(cvals)
                if d == "LT":
                    return max(c, 1), True
                if d == "LE":
                    return max(c + 1, 1), True
                return max(c, 1), False
    return 1, False


_MATERIALIZING_SKIP = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota",
}


def analyze_computation(comp: Computation, comps: Dict[str, Computation],
                        cache: Dict[str, HloCost]) -> HloCost:
    if comp.name in cache:
        return cache[comp.name]
    cost = HloCost()
    cache[comp.name] = cost        # breaks cycles defensively
    for ins in comp.instrs:
        op = ins.opcode
        if op == "while":
            body = _called(ins.rest, "body")
            cond = _called(ins.rest, "condition")
            trip, ok = _trip_count(ins.rest, comps.get(cond))
            if not ok:
                cost.notes.append(f"while {ins.name}: trip count guessed=1")
            if body in comps:
                cost.add(analyze_computation(comps[body], comps, cache),
                         trip)
            cost.bytes += ins.out_bytes   # loop state traffic once
        elif op == "fusion":
            called = _called(ins.rest, "calls")
            if called in comps:
                sub = analyze_computation(comps[called], comps, cache)
                cost.flops += sub.flops            # in-register compute
                cost.collective_wire_bytes += sub.collective_wire_bytes
                for k, v in sub.collective_bytes.items():
                    cost.collective_bytes[k] = \
                        cost.collective_bytes.get(k, 0.0) + v
            cost.bytes += _fusion_traffic(ins, comp)
        elif op == "conditional":
            branches = re.findall(r"%([\w.\-]+)", ins.rest)
            sub = [analyze_computation(comps[b], comps, cache)
                   for b in branches if b in comps]
            if sub:
                best = max(sub, key=lambda c: c.flops)
                cost.add(best)
            cost.bytes += ins.out_bytes
        elif op == "call":
            called = _called(ins.rest, "to_apply")
            if called in comps:
                cost.add(analyze_computation(comps[called], comps, cache))
        elif op == "dot":
            flops = _dot_flops(ins, comp)
            cost.flops += flops
            cost.bytes += ins.out_bytes + _operand_bytes(ins, comp)
        elif op == "convolution":
            cost.flops += 2 * ins.out_elems   # unused in this codebase
            cost.bytes += ins.out_bytes + _operand_bytes(ins, comp)
        elif any(op.startswith(c) for c in _COLLECTIVES):
            if op.endswith("-done"):
                continue
            base = next(c for c in _COLLECTIVES if op.startswith(c))
            obytes = _operand_bytes(ins, comp)
            if obytes == 0:
                obytes = ins.out_bytes
            n = _group_size(ins.rest, 2)
            if base == "all-reduce":
                wire = 2.0 * obytes * (n - 1) / max(n, 1)
            elif base == "all-gather":
                wire = float(max(ins.out_bytes, obytes)) * (n - 1) / max(n,
                                                                         1)
            elif base == "reduce-scatter":
                wire = obytes * (n - 1) / max(n, 1)
            elif base == "all-to-all":
                wire = obytes * (n - 1) / max(n, 1)
            else:                       # collective-permute
                wire = float(obytes)
            cost.collective_bytes[base] = \
                cost.collective_bytes.get(base, 0.0) + obytes
            cost.collective_wire_bytes += wire
            cost.bytes += ins.out_bytes + obytes
        elif op == "reduce":
            cost.flops += _operand_elems(ins, comp)
            cost.bytes += ins.out_bytes + _operand_bytes(ins, comp)
        elif op in _ELEMWISE:
            cost.flops += ins.out_elems
            cost.bytes += ins.out_bytes + _operand_bytes(ins, comp)
        elif op == "copy-start":
            # async copy pair: the transfer is charged once here — read
            # the source + write the destination.  The tuple output
            # (dest, source-alias, context) must not be summed as
            # traffic, and copy-done below is only the completion
            # handle; the old fall-through charged the pair ~6x.
            cost.bytes += 2.0 * _operand_bytes(ins, comp)
        elif op == "copy-done":
            continue
        elif op == "dynamic-slice":
            # reads only the slice (+indices), not the whole operand
            cost.bytes += 2.0 * ins.out_bytes
        elif op == "dynamic-update-slice":
            # in-place: traffic = update read + slice write
            cost.bytes += 2.0 * _small_operand_bytes(ins, comp)
        elif op in _MATERIALIZING_SKIP:
            continue
        else:
            # copy, transpose, reshape, slice, pad, etc.
            cost.bytes += ins.out_bytes + _operand_bytes(ins, comp)
    cache[comp.name] = cost
    return cost


def _fusion_traffic(ins: Instr, comp: Computation) -> float:
    """HBM traffic of a fusion: operands + output, with slice-pattern
    corrections.  A fusion whose root is a dynamic-update-slice is an
    in-place accumulator write (scan outputs): it touches only the update
    slice, so the full accumulator operand must not be charged.  A fusion
    built around dynamic-slice reads only the slice."""
    name = ins.name
    if "dynamic-update-slice" in name:
        return 2.0 * _small_operand_bytes(ins, comp)
    if "dynamic-slice" in name:
        return 2.0 * ins.out_bytes + _small_operand_bytes(ins, comp)
    return ins.out_bytes + _operand_bytes(ins, comp)


def _small_operand_bytes(ins: Instr, comp: Computation) -> float:
    """Sum of operand sizes excluding the single largest operand (the
    in-place/accumulator buffer)."""
    sizes = [_parse_shape(comp.table.get(n, ""))[1]
             for n in _operand_names(ins)]
    if not sizes:
        return float(ins.out_bytes)
    sizes.sort()
    return float(sum(sizes[:-1])) if len(sizes) > 1 else float(sizes[0])


def _operand_names(ins: Instr) -> List[str]:
    head = ins.rest.split("), ")[0]
    return re.findall(r"%([\w.\-]+)", head)


def _operand_bytes(ins: Instr, comp: Computation) -> float:
    return float(sum(_parse_shape(comp.table.get(n, ""))[1]
                     for n in _operand_names(ins)))


def _operand_elems(ins: Instr, comp: Computation) -> float:
    return float(sum(_parse_shape(comp.table.get(n, ""))[0]
                     for n in _operand_names(ins)))


def _dot_flops(ins: Instr, comp: Computation) -> float:
    ops = _operand_names(ins)
    if not ops:
        return 0.0
    lhs_type = comp.table.get(ops[0], "")
    m = _SHAPE_RE.search(lhs_type)
    if not m:
        return 0.0
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    contracting = 1
    if mc and mc.group(1):
        for d in mc.group(1).split(","):
            if int(d) < len(dims):
                contracting *= dims[int(d)]
    return 2.0 * ins.out_elems * contracting


def compiled_cost_analysis(compiled) -> Dict[str, float]:
    """Version-compat shim for ``jax.stages.Compiled.cost_analysis()``.

    Older JAX returns a single dict; newer JAX returns a *list* with one
    dict per executable module.  Normalizes both to a plain dict (first
    module — jit programs here compile to exactly one)."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def analyze_hlo_text(text: str) -> HloCost:
    comps = parse_hlo(text)
    if "__entry__" not in comps:
        raise ValueError("no ENTRY computation found")
    cache: Dict[str, HloCost] = {}
    cost = analyze_computation(comps["__entry__"], comps, cache)
    # collect trip-count warnings from all walked computations
    notes = []
    for c in cache.values():
        notes.extend(c.notes)
    cost.notes = sorted(set(notes))[:20]
    return cost
