"""Logical-axis → mesh-axis sharding rules (MaxText-style).

Baseline layout:
  * FSDP: the ``embed`` axis of every weight shards over the data axes
    (``("pod","data")`` on the multi-pod mesh) — optimizer state and params
    are fully sharded.
  * TP: ``mlp`` / ``vocab`` / one attention axis shard over ``model``.
  * Attention TP axis is picked per-arch by divisibility:
    kv_heads → q_group → heads → head_dim (first divisible by the model-axis
    size wins; the roofline notes any arch forced onto head_dim).
  * MoE: ``expert`` shards over ``model`` when divisible (deepseek-v2 160e),
    otherwise ``expert_mlp`` shards (mixtral 8e).

Activations: batch shards over (pod, data); logits over model.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import layers as L


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _attn_tp_axis(cfg, model_size: int, fallback: str = "replicate"):
    """Attention TP axis: first head-ish axis divisible by the model-axis
    size.  When nothing divides (qwen2: 28H/4kv; whisper: 20H), the choice
    is between (a) sharding head_dim — contraction sharding that psums
    every (Bq, Bk) score block (measured: collective-dominated, 135 s wire
    on qwen2 train_4k), and (b) replicating attention over the model axis —
    redundant attention compute but near-zero attention collectives
    (measured: 4.1 s wire, max-term 85.6 s vs 135 s).  Default (b); see
    EXPERIMENTS.md §Perf qwen2 iterations 2–3."""
    if cfg.attention == "mla":
        # MLA params carry a single "heads" axis (w_uq/w_uk/w_uv/wo)
        cands = [("heads", cfg.num_heads)]
    else:
        cands = [
            ("kv_heads", cfg.num_kv_heads),
            ("q_group", (cfg.num_heads // max(cfg.num_kv_heads, 1))),
            ("heads", 0),
        ]
    for name, size in cands:
        if size and size % model_size == 0:
            return name
    return "head_dim" if fallback == "head_dim" else None


def make_rules(cfg, mesh: Mesh, *, mode: str = "fsdp_tp") -> dict:
    dp = fsdp_axes(mesh)
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get(
        "model", 1)
    attn_axis = _attn_tp_axis(cfg, model_size)

    rules = {
        "layers": None, "mix5": None, "conv": None, "lora": None,
        "vis_patch": None, "expert_gate": None,
        "vocab": ("model",),
        "embed": dp if mode != "replicated" else None,
        "embed_out": None,
        "mlp": ("model",),
        "kv_heads": None, "q_group": None, "heads": None, "head_dim": None,
        # ssm / rwkv inner dims shard over model (they are mlp-like)
        "ssm_in": ("model",), "ssm_conv": ("model",),
        "ssm_inner": ("model",), "heads_x_dim": ("model",),
        "state": None,
        "seq_sp": ("model",),   # Megatron-SP residual sequence sharding
    }
    if attn_axis is not None:
        rules[attn_axis] = ("model",)
    if cfg.moe is not None:
        if cfg.moe.num_experts % model_size == 0:
            rules["expert"] = ("model",)
            rules["expert_mlp"] = None
        else:
            rules["expert"] = None
            rules["expert_mlp"] = ("model",)
    if mode == "replicated":
        return {k: None for k in rules}
    return rules


def spec_for_axes(axes: Tuple[Optional[str], ...], rules: dict) -> P:
    used = set()
    parts = []
    for ax in axes:
        mesh_axes = rules.get(ax) if ax is not None else None
        if mesh_axes is None:
            parts.append(None)
            continue
        mesh_axes = tuple(a for a in mesh_axes if a not in used)
        used.update(mesh_axes)
        parts.append(mesh_axes if len(mesh_axes) != 1 else mesh_axes[0])
        if not mesh_axes:
            parts[-1] = None
    return P(*parts)


def param_shardings(specs, mesh: Mesh, rules: dict):
    """NamedSharding tree for a ParamSpec tree.

    Dims not divisible by their assigned mesh-axis product fall back to
    replicated (pjit rejects uneven argument shardings) — e.g. whisper's
    51866-entry vocab on a 16-way model axis."""
    sizes = _axis_sizes(mesh)

    def one(s: L.ParamSpec):
        spec = spec_for_axes(s.axes, rules)
        parts = []
        for dim, part in zip(s.shape, tuple(spec) + (None,) * (
                len(s.shape) - len(spec))):
            if part is None:
                parts.append(None)
                continue
            axes = (part,) if isinstance(part, str) else tuple(part)
            n = int(np.prod([sizes[a] for a in axes]))
            parts.append(part if dim % n == 0 else None)
        return NamedSharding(mesh, P(*parts))
    return L.spec_tree_map(one, specs)


def tree_shardings_like(tree, mesh: Mesh, spec_fn):
    """Map arbitrary pytrees (caches, opt states) to shardings via a
    callable ``spec_fn(leaf) -> PartitionSpec``."""
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, spec_fn(leaf)), tree)


def _axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


# ---------------------------------------------------------------------------
# Fleet (cross-device) sharding: the ("clients",) mesh axis
# ---------------------------------------------------------------------------

CLIENT_AXIS = "clients"


def fleet_axis_size(mesh: Optional[Mesh]) -> int:
    """Size of the client axis (1 when no mesh / no such axis)."""
    if mesh is None:
        return 1
    return _axis_sizes(mesh).get(CLIENT_AXIS, 1)


def fleet_spec(ndim: int) -> P:
    """PartitionSpec for one client-stacked array: shard dim 0 over
    ``clients``, replicate the rest — (N,), (N, ...) and the packed
    (C, D) buffer all use this."""
    return P(CLIENT_AXIS, *([None] * (ndim - 1)))


def fleet_sharding(mesh: Mesh, ndim: int = 1) -> NamedSharding:
    return NamedSharding(mesh, fleet_spec(ndim))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def fleet_tree_shardings(tree, mesh: Mesh, num_clients: int):
    """NamedSharding tree for client-stacked pytrees (leaves (N, ...)).

    Every leaf whose leading dim equals ``num_clients`` shards over the
    client axis; anything else (scalars, replicated globals) stays
    replicated.  ``num_clients`` must divide the client-axis size times an
    integer — uneven fleets fall back to replicated per leaf (pjit rejects
    uneven argument shardings)."""
    size = fleet_axis_size(mesh)

    def one(leaf):
        shape = getattr(leaf, "shape", ())
        if shape and shape[0] == num_clients and num_clients % size == 0:
            return fleet_sharding(mesh, len(shape))
        return replicated_sharding(mesh)

    return jax.tree.map(one, tree)


def fleet_constraint(tree, mesh: Optional[Mesh], num_clients: int):
    """``with_sharding_constraint`` fleet specs on a pytree *inside* jit.

    Every (N, ...) leaf is pinned to the client-axis sharding, anything
    else (round clocks, replicated scalars) is left alone — applied to
    the dynamics ``step`` outputs so per-round draws stay sharded no
    matter what the process body did.  Identity when ``mesh`` is None.
    """
    if mesh is None:
        return tree
    size = fleet_axis_size(mesh)

    def one(leaf):
        shape = getattr(leaf, "shape", ())
        if shape and shape[0] == num_clients and num_clients % size == 0:
            return jax.lax.with_sharding_constraint(
                leaf, fleet_sharding(mesh, len(shape)))
        return leaf

    return jax.tree.map(one, tree)


def replicated_constraint(tree, mesh: Optional[Mesh]):
    """``with_sharding_constraint`` every leaf to fully-replicated inside
    jit (identity when ``mesh`` is None).

    Applied to the device scalars the round loop hands to the host-side
    ledger (round cut, billed duration, History counters): they are
    reductions over ``("clients",)``-sharded arrays, and pinning them
    replicated guarantees the deferred readback never depends on which
    shard GSPMD happened to leave the value on."""
    if mesh is None:
        return tree
    rep = NamedSharding(mesh, P())
    return jax.tree.map(
        lambda leaf: jax.lax.with_sharding_constraint(leaf, rep), tree)


def place_fleet(tree, mesh: Optional[Mesh], num_clients: int):
    """``jax.device_put`` a client-stacked pytree onto the fleet mesh
    (identity when ``mesh`` is None — the single-device path)."""
    if mesh is None:
        return jax.tree.map(jax.numpy.asarray, tree)
    return jax.device_put(tree, fleet_tree_shardings(tree, mesh,
                                                     num_clients))


# -- compact cohorts: dense (X, ...) blocks gathered from (N, ...) state ----

def cohort_spec(ndim: int) -> P:
    """PartitionSpec for a gathered cohort block: same layout as the full
    fleet — dim 0 (the X cohort rows) shards over ``clients``, the rest
    replicated.  Kept as its own name so call sites say which of the two
    row counts (X vs N) an array carries."""
    return fleet_spec(ndim)


def cohort_sharding(mesh: Mesh, ndim: int = 1) -> NamedSharding:
    return NamedSharding(mesh, cohort_spec(ndim))


def cohort_constraint(tree, mesh: Optional[Mesh], cohort_size: int):
    """``with_sharding_constraint`` cohort specs on a gathered pytree
    *inside* jit: every (X, ...) leaf is pinned to the client-axis
    sharding (the gather's output would otherwise inherit whatever
    layout GSPMD propagated from the (N,)-sized operand).  Identity when
    ``mesh`` is None.  The engine requires ``cohort_size %
    fleet_axis_size(mesh) == 0`` (FLConfig validation), so the pin never
    falls back to replicated."""
    return fleet_constraint(tree, mesh, cohort_size)


def cohort_scatter_constraint(tree, mesh: Optional[Mesh],
                              num_clients: int):
    """Pin scatter *outputs* — (N, ...) fleet state rebuilt from cohort
    rows — back onto the fleet placement, so a compact round's cache
    writes and receive masks land exactly where ``place_fleet`` put the
    originals and steady-state rounds never reshard."""
    return fleet_constraint(tree, mesh, num_clients)


def _dp_size(mesh: Mesh) -> int:
    sizes = _axis_sizes(mesh)
    return int(np.prod([sizes[a] for a in fsdp_axes(mesh)] or [1]))


def batch_shardings(batch_tree, mesh: Mesh):
    """Host batch inputs: shard the leading (batch) dim over the data axes
    when divisible (long_500k has batch 1 — stays replicated)."""
    dp = fsdp_axes(mesh)
    dpn = _dp_size(mesh)

    def one(leaf):
        ndim = len(leaf.shape)
        if dp and ndim >= 1 and leaf.shape[0] % dpn == 0:
            return NamedSharding(mesh, P(dp, *([None] * (ndim - 1))))
        return NamedSharding(mesh, P(*([None] * ndim)))
    return jax.tree.map(one, batch_tree)


def cache_shardings(cache_tree, mesh: Mesh):
    """Decode caches: every array leaf is stacked per layer — (L, B, ...).
    Shard batch (dim 1) over the data axes and the trailing feature dim over
    model, each only when divisible."""
    dp = fsdp_axes(mesh)
    dpn = _dp_size(mesh)
    model_size = _axis_sizes(mesh).get("model", 1)

    def one(leaf):
        shape = leaf.shape
        ndim = len(shape)
        parts = [None] * ndim
        if ndim >= 2 and dp and shape[1] % dpn == 0:
            parts[1] = dp
        if ndim >= 3 and model_size > 1 and shape[-1] % model_size == 0 \
                and shape[-1] >= model_size:
            parts[-1] = "model"
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(one, cache_tree)
