"""Aggregation unit tests: masked weighted FedAvg + staleness discounts."""
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation_weights, fed_aggregate, \
    fed_aggregate_delta


def test_fedavg_equivalence_when_uniform():
    g = {"w": jnp.zeros((3,))}
    c = {"w": jnp.stack([jnp.full((3,), 1.0), jnp.full((3,), 2.0),
                         jnp.full((3,), 3.0)])}
    w = aggregation_weights(jnp.array([True, True, True]))
    out = fed_aggregate(g, c, w)
    np.testing.assert_allclose(out["w"], 2.0)


def test_failed_devices_contribute_zero():
    g = {"w": jnp.zeros((2,))}
    c = {"w": jnp.stack([jnp.full((2,), 10.0), jnp.full((2,), 2.0)])}
    w = aggregation_weights(jnp.array([False, True]))
    out = fed_aggregate(g, c, w)
    np.testing.assert_allclose(out["w"], 2.0)


def test_empty_round_keeps_global():
    g = {"w": jnp.full((2,), 7.0)}
    c = {"w": jnp.zeros((3, 2))}
    w = aggregation_weights(jnp.zeros((3,), bool))
    out = fed_aggregate(g, c, w)
    np.testing.assert_allclose(out["w"], 7.0)


def test_sample_weighting():
    g = {"w": jnp.zeros((1,))}
    c = {"w": jnp.array([[0.0], [10.0]])}
    w = aggregation_weights(jnp.array([True, True]),
                            n_samples=jnp.array([1.0, 3.0]))
    out = fed_aggregate(g, c, w)
    np.testing.assert_allclose(out["w"], 7.5)


def test_staleness_discount_downweights():
    w = aggregation_weights(jnp.array([True, True]),
                            staleness=jnp.array([0.0, 9.0]),
                            staleness_discount=1.0)
    assert float(w[0]) == 1.0
    np.testing.assert_allclose(float(w[1]), 0.1)


def test_delta_aggregation_server_lr():
    g = {"w": jnp.full((1,), 1.0)}
    c = {"w": jnp.array([[3.0]])}
    out = fed_aggregate_delta(g, c, jnp.array([1.0]), server_lr=0.5)
    np.testing.assert_allclose(out["w"], 2.0)     # 1 + 0.5·(3−1)
