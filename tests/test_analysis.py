"""Static-analysis package tests: HLO contract checks, the invariant
auditor, the repo lint, and the ``debug_checks`` runtime sanitizers.

Negative paths first — every checker must *fire* on an injected
violation, naming the dispatch — then the clean paths: a real engine
audits clean, and the repo itself lints clean (the same gates the
``analysis-smoke`` CI job runs).

The checkers are pure functions over HLO text / python source, so most
cases run on synthetic inputs; the auditor smoke and the runtime-guard
tests drive a real single-device engine.
"""
import os
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo_checks as HC
from repro.analysis import lint as L
from repro.analysis import runtime as RT
from repro.analysis.audit import (audit_engine, check_transfer_stats,
                                  transfer_ceiling)
from repro.configs.base import FLConfig
from repro.core.cache_store import TransferStats
from repro.data.synthetic import federated_classification
from repro.fl import FleetEngine, SimConfig
from repro.analysis.hlo_checks import count_aliases
from repro.roofline.hlo import _parse_shape, analyze_hlo_text

_REPO = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------------------
# Contract checkers on synthetic HLO
# ---------------------------------------------------------------------------

def test_alias_block_counts_nested_entries():
    text = ('HloModule jit_step, input_output_alias={ {0}: (0, {}, '
            'may-alias), {1}: (2, {}, must-alias), {2}: (3, {}, '
            'may-alias) }, entry_computation_layout={(f32[4])->f32[4]}')
    assert count_aliases(text) == 3
    assert count_aliases("HloModule jit_f, num_partitions=2") == 0


def test_check_donation_fires_and_names_dispatch():
    text = "HloModule jit_step, input_output_alias={ {0}: (0, {}, may-alias) }"
    bad = HC.check_donation("server_step", text, min_aliases=3)
    assert len(bad) == 1
    assert bad[0].dispatch == "server_step"
    assert bad[0].contract == "donation"
    assert "found 1" in bad[0].message
    assert HC.check_donation("server_step", text, min_aliases=1) == []


def test_donation_on_real_jit():
    """A real donated jit aliases; the undonated twin does not."""
    x = jnp.zeros((8, 4))
    donated = jax.jit(lambda v: v + 1, donate_argnums=0).lower(x).compile()
    plain = jax.jit(lambda v: v + 1).lower(x).compile()
    assert HC.check_donation("d", donated.as_text(), 1) == []
    dropped = HC.check_donation("d", plain.as_text(), 1)
    assert len(dropped) == 1 and dropped[0].contract == "donation"


def test_check_no_host_ops_flags_injected_callback():
    """A jax.debug.callback compiled into a dispatch is exactly the
    python round-trip the zero-sync contract bans."""
    def leaky(v):
        jax.debug.callback(lambda a: None, v)
        return v * 2

    text = jax.jit(leaky).lower(jnp.ones(4)).compile().as_text()
    bad = HC.check_no_host_ops("trainer", text)
    assert bad, "injected host callback not flagged"
    assert bad[0].dispatch == "trainer"
    assert bad[0].contract == "host-sync"
    assert "callback" in bad[0].message


def test_check_no_host_ops_clean_on_plain_jit():
    text = jax.jit(lambda v: v @ v.T).lower(jnp.ones((4, 4))) \
        .compile().as_text()
    assert HC.check_no_host_ops("trainer", text) == []


def test_check_no_host_ops_flags_infeed_and_host_memory_space():
    text = """HloModule m

ENTRY %main (p0: f32[4]) -> f32[4] {
  %p0 = f32[4]{0} parameter(0)
  %tok = token[] after-all()
  %in = (f32[4]{0}, token[]) infeed(%tok)
  %h = f32[4]{0:S(5)} copy(%p0)
  ROOT %r = f32[4]{0} add(%p0, %p0)
}
"""
    bad = HC.check_no_host_ops("round_cut", text)
    contracts = {f.contract for f in bad}
    assert contracts == {"host-sync"}
    msgs = " | ".join(f.message for f in bad)
    assert "infeed" in msgs and "host-memory-space" in msgs


def test_check_no_f64_flags_upcast():
    with jax.experimental.enable_x64():
        text = jax.jit(lambda v: v * 2).lower(
            jnp.ones(4, jnp.float64)).compile().as_text()
    bad = HC.check_no_f64("metrics", text)
    assert len(bad) == 1
    assert bad[0].dispatch == "metrics" and bad[0].contract == "dtype"
    clean = jax.jit(lambda v: v * 2).lower(jnp.ones(4)).compile().as_text()
    assert HC.check_no_f64("metrics", clean) == []


def test_check_psum_dtype():
    text = """HloModule m

%sum (a: bf16[], b: bf16[]) -> bf16[] {
  %a = bf16[] parameter(0)
  %b = bf16[] parameter(1)
  ROOT %s = bf16[] add(%a, %b)
}

ENTRY %main (p0: bf16[8]) -> bf16[8] {
  %p0 = bf16[8]{0} parameter(0)
  ROOT %ar = bf16[8]{0} all-reduce(%p0), to_apply=%sum
}
"""
    bad = HC.check_psum_dtype("server_step", text)
    assert len(bad) == 1 and "bf16" in bad[0].message
    # f32 float psum and integer (ledger-count) psum are both fine
    ok = text.replace("bf16", "f32")
    assert HC.check_psum_dtype("server_step", ok) == []
    ints = text.replace("bf16", "s32")
    assert HC.check_psum_dtype("server_step", ints) == []


def test_check_partition_count():
    text = "HloModule jit_f, num_partitions=8"
    assert HC.check_partition_count("trainer", text, 8) == []
    bad = HC.check_partition_count("trainer", text, 4)
    assert len(bad) == 1 and bad[0].contract == "sharding"
    # absent annotation reads as 1 (the silent single-device fallback)
    lone = HC.check_partition_count("trainer", "HloModule jit_f", 8)
    assert "num_partitions=1" in lone[0].message


class _FakeSharding:
    def __init__(self, replicated):
        self.is_fully_replicated = replicated


def test_check_input_shardings_flags_replicated_fleet_operand():
    n, x = 32, 8
    leaves = [np.zeros((n,)), np.zeros((x, 4)), np.zeros((3,))]
    shardings = [_FakeSharding(True), _FakeSharding(False),
                 _FakeSharding(True)]
    bad = HC.check_input_shardings("flude_plan", leaves, shardings, (n, x))
    assert len(bad) == 1
    assert bad[0].dispatch == "flude_plan"
    assert "operand #0" in bad[0].message
    # small non-fleet arrays may replicate freely
    ok = HC.check_input_shardings(
        "flude_plan", leaves,
        [_FakeSharding(False), _FakeSharding(False), _FakeSharding(True)],
        (n, x))
    assert ok == []


# ---------------------------------------------------------------------------
# Transfer ceiling (contract 5)
# ---------------------------------------------------------------------------

def _fake_engine(offload, **stats):
    ts = TransferStats()
    for k, v in stats.items():
        setattr(ts, k, v)
    return types.SimpleNamespace(offload=offload, transfer_stats=ts)


def test_transfer_ceiling_is_zero_without_offload_or_cache():
    zeros = {"d2h_async": 0, "h2d_async": 0,
             "pre_issued_reads": 0, "sync_copies": 0}
    assert transfer_ceiling(_fake_engine(None), True) == zeros
    assert transfer_ceiling(_fake_engine(object()), False) == zeros
    assert transfer_ceiling(_fake_engine(object()), True) == {
        "d2h_async": 2, "h2d_async": 1,
        "pre_issued_reads": 2, "sync_copies": 0}


def test_check_transfer_stats_flags_sync_copy_and_excess():
    eng = _fake_engine(object(), d2h_async=6, h2d_async=3,
                       pre_issued_reads=6, sync_copies=0)
    assert check_transfer_stats(eng, rounds=3, uses_cache=True) == []
    eng = _fake_engine(object(), d2h_async=7, sync_copies=1)
    bad = check_transfer_stats(eng, rounds=3, uses_cache=True)
    keys = {f.message.split("=")[0] for f in bad}
    assert keys == {"d2h_async", "sync_copies"}
    assert all(f.contract == "transfer" for f in bad)


# ---------------------------------------------------------------------------
# Auditor smoke on a real engine (single device)
# ---------------------------------------------------------------------------

def _small_engine(**fl_kw):
    n = 16
    data = federated_classification(n, num_classes=3, dim=8,
                                    n_per_client=12, n_test=24, seed=1)
    sim = SimConfig(num_clients=n, rounds=3, local_steps=2, batch_size=6,
                    model_hidden=8, model_depth=1, seed=0)
    fl = FLConfig(num_clients=n, clients_per_round=8, dynamics="markov",
                  **fl_kw)
    return FleetEngine(data, sim, fl)


def test_audit_engine_clean_on_real_round_path():
    engine = _small_engine(donate_buffers=True)
    report = audit_engine(engine, "flude")
    assert report.ok(), report.summary()
    assert report.mode == "full" and report.mesh_size == 1
    for name in ("trainer", "round_cut", "server_step", "flude_plan",
                 "eval_accuracy"):
        assert name in report.dispatches, report.dispatches
    assert "all contracts hold" in report.summary()


def test_audit_report_raise_names_every_violation():
    engine = _small_engine()
    report = audit_engine(engine, "flude")
    report.findings.append(HC.Finding("trainer", "dtype", "injected"))
    with pytest.raises(AssertionError, match=r"\[dtype\] trainer"):
        report.raise_on_findings()


# ---------------------------------------------------------------------------
# debug_checks runtime sanitizers
# ---------------------------------------------------------------------------

def test_round_guard_fires_on_nonfinite_model():
    guard = RT.make_round_guard(8, with_idx=False)
    err, _ = guard({"w": jnp.array([1.0, jnp.nan])}, jnp.zeros(4))
    with pytest.raises(RT.RoundCheckError, match="round 5"):
        RT.throw_round_error(err, 5)
    err, _ = guard({"w": jnp.ones(2)}, jnp.zeros(4))
    RT.throw_round_error(err, 5)     # clean: no raise


def test_round_guard_checks_cohort_index_bounds():
    guard = RT.make_round_guard(8, with_idx=True)
    # N == 8 is the legal pad sentinel; 9 is out of bounds
    err, _ = guard({"w": jnp.ones(2)}, jnp.zeros(4),
                   jnp.array([0, 8], jnp.int32))
    RT.throw_round_error(err, 0)
    err, _ = guard({"w": jnp.ones(2)}, jnp.zeros(4),
                   jnp.array([0, 9], jnp.int32))
    with pytest.raises(RT.RoundCheckError, match="out of bounds"):
        RT.throw_round_error(err, 0)


def test_recompilation_detector_raises_on_retrace():
    sizes = {"n": 1}

    class _Jit:
        def _cache_size(self):
            return sizes["n"]

    eng = types.SimpleNamespace(
        _server_steps={"k": _Jit()}, _dyn_cache={}, _cut_fns={},
        _metrics_fns={}, _trainer=None, _acc_fn=None, _idx_fn=None,
        _expire_fn=None, _cache_reset=None)
    det = RT.RecompilationDetector(eng)
    det.check()                      # baseline
    det.check()                      # stable: fine
    sizes["n"] = 2
    with pytest.raises(RT.RoundCheckError, match="re-traced"):
        det.check()


def test_debug_checks_engine_run_is_observation_only():
    plain = _small_engine().run("flude", diagnostics=False)
    checked = _small_engine(debug_checks=True).run(
        "flude", diagnostics=False)
    assert checked.acc == plain.acc
    assert checked.received == plain.received


# ---------------------------------------------------------------------------
# Repo lint: fixture self-tests + clean repo
# ---------------------------------------------------------------------------

def _rules(findings):
    return sorted({f.rule for f in findings})


def test_lint_flags_host_syncs_in_round_path_modules():
    src = ("import jax\n"
           "import numpy as np\n"
           "def hot(x):\n"
           "    a = jax.device_get(x)\n"
           "    b = np.asarray(x)\n"
           "    c = x.item()\n"
           "    d = float(run(x))\n"
           "    return a, b, c, d\n")
    bad = L.lint_source(src, "repro/fl/engine.py")
    assert len(bad) == 4 and _rules(bad) == ["host-sync"]
    # same code outside a round-path module is not the lint's business
    assert L.lint_source(src, "repro/obs/report.py") == []
    # allowlisted seams are exempt, nested defs included
    seam = src.replace("def hot", "def host_round_cut")
    assert L.lint_source(seam, "repro/core/round.py") == []


def test_lint_flags_mutable_global_but_not_frozen_configs():
    bad = L.lint_source("STATS = TransferStats()\n",
                        "repro/core/cache_store.py")
    assert "mutable-global" in _rules(bad)
    ok = L.lint_source("CONFIG = ModelConfig(dim=4)\n",
                       "repro/configs/transformer.py")
    assert ok == []
    # lowercase module attrs and non-constructor calls are not flagged
    assert L.lint_source("helper = Maker()\nX = compute()\n",
                         "repro/fl/api.py") == []


def test_lint_flags_undocumented_or_computed_registry_names():
    src = ("@register_policy(NAME)\n"
           "def my_policy(cfg):\n"
           "    return 1\n")
    bad = L.lint_source(src, "repro/fl/policies.py")
    assert _rules(bad) == ["registry"] and len(bad) == 2   # name + docstring
    ok = ("@register_policy(\"mine\")\n"
          "def my_policy(cfg):\n"
          "    \"\"\"Documented.\"\"\"\n"
          "    return 1\n")
    assert L.lint_source(ok, "repro/fl/policies.py") == []


def test_lint_flags_nondeterminism_inside_jit():
    src = ("import jax, time\n"
           "@jax.jit\n"
           "def step(x):\n"
           "    return x * time.time()\n")
    bad = L.lint_source(src, "repro/core/round.py")
    assert "jit-determinism" in _rules(bad)
    ok = ("import jax, time\n"
          "def host_side():\n"
          "    return time.time()\n")
    assert L.lint_source(ok, "repro/obs/trace.py") == []


def test_lint_flags_deprecated_stats_references():
    bad = L.lint_source("from repro.core.cache_store import STATS\n",
                        "repro/fl/engine.py")
    assert "deprecated-stats" in _rules(bad)
    bad = L.lint_source("import repro.core.cache_store as CS\n"
                        "def f():\n"
                        "    CS.STATS.reset()\n",
                        "repro/obs/report.py")
    assert "deprecated-stats" in _rules(bad)


def test_lint_requires_post_init_registry_validation():
    src = ("class FLConfig:\n"
           "    def __post_init__(self):\n"
           "        pass\n")
    bad = L.lint_source(src, "repro/configs/base.py")
    assert len(bad) == len(L._POST_INIT_VALIDATORS)
    assert _rules(bad) == ["registry"]


def test_repo_lints_clean():
    """The gate the analysis-smoke CI job enforces on every push."""
    findings = L.lint_paths([os.path.join(_REPO, "src", "repro")])
    assert findings == [], "\n".join(str(f) for f in findings)


def test_config_rejects_unknown_dynamics():
    with pytest.raises(ValueError, match="dynamics"):
        FLConfig(num_clients=8, dynamics="not-a-registered-name")


# ---------------------------------------------------------------------------
# roofline/hlo.py regressions (satellite: parse gaps)
# ---------------------------------------------------------------------------

def test_parse_shape_tuple_and_fp8_dtypes():
    el, by = _parse_shape("(f32[128,4]{1,0}, f32[128,4], u32[])")
    assert el == 128 * 4 * 2 + 1
    assert by == 128 * 4 * 4 * 2 + 4
    el, by = _parse_shape("f8e4m3fnuz[32]")
    assert (el, by) == (32, 32)
    el, by = _parse_shape("(f8e5m2fnuz[8], u2[16], s2[4])")
    assert (el, by) == (8 + 16 + 4, 8 + 16 + 4)


def test_copy_start_done_pair_charged_once():
    """The async pair moves the buffer once: 2x buffer bytes at the
    start (read + write), nothing at the completion handle.  The old
    fall-through summed the tuple output and the pair ~6x."""
    text = """HloModule m

ENTRY %main (p0: f32[128]) -> f32[128] {
  %p0 = f32[128]{0} parameter(0)
  %cs = (f32[128]{0}, f32[128]{0}, u32[]) copy-start(%p0)
  ROOT %cd = f32[128]{0} copy-done(%cs)
}
"""
    cost = analyze_hlo_text(text)
    assert cost.bytes == 2 * 128 * 4
