"""Attention-variant and MoE behaviour tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.models.attention import chunked_attention, dense_attention
from repro.models.moe import moe_forward


def _qkv(B=2, S=128, Hk=2, G=2, D=32, seed=0):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(B, S, Hk, G, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, Hk, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, Hk, D), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("window", [None, 32])
@pytest.mark.parametrize("q_chunk,k_chunk", [(32, 32), (64, 32), (128, 64)])
def test_chunked_equals_dense(window, q_chunk, k_chunk):
    q, k, v = _qkv()
    got = chunked_attention(q, k, v, causal=True, window=window,
                            scale=0.17, q_chunk=q_chunk, k_chunk=k_chunk)
    want = dense_attention(q, k, v, causal=True, window=window, scale=0.17)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_unroll_causal_equals_scan():
    q, k, v = _qkv(S=128)
    a = chunked_attention(q, k, v, causal=True, window=None, scale=0.2,
                          q_chunk=32, k_chunk=32, unroll_causal=True)
    b = chunked_attention(q, k, v, causal=True, window=None, scale=0.2,
                          q_chunk=32, k_chunk=32, unroll_causal=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_swa_ignores_distant_context():
    """With window w, perturbing a key more than w behind a query must not
    change that query's output."""
    q, k, v = _qkv(S=64)
    w = 16
    out1 = dense_attention(q, k, v, causal=True, window=w, scale=0.2)
    k2 = k.at[:, 10].add(100.0)       # token 10 is > w behind query 40
    v2 = v.at[:, 10].add(100.0)
    out2 = dense_attention(q, k2, v2, causal=True, window=w, scale=0.2)
    np.testing.assert_allclose(np.asarray(out1[:, 40:]),
                               np.asarray(out2[:, 40:]), rtol=1e-5,
                               atol=1e-5)
    # ...but it does change queries within the window
    assert float(jnp.abs(out1[:, 12] - out2[:, 12]).max()) > 1e-3


def test_causality():
    """Perturbing a future token never changes past outputs."""
    q, k, v = _qkv(S=32)
    out1 = chunked_attention(q, k, v, causal=True, window=None, scale=0.2,
                             q_chunk=16, k_chunk=16)
    k2 = k.at[:, 20].add(10.0)
    v2 = v.at[:, 20].add(10.0)
    out2 = chunked_attention(q, k2, v2, causal=True, window=None,
                             scale=0.2, q_chunk=16, k_chunk=16)
    np.testing.assert_allclose(np.asarray(out1[:, :20]),
                               np.asarray(out2[:, :20]), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _moe_setup(seed=0):
    cfg = get_config("mixtral-8x7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(seed))
    p = jax.tree.map(lambda a: a[0], params["blocks"]["moe"])
    return cfg, p


def test_moe_output_shape_and_finite():
    cfg, p = _moe_setup()
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    y, aux = moe_forward(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux) >= 0.0


def test_moe_grouping_invariance():
    """Grouped dispatch (G>1) ~= ungrouped on balanced inputs; exact when
    capacity is not exceeded."""
    cfg, p = _moe_setup()
    import dataclasses
    # generous capacity so no token is dropped in either grouping
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    x = jax.random.normal(jax.random.key(2), (4, 16, cfg.d_model))

    class E1:
        moe_groups = 1

    class E4:
        moe_groups = 4
        mesh = None
        rules = None
    y1, _ = moe_forward(p, x, cfg, E1)
    y4, _ = moe_forward(p, x, cfg, E4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), rtol=1e-4,
                               atol=1e-4)


def test_moe_capacity_drops_tokens():
    """With a tiny capacity factor some tokens must be dropped (zero
    contribution), never NaN."""
    cfg, p = _moe_setup()
    import dataclasses
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    x = jax.random.normal(jax.random.key(3), (2, 32, cfg.d_model))
    y, _ = moe_forward(p, x, cfg)
    assert bool(jnp.isfinite(y).all())


def test_moe_router_aux_penalizes_imbalance():
    """A router forced to one expert yields a larger aux loss than the
    learned (roughly balanced) router."""
    cfg, p = _moe_setup()
    x = jax.random.normal(jax.random.key(4), (2, 64, cfg.d_model))
    _, aux_balanced = moe_forward(p, x, cfg)
    p_bad = dict(p)
    bias = jnp.zeros((cfg.d_model, cfg.moe.num_experts))
    p_bad["router"] = bias.at[:, 0].set(10.0)   # everything to expert 0
    _, aux_collapsed = moe_forward(p_bad, x, cfg)
    assert float(aux_collapsed) > float(aux_balanced)


def test_deepseek_shared_experts_always_active():
    cfg = get_config("deepseek-v2-236b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    p = jax.tree.map(lambda a: a[0], params["blocks"]["moe"])
    assert "shared" in p
    x = jax.random.normal(jax.random.key(1), (1, 16, cfg.d_model))
    y, _ = moe_forward(p, x, cfg)
    assert bool(jnp.isfinite(y).all())
