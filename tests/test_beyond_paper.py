"""Beyond-paper features: Thompson-sampling selection, status-aware
exploration, Pallas fed_agg in the aggregation path."""
import importlib

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.configs.base import FLConfig

D = importlib.import_module("repro.core.dependability")
SE = importlib.import_module("repro.core.selection")


def _belief(dep, n=1000.0):
    dep = jnp.asarray(dep, jnp.float32)
    return D.update_belief(D.init_belief(dep.shape[0], 0.0, 0.0),
                           dep * n, (1 - dep) * n)


def test_thompson_selection_valid_and_stochastic():
    N = 32
    b = _belief(jnp.linspace(0.1, 0.9, N), n=5.0)   # wide posteriors
    kw = dict(part_count=jnp.zeros((N,), jnp.int32),
              explored=jnp.ones((N,), bool), online=jnp.ones((N,), bool),
              total_selected=jnp.float32(0.0), X=jnp.int32(8),
              epsilon=jnp.float32(0.0), sigma=0.5)
    sels = []
    for seed in range(6):
        res = SE.select_participants(b, rng=jax.random.key(seed),
                                     mode="thompson", **kw)
        assert int(res.selected.sum()) == 8
        sels.append(np.asarray(res.selected))
    # thompson sampling varies the selection across seeds (mean mode does
    # not once priorities are fixed)
    assert any(not (sels[0] == s).all() for s in sels[1:])
    # ... but still prefers dependable devices on average
    freq = np.stack(sels).mean(0)
    assert freq[-8:].mean() > freq[:8].mean()


def test_thompson_concentrates_with_evidence():
    """With tight posteriors Thompson ranks ≈ mean ranks."""
    N = 16
    dep = jnp.linspace(0.05, 0.95, N)
    b = _belief(dep, n=5000.0)
    res = SE.select_participants(
        b, jnp.zeros((N,), jnp.int32), jnp.ones((N,), bool),
        jnp.ones((N,), bool), jnp.float32(0.0), jnp.int32(4),
        jnp.float32(0.0), 0.5, jax.random.key(0), mode="thompson")
    assert bool(res.selected[-4:].all())


def test_status_aware_exploration():
    """§4.1 optional heuristic: charged/stable devices explored first."""
    N = 20
    b = D.init_belief(N)
    hints = jnp.arange(N, dtype=jnp.float32) / N     # device N-1 best
    res = SE.select_participants(
        b, jnp.zeros((N,), jnp.int32), jnp.zeros((N,), bool),
        jnp.ones((N,), bool), jnp.float32(0.0), jnp.int32(5),
        jnp.float32(1.0), 0.5, jax.random.key(0), explore_hints=hints)
    assert bool(res.explored_new[-5:].all())


def test_flude_thompson_config_runs():
    import dataclasses
    from repro.data.synthetic import federated_classification
    from repro.fl import SimConfig, run_fl
    n = 24
    data = federated_classification(n, seed=3, margin=1.2, noise=1.4,
                                    n_per_client=32)
    sim = SimConfig(num_clients=n, rounds=6, seed=3)
    fl = FLConfig(num_clients=n, clients_per_round=6,
                  selection_mode="thompson")
    h = run_fl("flude", data, sim, fl)
    assert len(h.acc) == 6 and np.isfinite(h.acc[-1])


def test_fed_agg_kernel_in_aggregation_path():
    from repro.kernels.fed_agg.ops import fed_agg
    rng = np.random.RandomState(0)
    C = 5
    g = {"w": jnp.zeros((3, 4))}
    clients = {"w": jnp.asarray(rng.randn(C, 3, 4), jnp.float32)}
    w = jnp.asarray(rng.rand(C), jnp.float32)
    ref = core.fed_aggregate(g, clients, w)
    kern = core.fed_aggregate(
        g, clients, w,
        kernel=lambda u, nw: fed_agg(u, nw, impl="pallas_interpret"))
    np.testing.assert_allclose(np.asarray(kern["w"]), np.asarray(ref["w"]),
                               rtol=1e-5, atol=1e-5)
