"""Host-offloaded C3 cache store (``FLConfig.cache_offload``).

Covers, on a single device (the sharded variant is the slow subprocess
test at the bottom):

* config validation of the offload knobs;
* ``HostCacheStore`` semantics — sparse rows, empty-slot gathers,
  write/clear/prune bookkeeping, owned-copy rows;
* offload-vs-resident golden parity: every registered policy, padded
  cohorts, pipelined depths, repeated runs on one engine, the stateful
  robust rule and ``"discard"`` with a bound the run never crosses —
  bit-identical ``History``;
* the streaming contract: zero synchronous round-blocking copies, O(1)
  async copies per round, per-round host transfers independent of the
  round count (and of N — the stream only ever moves (X, ...) blocks);
* ``server_step_memory`` reporting the device/host cache residency
  split (device O(X·D) under offload) and the agg-rule state bytes;
* ``"discard"`` staleness semantics on the live store.

The hypothesis round-trip property tests live in
``test_cache_store_properties.py``.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.configs.base import FLConfig
from repro.core import cache_store as CS
from repro.data.synthetic import federated_classification
from repro.fl import FleetEngine, SimConfig, available_policies

N = 32
SIM = SimConfig(num_clients=N, rounds=3, local_steps=2, batch_size=8,
                seed=3)
FL = FLConfig(num_clients=N, clients_per_round=8, dynamics="markov",
              cohort_size=8)


@pytest.fixture(scope="module")
def data():
    return federated_classification(N, seed=4, n_per_client=16)


def _run(data, fl, policy, **kw):
    return FleetEngine(data, SIM, fl).run(policy, diagnostics=False, **kw)


def _assert_hist_equal(a, b, ctx=""):
    """Bitwise History equality — the offload path's exactness contract."""
    for f in ("acc", "comm_mb", "wall_clock", "received", "selected"):
        assert getattr(a, f) == getattr(b, f), (ctx, f)


def _template():
    return {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": np.zeros(4, np.float32)}


# ---------------------------------------------------------------------------
# Config validation
# ---------------------------------------------------------------------------

def test_cache_offload_rejects_unknown_mode():
    with pytest.raises(ValueError, match="cache_offload"):
        FLConfig(num_clients=N, cohort_size=8, cache_offload="disk")


def test_cache_offload_requires_cohort():
    with pytest.raises(ValueError, match="requires cohort_size"):
        FLConfig(num_clients=N, cache_offload="host")


@pytest.mark.parametrize("bad", [0, -3, True, 1.5])
def test_staleness_bound_rejects_non_positive(bad):
    with pytest.raises(ValueError, match="cache_staleness_bound"):
        FLConfig(num_clients=N, cohort_size=8, cache_offload="discard",
                 cache_staleness_bound=bad)


# ---------------------------------------------------------------------------
# HostCacheStore semantics
# ---------------------------------------------------------------------------

def test_store_empty_gather_is_zero():
    store = CS.HostCacheStore(_template(), num_clients=8)
    got = store.gather(np.array([0, 3, 8]))      # 8 = sentinel
    assert got["w"].shape == (3, 2, 3)
    assert not got["w"].any() and not got["b"].any()
    assert len(store) == 0 and store.nbytes == 0


def test_store_write_fetch_clear_roundtrip():
    store = CS.HostCacheStore(_template(), num_clients=8)
    block = {"w": np.random.default_rng(0).normal(size=(3, 2, 3))
             .astype(np.float32),
             "b": np.ones((3, 4), np.float32)}
    idx = np.array([1, 4, 8])                    # last row is the sentinel
    store.apply(idx, write=np.array([True, True, True]),
                clear=np.zeros(3, bool), stamps=np.array([0, 0, 0]),
                block=block, current_round=0)
    assert len(store) == 2                       # sentinel write dropped
    assert store.nbytes == 2 * store.row_bytes
    got = store.gather(np.array([4, 1, 2]))
    np.testing.assert_array_equal(got["w"][0], block["w"][1])
    np.testing.assert_array_equal(got["w"][1], block["w"][0])
    assert not got["w"][2].any()                 # never-written row
    # rows are owned copies, not views into the transient block
    block["w"][:] = -1.0
    np.testing.assert_array_equal(store.gather(np.array([1]))["w"][0]
                                  .ravel()[:1] == -1.0, [False])
    store.apply(np.array([1]), write=np.array([False]),
                clear=np.array([True]), stamps=np.array([0]),
                block={"w": np.zeros((1, 2, 3), np.float32),
                       "b": np.zeros((1, 4), np.float32)},
                current_round=1)
    assert len(store) == 1 and store.stamp_of(1) is None


def test_store_prune_drops_stale_rows():
    store = CS.HostCacheStore(_template(), num_clients=8,
                              staleness_bound=2)
    block = {"w": np.ones((2, 2, 3), np.float32),
             "b": np.ones((2, 4), np.float32)}
    store.apply(np.array([0, 5]), write=np.array([True, True]),
                clear=np.zeros(2, bool), stamps=np.array([0, 3]),
                block=block, current_round=2)   # 2-0 <= 2: both survive
    assert len(store) == 2
    store.prune(5)           # 5 - 0 > 2 drops row 0; 5 - 3 <= 2 keeps 5
    assert len(store) == 1 and store.stamp_of(0) is None
    assert store.stamp_of(5) == 3


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_store_roundtrip_random_sequences(seed):
    """Seeded sweep of the round-trip invariant (the hypothesis version
    lives in ``test_cache_store_properties.py``): after any sequence of
    applies, a gather reads the resident-reference bytes wherever the
    metadata says "live cache" and zeros everywhere else — sentinel
    rows, cleared rows and bound-expired rows included."""
    rng = np.random.default_rng(seed)
    n, x = int(rng.integers(4, 20)), int(rng.integers(1, 8))
    bound = None if seed % 2 else int(rng.integers(1, 4))
    template = _template()
    store = CS.HostCacheStore(template, n, staleness_bound=bound)
    ref_rows = {k: np.zeros((n,) + v.shape, v.dtype)
                for k, v in template.items()}
    ref_stamp = np.full(n, -1, np.int64)
    for rnd in range(6):
        ids = rng.choice(n, size=min(x, n), replace=False)
        k_live = int(rng.integers(0, len(ids) + 1))
        idx = np.full(x, n, np.int64)
        idx[:k_live] = np.sort(ids[:k_live])
        op = rng.integers(0, 3, size=x)          # 0 write, 1 clear, 2 no-op
        write, clear = op == 0, op == 1
        stamps = rng.integers(0, rnd + 1, size=x)
        block = {k: rng.normal(size=(x,) + v.shape).astype(v.dtype)
                 for k, v in template.items()}
        store.apply(idx, write, clear, stamps, block, rnd)
        for k in range(x):
            cid = int(idx[k])
            if cid >= n:
                continue
            if write[k]:
                for name in ref_rows:
                    ref_rows[name][cid] = block[name][k]
                ref_stamp[cid] = stamps[k]
            elif clear[k]:
                ref_stamp[cid] = -1
        if bound is not None:
            ref_stamp[(rnd - ref_stamp > bound) & (ref_stamp >= 0)] = -1
        probe = rng.integers(0, n + 1, size=5)   # n = sentinel probe
        got = store.gather(probe)
        for name in ref_rows:
            for k, cid in enumerate(probe):
                cid = int(cid)
                want = ref_rows[name][cid] \
                    if cid < n and ref_stamp[cid] >= 0 \
                    else np.zeros_like(ref_rows[name][0])
                np.testing.assert_array_equal(got[name][k], want,
                                              err_msg=f"r{rnd} {name}")
    assert len(store) == int((ref_stamp >= 0).sum())


def test_store_matches_device_expiry_predicate():
    """Host prune and device ``expire_caches`` share one predicate
    (``current_round - stamp > bound``) — a row is pruned iff its device
    metadata was expired, so the planner can never resume a pruned row."""
    bound = 3
    stamps = np.array([-1, 0, 2, 5, 9], np.int32)
    rnd = 9
    caches = core.ClientCaches({}, np.full(5, 0.5, np.float32),
                               jnp.asarray(stamps))
    expired = np.asarray(
        core.expire_caches(caches, rnd, bound).round_stamp) < 0
    host_dead = np.array([rnd - int(s) > bound for s in stamps])
    # empty slots (stamp -1) read expired either way
    np.testing.assert_array_equal(expired, host_dead | (stamps < 0))


# ---------------------------------------------------------------------------
# Offload-vs-resident golden parity (single device, bit-identical)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", sorted(available_policies()))
def test_policy_parity_offload_vs_resident(policy, data):
    """Every registered policy: the host-offload path replays the
    resident cohort History bit for bit."""
    bounded = policy not in ("mifa", "asyncfeded")
    fl = FL if bounded else dataclasses.replace(FL, cohort_size=N)
    resident = _run(data, fl, policy)
    offload = _run(data, dataclasses.replace(fl, cache_offload="host"),
                   policy)
    _assert_hist_equal(resident, offload, policy)


def test_parity_padded_cohort_and_depths(data):
    """Sentinel-padded cohorts and pipelined depths change nothing."""
    resident = _run(data, FL, "flude")
    for x in (12, N):
        for depth in (1, 4):
            fl = dataclasses.replace(FL, cohort_size=x,
                                     cache_offload="host",
                                     pipeline_depth=depth)
            _assert_hist_equal(resident, _run(data, fl, "flude"),
                               f"X={x} depth={depth}")


def test_parity_discard_with_uncrossed_bound(data):
    """A staleness bound the run never crosses makes ``"discard"``
    bit-identical to ``"host"`` (and so to the resident path)."""
    resident = _run(data, FL, "flude")
    fl = dataclasses.replace(FL, cache_offload="discard",
                             cache_staleness_bound=SIM.rounds + 10)
    _assert_hist_equal(resident, _run(data, fl, "flude"), "discard")


def test_parity_repeated_runs_reset_store(data):
    """Back-to-back runs on one engine reset the host store with the
    device caches — run 2 replays run 1 (and the resident engine)."""
    fl = dataclasses.replace(FL, cache_offload="host")
    engine = FleetEngine(data, SIM, fl)
    h1 = engine.run("flude", diagnostics=False)
    h2 = engine.run("flude", diagnostics=False)
    _assert_hist_equal(h1, h2, "rerun")
    _assert_hist_equal(_run(data, FL, "flude"), h2, "vs resident")


def test_parity_with_stateful_rule(data):
    """The offload server step threads the stateful robust-aggregation
    state exactly like the resident one (trust scores included)."""
    fl = dataclasses.replace(FL, agg_rule="trust")
    resident = _run(data, fl, "flude")
    offload = _run(data, dataclasses.replace(fl, cache_offload="host"),
                   "flude")
    _assert_hist_equal(resident, offload, "trust")
    np.testing.assert_array_equal(resident.trust, offload.trust)


# ---------------------------------------------------------------------------
# Streaming contract: async only, O(1) per round, O(X) bytes
# ---------------------------------------------------------------------------

def test_stream_never_blocks_a_round(data):
    """The protocol's invariant: zero synchronous copies; every blocking
    read is on a handle whose device-to-host copy was issued a full
    dispatch earlier; one fetch + one write-back stage per round."""
    fl = dataclasses.replace(FL, cache_offload="host")
    engine = FleetEngine(data, SIM, fl)
    engine.run("flude", diagnostics=False)          # compile + place
    engine.transfer_stats.reset()
    engine.run("flude", rounds=3, diagnostics=False)
    s = engine.transfer_stats.snapshot()
    assert s["sync_copies"] == 0
    # per round: one d2h dispatch for the fetch's idx + one for the
    # staged write-back; one h2d for the fetched block
    assert s["d2h_async"] == 2 * 3
    assert s["h2d_async"] == 3
    assert s["pre_issued_reads"] == 2 * 3


def test_stream_transfers_round_count_independent(data):
    """Per-round transfer work is constant: counts scale linearly in
    rounds with zero fixed-point drift, and bytes scale with X·D, not
    N·D."""
    fl = dataclasses.replace(FL, cache_offload="host")
    engine = FleetEngine(data, SIM, fl)
    engine.run("flude", diagnostics=False)
    per_run = []
    for rounds in (1, 3):
        engine.transfer_stats.reset()
        engine.run("flude", rounds=rounds, diagnostics=False)
        per_run.append(engine.transfer_stats.snapshot())
    assert per_run[0]["d2h_async"] * 3 == per_run[1]["d2h_async"]
    assert per_run[0]["h2d_async"] * 3 == per_run[1]["h2d_async"]
    # every h2d payload is one (X, ...) block (+ negligible (X,) masks)
    x, n = FL.cohort_size, N
    block_bytes = x * engine.cache_store.row_bytes
    assert per_run[1]["h2d_bytes"] == 3 * block_bytes
    assert per_run[1]["h2d_bytes"] < 3 * n * engine.cache_store.row_bytes


def test_no_stream_transfers_without_cache(data):
    """``uses_cache=False`` policies skip the stream entirely — the
    offload engine feeds the trainer a constant zeros block."""
    fl = dataclasses.replace(FL, cache_offload="host")
    engine = FleetEngine(data, SIM, fl)
    engine.run("random", diagnostics=False)
    assert engine.transfer_stats.snapshot() == CS.TransferStats().snapshot()
    assert len(engine.cache_store) == 0


def test_offload_adds_no_per_round_uploads(data, monkeypatch):
    """The ``place_per_client`` seam: offload rounds upload exactly what
    resident cohort rounds upload — the cache stream's own transfers go
    through ``device_put``/``copy_to_host_async``, never through the
    per-client placement path."""
    import repro.fl.engine as ENG
    import repro.fl.policies as POL
    import repro.fl.simulator as SIMM

    counts = {"n": 0}
    orig = SIMM.place_per_client

    def counting(arr, mesh=None):
        counts["n"] += 1
        return orig(arr, mesh)

    for mod in (ENG, POL, SIMM):
        monkeypatch.setattr(mod, "place_per_client", counting)

    per_path = {}
    for label, fl in (("resident", FL),
                      ("offload",
                       dataclasses.replace(FL, cache_offload="host"))):
        engine = FleetEngine(data, SIM, fl)
        engine.run("flude", diagnostics=False)      # compile + place
        per_run = []
        for rounds in (1, 3):
            counts["n"] = 0
            engine.run("flude", rounds=rounds, diagnostics=False)
            per_run.append(counts["n"])
        assert per_run[0] == per_run[1], (label, per_run)
        per_path[label] = per_run[0]
    assert per_path["offload"] == per_path["resident"], per_path


# ---------------------------------------------------------------------------
# Memory profile: device O(X·D), host = live rows, rule state
# ---------------------------------------------------------------------------

def test_server_step_memory_reports_residency_split(data):
    x = FL.cohort_size
    resident = FleetEngine(data, SIM, FL)
    offload = FleetEngine(data, SIM,
                          dataclasses.replace(FL, cache_offload="host"))
    mr = resident.server_step_memory()
    mo = offload.server_step_memory()
    row = offload.cache_store.row_bytes
    meta = N * (4 + 4)                    # (N,) f32 progress + i32 stamp
    assert mr["cache_host_bytes"] == 0
    assert mr["cache_device_bytes"] == meta + N * row
    # offload device residency is O(X·D) + O(N) metadata — fleet-size
    # independent in the model dimension
    assert mo["cache_device_bytes"] == meta + x * row
    assert mo["cache_device_bytes"] < mr["cache_device_bytes"]
    assert mo["cache_host_bytes"] == 0    # nothing stored before a run
    engine = FleetEngine(data, SIM,
                         dataclasses.replace(FL, cache_offload="host"))
    engine.run("flude", diagnostics=False)
    after = engine.server_step_memory()
    assert after["cache_host_bytes"] == \
        len(engine.cache_store) * row


def test_server_step_memory_reports_rule_state(data):
    mr = FleetEngine(data, SIM, FL).server_step_memory()
    assert mr["rule_state_bytes"] == 0
    mt = FleetEngine(
        data, SIM, dataclasses.replace(FL, agg_rule="trust")
    ).server_step_memory()
    assert mt["rule_state_bytes"] == N * 4     # (N,) float32 trust


# ---------------------------------------------------------------------------
# Discard staleness semantics on the live store
# ---------------------------------------------------------------------------

def test_discard_prunes_stale_store_rows(data):
    sim = dataclasses.replace(SIM, rounds=8)
    fl = dataclasses.replace(FL, cache_offload="discard",
                             cache_staleness_bound=1)
    engine = FleetEngine(data, sim, fl)
    engine.run("flude", diagnostics=False)
    # every surviving row was written within the bound of the final
    # prune (run end drains at round ``rounds``)
    for cid in list(engine.cache_store._stamps):
        assert sim.rounds - engine.cache_store.stamp_of(cid) <= 1
    loose = FleetEngine(data, sim,
                        dataclasses.replace(fl, cache_staleness_bound=64))
    loose.run("flude", diagnostics=False)
    assert len(engine.cache_store) <= len(loose.cache_store)


# ---------------------------------------------------------------------------
# Sharded (8 forced host devices) offload round path
# ---------------------------------------------------------------------------

def _run_script(script, timeout=540):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


_MESH_SCRIPT = r"""
from repro.launch.mesh import force_host_platform_device_count
force_host_platform_device_count(8)
import dataclasses
import json
import jax

from repro.configs.base import FLConfig
from repro.data.synthetic import federated_classification
from repro.fl import FleetEngine, SimConfig

n = 32
data = federated_classification(n, seed=0, n_per_client=32)
sim = SimConfig(num_clients=n, rounds=3, seed=0, local_steps=2)
out = {"n_dev": len(jax.devices()), "cases": {}}

for pol, x in (("flude", 8), ("mifa", 32)):
    fl = FLConfig(num_clients=n, clients_per_round=8, dynamics="markov",
                  mesh_shape=(8,), cohort_size=x)
    ref = FleetEngine(data, sim, fl).run(pol, diagnostics=False)
    engine = FleetEngine(data, sim,
                         dataclasses.replace(fl, cache_offload="host"))
    h = engine.run(pol, diagnostics=False)
    out["cases"][f"{pol}-x{x}"] = {
        "hist_equal": (h.acc == ref.acc and h.comm_mb == ref.comm_mb
                       and h.wall_clock == ref.wall_clock
                       and h.received == ref.received
                       and h.selected == ref.selected),
        "sync_copies": engine.transfer_stats.sync_copies,
        "store_rows": len(engine.cache_store),
    }
print(json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_offload_round_path():
    """Offload vs resident cohort over 8 forced host devices: the two
    paths dispatch the same cohort ops over the same rows (the fetched
    block lands on the cohort sharding), so the full History — floats
    included — is bit-identical, with zero synchronous copies."""
    rec = _run_script(_MESH_SCRIPT)
    assert rec["n_dev"] == 8
    for case, r in rec["cases"].items():
        assert r["hist_equal"], (case, r)
        assert r["sync_copies"] == 0, (case, r)
