"""Hypothesis property tests for the host-offloaded C3 cache store.

``test_cache_store.py`` holds a seeded-random sweep of the same
round-trip invariant so coverage survives without the hypothesis
dependency; this module widens the search (arbitrary fleet sizes,
cohort widths, write/clear sequences, sentinel rows and staleness
bounds) where hypothesis is available.

The invariant under test is the store's parity contract with the
resident (N, D) pytree: after any sequence of per-round
``apply(idx, write, clear, stamps, block)`` calls, a ``gather`` reads
— for every row whose metadata says "has a live cache" — exactly the
bytes the resident pytree's ``gather_caches`` would produce, and zeros
everywhere metadata says "empty" (never-written, cleared, sentinel, or
expired under a ``"discard"`` staleness bound).  Metadata is the
arbiter on both paths, which is why the two engines run bit-identical
rounds.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.cache_store import HostCacheStore  # noqa: E402

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _template(dim):
    return {"w": np.zeros((2, dim), np.float32),
            "b": np.zeros((dim,), np.float32)}


class _ResidentReference:
    """The resident-pytree semantics, in plain numpy: a dense (N, ...)
    buffer plus per-row stamps.  ``write`` overwrites rows, ``clear``
    resets metadata (the buffer keeps its stale bytes — exactly the
    resident engine's behavior), expiry resets metadata under a bound.
    A gather returns the buffer where the stamp is live, zeros
    elsewhere — what the jitted round body actually consumes."""

    def __init__(self, template, n, bound=None):
        self.rows = {k: np.zeros((n,) + v.shape, v.dtype)
                     for k, v in template.items()}
        self.stamp = np.full(n, -1, np.int64)
        self.n = n
        self.bound = bound

    def apply(self, idx, write, clear, stamps, block, rnd):
        for k in range(len(idx)):
            cid = int(idx[k])
            if cid >= self.n:
                continue
            if write[k]:
                for name in self.rows:
                    self.rows[name][cid] = block[name][k]
                self.stamp[cid] = int(stamps[k])
            elif clear[k]:
                self.stamp[cid] = -1
        if self.bound is not None:
            self.stamp[(rnd - self.stamp > self.bound)
                       & (self.stamp >= 0)] = -1

    def gather(self, idx):
        out = {name: np.zeros((len(idx),) + buf.shape[1:], buf.dtype)
               for name, buf in self.rows.items()}
        for k, cid in enumerate(idx):
            cid = int(cid)
            if cid < self.n and self.stamp[cid] >= 0:
                for name in self.rows:
                    out[name][k] = self.rows[name][cid]
        return out


@st.composite
def _round_sequences(draw):
    n = draw(st.integers(2, 24))
    x = draw(st.integers(1, min(n, 8)))
    dim = draw(st.integers(1, 4))
    bound = draw(st.one_of(st.none(), st.integers(1, 4)))
    n_rounds = draw(st.integers(1, 6))
    rounds = []
    for r in range(n_rounds):
        ids = draw(st.lists(st.integers(0, n - 1), min_size=0,
                            max_size=x, unique=True))
        idx = np.full(x, n, np.int64)          # sentinel padding
        idx[:len(ids)] = sorted(ids)
        write = np.zeros(x, bool)
        clear = np.zeros(x, bool)
        for k in range(len(ids)):
            op = draw(st.sampled_from(["write", "clear", "none"]))
            write[k] = op == "write"
            clear[k] = op == "clear"
        stamps = np.array([draw(st.integers(0, r)) for _ in range(x)],
                          np.int64)
        seed = draw(st.integers(0, 2 ** 16))
        probe = draw(st.lists(st.integers(0, n), min_size=1,
                              max_size=6))   # n itself = sentinel probe
        rounds.append((idx, write, clear, stamps, seed, probe))
    return n, x, dim, bound, rounds


@given(_round_sequences())
def test_store_roundtrip_matches_resident_reference(case):
    """evict→fetch parity: any select/write/clear sequence leaves the
    sparse store and the dense resident reference gather-identical,
    sentinel rows and staleness expiry included."""
    n, x, dim, bound, rounds = case
    template = _template(dim)
    store = HostCacheStore(template, n, staleness_bound=bound)
    ref = _ResidentReference(template, n, bound=bound)
    for rnd, (idx, write, clear, stamps, seed, probe) in enumerate(rounds):
        rng = np.random.default_rng(seed)
        block = {name: rng.normal(size=(x,) + v.shape).astype(v.dtype)
                 for name, v in template.items()}
        store.apply(idx, write, clear, stamps, block, rnd)
        ref.apply(idx, write, clear, stamps, block, rnd)
        got = store.gather(np.asarray(probe))
        want = ref.gather(np.asarray(probe))
        for name in template:
            np.testing.assert_array_equal(got[name], want[name],
                                          err_msg=f"round {rnd} {name}")
    # live-row accounting: writes/clears (and, under a bound, the shared
    # prune predicate) keep the sparse store and the reference's live
    # stamps in lockstep
    assert len(store) == int((ref.stamp >= 0).sum())


@given(st.integers(2, 16), st.integers(1, 6), st.integers(0, 2 ** 16))
def test_store_rows_are_owned_copies(n, dim, seed):
    """Mutating the staged block after ``apply`` never changes what a
    later ``gather`` reads — rows are copies, not views."""
    template = _template(dim)
    store = HostCacheStore(template, n)
    rng = np.random.default_rng(seed)
    block = {name: rng.normal(size=(1,) + v.shape).astype(v.dtype)
             for name, v in template.items()}
    keep = {name: v.copy() for name, v in block.items()}
    store.apply(np.array([0]), np.array([True]), np.array([False]),
                np.array([3]), block, 3)
    for v in block.values():
        v[:] = np.inf
    got = store.gather(np.array([0]))
    for name in template:
        np.testing.assert_array_equal(got[name][0], keep[name][0])
