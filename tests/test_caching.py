"""C3 unit tests: local model caching."""
import jax.numpy as jnp
import numpy as np

from repro.core import (adaptive_cache_interval, clear_cache, has_cache,
                        init_caches, resume_params, staleness, write_cache)


def _caches(n=4):
    return init_caches({"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}, n)


def test_init_empty():
    c = _caches()
    assert not bool(has_cache(c).any())
    assert bool((staleness(c, 5) > 1e5).all())


def test_rolling_write_keeps_latest_only():
    c = _caches()
    mask = jnp.array([True, False, False, False])
    p1 = {"w": jnp.ones((4, 2, 2)), "b": jnp.ones((4, 2))}
    p2 = {"w": 2 * jnp.ones((4, 2, 2)), "b": 2 * jnp.ones((4, 2))}
    c = write_cache(c, mask, p1, jnp.full((4,), 0.5), 3)
    c = write_cache(c, mask, p2, jnp.full((4,), 0.75), 7)
    np.testing.assert_allclose(c.params["w"][0], 2.0)   # latest wins
    assert int(c.round_stamp[0]) == 7
    assert float(c.progress[0]) == 0.75
    # unmasked untouched
    np.testing.assert_allclose(c.params["w"][1], 0.0)
    assert int(c.round_stamp[1]) == -1


def test_staleness_counts_rounds():
    c = _caches()
    c = write_cache(c, jnp.array([True, True, False, False]),
                    {"w": jnp.ones((4, 2, 2)), "b": jnp.ones((4, 2))},
                    jnp.full((4,), 0.5), 3)
    s = staleness(c, 10)
    np.testing.assert_allclose(s[:2], 7.0)
    assert float(s[2]) > 1e5


def test_clear_on_upload():
    c = _caches()
    mask = jnp.array([True, True, False, False])
    c = write_cache(c, mask, {"w": jnp.ones((4, 2, 2)),
                              "b": jnp.ones((4, 2))},
                    jnp.full((4,), 0.5), 1)
    c = clear_cache(c, jnp.array([True, False, False, False]))
    assert not bool(has_cache(c)[0])
    assert bool(has_cache(c)[1])


def test_resume_picks_cache_or_global():
    c = _caches()
    stacked = {"w": 5 * jnp.ones((4, 2, 2)), "b": 5 * jnp.ones((4, 2))}
    c = write_cache(c, jnp.ones((4,), bool), stacked,
                    jnp.full((4,), 0.5), 0)
    g = {"w": 9 * jnp.ones((2, 2)), "b": 9 * jnp.ones((2,))}
    start = resume_params(c, g, jnp.array([True, False, True, False]))
    np.testing.assert_allclose(start["w"][0], 5.0)
    np.testing.assert_allclose(start["w"][1], 9.0)


def test_adaptive_frequency_direction():
    """Paper §4.2: low battery / flaky network ⇒ cache MORE often."""
    lo = adaptive_cache_interval(60.0, jnp.array([0.2]), jnp.array([0.3]))
    hi = adaptive_cache_interval(60.0, jnp.array([1.0]), jnp.array([1.0]))
    assert float(lo[0]) < float(hi[0])
    assert 25.0 <= float(lo[0]) <= 60.0       # ~30s around a 60s base
    assert float(hi[0]) <= 300.0              # capped at 5 min
