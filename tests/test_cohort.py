"""Compact selected-cohort round path (``FLConfig.cohort_size``).

Covers, on a single device (the sharded variant is the slow subprocess
test at the bottom):

* static validation of the cohort contract — config shape checks, the
  policy selection-bound check (names the policy), host-side dynamics
  rejection;
* ``cohort_index`` / ``cohort_overflow`` semantics (ascending ids,
  sentinel padding, truncation);
* gather→update→scatter round trips against the full-fleet cache ops
  (seeded-random sweeps here; the hypothesis versions live in
  ``test_cohort_properties.py``);
* compact-vs-full golden parity: every registered policy, pad-exercising
  cohorts, pipelined depths — bit-identical ``History``;
* zero new per-round host→device transfers, and ``server_step_memory``
  reporting the active (X, D) packed buffer;
* runtime overflow detection deferred through the round ledger.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.configs.base import FLConfig
from repro.data.synthetic import federated_classification
from repro.fl import FleetEngine, SimConfig, available_policies
from repro.fl.api import cohort_index, cohort_overflow
from repro.fl.policies import MifaPolicy

N = 32
SIM = SimConfig(num_clients=N, rounds=3, local_steps=2, batch_size=8,
                seed=3)
FL = FLConfig(num_clients=N, clients_per_round=8, dynamics="markov")


@pytest.fixture(scope="module")
def data():
    return federated_classification(N, seed=4, n_per_client=16)


def _run(data, fl, policy, **kw):
    return FleetEngine(data, SIM, fl).run(policy, diagnostics=False, **kw)


def _assert_hist_equal(a, b, ctx=""):
    """Bitwise History equality — the compact path's exactness contract."""
    for f in ("acc", "comm_mb", "wall_clock", "received", "selected"):
        assert getattr(a, f) == getattr(b, f), (ctx, f)


# ---------------------------------------------------------------------------
# Static validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [0, -3, True, 2.0])
def test_cohort_size_rejects_non_positive_int(bad):
    with pytest.raises(ValueError, match="cohort_size"):
        FLConfig(num_clients=N, cohort_size=bad)


def test_cohort_size_rejects_larger_than_fleet():
    with pytest.raises(ValueError, match="exceeds num_clients"):
        FLConfig(num_clients=N, cohort_size=2 * N)


def test_cohort_size_rejects_mesh_indivisible():
    with pytest.raises(ValueError, match="divisible"):
        FLConfig(num_clients=N, cohort_size=12, mesh_shape=(8,))
    # divisible is fine
    FLConfig(num_clients=N, cohort_size=16, mesh_shape=(8,))


def test_cohort_rejects_host_side_dynamics(data):
    fl = dataclasses.replace(FL, dynamics="bernoulli_host", cohort_size=8)
    with pytest.raises(ValueError, match="bernoulli_host"):
        FleetEngine(data, SIM, fl)


def test_cohort_smaller_than_policy_bound_rejected(data):
    """Select-all policies (bound = N) must not run under a small cohort —
    the error names the policy and the bound."""
    fl = dataclasses.replace(FL, cohort_size=8)
    with pytest.raises(ValueError, match=r"'mifa'.*32"):
        _run(data, fl, "mifa")


# ---------------------------------------------------------------------------
# cohort_index / cohort_overflow semantics
# ---------------------------------------------------------------------------

def test_cohort_index_ascending_with_sentinel_padding():
    sel = np.zeros(N, bool)
    sel[[3, 17, 5]] = True
    idx = np.asarray(cohort_index(sel, 6))
    assert idx.tolist() == [3, 5, 17, N, N, N]
    assert not bool(cohort_overflow(sel, 6))
    assert not bool(cohort_overflow(sel, 3))


def test_cohort_index_truncates_and_flags_overflow():
    sel = np.zeros(N, bool)
    sel[[1, 2, 8, 30]] = True
    idx = np.asarray(cohort_index(sel, 3))
    assert idx.tolist() == [1, 2, 8]        # lowest ids win
    assert bool(cohort_overflow(sel, 3))


# ---------------------------------------------------------------------------
# Gather / scatter round trips vs the full-fleet cache ops (seeded sweep)
# ---------------------------------------------------------------------------

def _rand_caches(rng, n):
    params = {"w": jnp.asarray(rng.randn(n, 3, 2), jnp.float32),
              "b": jnp.asarray(rng.randn(n, 4), jnp.float32)}
    return core.ClientCaches(
        params,
        jnp.asarray(rng.rand(n), jnp.float32),
        jnp.asarray(rng.randint(-1, 5, n), jnp.int32))


def _scatter_full(rng, idx, mask_x, n, shape):
    """(N,)-shaped array whose cohort rows hold given (X,)-leading values
    (rows outside the write mask hold junk — the full-path ops must not
    read them)."""
    vals = jnp.asarray(rng.randn(*((len(idx),) + shape)), jnp.float32)
    full = jnp.asarray(rng.randn(*((n,) + shape)), jnp.float32)
    target = jnp.where(mask_x, idx, n)
    return vals, full.at[target].set(vals, mode="drop")


@pytest.mark.parametrize("seed", range(5))
def test_gather_scatter_matches_full_cache_ops(seed):
    rng = np.random.RandomState(seed)
    n = 24
    x = int(rng.randint(2, n + 1))
    sel = rng.rand(n) < rng.rand()
    while sel.sum() > x:
        sel[np.flatnonzero(sel)[-1]] = False
    idx = cohort_index(sel, x)
    caches = _rand_caches(rng, n)

    # gather: real rows match, pad rows read as empty slots
    g = core.gather_caches(caches, idx)
    ids = np.flatnonzero(sel)
    k = len(ids)
    for key in ("w", "b"):
        np.testing.assert_array_equal(np.asarray(g.params[key])[:k],
                                      np.asarray(caches.params[key])[ids])
        assert not np.asarray(g.params[key])[k:].any()
    np.testing.assert_array_equal(np.asarray(g.progress)[:k],
                                  np.asarray(caches.progress)[ids])
    assert (np.asarray(g.progress)[k:] == 0.0).all()
    assert (np.asarray(g.round_stamp)[k:] == -1).all()

    # scatter-write == full write_cache on the equivalent (N,) mask
    mask_x = jnp.asarray((rng.rand(x) < 0.6) & (np.asarray(idx) < n))
    w_x, w_n = _scatter_full(rng, idx, mask_x, n, (3, 2))
    b_x, b_n = _scatter_full(rng, idx, mask_x, n, (4,))
    p_x, p_n = _scatter_full(rng, idx, mask_x, n, ())
    mask_n = jnp.zeros(n, bool).at[jnp.where(mask_x, idx, n)].set(
        True, mode="drop")
    got = core.scatter_write_cache(caches, idx, mask_x,
                                   {"w": w_x, "b": b_x}, p_x, 7)
    want = core.write_cache(caches, mask_n, {"w": w_n, "b": b_n}, p_n, 7)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        got, want)

    # scatter-clear == full clear_cache
    got_c = core.scatter_clear_cache(caches, idx, mask_x)
    want_c = core.clear_cache(caches, mask_n)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        got_c, want_c)


# ---------------------------------------------------------------------------
# Compact-vs-full golden parity (single device, bit-identical)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", sorted(available_policies()))
def test_policy_parity_compact_vs_full(policy, data):
    """Every registered policy: the compact path replays the full-scan
    History bit for bit (accuracy, comm, wall clock, counts)."""
    bounded = policy not in ("mifa", "asyncfeded")
    x = 8 if bounded else N
    full = _run(data, FL, policy)
    compact = _run(data, dataclasses.replace(FL, cohort_size=x), policy)
    _assert_hist_equal(full, compact, policy)


def test_parity_with_padded_cohort(data):
    """X strictly larger than any selection: sentinel rows ride through
    training, cut, aggregation and all scatters without a trace."""
    full = _run(data, FL, "flude")
    for x in (12, N):
        compact = _run(data, dataclasses.replace(FL, cohort_size=x),
                       "flude")
        _assert_hist_equal(full, compact, f"X={x}")


def test_parity_across_dynamics(data):
    for dyn in ("bernoulli", "sessions"):
        fl = dataclasses.replace(FL, dynamics=dyn)
        full = _run(data, fl, "flude")
        compact = _run(data, dataclasses.replace(fl, cohort_size=8),
                       "flude")
        _assert_hist_equal(full, compact, dyn)


def test_parity_pipelined(data):
    """Pipelining interacts only with scheduling: depth 1 == depth 4 on
    the compact path, both equal to the full scan."""
    full = _run(data, FL, "flude")
    for depth in (1, 4):
        fl = dataclasses.replace(FL, cohort_size=8, pipeline_depth=depth)
        _assert_hist_equal(full, _run(data, fl, "flude"), f"depth={depth}")


# ---------------------------------------------------------------------------
# Host transfers and memory profile
# ---------------------------------------------------------------------------

def test_cohort_adds_no_per_round_transfers(data, monkeypatch):
    """The cohort index is derived on device from the selection mask —
    per-round ``place_per_client`` hand-offs stay round-count-independent
    and identical to the full-scan path."""
    import repro.fl.engine as ENG
    import repro.fl.policies as POL
    import repro.fl.simulator as SIMM

    counts = {"n": 0}
    orig = SIMM.place_per_client

    def counting(arr, mesh=None):
        counts["n"] += 1
        return orig(arr, mesh)

    for mod in (ENG, POL, SIMM):
        monkeypatch.setattr(mod, "place_per_client", counting)

    per_path = {}
    for label, fl in (("full", FL),
                      ("cohort", dataclasses.replace(FL, cohort_size=8))):
        engine = FleetEngine(data, SIM, fl)
        engine.run("flude", diagnostics=False)      # compile + place
        per_run = []
        for rounds in (1, 3):
            counts["n"] = 0
            engine.run("flude", rounds=rounds, diagnostics=False)
            per_run.append(counts["n"])
        assert per_run[0] == per_run[1], (label, per_run)
        per_path[label] = per_run[0]
    assert per_path["cohort"] == per_path["full"], per_path


def test_server_step_memory_reports_packed_cohort_buffer(data):
    """The memory profile describes the *active* step: with a cohort the
    packed aggregation buffer is (X, D), not (N, D)."""
    x = 8
    full = FleetEngine(data, SIM, FL)
    compact = FleetEngine(data, SIM,
                          dataclasses.replace(FL, cohort_size=x))
    dim = core.pack_layout(full._template).dim
    mf = full.server_step_memory()
    mc = compact.server_step_memory()
    assert mf["packed_rows"] == N
    assert mf["packed_buffer_bytes"] == N * dim * 4
    assert mc["packed_rows"] == x
    assert mc["packed_buffer_bytes"] == x * dim * 4
    assert mc["peak_live_bytes"] < mf["peak_live_bytes"]


# ---------------------------------------------------------------------------
# Runtime overflow (deferred through the round ledger)
# ---------------------------------------------------------------------------

class _LyingMifa(MifaPolicy):
    """Claims the bounded-selection trait while selecting every online
    client — defeats the static bound check so the *runtime* overflow
    flag has to catch the truncation."""
    selects_at_most_clients_per_round = True


def test_runtime_overflow_raises(data):
    fl = dataclasses.replace(FL, cohort_size=8)
    engine = FleetEngine(data, SIM, fl)
    pol = _LyingMifa(SIM, fl, mesh=engine.mesh)
    with pytest.raises(RuntimeError, match="cohort overflow"):
        engine.run(pol, diagnostics=False)


# ---------------------------------------------------------------------------
# Sharded (8 forced host devices) compact round path
# ---------------------------------------------------------------------------

def _run_script(script, timeout=540):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


_MESH_SCRIPT = r"""
from repro.launch.mesh import force_host_platform_device_count
force_host_platform_device_count(8)
import dataclasses
import json
import jax

from repro.configs.base import FLConfig
from repro.data.synthetic import federated_classification
from repro.fl import FleetEngine, SimConfig

n = 32
data = federated_classification(n, seed=0, n_per_client=32)
sim = SimConfig(num_clients=n, rounds=3, seed=0, local_steps=2)
out = {"n_dev": len(jax.devices()), "cases": {}}

for pol, x in (("flude", 8), ("flude", 16), ("mifa", 32)):
    fl = FLConfig(num_clients=n, clients_per_round=8, dynamics="markov",
                  mesh_shape=(8,))
    ref = FleetEngine(data, sim, fl).run(pol, diagnostics=False)
    engine = FleetEngine(data, sim,
                         dataclasses.replace(fl, cohort_size=x))
    h = engine.run(pol, diagnostics=False)
    idx = engine._last_cohort_idx
    out["cases"][f"{pol}-x{x}"] = {
        "ints_exact": (h.received == ref.received
                       and h.selected == ref.selected
                       and h.wall_clock == ref.wall_clock),
        "acc_err": float(max(abs(a - b)
                             for a, b in zip(h.acc, ref.acc))),
        "idx_shape": list(idx.shape),
        "idx_shards": len(idx.sharding.device_set),
    }
print(json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_compact_round_path():
    """Compact vs full-scan over 8 forced host devices: the integer
    trajectory (received/selected/wall clock) is exact and accuracy agrees
    to float tolerance (the sharded psum reassociates the same summands);
    the cohort index itself lives sharded over the client mesh."""
    rec = _run_script(_MESH_SCRIPT)
    assert rec["n_dev"] == 8
    for case, r in rec["cases"].items():
        assert r["ints_exact"], (case, r)
        assert r["acc_err"] < 1e-6, (case, r)
        x = int(case.split("x")[-1])
        assert r["idx_shape"] == [x], (case, r)
        assert r["idx_shards"] == 8, (case, r)
