"""Hypothesis property tests for the compact-cohort gather/scatter ops.

``test_cohort.py`` holds seeded-random sweeps of the same invariants so
coverage survives without the hypothesis dependency; this module widens
the search (arbitrary fleet sizes, masks, cohort widths, including the
truncating overflow regime) where hypothesis is available.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import core
from repro.fl.api import cohort_index, cohort_overflow

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _mask(draw_bits, n):
    return np.array([(draw_bits >> i) & 1 == 1 for i in range(n)])


@given(st.integers(2, 48), st.integers(1, 48),
       st.integers(0, 2 ** 48 - 1))
def test_cohort_index_sorted_padded_and_overflow_flag(n, x, bits):
    """The index is the ascending selected ids, truncated to the lowest
    X, padded with the sentinel N; the overflow flag fires iff the
    selection count exceeds X."""
    x = min(x, n)
    sel = _mask(bits, n)
    idx = np.asarray(cohort_index(sel, x))
    ids = np.flatnonzero(sel)
    k = min(len(ids), x)
    assert idx.shape == (x,)
    assert idx[:k].tolist() == ids[:k].tolist()
    assert (idx[k:] == n).all()
    assert bool(cohort_overflow(sel, x)) == (len(ids) > x)


@given(st.integers(2, 32), st.integers(1, 32),
       st.integers(0, 2 ** 32 - 1), st.integers(0, 2 ** 31 - 1))
def test_gather_scatter_roundtrip_equals_full_ops(n, x, bits, seed):
    """gather → masked update → scatter equals the full-fleet
    write_cache/clear_cache for any mask that is zero outside the cohort
    (which every engine write mask is: writes require selection)."""
    x = min(x, n)
    sel = _mask(bits, n)
    ids = np.flatnonzero(sel)[:x]          # cohort truncates to lowest X
    rng = np.random.RandomState(seed)
    idx = cohort_index(sel, x)

    caches = core.ClientCaches(
        {"w": jnp.asarray(rng.randn(n, 2, 3), jnp.float32)},
        jnp.asarray(rng.rand(n), jnp.float32),
        jnp.asarray(rng.randint(-1, 4, n), jnp.int32))

    g = core.gather_caches(caches, idx)
    k = len(ids)
    np.testing.assert_array_equal(np.asarray(g.params["w"])[:k],
                                  np.asarray(caches.params["w"])[ids])
    assert not np.asarray(g.params["w"])[k:].any()
    assert (np.asarray(g.round_stamp)[k:] == -1).all()

    mask_x = jnp.asarray((rng.rand(x) < 0.5) & (np.asarray(idx) < n))
    target = jnp.where(mask_x, idx, n)
    mask_n = jnp.zeros(n, bool).at[target].set(True, mode="drop")
    w_x = jnp.asarray(rng.randn(x, 2, 3), jnp.float32)
    w_n = jnp.asarray(rng.randn(n, 2, 3), jnp.float32) \
        .at[target].set(w_x, mode="drop")
    p_x = jnp.asarray(rng.rand(x), jnp.float32)
    p_n = jnp.asarray(rng.rand(n), jnp.float32) \
        .at[target].set(p_x, mode="drop")

    got = core.scatter_write_cache(caches, idx, mask_x, {"w": w_x},
                                   p_x, 5)
    want = core.write_cache(caches, mask_n, {"w": w_n}, p_n, 5)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        got, want)

    got_c = core.scatter_clear_cache(caches, idx, mask_x)
    want_c = core.clear_cache(caches, mask_n)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        got_c, want_c)
