"""Decode-vs-full-forward consistency: prefill + step == teacher forcing.

For every cached-decode family: run the full forward on a prompt, then
prefill the prompt and decode the next token — the decode logits must match
the forward logits at the last position.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ExecConfig, build_model

DECODE_ARCHS = ["qwen2-7b", "h2o-danube-1.8b", "mixtral-8x7b",
                "deepseek-v2-236b", "zamba2-1.2b", "rwkv6-7b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 32
    rng = jax.random.key(1)
    tokens = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)

    # teacher-forced forward over S+1 tokens
    ecfg = ExecConfig(attn_impl="dense")
    full = model.logits(params, {"tokens": tokens}, ecfg)      # (B,S+1,V)

    # prefill on S tokens (with one slot of decode headroom), then decode
    _, cache = model.prefill(params, {"tokens": tokens[:, :S]}, ecfg,
                             max_len=S + 1)
    pos = jnp.full((B, 1), S, jnp.int32)
    step_logits, _ = model.decode_step(params, tokens[:, S:S + 1], pos,
                                       cache)
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0]), np.asarray(full[:, S]),
        rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["qwen2-7b", "rwkv6-7b", "zamba2-1.2b"])
def test_multistep_decode_matches_forward(arch):
    """Decode 4 consecutive tokens; each must match teacher forcing."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S, K = 1, 16, 4
    tokens = jax.random.randint(jax.random.key(2), (B, S + K), 0,
                                cfg.vocab_size)
    ecfg = ExecConfig(attn_impl="dense")
    full = model.logits(params, {"tokens": tokens}, ecfg)

    _, cache = model.prefill(params, {"tokens": tokens[:, :S]}, ecfg,
                             max_len=S + K)
    for k in range(K):
        pos = jnp.full((B, 1), S + k, jnp.int32)
        lg, cache = model.decode_step(params, tokens[:, S + k:S + k + 1],
                                      pos, cache)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full[:, S + k]),
            rtol=3e-2, atol=3e-2)


def test_swa_ring_cache_decode():
    """SWA archs decode correctly once the ring cache wraps."""
    cfg = get_config("h2o-danube-1.8b").reduced()   # window 16
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 1, 24                                    # prompt exceeds window
    tokens = jax.random.randint(jax.random.key(3), (B, S + 2), 0,
                                cfg.vocab_size)
    ecfg = ExecConfig(attn_impl="dense")
    full = model.logits(params, {"tokens": tokens}, ecfg)
    _, cache = model.prefill(params, {"tokens": tokens[:, :S]}, ecfg)
    lg, cache = model.decode_step(
        params, tokens[:, S:S + 1], jnp.full((B, 1), S, jnp.int32), cache)
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, S]),
                               rtol=3e-2, atol=3e-2)
    lg2, _ = model.decode_step(
        params, tokens[:, S + 1:S + 2], jnp.full((B, 1), S + 1, jnp.int32),
        cache)
    np.testing.assert_allclose(np.asarray(lg2[:, 0]),
                               np.asarray(full[:, S + 1]),
                               rtol=3e-2, atol=3e-2)


def test_whisper_decode_matches_teacher_forcing():
    cfg = get_config("whisper-large-v3").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, Senc = 2, 32
    Sdec = cfg.encdec.max_target_len
    frames = jax.random.normal(jax.random.key(1), (B, Senc, cfg.d_model))
    dec = jax.random.randint(jax.random.key(2), (B, Sdec), 0,
                             cfg.vocab_size)
    ecfg = ExecConfig(attn_impl="dense")
    full = model.logits(params, {"frames": frames, "dec_tokens": dec},
                        ecfg)
    _, cache = model.prefill(params, {"frames": frames}, ecfg)
    for k in range(3):
        pos = jnp.full((B, 1), k, jnp.int32)
        lg, cache = model.decode_step(params, dec[:, k:k + 1], pos, cache)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, k]),
                                   rtol=3e-2, atol=3e-2)
