"""C1 unit tests: Beta-posterior dependability assessment (Eq. 1)."""
import jax.numpy as jnp
import numpy as np

from repro.core import (dependability, init_belief, update_belief, variance)


def test_neutral_prior():
    b = init_belief(8)
    np.testing.assert_allclose(dependability(b), 0.5)


def test_eq1_update_matches_paper():
    """α_new = α + s, β_new = β + f, E[R] = α_new / (α_new + β_new)."""
    b = init_belief(3, alpha0=2.0, beta0=2.0)
    s = jnp.array([3, 0, 1])
    f = jnp.array([0, 4, 1])
    b2 = update_belief(b, s, f)
    np.testing.assert_allclose(b2.alpha, [5, 2, 3])
    np.testing.assert_allclose(b2.beta, [2, 6, 3])
    np.testing.assert_allclose(dependability(b2),
                               [5 / 7, 2 / 8, 3 / 6])


def test_successes_raise_failures_lower():
    b = init_belief(1)
    up = update_belief(b, jnp.array([5]), jnp.array([0]))
    dn = update_belief(b, jnp.array([0]), jnp.array([5]))
    assert float(dependability(up)[0]) > 0.5 > float(dependability(dn)[0])


def test_variance_shrinks_with_evidence():
    b = init_belief(1)
    b2 = update_belief(b, jnp.array([10]), jnp.array([10]))
    assert float(variance(b2)[0]) < float(variance(b)[0])


def test_convergence_to_true_rate():
    """After many observations the posterior mean approaches s/(s+f)."""
    b = init_belief(1)
    b2 = update_belief(b, jnp.array([700]), jnp.array([300]))
    assert abs(float(dependability(b2)[0]) - 0.7) < 0.01
