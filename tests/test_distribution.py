"""C4 unit tests: staleness-aware distribution (Eq. 4)."""
import jax.numpy as jnp
import numpy as np

from repro.core import (DistributorState, init_distributor,
                        plan_distribution, predicted_comm_cost)

KW = dict(lam=1.0, mu=0.5, w_min=1.0, w_max=50.0)


def test_u_devices_always_receive():
    st = init_distributor(3.0)
    sel = jnp.array([True, True, True, False])
    in_v = jnp.array([False, False, True, False])
    cache = jnp.array([False, False, True, False])
    stale = jnp.array([0.0, 0.0, 1.0, 0.0])
    plan = plan_distribution(st, sel, in_v, cache, stale, **KW)
    # devices in U (not in V) that are selected must get the model
    assert bool(plan.distribute[0]) and bool(plan.distribute[1])
    # fresh-cached V device resumes (staleness 1 < W)
    assert bool(plan.resume[2]) and not bool(plan.distribute[2])
    assert not bool(plan.distribute[3])     # unselected gets nothing


def test_overly_stale_cache_forces_distribution():
    st = DistributorState(jnp.float32(3.0), jnp.float32(5.0),
                          jnp.float32(2.0))
    sel = jnp.array([True, True])
    in_v = jnp.array([True, True])
    cache = jnp.array([True, True])
    stale = jnp.array([1.0, 40.0])
    plan = plan_distribution(st, sel, in_v, cache, stale, **KW)
    assert bool(plan.resume[0])
    assert bool(plan.distribute[1])         # 40 rounds stale ⇒ refresh


def test_eq4_staleness_pressure_lowers_threshold():
    """H_new > H_old ⇒ W' shrinks (more refreshes)."""
    st = DistributorState(jnp.float32(10.0), jnp.float32(2.0),
                          jnp.float32(1.0))
    sel = jnp.ones((4,), bool)
    in_v = jnp.ones((4,), bool)
    cache = jnp.ones((4,), bool)
    stale = jnp.full((4,), 8.0)             # H_new = 8 > H_old = 2
    plan = plan_distribution(st, sel, in_v, cache, stale, **KW)
    assert float(plan.state.w_threshold) < 10.0


def test_eq4_comm_pressure_raises_threshold():
    """N_new > N_old ⇒ W grows back (fewer distributions)."""
    st = DistributorState(jnp.float32(5.0), jnp.float32(6.0),
                          jnp.float32(1.0))
    sel = jnp.ones((6,), bool)
    in_v = jnp.ones((6,), bool)
    cache = jnp.ones((6,), bool)
    stale = jnp.array([6.0, 6.0, 6.0, 6.0, 6.0, 6.0])
    plan = plan_distribution(st, sel, in_v, cache, stale, **KW)
    w_prime = 5.0 * (1.0 - 1.0 * (6.0 - 6.0) / 6.0)     # = 5.0
    n_new = float((stale > w_prime).sum())               # = 6
    expect = w_prime * (1.0 + 0.5 * (n_new - 1.0) / 1.0)
    np.testing.assert_allclose(float(plan.state.w_threshold),
                               min(expect, 50.0), rtol=1e-5)


def test_threshold_clipped():
    st = DistributorState(jnp.float32(2.0), jnp.float32(1.0),
                          jnp.float32(1.0))
    sel = jnp.ones((2,), bool)
    stale = jnp.array([500.0, 500.0])
    plan = plan_distribution(st, sel, jnp.ones((2,), bool),
                             jnp.ones((2,), bool), stale, **KW)
    assert 1.0 <= float(plan.state.w_threshold) <= 50.0


def test_full_and_least_modes():
    st = init_distributor()
    sel = jnp.array([True, True, True])
    in_v = jnp.array([False, True, True])
    cache = jnp.array([False, True, True])
    stale = jnp.array([0.0, 2.0, 30.0])
    full = plan_distribution(st, sel, in_v, cache, stale, mode="full", **KW)
    assert bool(full.distribute.all()) and not bool(full.resume.any())
    least = plan_distribution(st, sel, in_v, cache, stale, mode="least",
                              **KW)
    assert bool(least.resume[1]) and bool(least.resume[2])
    assert bool(least.distribute[0])


def test_predicted_cost_alg2():
    """B_pred = |S_distr| + |S| · R̄ (Algorithm 2 line 11)."""
    dist = jnp.array([True, True, False, False])
    sel = jnp.array([True, True, True, True])
    np.testing.assert_allclose(
        float(predicted_comm_cost(dist, sel, jnp.float32(0.75))),
        2 + 4 * 0.75)
