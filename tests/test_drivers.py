"""Integration tests for the launch drivers (train / serve), tiny configs."""
import sys

import jax.numpy as jnp
import numpy as np
import pytest


def test_train_driver_runs_and_learns(monkeypatch, tmp_path, capsys):
    from repro.launch import train
    ckpt = str(tmp_path / "ckpt.msgpack")
    monkeypatch.setattr(sys, "argv", [
        "train", "--rounds", "12", "--silos", "4", "--batch-per-silo", "2",
        "--seq-len", "32", "--undep", "0.3", "--log-every", "4",
        "--ckpt", ckpt])
    state = train.main()
    out = capsys.readouterr().out
    assert "round" in out and "checkpoint saved" in out
    losses = [float(l.split("loss ")[1].split()[0])
              for l in out.splitlines() if l.startswith("round")]
    assert all(np.isfinite(losses))
    # checkpoint round-trips
    from repro.checkpoint.checkpointer import restore_like
    back = restore_like(ckpt, state.params)
    import jax
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(back)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_serve_driver_runs(monkeypatch, capsys):
    from repro.launch import serve
    monkeypatch.setattr(sys, "argv", [
        "serve", "--arch", "flude-paper", "--batch", "2",
        "--prompt-len", "16", "--decode-tokens", "4"])
    serve.main()
    out = capsys.readouterr().out
    assert "prefill:" in out and "decode:" in out
    assert "sampled ids" in out


@pytest.mark.parametrize("arch", ["zamba2-1.2b", "rwkv6-7b"])
def test_serve_driver_stateful_archs(monkeypatch, capsys, arch):
    from repro.launch import serve
    monkeypatch.setattr(sys, "argv", [
        "serve", "--arch", arch, "--reduced", "--batch", "2",
        "--prompt-len", "16", "--decode-tokens", "3"])
    serve.main()
    assert "decode:" in capsys.readouterr().out
