"""Fleet-dynamics subsystem: process registry, process statistics
(property tests), trace replay/generation, scenarios, and the
no-per-round-host-transfer guarantee of the device round path.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.data.synthetic import federated_classification
from repro.fl import FleetEngine, Policy, SimConfig, register_policy
from repro.fl import api as API
from repro.fleet import (FleetFeatures, MarkovProcess, SessionsProcess,
                         TraceProcess, apply_scenario,
                         availability_summary, available_dynamics,
                         available_scenarios, get_dynamics, get_scenario,
                         make_dynamics, register_dynamics,
                         simulate_availability, synthesize_trace)
from repro.fleet.api import DynamicsProcess

DEVICE_PROCESSES = ("bernoulli", "markov", "sessions", "trace")


def _features(n, online_rate=0.5, undep=0.3, seed=0):
    """Hand-built population (no Fleet) for statistical process tests."""
    rng = np.random.RandomState(seed)
    r = np.full(n, online_rate, np.float32) if np.isscalar(online_rate) \
        else np.asarray(online_rate, np.float32)
    return FleetFeatures(
        undep=jnp.full((n,), undep, jnp.float32),
        online_rate=jnp.asarray(r),
        steps_per_sec=jnp.asarray(rng.uniform(0.5, 2.0, n)
                                  .astype(np.float32)),
        bandwidth=jnp.asarray(rng.uniform(1.0, 30.0, n)
                              .astype(np.float32)),
        battery=jnp.asarray(rng.uniform(0.2, 1.0, n).astype(np.float32)),
        stability=jnp.asarray(rng.uniform(0.3, 1.0, n)
                              .astype(np.float32)))


def _setup(n=16, rounds=3, dynamics=None, **fl_kw):
    data = federated_classification(n, seed=0, n_per_client=32)
    sim = SimConfig(num_clients=n, rounds=rounds, seed=0, local_steps=2)
    fl = FLConfig(num_clients=n, clients_per_round=8,
                  **({"dynamics": dynamics} if dynamics else {}), **fl_kw)
    return data, sim, fl


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_has_builtin_processes():
    assert {"bernoulli_host", *DEVICE_PROCESSES} <= set(
        available_dynamics())
    assert get_dynamics("bernoulli_host").host_side
    for name in DEVICE_PROCESSES:
        assert not get_dynamics(name).host_side


def test_registry_unknown_and_duplicates():
    with pytest.raises(KeyError, match="unknown dynamics 'nope'"):
        get_dynamics("nope")

    @register_dynamics("_test_dyn")
    class Dummy(DynamicsProcess):
        pass
    try:
        assert get_dynamics("_test_dyn") is Dummy
        with pytest.raises(ValueError, match="already registered"):
            @register_dynamics("_test_dyn")
            class Dummy2(DynamicsProcess):
                pass
        with pytest.raises(TypeError):
            register_dynamics("_test_fn2")(lambda: None)
    finally:
        from repro.fleet import api as FAPI
        FAPI._REGISTRY.pop("_test_dyn", None)


def test_unknown_dynamics_rejected_at_config_construction():
    _data, _sim, fl = _setup()
    # __post_init__ name-validates the registry axis, so a bad name
    # never reaches the engine (dataclasses.replace re-runs it)
    with pytest.raises(ValueError, match="dynamics"):
        dataclasses.replace(fl, dynamics="nope")


# ---------------------------------------------------------------------------
# Legacy equivalence + device processes run the full round path
# ---------------------------------------------------------------------------

def test_bernoulli_host_explicit_matches_default():
    """Default config and an explicit bernoulli_host run are the same
    legacy path — identical History."""
    data, sim, fl = _setup()
    ref = FleetEngine(data, sim, fl).run("flude", diagnostics=False)
    fl_h = dataclasses.replace(fl, dynamics="bernoulli_host")
    h = FleetEngine(data, sim, fl_h).run("flude", diagnostics=False)
    assert h.acc == ref.acc
    assert h.received == ref.received and h.selected == ref.selected
    assert h.wall_clock == ref.wall_clock and h.comm_mb == ref.comm_mb


@pytest.mark.parametrize("dynamics", DEVICE_PROCESSES)
def test_device_process_runs_full_round_path(dynamics):
    data, sim, fl = _setup(dynamics=dynamics)
    engine = FleetEngine(data, sim, fl)
    h1 = engine.run("flude", diagnostics=False)
    h2 = engine.run("flude", diagnostics=False)     # reproducible per run
    assert len(h1.acc) == 3
    assert h1.acc == h2.acc and h1.received == h2.received
    assert all(r <= s for r, s in zip(h1.received, h1.selected))
    assert all(np.isfinite(h1.wall_clock))
    # the fleet process state stays device-resident between runs
    assert engine._last_fleet_state is not None
    assert engine._last_draw.online.shape == (16,)


def test_observation_carries_device_draw():
    seen = {}

    @register_policy("_test_draw_probe")
    class Probe(Policy):
        def plan(self, state, obs, rng):
            seen["draw"] = obs.draw
            n = self.fl_cfg.num_clients
            sel = np.asarray(obs.online).copy()
            from repro.fl.api import RoundPlan
            return state, RoundPlan.create(
                sel, sel, np.zeros(n, bool), float(max(sel.sum(), 0)))
    try:
        data, sim, fl = _setup(rounds=1, dynamics="markov")
        FleetEngine(data, sim, fl).run("_test_draw_probe",
                                       diagnostics=False)
        assert seen["draw"] is not None
        assert isinstance(seen["draw"].online, jax.Array)
        data, sim, fl = _setup(rounds=1)
        FleetEngine(data, sim, fl).run("_test_draw_probe",
                                       diagnostics=False)
        assert seen["draw"] is None          # legacy path: no device draw
    finally:
        API._REGISTRY.pop("_test_draw_probe", None)


# ---------------------------------------------------------------------------
# Process statistics (property tests)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mean_on,rate", [(4.0, 0.5), (6.0, 0.3),
                                          (5.0, 0.7)])
def test_markov_empirical_availability_matches_stationary(mean_on, rate):
    """Long-run per-device availability of the markov chain matches its
    analytic stationary distribution (which equals online_rate when the
    transition rates are unclipped)."""
    n, T = 256, 1200
    proc = MarkovProcess(SimConfig(num_clients=n),
                         features=_features(n, online_rate=rate),
                         mean_on=mean_on)
    stat = proc.stationary()
    np.testing.assert_allclose(stat, rate, atol=1e-6)
    online = simulate_availability(proc, T, seed=3)          # (T, N)
    emp = online.mean(axis=0)
    # fleet-level bias averages out; per-device error is bounded by the
    # chain's mixing time (~mean_on rounds of correlation)
    assert abs(emp.mean() - rate) < 0.02
    assert np.abs(emp - stat).mean() < 0.07


def test_markov_availability_is_persistent():
    """Sanity on the churn structure: P(online_t | online_{t-1}) ==
    1 - 1/mean_on >> stationary rate (unlike the memoryless bernoulli)."""
    n, T, mean_on = 256, 600, 6.0
    proc = MarkovProcess(SimConfig(num_clients=n),
                         features=_features(n, online_rate=0.4),
                         mean_on=mean_on)
    online = simulate_availability(proc, T, seed=5)
    prev, cur = online[:-1], online[1:]
    stay = (cur & prev).sum() / max(prev.sum(), 1)
    assert abs(stay - (1.0 - 1.0 / mean_on)) < 0.03
    assert stay > 0.6


def test_sessions_memoryless_reduces_to_bernoulli_exposure():
    """With Weibull shape k=1 the session hazard is constant, so the
    engine's exposure rule 1-(1-p)^w is *exactly* the memoryless
    session-end probability within work fraction w."""
    n, T, mean_on = 512, 300, 5.0
    proc = SessionsProcess(SimConfig(num_clients=n),
                           features=_features(n, online_rate=0.6),
                           mean_on=mean_on, shape_on=1.0, shape_gap=1.0,
                           undep_mix=0.0)
    p_analytic = 1.0 - np.exp(-1.0 / mean_on)     # λ = mean_on at k=1
    # hazard is age-independent at k=1
    for age in (0.0, 3.0, 11.0):
        assert float(proc.session_hazard(age)) == pytest.approx(
            p_analytic, abs=1e-6)
    step = jax.jit(proc.step)
    base = jax.random.key(7)
    state = proc.init_state(jax.random.fold_in(base, 1 << 16))
    hits = {w: 0 for w in (0.25, 0.5, 1.0)}
    total = 0
    for t in range(T):
        state, draw = step(state, jax.random.fold_in(base, t))
        for w in hits:
            hits[w] += int(np.asarray(
                draw.failure_mask(jnp.full((n,), w))).sum())
        total += n
    for w, h in hits.items():
        expect = 1.0 - (1.0 - p_analytic) ** w
        assert abs(h / total - expect) < 0.01, (w, h / total, expect)


def test_sessions_heavy_tail_hazard_decreases_with_age():
    """k<1 (heavy-tailed sessions): old sessions are *safer* per round —
    the non-memoryless regime the i.i.d. simulator cannot express."""
    proc = SessionsProcess(SimConfig(num_clients=8),
                           features=_features(8), mean_on=4.0,
                           shape_on=0.5)
    h0 = float(proc.session_hazard(0.0))
    h8 = float(proc.session_hazard(8.0))
    assert h0 > h8 > 0.0


def test_sessions_diurnal_modulates_availability():
    n, period = 256, 16
    proc = SessionsProcess(SimConfig(num_clients=n),
                           features=_features(n, online_rate=0.5),
                           mean_on=3.0, amp=0.8, period=float(period))
    online = simulate_availability(proc, 8 * period, seed=11)
    by_phase = online.reshape(-1, period, n).mean(axis=(0, 2))  # (period,)
    assert by_phase.max() - by_phase.min() > 0.1
    flat = SessionsProcess(SimConfig(num_clients=n),
                           features=_features(n, online_rate=0.5),
                           mean_on=3.0, amp=0.0, period=float(period))
    online_f = simulate_availability(flat, 8 * period, seed=11)
    by_phase_f = online_f.reshape(-1, period, n).mean(axis=(0, 2))
    assert by_phase_f.max() - by_phase_f.min() < \
        (by_phase.max() - by_phase.min())


# ---------------------------------------------------------------------------
# Trace replay + synthetic generator
# ---------------------------------------------------------------------------

def test_trace_replay_is_exact_and_wraps():
    n, T = 12, 7
    mat = np.random.RandomState(0).rand(n, T) < 0.5
    proc = TraceProcess(SimConfig(num_clients=n), features=_features(n),
                        trace=mat)
    online = simulate_availability(proc, 2 * T + 3, seed=0)
    expect = np.concatenate([mat, mat, mat[:, :3]], axis=1).T
    np.testing.assert_array_equal(online, expect)


def test_trace_rejects_bad_shapes():
    with pytest.raises(ValueError, match="must be"):
        TraceProcess(SimConfig(num_clients=8), features=_features(8),
                     trace=np.ones((4, 5), bool))


def test_trace_generator_patterns():
    n, T = 128, 96
    for pattern in ("diurnal", "flash-crowd", "correlated-dropout"):
        mat = synthesize_trace(n, T, pattern=pattern, seed=2)
        assert mat.shape == (n, T) and mat.dtype == bool
        assert 0.05 < mat.mean() < 0.95
    # flash-crowd: burst rounds vs sparse baseline
    fc = synthesize_trace(n, T, pattern="flash-crowd", seed=2)
    col = fc.mean(axis=0)
    assert col.max() > 0.6 and np.median(col) < 0.35
    # diurnal: availability oscillates across rounds
    di = synthesize_trace(n, T, pattern="diurnal", seed=2, amp=0.4)
    cold = di.mean(axis=0)
    assert cold.std() > 0.05
    # correlated-dropout: some round loses far more devices than the
    # independent baseline would
    cd = synthesize_trace(n, 400, pattern="correlated-dropout", seed=2,
                          event_rate=0.15)
    colc = cd.mean(axis=0)
    assert colc.min() < colc.mean() - 0.15
    with pytest.raises(ValueError, match="unknown trace pattern"):
        synthesize_trace(n, T, pattern="nope")


def test_availability_summary_counts_sessions():
    # two devices: [1,1,0,1,0], [0,1,1,1,1] -> 3 sessions, lengths 2,1,4
    mat = np.array([[1, 0], [1, 1], [0, 1], [1, 1], [0, 1]], bool)
    s = availability_summary(mat)
    assert s["num_sessions"] == 3
    assert s["mean_session_length"] == pytest.approx(7.0 / 3.0)
    assert s["mean_online_fraction"] == pytest.approx(0.7)


# ---------------------------------------------------------------------------
# Scenarios
# ---------------------------------------------------------------------------

def test_scenario_presets_resolve():
    names = available_scenarios()
    assert {"paper", "diurnal", "flash-crowd", "correlated-dropout",
            "trace-replay", "churn"} <= set(names)
    for name in names:
        sc = get_scenario(name)
        get_dynamics(sc.dynamics)           # every preset is constructible
    assert get_dynamics(get_scenario("paper").dynamics).host_side


def test_apply_scenario_sets_dynamics():
    _, _, fl = _setup()
    fl2 = apply_scenario(fl, "churn")
    assert fl2.dynamics == "markov"
    assert dict(fl2.dynamics_params)["mean_on"] == 5.0
    assert fl2.clients_per_round == fl.clients_per_round
    with pytest.raises(KeyError, match="unknown scenario"):
        apply_scenario(fl, "nope")


def test_make_dynamics_forwards_scenario_params():
    sc = get_scenario("diurnal")
    proc = make_dynamics(sc.dynamics, SimConfig(num_clients=8),
                         features=_features(8), params=sc.params)
    assert isinstance(proc, SessionsProcess)
    assert proc.amp == 0.6 and proc.period == 24.0


# ---------------------------------------------------------------------------
# The device round path never uploads per-round state
# ---------------------------------------------------------------------------

def test_device_rounds_no_per_round_place_per_client(monkeypatch):
    """Acceptance: under a device process the engine's round loop does no
    per-round ``place_per_client`` host→device hand-off — the call count
    is independent of the round count (per-run policy/constant placement
    only), and zero in the steady state."""
    import repro.fl.engine as ENG
    import repro.fl.policies as POL
    import repro.fl.simulator as SIMM

    counts = {"n": 0}
    orig = SIMM.place_per_client

    def counting(arr, mesh=None):
        counts["n"] += 1
        return orig(arr, mesh)

    for mod in (ENG, POL, SIMM):
        monkeypatch.setattr(mod, "place_per_client", counting)

    data, sim, fl = _setup(dynamics="markov")
    engine = FleetEngine(data, sim, fl)
    engine.run("flude", rounds=1, diagnostics=False)     # compile+place

    per_run = []
    for rounds in (1, 5):
        counts["n"] = 0
        engine.run("flude", rounds=rounds, diagnostics=False)
        per_run.append(counts["n"])
    assert per_run[0] == per_run[1], per_run     # independent of rounds
    assert per_run[1] <= 2, per_run              # per-run hints at most


# ---------------------------------------------------------------------------
# Sharded (8 forced host devices) dynamics round path
# ---------------------------------------------------------------------------

def _run(script, timeout=540):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


_MESH_SCRIPT = r"""
from repro.launch.mesh import force_host_platform_device_count
force_host_platform_device_count(8)
import dataclasses
import json
import numpy as np
import jax

import repro.fl.engine as ENG
import repro.fl.policies as POL
import repro.fl.simulator as SIMM
from repro.configs.base import FLConfig
from repro.data.synthetic import federated_classification
from repro.fl import FleetEngine, SimConfig

n = 32
data = federated_classification(n, seed=0, n_per_client=32)
sim = SimConfig(num_clients=n, rounds=3, seed=0, local_steps=2)
out = {"n_dev": len(jax.devices()), "dynamics": {}}

counts = {"n": 0}
orig = SIMM.place_per_client
def counting(arr, mesh=None):
    counts["n"] += 1
    return orig(arr, mesh)
for mod in (ENG, POL, SIMM):
    mod.place_per_client = counting

for dyn in ("markov", "sessions", "trace"):
    fl = FLConfig(num_clients=n, clients_per_round=8, dynamics=dyn)
    ref = FleetEngine(data, sim, fl).run("flude", diagnostics=False)
    fl_m = dataclasses.replace(fl, mesh_shape=(8,))
    engine = FleetEngine(data, sim, fl_m)
    engine.run("flude", diagnostics=False)          # compile + place
    per_run = []
    for rounds in (1, 3):
        counts["n"] = 0
        h = engine.run("flude", rounds=rounds, diagnostics=False)
        per_run.append(counts["n"])
    draw = engine._last_draw
    state_leaves = jax.tree.leaves(engine._last_fleet_state)
    out["dynamics"][dyn] = {
        "ints_exact": (h.received == ref.received
                       and h.selected == ref.selected
                       and h.wall_clock == ref.wall_clock),
        "acc_err": float(max(abs(a - b)
                             for a, b in zip(h.acc, ref.acc))),
        "draw_shards": len(draw.online.sharding.device_set),
        "state_sharded": all(
            len(l.sharding.device_set) == 8
            for l in state_leaves if getattr(l, "ndim", 0) >= 1
            and l.shape and l.shape[0] == n),
        "transfer_counts": per_run,
    }
print(json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_dynamics_round_path():
    """Every device process runs the full round path sharded over 8
    forced host devices: the trajectory matches single-device, draws and
    process state live sharded on all 8 devices, and the
    ``place_per_client`` count is round-count-independent (no per-round
    host→device hand-off)."""
    rec = _run(_MESH_SCRIPT)
    assert rec["n_dev"] == 8
    for dyn, r in rec["dynamics"].items():
        assert r["ints_exact"], (dyn, r)
        assert r["acc_err"] < 1e-6, (dyn, r)
        assert r["draw_shards"] == 8, (dyn, r)
        assert r["state_sharded"], (dyn, r)
        assert r["transfer_counts"][0] == r["transfer_counts"][1], (dyn, r)


# ---------------------------------------------------------------------------
# Pipelined rounds: depth changes scheduling only, even under a mesh
# ---------------------------------------------------------------------------

_PIPELINE_SCRIPT = r"""
from repro.launch.mesh import force_host_platform_device_count
force_host_platform_device_count(8)
import dataclasses
import json
import jax

from repro.configs.base import FLConfig
from repro.data.synthetic import federated_classification
from repro.fl import FleetEngine, SimConfig, available_policies

n = 32
data = federated_classification(n, seed=0, n_per_client=32)
sim = SimConfig(num_clients=n, rounds=3, seed=0, local_steps=2)
fl = FLConfig(num_clients=n, clients_per_round=8, dynamics="bernoulli",
              mesh_shape=(8,))

out = {"n_dev": len(jax.devices()), "policies": {}}
for policy in sorted(available_policies()):
    ref = FleetEngine(data, sim, fl).run(policy, eval_every=2,
                                         diagnostics=False)
    fl_p = dataclasses.replace(fl, pipeline_depth=2)
    h = FleetEngine(data, sim, fl_p).run(policy, eval_every=2,
                                         diagnostics=False)
    out["policies"][policy] = {
        "rows_exact": (h.acc == ref.acc
                       and h.wall_clock == ref.wall_clock
                       and h.comm_mb == ref.comm_mb
                       and h.received == ref.received
                       and h.selected == ref.selected
                       and h.eval_mask == ref.eval_mask),
    }
print(json.dumps(out))
"""


@pytest.mark.slow
def test_pipelined_rounds_parity_sharded():
    """pipeline_depth=2 reproduces the depth-1 History exactly for every
    registered policy on the 8-forced-host-device client mesh — the
    pipelined loop changes when bookkeeping is read back, never what the
    rounds compute."""
    rec = _run(_PIPELINE_SCRIPT)
    assert rec["n_dev"] == 8
    for policy, r in rec["policies"].items():
        assert r["rows_exact"], policy
