"""Pallas kernel allclose sweeps vs pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.fed_agg.ops import fed_agg
from repro.kernels.fed_agg.ref import fed_agg_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rwkv6_scan.ops import rwkv6_scan
from repro.kernels.ssm_scan.ops import ssm_scan

settings.register_profile("kern", max_examples=8, deadline=None)
settings.load_profile("kern")


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (B, Hq, Hkv, S, D, causal, window, dtype)
    (2, 4, 2, 128, 32, True, None, jnp.float32),
    (1, 8, 8, 256, 64, True, 64, jnp.float32),
    (2, 4, 1, 96, 48, True, None, jnp.float32),      # padding path
    (1, 2, 2, 128, 128, False, None, jnp.float32),
    (2, 4, 2, 128, 64, True, None, jnp.bfloat16),
    (1, 6, 3, 64, 64, True, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("B,Hq,Hkv,S,D,causal,window,dtype", FLASH_CASES)
def test_flash_attention_sweep(B, Hq, Hkv, S, D, causal, window, dtype):
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(B, Hq, S, D), dtype)
    k = jnp.asarray(rng.randn(B, Hkv, S, D), dtype)
    v = jnp.asarray(rng.randn(B, Hkv, S, D), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, impl="pallas_interpret")
    want = attention_ref(q, k, v, causal=causal, window=window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_matches_model_chunked_attention():
    """Pallas kernel == the model's chunked-XLA path == dense ref."""
    from repro.models.attention import chunked_attention
    rng = np.random.RandomState(1)
    B, S, Hk, G, D = 2, 128, 2, 2, 32
    q = jnp.asarray(rng.randn(B, S, Hk, G, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, Hk, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, Hk, D), jnp.float32)
    xla = chunked_attention(q, k, v, causal=True, window=None,
                            scale=D ** -0.5, q_chunk=64, k_chunk=64)
    qc = jnp.transpose(q, (0, 2, 3, 1, 4)).reshape(B, Hk * G, S, D)
    kc = jnp.transpose(k, (0, 2, 1, 3))
    vc = jnp.transpose(v, (0, 2, 1, 3))
    pall = flash_attention(qc, kc, vc, causal=True, block_q=64, block_k=64,
                           impl="pallas_interpret")
    pall = jnp.transpose(pall.reshape(B, Hk, G, S, D), (0, 3, 1, 2, 4))
    np.testing.assert_allclose(np.asarray(xla), np.asarray(pall),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# ssm scan (Mamba2 SSD)
# ---------------------------------------------------------------------------

SSM_CASES = [
    # (B, S, H, P, N, G, chunk, dtype)
    (2, 64, 4, 32, 16, 2, 16, jnp.float32),
    (1, 100, 2, 16, 8, 1, 32, jnp.float32),     # ragged padding
    (2, 128, 4, 64, 64, 4, 64, jnp.float32),
    (1, 64, 2, 32, 16, 2, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,H,P,N,G,chunk,dtype", SSM_CASES)
def test_ssm_scan_sweep(B, S, H, P, N, G, chunk, dtype):
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(B, S, H, P), dtype)
    dt = jnp.asarray(rng.rand(B, S, H) * 0.5, jnp.float32)
    A = jnp.asarray(-rng.rand(H) - 0.1, jnp.float32)
    Bm = jnp.asarray(rng.randn(B, S, G, N), dtype)
    Cm = jnp.asarray(rng.randn(B, S, G, N), dtype)
    y1, h1 = ssm_scan(x, dt, A, Bm, Cm, impl="pallas_interpret",
                      chunk=chunk)
    y2, h2 = ssm_scan(x, dt, A, Bm, Cm, impl="xla")
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=tol,
                               atol=tol)


def test_ssm_scan_with_initial_state():
    """Chunked scan continues correctly from a nonzero carried state."""
    rng = np.random.RandomState(2)
    B, S, H, P, N = 1, 64, 2, 16, 8
    x = jnp.asarray(rng.randn(B, S, H, P), jnp.float32)
    dt = jnp.asarray(rng.rand(B, S, H) * 0.3, jnp.float32)
    A = jnp.asarray(-rng.rand(H) - 0.1, jnp.float32)
    Bm = jnp.asarray(rng.randn(B, S, 1, N), jnp.float32)
    Cm = jnp.asarray(rng.randn(B, S, 1, N), jnp.float32)
    # run full sequence vs two halves with carried state
    y_full, h_full = ssm_scan(x, dt, A, Bm, Cm, impl="xla")
    half = S // 2
    y1, h1 = ssm_scan(x[:, :half], dt[:, :half], A, Bm[:, :half],
                      Cm[:, :half], impl="pallas_interpret", chunk=16)
    y2, h2 = ssm_scan(x[:, half:], dt[:, half:], A, Bm[:, half:],
                      Cm[:, half:], h0=h1, impl="pallas_interpret",
                      chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# rwkv6 scan
# ---------------------------------------------------------------------------

RWKV_CASES = [
    (2, 2, 48, 16, 16, jnp.float32),
    (1, 4, 100, 32, 32, jnp.float32),
    (2, 2, 64, 64, 64, jnp.float32),
    (1, 2, 32, 32, 16, jnp.bfloat16),
]


@pytest.mark.parametrize("B,H,S,D,chunk,dtype", RWKV_CASES)
def test_rwkv6_scan_sweep(B, H, S, D, chunk, dtype):
    rng = np.random.RandomState(0)
    r = jnp.asarray(rng.randn(B, H, S, D) * 0.5, dtype)
    k = jnp.asarray(rng.randn(B, H, S, D) * 0.5, dtype)
    v = jnp.asarray(rng.randn(B, H, S, D) * 0.5, dtype)
    lw = jnp.asarray(-np.exp(rng.randn(B, H, S, D) * 0.5), jnp.float32)
    u = jnp.asarray(rng.randn(H, D) * 0.3, jnp.float32)
    y1, s1 = rwkv6_scan(r, k, v, lw, u, impl="pallas_interpret",
                        chunk=chunk)
    y2, s2 = rwkv6_scan(r, k, v, lw, u, impl="xla")
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=tol,
                               atol=tol)


def test_rwkv_kernel_plugs_into_model():
    """time_mix(kernel=pallas adapter) == time_mix(exact recurrence)."""
    from repro.configs import get_config
    from repro.kernels.rwkv6_scan.ops import wkv_kernel_adapter
    from repro.models import build_model
    from repro.models import rwkv as R
    import jax
    cfg = get_config("rwkv6-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    p_l = jax.tree.map(lambda a: a[0], params["blocks"])
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    y_exact, s_exact = R.time_mix(p_l["rwkv"], x, cfg, None)
    y_kern, s_kern = R.time_mix(p_l["rwkv"], x, cfg, None,
                                kernel=wkv_kernel_adapter(chunk=16))
    np.testing.assert_allclose(np.asarray(y_exact), np.asarray(y_kern),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_exact), np.asarray(s_kern),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fed_agg
# ---------------------------------------------------------------------------

@given(st.integers(1, 24), st.integers(1, 300),
       st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_fed_agg_property(C, D, dtype):
    rng = np.random.RandomState(C * 1000 + D)
    u = jnp.asarray(rng.randn(C, D), dtype)
    w = jnp.asarray(rng.rand(C), jnp.float32)
    got = fed_agg(u, w, impl="pallas_interpret", block_c=4, block_d=64)
    want = fed_agg_ref(u, w)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_fed_agg_matches_core_aggregation():
    """Pallas fed_agg == repro.core.fed_aggregate on a pytree."""
    from repro import core
    from repro.kernels.fed_agg.ops import fed_agg_tree
    rng = np.random.RandomState(3)
    C = 6
    stacked = {"a": jnp.asarray(rng.randn(C, 4, 5), jnp.float32),
               "b": jnp.asarray(rng.randn(C, 7), jnp.float32)}
    w = jnp.asarray(rng.rand(C), jnp.float32)
    g = {"a": jnp.zeros((4, 5)), "b": jnp.zeros((7,))}
    want = core.fed_aggregate(g, stacked, w)
    got = fed_agg_tree(stacked, w / w.sum(), impl="pallas_interpret")
    for key in ("a", "b"):
        np.testing.assert_allclose(np.asarray(got[key]),
                                   np.asarray(want[key]), rtol=1e-5,
                                   atol=1e-5)
