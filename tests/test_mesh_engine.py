"""Fleet-mesh round path: sharded-vs-single-device parity + donation.

Multi-device tests fork a python with 8 forced host devices (via
``repro.launch.mesh.force_host_platform_device_count`` — applied before
any jax import) and compare against the single-device path *inside* the
subprocess, so the main pytest process keeps its 1 device.

Donation needs no subprocess: CPU jax invalidates donated buffers, so the
tests assert the dead round inputs really are deleted after the call.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.configs.base import FLConfig
from repro.data.synthetic import federated_classification
from repro.fl import FleetEngine, SimConfig
from repro.fl.engine import make_trainer


def _run(script, timeout=540):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# ---------------------------------------------------------------------------
# Packed aggregation: shard_map partial sums + psum vs the flat kernel
# ---------------------------------------------------------------------------

_AGG_SCRIPT = r"""
from repro.launch.mesh import force_host_platform_device_count
force_host_platform_device_count(8)
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fed_agg.ops import fed_agg_packed, fed_agg_packed_sharded
from repro.launch.mesh import make_fleet_mesh
from repro.sharding.partitioning import fleet_sharding

C, D = 32, 3000
rng = np.random.RandomState(0)
u = jnp.asarray(rng.randn(C, D).astype(np.float32))
w = jnp.asarray(rng.rand(C).astype(np.float32))
w = w / w.sum()

ref = fed_agg_packed(u, w, impl="xla")

mesh = make_fleet_mesh(8)
u_sh = jax.device_put(u, fleet_sharding(mesh, 2))
w_sh = jax.device_put(w, fleet_sharding(mesh, 1))
errs = {}
for impl in ("xla", "pallas_interpret"):
    out = jax.jit(lambda a, b: fed_agg_packed_sharded(
        a, b, mesh=mesh, impl=impl, block_c=8, block_d=512))(u_sh, w_sh)
    errs[impl] = float(jnp.abs(out - ref).max() /
                       jnp.abs(ref).max())
print(json.dumps({"n_dev": len(jax.devices()), **errs}))
"""


@pytest.mark.slow
def test_packed_aggregation_sharded_matches_single_device():
    """Per-shard partial weighted sums + fp32 psum agree with the flat
    single-device packed kernel for both the xla and the (interpreted)
    pallas per-shard impls."""
    rec = _run(_AGG_SCRIPT)
    assert rec["n_dev"] == 8
    assert rec["xla"] < 1e-5
    assert rec["pallas_interpret"] < 1e-5


# ---------------------------------------------------------------------------
# Engine: 3-round sharded run reproduces the single-device trajectory
# ---------------------------------------------------------------------------

_ENGINE_SCRIPT = r"""
from repro.launch.mesh import force_host_platform_device_count
force_host_platform_device_count(8)
import dataclasses
import json
import numpy as np

from repro.configs.base import FLConfig
from repro.data.synthetic import federated_classification
from repro.fl import FleetEngine, SimConfig, available_policies

n = 32
data = federated_classification(n, seed=0, n_per_client=32)
sim = SimConfig(num_clients=n, rounds=3, seed=0, local_steps=2)
fl = FLConfig(num_clients=n, clients_per_round=8)

out = {"n_dev": 0, "policies": {}}
import jax
out["n_dev"] = len(jax.devices())
for policy in sorted(available_policies()):
    ref = FleetEngine(data, sim, fl).run(policy, diagnostics=False)
    fl_m = dataclasses.replace(fl, mesh_shape=(8,), donate_buffers=True)
    h = FleetEngine(data, sim, fl_m).run(policy, diagnostics=False)
    out["policies"][policy] = {
        "acc_exact": h.acc == ref.acc,
        "acc_err": float(max(abs(a - b) for a, b in zip(h.acc, ref.acc))),
        "ints_exact": (h.received == ref.received
                       and h.selected == ref.selected),
        "wall_exact": h.wall_clock == ref.wall_clock,
        "comm_exact": h.comm_mb == ref.comm_mb,
    }
print(json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_engine_matches_single_device_3rounds():
    """Every registered policy's 3-round History on the forced-8-device
    client mesh (with donation) reproduces the single-device trajectory.

    The host-side trajectory (selected/received/wall clock/comm) must be
    exact.  Accuracy is asserted to 1e-6: the sharded psum uses a
    different fp32 reduction order than the flat einsum, so bit-equality
    of the model — observed on the pinned CI toolchain, where acc comes
    out exactly equal too — is not guaranteed across CPU microarchs.
    """
    rec = _run(_ENGINE_SCRIPT, timeout=540)
    assert rec["n_dev"] == 8
    for policy, r in rec["policies"].items():
        assert r["ints_exact"], (policy, r)
        assert r["wall_exact"] and r["comm_exact"], (policy, r)
        assert r["acc_err"] < 1e-6, (policy, r)


_SHARDED_STATE_SCRIPT = r"""
from repro.launch.mesh import force_host_platform_device_count
force_host_platform_device_count(8)
import dataclasses
import json
import jax

from repro.configs.base import FLConfig
from repro.data.synthetic import federated_classification
from repro.fl import FleetEngine, SimConfig

n = 32
data = federated_classification(n, seed=0, n_per_client=32)
sim = SimConfig(num_clients=n, rounds=2, seed=0, local_steps=2)
fl = FLConfig(num_clients=n, clients_per_round=8, mesh_shape=(8,))
engine = FleetEngine(data, sim, fl)
h = engine.run("flude", diagnostics=False)
caches = engine._last_caches
leaf = jax.tree.leaves(caches.params)[0]
print(json.dumps({
    "n_dev": len(jax.devices()),
    "cache_shards": len(leaf.sharding.device_set),
    "scalar_shards": len(caches.progress.sharding.device_set),
    "global_replicated": all(
        len(l.sharding.device_set) == 8 and
        l.sharding.is_fully_replicated
        for l in jax.tree.leaves(h.final_params)),
}))
"""


@pytest.mark.slow
def test_fleet_state_stays_sharded_across_rounds():
    """After a run, the caches (stacked pytree + per-client scalars) still
    live sharded over all 8 devices and the global model is replicated —
    rounds never silently collapse the fleet onto one device."""
    rec = _run(_SHARDED_STATE_SCRIPT)
    assert rec["n_dev"] == 8
    assert rec["cache_shards"] == 8
    assert rec["scalar_shards"] == 8
    assert rec["global_replicated"]


# ---------------------------------------------------------------------------
# Donation: dead round inputs are actually invalidated (and values agree)
# ---------------------------------------------------------------------------

def _toy_round_inputs(n=8):
    template = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": jnp.ones((3,), jnp.float32)}
    caches = core.init_caches(template, n)
    rng = np.random.RandomState(0)
    final = jax.tree.map(
        lambda a: jnp.asarray(rng.randn(n, *a.shape).astype(np.float32)),
        template)
    cache_p = jax.tree.map(jnp.zeros_like, final)
    cached_steps = jnp.zeros((n,), jnp.int32)
    sel = jnp.asarray(rng.rand(n) < 0.7)
    fail = jnp.zeros((n,), bool)
    received = sel
    resume = jnp.zeros((n,), bool)
    n_samples = jnp.full((n,), 4.0, jnp.float32)
    ones = jnp.ones((n,), jnp.float32)
    return (template, caches, final, cache_p, cached_steps, sel, fail,
            received, resume, n_samples, ones)


def test_server_step_donation_invalidates_inputs():
    (template, caches, final, cache_p, cached_steps, sel, fail, received,
     resume, n_samples, ones) = _toy_round_inputs()
    ref_step = core.make_server_round_step(template, local_steps=2,
                                           donate=False)
    ref_g, ref_c = ref_step(template, caches, final, cache_p, cached_steps,
                            sel, fail, received, resume, n_samples, ones, 0)

    (template2, caches2, final2, cache_p2, cached_steps2, *_) = \
        _toy_round_inputs()
    don_step = core.make_server_round_step(template2, local_steps=2,
                                           donate=True)
    g_in = jax.tree.map(jnp.copy, template2)
    got_g, got_c = don_step(g_in, caches2, final2, cache_p2, cached_steps2,
                            sel, fail, received, resume, n_samples, ones, 0)
    # donated inputs (previous global model + caches) are dead...
    assert all(l.is_deleted() for l in jax.tree.leaves(g_in))
    assert all(l.is_deleted() for l in jax.tree.leaves(caches2))
    # ...the undonated stacked trainer outputs are not...
    assert not any(l.is_deleted() for l in jax.tree.leaves(final2))
    assert not any(l.is_deleted() for l in jax.tree.leaves(cache_p2))
    # ...and donation changes no values
    for a, b in zip(jax.tree.leaves(ref_g), jax.tree.leaves(got_g)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(ref_c), jax.tree.leaves(got_c)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_donation_invalidates_step_carry():
    n = 8
    data = federated_classification(n, seed=0, n_per_client=16)
    sim = SimConfig(num_clients=n, local_steps=2, batch_size=8)
    trainer = make_trainer(sim, data, donate=True)
    from repro.fl.classifier import init_classifier
    params = init_classifier(jax.random.key(0), dim=data.x.shape[-1],
                             num_classes=data.num_classes)
    caches = core.init_caches(params, n)
    steps = jnp.full((n,), 2, jnp.int32)
    stop = jnp.full((n,), 1 << 20, jnp.int32)
    trainer(params, caches, jnp.zeros((n,), bool), steps, stop,
            jnp.full((n,), 2, jnp.int32))
    assert steps.is_deleted()          # donated (N,) step-count carry
    assert not stop.is_deleted()       # everything else stays live
    assert not any(l.is_deleted() for l in jax.tree.leaves(caches))


def test_engine_donation_trajectory_unchanged():
    """donate_buffers flips allocation behavior only — same History."""
    import dataclasses
    n = 16
    data = federated_classification(n, seed=1, n_per_client=32)
    sim = SimConfig(num_clients=n, rounds=3, seed=1, local_steps=2)
    fl = FLConfig(num_clients=n, clients_per_round=6)
    ref = FleetEngine(data, sim, fl).run("flude")
    fl_d = dataclasses.replace(fl, donate_buffers=True)
    engine = FleetEngine(data, sim, fl_d)
    h1 = engine.run("flude")
    h2 = engine.run("flude")           # template survives donation
    assert h1.acc == ref.acc and h2.acc == ref.acc
    assert h1.received == ref.received and h2.received == ref.received


def test_server_step_memory_donation_reduces_peak():
    """The compiled-step memory profile shows donation aliasing the
    persistent fleet state into the outputs (the bench's peak-live
    metric)."""
    import dataclasses
    n = 32
    data = federated_classification(n, seed=0, n_per_client=16)
    sim = SimConfig(num_clients=n, rounds=1, seed=0, local_steps=2)
    fl = FLConfig(num_clients=n, clients_per_round=8)
    m_off = FleetEngine(data, sim, fl).server_step_memory()
    fl_d = dataclasses.replace(fl, donate_buffers=True)
    m_on = FleetEngine(data, sim, fl_d).server_step_memory()
    assert m_off["alias_bytes"] == 0
    assert m_on["alias_bytes"] > 0
    assert m_on["peak_live_bytes"] < m_off["peak_live_bytes"]


def test_engine_rejects_uneven_mesh():
    n = 10
    data = federated_classification(n, seed=0, n_per_client=16)
    sim = SimConfig(num_clients=n, rounds=1, seed=0)
    fl = FLConfig(num_clients=n, clients_per_round=4, mesh_shape=(4,))
    with pytest.raises(ValueError, match="does not divide"):
        FleetEngine(data, sim, fl)


def test_force_host_device_count_guards_late_calls():
    from repro.launch.mesh import force_host_platform_device_count
    n_now = len(jax.devices())
    before = os.environ.get("XLA_FLAGS")
    try:
        # matching the already-initialized count passes; any other count
        # must raise (the backend can no longer honor the flag)
        force_host_platform_device_count(n_now)
        with pytest.raises(RuntimeError, match="after jax was initialized"):
            force_host_platform_device_count(n_now + 7)
    finally:
        if before is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = before
