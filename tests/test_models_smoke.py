"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates a REDUCED variant of the same family
(≤2 layers — 4 for the hybrid pattern —, d_model ≤ 512, ≤4 experts) and runs
one forward pass AND one train step on CPU, asserting output shapes and
finiteness.  The FULL configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.base import TrainConfig
from repro.models import build_model
from repro.optim.optimizers import make_optimizer

B, S = 2, 64


def _batch(cfg, rng):
    if cfg.encdec is not None:
        e = cfg.encdec
        return {
            "frames": jax.random.normal(rng, (B, S, cfg.d_model)),
            "dec_tokens": jnp.ones((B, e.max_target_len), jnp.int32),
            "dec_labels": jnp.ones((B, e.max_target_len), jnp.int32),
        }
    batch = {
        "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
    }
    if cfg.vision is not None:
        batch["image_embeds"] = jax.random.normal(
            rng, (B, cfg.vision.num_image_tokens,
                  cfg.vision.patch_embed_dim))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))

    # forward
    logits = jax.jit(model.logits)(params, batch)
    exp_len = cfg.encdec.max_target_len if cfg.encdec else S
    assert logits.shape == (B, exp_len, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    # one train step (loss + grad + optimizer update)
    opt = make_optimizer(TrainConfig(optimizer="adamw", grad_clip=1.0))
    opt_state = opt.init(params)

    @jax.jit
    def step(p, o, b):
        (loss, _), g = jax.value_and_grad(
            lambda pp: model.loss(pp, b), has_aux=True)(p)
        p2, o2 = opt.step(p, g, o)
        return p2, o2, loss

    p2, o2, loss = step(params, opt_state, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    # params actually changed
    diffs = jax.tree.map(lambda a, b_: float(jnp.abs(a - b_).max()),
                         params, p2)
    assert max(jax.tree.leaves(diffs)) > 0.0

    # loss decreases over a few steps on a fixed batch
    for _ in range(3):
        p2, o2, loss2 = step(p2, o2, batch)
    assert float(loss2) < float(loss), \
        f"{arch}: loss did not decrease ({loss} -> {loss2})"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_full_config_matches_assignment(arch):
    """The full (dry-run) configs carry the exact assigned hyperparams."""
    cfg = get_config(arch)
    expected = {
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, f"{arch}: {got} != {expected}"
    # family-specific invariants
    if arch == "deepseek-v2-236b":
        assert cfg.moe.num_experts == 160 and cfg.moe.top_k == 6
        assert cfg.mla.kv_lora_rank == 512
    if arch == "mixtral-8x7b":
        assert cfg.moe.num_experts == 8 and cfg.moe.top_k == 2
        assert cfg.sliding_window is not None
    if arch == "zamba2-1.2b":
        assert cfg.ssm.d_state == 64
    if arch == "rwkv6-7b":
        assert cfg.attention == "none"
    if arch == "h2o-danube-1.8b":
        assert cfg.sliding_window is not None
    if arch == "nemotron-4-340b":
        assert cfg.mlp_act == "relu2"
    if arch == "qwen2-7b":
        assert cfg.qkv_bias
