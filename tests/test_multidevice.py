"""Multi-device sharding correctness via a subprocess with 8 host devices.

The main pytest process keeps 1 device (per the dry-run isolation rule);
these tests fork a python with XLA_FLAGS=--xla_force_host_platform_device_count=8
and check that the sharded cross-silo step agrees with the single-device
step numerically.
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.fl import cross_silo
from repro.models import ExecConfig, build_model
from repro.optim.optimizers import make_optimizer
from repro.sharding import partitioning as SP

cfg = get_config("qwen2-7b").reduced(num_kv_heads=2, num_heads=4)
model = build_model(cfg)
tc = TrainConfig(learning_rate=1e-2, warmup_steps=0)
opt = make_optimizer(tc)
params = model.init(jax.random.key(0))
state = cross_silo.TrainState(params, opt.init(params),
                              jnp.zeros((), jnp.int32))
B, S = 8, 32
batch = {
    "tokens": jax.random.randint(jax.random.key(1), (B, S), 0,
                                 cfg.vocab_size),
    "labels": jax.random.randint(jax.random.key(2), (B, S), 0,
                                 cfg.vocab_size),
}
w = jnp.array([1.0, 0.5, 0.0, 1.0])

# single-device reference
step1 = jax.jit(cross_silo.make_train_step(model, tc, 4))
s_ref, m_ref = step1(state, batch, w)

# sharded (4 data x 2 model)
mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = SP.make_rules(cfg, mesh)
ecfg = ExecConfig(mesh=mesh, rules=rules)
pspecs = SP.param_shardings(model.specs, mesh, rules)
from repro.optim.optimizers import OptState
state_sh = cross_silo.TrainState(
    params=pspecs, opt_state=OptState(pspecs, pspecs,
                                      NamedSharding(mesh, P())),
    step=NamedSharding(mesh, P()))
batch_sh = SP.batch_shardings(batch, mesh)
step2 = jax.jit(cross_silo.make_train_step(model, tc, 4, ecfg),
                in_shardings=(state_sh, batch_sh,
                              NamedSharding(mesh, P())))
with mesh:
    s_sh, m_sh = step2(state, batch, w)

err = max(float(jnp.abs(a - b).max()) for a, b in
          zip(jax.tree.leaves(s_ref.params), jax.tree.leaves(s_sh.params)))
print(json.dumps({
    "loss_ref": float(m_ref["loss"]), "loss_sh": float(m_sh["loss"]),
    "max_param_err": err,
    "n_dev": len(jax.devices()),
}))
"""


@pytest.mark.slow
def test_sharded_step_matches_single_device(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["n_dev"] == 8
    assert abs(rec["loss_ref"] - rec["loss_sh"]) < 1e-3
    assert rec["max_param_err"] < 5e-3


_FLEET_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.data.synthetic import federated_classification
from repro.fl import SimConfig
from repro.fl.runner import make_trainer

# 32 clients sharded 8-ways over the client axis (cross-device cohorts)
data = federated_classification(32, seed=0, n_per_client=32)
sim = SimConfig(num_clients=32, local_steps=4)
trainer = make_trainer(sim, data)

from repro.fl.classifier import init_classifier
import repro.core as core
params = init_classifier(jax.random.key(0), dim=data.x.shape[-1])
stacked = jax.tree.map(lambda a: jnp.broadcast_to(a, (32,) + a.shape), params)
caches = core.init_caches(params, 32)._replace(params=stacked)
resume = jnp.ones((32,), bool)     # start from the stacked cached states
steps = jnp.full((32,), 4, jnp.int32)
stop = jnp.full((32,), 1 << 20, jnp.int32)
cache_every = jnp.full((32,), 2, jnp.int32)

ref = trainer(params, caches, resume, steps, stop, cache_every)

mesh = jax.make_mesh((8,), ("clients",))
shard = NamedSharding(mesh, P("clients"))
caches_sh = jax.device_put(caches, jax.tree.map(lambda _: shard, caches))
with mesh:
    got = trainer(params, caches_sh, jax.device_put(resume, shard),
                  jax.device_put(steps, shard),
                  jax.device_put(stop, shard),
                  jax.device_put(cache_every, shard))

err = max(float(jnp.abs(a - b).max()) for a, b in
          zip(jax.tree.leaves(ref[0]), jax.tree.leaves(got[0])))
print(json.dumps({"err": err, "n_dev": len(jax.devices()),
                  "shards": len(jax.tree.leaves(got[0])[0].sharding.device_set)}))
"""


@pytest.mark.slow
def test_fleet_trainer_shards_over_client_axis():
    """DESIGN.md §3 cross-device claim: the vmapped fleet trainer runs with
    the client axis sharded across devices, numerically identical."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run([sys.executable, "-c", _FLEET_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["n_dev"] == 8
    assert rec["shards"] == 8
    assert rec["err"] < 1e-5
