"""Observability subsystem (repro.obs): metric registry + numpy oracles,
span tracer / Perfetto export, sinks, report CLI, and — the invariants
that gate the whole feature — telemetry="full" adding zero per-round
host syncs while telemetry=None stays bit- and dispatch-identical to an
uninstrumented engine.
"""
import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro import obs
from repro.configs.base import FLConfig
from repro.core import cache_store as CS
from repro.data.synthetic import federated_classification
from repro.fl import FleetEngine, History, SimConfig, make_policy
from repro.obs import metrics as OM
from repro.obs import report as OR
from repro.obs.trace import NullTracer, Tracer

ALL_POLICIES = ("flude", "random", "oort", "safa", "fedsea",
                "asyncfeded", "mifa")


def _setup(n=16, rounds=3, **fl_kw):
    data = federated_classification(n, seed=0, n_per_client=32)
    sim = SimConfig(num_clients=n, rounds=rounds, seed=0, local_steps=2)
    fl = FLConfig(num_clients=n, clients_per_round=8, **fl_kw)
    return data, sim, fl


def _rows(h):
    return (h.acc, h.wall_clock, h.comm_mb, h.received, h.selected,
            h.eval_mask)


# ---------------------------------------------------------------------------
# Tracer / Chrome export
# ---------------------------------------------------------------------------

def test_tracer_spans_and_summary():
    tr = Tracer()
    with tr.span("a", round=0):
        pass
    with tr.span("a"):
        pass
    with tr.span("b") as sp:
        pass
    assert sp.seconds >= 0.0
    s = tr.summary()
    assert s["a"]["count"] == 2 and s["b"]["count"] == 1
    assert s["a"]["total_s"] >= s["a"]["max_s"] >= 0.0
    assert s["a"]["mean_s"] == pytest.approx(s["a"]["total_s"] / 2)


def test_tracer_chrome_export(tmp_path):
    tr = Tracer()
    with tr.span("trainer", round=1):
        pass
    tr.instant("mark")
    tr.counter("received", value=3)
    path = str(tmp_path / "trace.json")
    tr.save(path)
    doc = json.load(open(path))
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert evs[0]["ph"] == "M"                      # process metadata
    by_name = {e["name"]: e for e in evs}
    x = by_name["trainer"]
    assert x["ph"] == "X" and x["dur"] >= 0 and x["args"] == {"round": 1}
    assert {"pid", "tid", "ts"} <= set(x)
    assert by_name["mark"]["ph"] == "i"
    assert by_name["received"]["ph"] == "C"
    assert by_name["received"]["args"] == {"value": 3}


def test_null_tracer_is_inert():
    nt = NullTracer()
    with nt.span("x", round=9) as sp:
        pass
    assert sp.seconds == 0.0
    nt.instant("y")
    nt.counter("z", v=1)
    assert nt.summary() == {} and nt.events == []
    # the module-level singleton hands out one shared span object
    assert obs.NULL_TRACER.span("a") is obs.NULL_TRACER.span("b")


def test_tracer_reset_clears_events():
    tr = Tracer()
    with tr.span("a"):
        pass
    tr.reset()
    assert tr.events == [] and tr.summary() == {}


# ---------------------------------------------------------------------------
# Sinks
# ---------------------------------------------------------------------------

def test_jsonl_sink_appends_valid_lines(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    s = obs.JsonlSink(path)
    s.emit({"kind": "round", "x": 1.5, "v": [1, 2]})
    s.emit({"kind": "round", "f": np.float32(2.0)})   # default=float
    s.close()
    s2 = obs.JsonlSink(path)                          # append, not truncate
    s2.emit({"kind": "run_end"})
    s2.close()
    lines = [json.loads(l) for l in open(path)]
    assert [l["kind"] for l in lines] == ["round", "round", "run_end"]
    assert lines[1]["f"] == 2.0


def test_tee_sink_fans_out_and_drops_none():
    a, b = obs.MemorySink(), obs.MemorySink()
    t = obs.TeeSink(a, None, b)
    t.emit({"kind": "x"})
    assert a.events == b.events == [{"kind": "x"}]
    t.close()


# ---------------------------------------------------------------------------
# Metric registry
# ---------------------------------------------------------------------------

def test_registry_levels_and_needs():
    specs = {s.name: s for s in OM.metrics_for(
        "full", {"selected", "received", "fail", "online", "distribute",
                 "losses", "times", "stamp", "resume", "rnd"})}
    assert "counts" in specs and "staleness_hist" in specs
    assert "update_norm" not in specs        # rows/global not available
    basic = {s.name for s in OM.metrics_for(
        "basic", {"selected", "received", "fail", "online", "distribute",
                  "stamp", "rnd"})}
    assert "staleness_hist" not in basic     # full-level metric
    assert "counts" in basic
    with pytest.raises(ValueError, match="telemetry level"):
        OM.metrics_for("verbose", set())


def test_register_metric_validation():
    with pytest.raises(ValueError, match="metric level"):
        OM.register_metric("_t_bad", level="loud")(lambda c, s: {})
    OM.register_metric("_t_dup", needs=())(lambda c, s: {"_t_dup": 0})
    try:
        with pytest.raises(ValueError, match="already registered"):
            OM.register_metric("_t_dup")(lambda c, s: {})
        OM.register_metric("_t_dup", allow_override=True)(
            lambda c, s: {"_t_dup": 1})
        assert "_t_dup" in OM.available_metrics()
    finally:
        OM._REGISTRY.pop("_t_dup", None)


def test_make_metrics_fn_empty_and_needed_keys():
    fn, needed = OM.make_metrics_fn("basic", set(), {})
    assert fn is None and needed == ()
    fn, needed = OM.make_metrics_fn(
        "basic", {"selected", "received", "fail", "online", "distribute"},
        {"num_clients": 8})
    assert fn is not None and "selected" in needed
    assert "num_clients" not in needed       # static keys aren't ctx


# ---------------------------------------------------------------------------
# Metric numpy oracles (synthetic round context)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def synth_ctx():
    rng = np.random.default_rng(7)
    n = 12
    sel = np.zeros(n, bool); sel[:8] = True
    online = rng.random(n) < 0.8
    dist = sel.copy()
    recv = sel & online & (rng.random(n) < 0.7)
    fail = sel & ~recv
    resume = np.zeros(n, bool); resume[2:5] = True
    losses = rng.random(n).astype(np.float32) * 2
    times = rng.random(n).astype(np.float32) * 50
    stamp = rng.integers(-1, 6, n).astype(np.int32)
    stamp_pre = stamp.copy()
    stamp[stamp == 1] = -1                   # "expired" rows
    rule_state = rng.random(n).astype(np.float32)
    rows = {"w": rng.standard_normal((n, 3, 2)).astype(np.float32),
            "b": rng.standard_normal((n, 4)).astype(np.float32)}
    glob = {"w": rng.standard_normal((3, 2)).astype(np.float32),
            "b": rng.standard_normal(4).astype(np.float32)}
    return dict(selected=sel, distribute=dist, resume=resume,
                online=online, received=recv, fail=fail, losses=losses,
                times=times, progress=np.zeros(n, np.int32), stamp=stamp,
                stamp_pre_expire=stamp_pre, rule_state=rule_state,
                rows=rows, rows_mask=recv, rnd=7, **{"global": glob})


@pytest.fixture(scope="module")
def synth_out(synth_ctx):
    avail = set(synth_ctx) | {"cohort_size"}
    static = {"num_clients": 12, "cohort_size": 8, "local_steps": 2,
              "staleness_edges": OM.STALENESS_EDGES}
    fn, needed = OM.make_metrics_fn("full", avail, static)
    assert set(needed) <= set(synth_ctx)
    return jax.device_get(fn({k: synth_ctx[k] for k in needed}))


def test_oracle_counts(synth_ctx, synth_out):
    c = synth_ctx
    assert synth_out["selected_count"] == c["selected"].sum()
    assert synth_out["received_count"] == c["received"].sum()
    assert synth_out["interrupted_count"] == c["fail"].sum()
    assert synth_out["online_count"] == c["online"].sum()
    assert synth_out["download_count"] == \
        (c["distribute"] & c["online"]).sum()


def test_oracle_masked_means(synth_ctx, synth_out):
    c = synth_ctx
    got = c["losses"][c["received"]]
    np.testing.assert_allclose(synth_out["local_loss_mean"], got.mean(),
                               rtol=1e-6)
    np.testing.assert_allclose(synth_out["local_loss_max"], got.max(),
                               rtol=1e-6)
    t = c["times"][c["received"]]
    np.testing.assert_allclose(synth_out["finish_time_mean"], t.mean(),
                               rtol=1e-6)
    np.testing.assert_allclose(synth_out["finish_time_max"], t.max(),
                               rtol=1e-6)


def test_oracle_cache_and_cohort(synth_ctx, synth_out):
    c = synth_ctx
    assert synth_out["cache_rows"] == (c["stamp"] >= 0).sum()
    assert synth_out["cache_hit_count"] == \
        (c["resume"] & c["selected"]).sum()
    assert synth_out["cache_expired_count"] == \
        ((c["stamp_pre_expire"] >= 0) & (c["stamp"] < 0)).sum()
    np.testing.assert_allclose(synth_out["cohort_fill"],
                               c["selected"].sum() / 8.0, rtol=1e-6)


def test_oracle_staleness_hist(synth_ctx, synth_out):
    c = synth_ctx
    live = c["stamp"] >= 0
    s = c["rnd"] - c["stamp"]
    edges = OM.STALENESS_EDGES
    want = []
    for b, lo in enumerate(edges):
        hi = edges[b + 1] if b + 1 < len(edges) else np.inf
        want.append((live & (s >= lo) & (s < hi)).sum())
    np.testing.assert_array_equal(synth_out["staleness_hist"], want)
    assert synth_out["staleness_hist"].sum() == live.sum()


def test_oracle_trust_quantiles(synth_ctx, synth_out):
    st = synth_ctx["rule_state"]
    np.testing.assert_allclose(
        synth_out["trust_quartiles"],
        np.quantile(st, [0.25, 0.5, 0.75]), rtol=1e-5)
    np.testing.assert_allclose(synth_out["trust_min"], st.min())
    np.testing.assert_allclose(synth_out["trust_max"], st.max())


def test_oracle_update_norms(synth_ctx, synth_out):
    c = synth_ctx
    rows, g, mask = c["rows"], c["global"], c["rows_mask"]
    flat = np.concatenate(
        [(rows["w"] - g["w"]).reshape(12, -1),
         (rows["b"] - g["b"]).reshape(12, -1)], axis=1)
    norms = np.linalg.norm(flat, axis=1)
    np.testing.assert_allclose(synth_out["update_norm_mean"],
                               norms[mask].mean(), rtol=1e-5)
    np.testing.assert_allclose(synth_out["update_norm_max"],
                               norms[mask].max(), rtol=1e-5)
    mean_row = {k: g[k] + (rows[k] - g[k])[mask].sum(0) / mask.sum()
                for k in rows}
    rflat = np.concatenate(
        [(rows["w"] - mean_row["w"]).reshape(12, -1),
         (rows["b"] - mean_row["b"]).reshape(12, -1)], axis=1)
    resid = np.linalg.norm(rflat, axis=1)
    np.testing.assert_allclose(synth_out["agg_residual_mean"],
                               resid[mask].mean(), rtol=1e-5)
    np.testing.assert_allclose(synth_out["agg_residual_max"],
                               resid[mask].max(), rtol=1e-5)


@pytest.mark.parametrize("bound", [8, 12, 20])
def test_update_norm_rows_bound_gather_matches(synth_ctx, synth_out,
                                               bound):
    """``rows_bound`` makes update_norm gather the received rows into a
    compact (K, ...) block before reducing (the full-scan fast path);
    the stats must match the ungathered reduction, whether the bound is
    tight, equal to, or above the fleet view."""
    avail = set(synth_ctx) | {"cohort_size"}
    static = {"num_clients": 12, "cohort_size": 8, "local_steps": 2,
              "staleness_edges": OM.STALENESS_EDGES,
              "rows_bound": bound}
    fn, needed = OM.make_metrics_fn("full", avail, static)
    out = jax.device_get(fn({k: synth_ctx[k] for k in needed}))
    for col in ("update_norm_mean", "update_norm_max",
                "agg_residual_mean", "agg_residual_max"):
        np.testing.assert_allclose(out[col], synth_out[col], rtol=1e-5,
                                   err_msg=col)


# ---------------------------------------------------------------------------
# Engine integration: the invariants
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module", params=[1, 2],
                ids=["depth1", "depth2"])
def depth_engine(request):
    """One engine per pipeline depth, shared across the policy sweep so
    the compiled trainer is reused (same-task multi-policy loop)."""
    data, sim, fl = _setup(dynamics="bernoulli",
                           pipeline_depth=request.param)
    return FleetEngine(data, sim, fl)


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_full_telemetry_is_bit_identical(depth_engine, policy):
    """telemetry="full" must not perturb the trajectory: History rows
    are bit-identical to a telemetry-off run for every policy at
    pipeline depths 1 and 2."""
    h0 = depth_engine.run(policy, diagnostics=False, telemetry=False)
    h1 = depth_engine.run(policy, diagnostics=False, telemetry="full")
    assert _rows(h1) == _rows(h0), policy
    assert h0.metrics is None
    assert h1.metrics is not None and len(h1.metrics["selected_count"]) \
        == len(h1.acc)


def test_host_loop_telemetry_bit_identical():
    data, sim, fl = _setup()                 # bernoulli_host loop
    engine = FleetEngine(data, sim, fl)
    h0 = engine.run("flude", diagnostics=False, telemetry=False)
    h1 = engine.run("flude", diagnostics=False, telemetry="full")
    assert _rows(h1) == _rows(h0)
    assert h1.metrics["received_count"] == h1.received
    assert h1.metrics["selected_count"] == h1.selected


def test_full_telemetry_adds_zero_host_syncs(monkeypatch):
    """The fused metrics dispatch rides the ledger's existing readback:
    a telemetry="full" run performs exactly as many ``jax.device_get``
    host syncs as a telemetry-off run (flude = device-native planning,
    pipelined)."""
    data, sim, fl = _setup(dynamics="bernoulli", pipeline_depth=2)
    engine = FleetEngine(data, sim, fl)
    engine.run("flude", diagnostics=False, telemetry=False)   # warm up

    counts = []
    real = jax.device_get

    def counting(x):
        counts.append(1)
        return real(x)

    monkeypatch.setattr(jax, "device_get", counting)
    engine.run("flude", diagnostics=False, telemetry=False)
    off = len(counts)
    counts.clear()
    engine.run("flude", diagnostics=False, telemetry="full")
    on = len(counts)
    assert on == off > 0


def test_telemetry_off_never_builds_metrics(monkeypatch):
    """telemetry=None is compiled out: the metrics factory must never
    run and the tracer stays the shared null singleton."""
    def boom(*a, **k):
        raise AssertionError("make_metrics_fn called with telemetry off")

    monkeypatch.setattr(obs, "make_metrics_fn", boom)
    monkeypatch.setattr(OM, "make_metrics_fn", boom)
    data, sim, fl = _setup(dynamics="bernoulli")
    engine = FleetEngine(data, sim, fl)
    h = engine.run("flude", diagnostics=False)
    assert h.metrics is None
    assert engine._tracer is obs.NULL_TRACER


def test_metric_columns_match_history_counts():
    """Device-computed counters agree with the ledger's History ints on
    a seeded run (independent reductions over the same masks)."""
    data, sim, fl = _setup(dynamics="bernoulli")
    h = FleetEngine(data, sim, fl).run("flude", diagnostics=False,
                                      telemetry="full")
    assert h.metrics["received_count"] == h.received
    assert h.metrics["selected_count"] == h.selected
    for r in range(len(h.acc)):
        assert h.metrics["interrupted_count"][r] >= 0
        assert h.metrics["download_count"][r] <= \
            h.metrics["selected_count"][r]
        assert h.metrics["online_count"][r] <= sim.num_clients


def test_report_losses_match_metrics():
    """local_loss_* and finish_time_* equal numpy reductions of the
    RoundReport the policy observed (full-scan (N,) views)."""
    data, sim, fl = _setup(dynamics="bernoulli")
    pol = make_policy("flude", sim, fl)
    reports = []
    orig = pol.observe

    def recording(state, plan, report):
        reports.append(jax.device_get(
            (report.received, report.losses, report.durations)))
        return orig(state, plan, report)

    object.__setattr__(pol, "observe", recording)
    h = FleetEngine(data, sim, fl).run(pol, diagnostics=False,
                                      telemetry="full")
    assert len(reports) == len(h.acc)
    for r, (recv, losses, times) in enumerate(reports):
        got = losses[recv]
        np.testing.assert_allclose(h.metrics["local_loss_mean"][r],
                                   got.mean(), rtol=1e-5)
        np.testing.assert_allclose(h.metrics["local_loss_max"][r],
                                   got.max(), rtol=1e-5)
        np.testing.assert_allclose(h.metrics["finish_time_mean"][r],
                                   times[recv].mean(), rtol=1e-5)


def test_basic_level_and_config_default():
    """FLConfig.telemetry="basic" turns metrics on by default and the
    full-level reductions stay compiled out."""
    data, sim, fl = _setup(dynamics="bernoulli", telemetry="basic")
    h = FleetEngine(data, sim, fl).run("flude", diagnostics=False)
    assert h.metrics is not None
    assert "selected_count" in h.metrics
    assert "update_norm_mean" not in h.metrics
    assert "staleness_hist" not in h.metrics


def test_flconfig_telemetry_validated():
    with pytest.raises(ValueError, match="telemetry"):
        FLConfig(num_clients=8, telemetry="verbose")
    with pytest.raises(ValueError, match="telemetry level"):
        obs.Telemetry(level="loud")


def test_offload_discard_emits_cache_metrics():
    data, sim, fl = _setup(dynamics="bernoulli", cohort_size=8,
                           cache_offload="discard",
                           cache_staleness_bound=2)
    engine = FleetEngine(data, sim, fl)
    h0 = engine.run("flude", diagnostics=False, telemetry=False)
    h1 = engine.run("flude", diagnostics=False, telemetry="full")
    assert _rows(h1) == _rows(h0)
    assert "cache_expired_count" in h1.metrics
    assert "cohort_fill" in h1.metrics
    assert all(0.0 <= f <= 1.0 for f in h1.metrics["cohort_fill"])


# ---------------------------------------------------------------------------
# Per-engine transfer stats
# ---------------------------------------------------------------------------

def test_transfer_stats_are_per_engine():
    data, sim, fl = _setup(dynamics="bernoulli", cohort_size=8,
                           cache_offload="host")
    e1 = FleetEngine(data, sim, fl)
    e2 = FleetEngine(data, sim, fl)
    e1.run("flude", diagnostics=False)
    assert e1.transfer_stats.d2h_async > 0
    assert e1.transfer_stats.sync_copies == 0
    # the second engine's counters are untouched by the first's run
    assert e2.transfer_stats.d2h_async == 0
    e2.run("flude", diagnostics=False)
    assert e2.transfer_stats.d2h_async == e1.transfer_stats.d2h_async
    # the module exposes no process-wide aggregate (lint enforces this)
    assert not hasattr(CS, "STATS")


def test_engine_without_offload_has_zero_transfer_stats():
    data, sim, fl = _setup(dynamics="bernoulli")
    e = FleetEngine(data, sim, fl)
    e.run("flude", diagnostics=False)
    assert e.transfer_stats.snapshot() == {
        "h2d_async": 0, "d2h_async": 0, "h2d_bytes": 0, "d2h_bytes": 0,
        "pre_issued_reads": 0, "sync_copies": 0}


# ---------------------------------------------------------------------------
# History JSON round-trip (golden-file format)
# ---------------------------------------------------------------------------

def test_history_json_roundtrip():
    data, sim, fl = _setup(dynamics="bernoulli")
    h = FleetEngine(data, sim, fl).run("flude", telemetry="full")
    h.trust = np.linspace(0, 1, sim.num_clients)      # dynamic extra
    d = json.loads(json.dumps(h.to_json()))           # through real JSON
    assert "final_params" not in d
    h2 = History.from_json(d)
    assert _rows(h2) == _rows(h)
    assert h2.metrics == h.metrics
    np.testing.assert_allclose(h2.trust, h.trust)
    np.testing.assert_allclose(h2.part_count, h.part_count)


def test_history_from_json_tolerates_golden_dicts():
    h = History.from_json({"acc": [0.5], "wall_clock": [1.0],
                           "comm_mb": [2.0], "received": [3],
                           "selected": [4]})
    assert h.eval_mask == [] and h.metrics is None
    assert h.time_to_accuracy(0.4) == 1.0             # empty mask = all-True


# ---------------------------------------------------------------------------
# Telemetry session + JSONL + report CLI end-to-end
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def run_artifacts(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("obs")
    jsonl = str(tmp / "run.jsonl")
    trace = str(tmp / "trace.json")
    data, sim, fl = _setup(dynamics="bernoulli")
    tel = obs.Telemetry(level="full", jsonl=jsonl, trace=trace)
    h = FleetEngine(data, sim, fl).run("flude", diagnostics=False,
                                      telemetry=tel)
    tel.close()
    return jsonl, trace, tel, h


def test_jsonl_stream_well_formed(run_artifacts):
    jsonl, _, tel, h = run_artifacts
    lines = [json.loads(l) for l in open(jsonl)]
    kinds = [l["kind"] for l in lines]
    assert kinds[0] == "run_start" and kinds[-1] == "run_end"
    assert kinds.count("round") == len(h.acc)
    start = lines[0]
    assert start["policy"] == "flude" and start["level"] == "full"
    rounds = [l for l in lines if l["kind"] == "round"]
    assert [r["round"] for r in rounds] == list(range(len(h.acc)))
    for r in rounds:
        assert r["received"] == h.received[r["round"]]
        assert r["selected_count"] == h.selected[r["round"]]
    end = lines[-1]
    assert end["rounds"] == len(h.acc)
    assert end["final_acc"] == pytest.approx(h.acc[-1])
    assert "spans" in end and end["spans"]["trainer"]["count"] == \
        len(h.acc)
    assert tel.last_events == lines


def test_trace_file_is_perfetto_loadable(run_artifacts):
    _, trace, tel, h = run_artifacts
    doc = json.load(open(trace))
    evs = doc["traceEvents"]
    assert evs and doc["displayTimeUnit"] == "ms"
    names = {e["name"] for e in evs}
    assert {"trainer", "server_step", "round_cut", "plan",
            "ledger_resolve", "metrics", "rounds"} <= names
    for e in evs:
        assert "ph" in e and "pid" in e and "tid" in e
        if e["ph"] == "X":
            assert e["dur"] >= 0 and isinstance(e["ts"], float)
    # span summary agrees with the event stream
    assert tel.tracer.summary()["trainer"]["count"] == len(h.acc)


def test_report_cli_renders_and_exits_zero(run_artifacts, capsys):
    jsonl, _, _, h = run_artifacts
    assert OR.main([jsonl]) == 0
    out = capsys.readouterr().out
    assert "round-time breakdown" in out
    assert "policy=flude" in out
    assert "local_loss_mean" in out
    assert OR.main([jsonl, "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["rounds"] == len(h.acc)
    assert doc["metrics"]["selected_count"]["last"] == h.selected[-1]
    assert doc["spans"]["trainer"]["count"] == len(h.acc)


def test_report_cli_error_paths(tmp_path, capsys):
    assert OR.main([str(tmp_path / "missing.jsonl")]) == 1
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"kind": "round"\n')
    assert OR.main([str(bad)]) == 1
    assert "bad JSON line" in capsys.readouterr().err
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert OR.main([str(empty)]) == 1


def test_report_parse_groups_multiple_runs(tmp_path):
    path = str(tmp_path / "multi.jsonl")
    data, sim, fl = _setup(dynamics="bernoulli", rounds=2)
    engine = FleetEngine(data, sim, fl)
    for policy in ("flude", "random"):
        tel = obs.Telemetry(level="basic", jsonl=path)
        engine.run(policy, diagnostics=False, telemetry=tel)
        tel.close()
    runs = OR.parse_runs(path)
    assert len(runs) == 2
    assert runs[0]["start"]["policy"] == "flude"
    assert runs[1]["start"]["policy"] == "random"
    assert len(runs[1]["rounds"]) == 2 and runs[1]["end"] is not None
    s = OR.summarize(runs[-1])
    assert s["policy"] == "random" and s["rounds"] == 2


def test_sparkline():
    assert OR.sparkline([]) == ""
    assert OR.sparkline([1.0]) == "▁"
    line = OR.sparkline([0, 1, 2, 3])
    assert line[0] == "▁" and line[-1] == "█" and len(line) == 4
    assert len(OR.sparkline(list(range(100)), width=32)) == 32
