"""Typed policy API: registry, RoundPlan validation, engine semantics,
and cross-policy equivalence with the pre-refactor runner (golden file).
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro import core
from repro.configs.base import FLConfig
from repro.data.synthetic import federated_classification
from repro.fl import (Fleet, FleetEngine, History, Policy, RoundObservation,
                      RoundPlan, SimConfig, available_policies, get_policy,
                      make_policy, register_policy, run_fl)
from repro.fl import api as API
from repro.fl.policies import FludePolicy, SafaPolicy

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "history_prerefactor.json")
GOLDEN_MIFA = os.path.join(os.path.dirname(__file__), "golden",
                           "history_mifa.json")
BUILTINS = ("flude", "random", "oort", "safa", "fedsea", "asyncfeded")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_has_builtins():
    assert set(BUILTINS) | {"mifa"} <= set(available_policies())


def test_registry_roundtrip():
    assert get_policy("flude") is FludePolicy
    sim = SimConfig(num_clients=8)
    fl = FLConfig(num_clients=8, clients_per_round=4)
    pol = make_policy("safa", sim, fl)
    assert isinstance(pol, SafaPolicy) and pol.name == "safa"


def test_registry_unknown_name():
    with pytest.raises(KeyError, match="unknown policy 'nope'"):
        get_policy("nope")
    with pytest.raises(KeyError, match="registered:"):
        make_policy("nope", SimConfig(), FLConfig())


def test_register_decorator_and_duplicates():
    @register_policy("_test_dummy")
    class Dummy(Policy):
        pass
    try:
        assert get_policy("_test_dummy") is Dummy
        assert Dummy.name == "_test_dummy"
        with pytest.raises(ValueError, match="already registered"):
            @register_policy("_test_dummy")
            class Dummy2(Policy):
                pass

        @register_policy("_test_dummy", allow_override=True)
        class Dummy3(Policy):
            pass
        assert get_policy("_test_dummy") is Dummy3
        with pytest.raises(TypeError):
            register_policy("_test_fn")(lambda: None)
    finally:
        API._REGISTRY.pop("_test_dummy", None)


# ---------------------------------------------------------------------------
# RoundPlan validation
# ---------------------------------------------------------------------------

def _masks(n=8, k=3):
    sel = np.zeros(n, bool)
    sel[:k] = True
    return sel


def test_roundplan_create_defaults():
    sel = _masks()
    p = RoundPlan.create(sel, sel, np.zeros(8, bool), 3.0)
    assert p.steps_override is None and p.agg_weights is None
    assert p.quorum == 3.0
    assert p.validate(8) is p


def test_roundplan_rejects_quorum_over_selected():
    sel = _masks(8, 3)
    with pytest.raises(ValueError, match="exceeds the selected count"):
        RoundPlan.create(sel, sel, np.zeros(8, bool), 5.0)


def test_roundplan_rejects_zero_quorum_with_selection():
    sel = _masks(8, 3)
    with pytest.raises(ValueError, match="idle-waits"):
        RoundPlan.create(sel, sel, np.zeros(8, bool), 0.0)
    # no selection -> zero quorum is the only legal value
    empty = np.zeros(8, bool)
    RoundPlan.create(empty, empty, empty, 0.0)


def test_roundplan_rejects_bad_shapes_and_dtypes():
    sel = _masks()
    with pytest.raises(ValueError, match="1-D mask"):
        RoundPlan.create(sel.reshape(2, 4), sel, np.zeros(8, bool), 1.0)
    with pytest.raises(ValueError, match="entries, expected"):
        RoundPlan.create(sel, sel[:4], np.zeros(8, bool), 1.0)
    with pytest.raises(ValueError, match="must be bool"):
        RoundPlan(sel, sel, np.zeros(8, np.int32), 1.0).validate(8)
    with pytest.raises(ValueError, match="required"):
        RoundPlan(sel, None, np.zeros(8, bool), 1.0).validate(8)


def test_roundplan_rejects_resume_outside_selection():
    sel = _masks(8, 3)
    resume = np.zeros(8, bool)
    resume[7] = True
    with pytest.raises(ValueError, match="subset"):
        RoundPlan.create(sel, sel, resume, 1.0)


def test_roundplan_optional_field_validation():
    sel = _masks()
    with pytest.raises(ValueError, match="steps_override"):
        RoundPlan.create(sel, sel, np.zeros(8, bool), 1.0,
                         steps_override=np.ones(8, np.float32))
    with pytest.raises(ValueError, match="steps_override"):
        RoundPlan.create(sel, sel, np.zeros(8, bool), 1.0,
                         steps_override=np.full(8, -1, np.int32))
    with pytest.raises(ValueError, match="agg_weights"):
        RoundPlan.create(sel, sel, np.zeros(8, bool), 1.0,
                         agg_weights=np.full(8, -0.5, np.float32))
    with pytest.raises(ValueError, match="agg_weights"):
        RoundPlan.create(sel, sel, np.zeros(8, bool), 1.0,
                         agg_weights=np.full(4, 1.0, np.float32))
    RoundPlan.create(sel, sel, np.zeros(8, bool), 1.0,
                     steps_override=np.ones(8, np.int32),
                     agg_weights=np.ones(8, np.float32))


def test_roundplan_is_pytree():
    import jax
    sel = _masks()
    p = RoundPlan.create(sel, sel, np.zeros(8, bool), 2.0)
    leaves = jax.tree.leaves(p)
    assert len(leaves) == 4            # None optionals drop out
    p2 = jax.tree.map(lambda x: x, p)
    assert isinstance(p2, RoundPlan) and float(p2.quorum) == 2.0


def test_roundplan_validate_under_jit():
    """Shape/dtype checks run on tracers; value checks skip gracefully."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def f(sel):
        plan = RoundPlan(sel, sel, jnp.zeros_like(sel), 1.0)
        plan.validate(8)
        return plan.selected.sum()

    assert int(f(jnp.ones(8, bool))) == 8


# ---------------------------------------------------------------------------
# SAFA zero-quorum fix
# ---------------------------------------------------------------------------

def test_safa_quorum_clamped_to_one():
    """floor(0.75 * 1) == 0 used to idle-wait the whole deadline."""
    n = 8
    sim = SimConfig(num_clients=n, seed=0)
    fl = FLConfig(num_clients=n, clients_per_round=1)
    pol = SafaPolicy(sim, fl)
    caches = core.init_caches({"w": np.zeros((2,), np.float32)}, n)
    state = pol.init_state()
    _, plan = pol.plan(state, RoundObservation(0, np.ones(n, bool), caches),
                       None)
    assert int(np.asarray(plan.selected).sum()) == 1
    assert float(plan.quorum) == 1.0
    plan.validate(n)


# ---------------------------------------------------------------------------
# History eval semantics
# ---------------------------------------------------------------------------

def test_history_eval_mask_skips_stale_entries():
    h = History(acc=[0.1, 0.95, 0.95], wall_clock=[1.0, 2.0, 3.0],
                comm_mb=[10.0, 20.0, 30.0],
                eval_mask=[True, False, True])
    # the stale (unevaluated) entry at t=2 must not be credited
    assert h.time_to_accuracy(0.9) == 3.0
    assert h.comm_to_accuracy(0.9) == 30.0
    # no mask (legacy construction) -> every entry counts
    h2 = History(acc=[0.1, 0.95], wall_clock=[1.0, 2.0],
                 comm_mb=[10.0, 20.0])
    assert h2.time_to_accuracy(0.9) == 2.0


def test_engine_eval_every_records_mask():
    n = 16
    data = federated_classification(n, seed=0, n_per_client=32)
    sim = SimConfig(num_clients=n, rounds=5, seed=0, local_steps=2)
    fl = FLConfig(num_clients=n, clients_per_round=8)
    h = FleetEngine(data, sim, fl).run("random", eval_every=2)
    assert h.eval_mask == [True, False, True, False, True]
    assert len(h.acc) == 5
    # stale rounds carry the previous measured accuracy forward
    assert h.acc[1] == h.acc[0] and h.acc[3] == h.acc[2]


# ---------------------------------------------------------------------------
# Engine behavior
# ---------------------------------------------------------------------------

def test_engine_runs_reproduce_and_reuse_trainer():
    n = 16
    data = federated_classification(n, seed=1, n_per_client=32)
    sim = SimConfig(num_clients=n, rounds=3, seed=1, local_steps=2)
    fl = FLConfig(num_clients=n, clients_per_round=6)
    engine = FleetEngine(data, sim, fl)
    h1 = engine.run("flude")
    h2 = engine.run("flude")        # fresh fleet per run -> identical
    np.testing.assert_allclose(h1.acc, h2.acc)
    assert len(engine._server_steps) == 1     # compiled path reused
    h3 = engine.run("random")
    assert len(h3.acc) == 3


def test_engine_accepts_policy_instance_and_rounds_cap():
    n = 16
    data = federated_classification(n, seed=1, n_per_client=32)
    sim = SimConfig(num_clients=n, rounds=10, seed=1, local_steps=2)
    fl = FLConfig(num_clients=n, clients_per_round=6)
    engine = FleetEngine(data, sim, fl)
    fleet = Fleet(sim)
    pol = make_policy("safa", sim, fl, fleet)
    h = engine.run(pol, rounds=4)
    assert len(h.acc) == 4


def test_engine_rejects_invalid_plans():
    @register_policy("_test_bad_quorum")
    class BadQuorum(Policy):
        def plan(self, state, obs, rng):
            n = self.fl_cfg.num_clients
            sel = np.zeros(n, bool)
            sel[0] = True
            return state, RoundPlan(sel, sel, np.zeros(n, bool), 7.0)
    try:
        n = 16
        data = federated_classification(n, seed=1, n_per_client=32)
        sim = SimConfig(num_clients=n, rounds=2, seed=1, local_steps=2)
        fl = FLConfig(num_clients=n, clients_per_round=6)
        with pytest.raises(ValueError, match="exceeds the selected count"):
            FleetEngine(data, sim, fl).run("_test_bad_quorum")
    finally:
        API._REGISTRY.pop("_test_bad_quorum", None)


# ---------------------------------------------------------------------------
# Cross-policy equivalence with the pre-refactor runner
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def golden_setup(golden):
    g = golden
    sim = SimConfig(num_clients=g["sim"]["num_clients"],
                    rounds=g["sim"]["rounds"], seed=g["sim"]["seed"],
                    local_steps=g["sim"]["local_steps"])
    fl = FLConfig(num_clients=g["fl"]["num_clients"],
                  clients_per_round=g["fl"]["clients_per_round"])
    data = federated_classification(
        g["sim"]["num_clients"], seed=g["data"]["seed"],
        margin=g["data"]["margin"], noise=g["data"]["noise"],
        n_per_client=g["data"]["n_per_client"])
    return sim, fl, data


@pytest.mark.parametrize("policy", BUILTINS)
def test_matches_prerefactor_trajectory(golden, golden_setup, policy):
    """Each ported policy reproduces the dict-era runner's History on a
    fixed seed (golden recorded from the pre-refactor run_fl; loaded
    through ``History.from_json`` — the golden-file format)."""
    sim, fl, data = golden_setup
    ref = History.from_json(golden["policies"][policy])
    h = run_fl(policy, data, sim, fl)
    np.testing.assert_allclose(h.acc, ref.acc, atol=1e-6)
    np.testing.assert_allclose(h.wall_clock, ref.wall_clock, atol=1e-5)
    np.testing.assert_allclose(h.comm_mb, ref.comm_mb, atol=1e-5)
    assert h.received == ref.received
    assert h.selected == ref.selected


# ---------------------------------------------------------------------------
# MIFA memorized-update baseline (arXiv 2106.04159)
# ---------------------------------------------------------------------------

def test_mifa_matches_golden_trajectory(golden_setup):
    """mifa reproduces its engine-recorded golden (same fixed-seed setup
    as the six pre-refactor policies)."""
    sim, fl, data = golden_setup
    with open(GOLDEN_MIFA) as f:
        ref = History.from_json(json.load(f)["history"])
    h = run_fl("mifa", data, sim, fl)
    np.testing.assert_allclose(h.acc, ref.acc, atol=1e-6)
    np.testing.assert_allclose(h.wall_clock, ref.wall_clock, atol=1e-5)
    np.testing.assert_allclose(h.comm_mb, ref.comm_mb, atol=1e-5)
    assert h.received == ref.received
    assert h.selected == ref.selected


def test_mifa_memorizes_and_undiscounts():
    """mifa selects every online device, always resumes memorized local
    state, and its agg_weights cancel the engine's staleness discount."""
    from repro.fl.policies import MifaPolicy

    n = 8
    sim = SimConfig(num_clients=n, seed=0)
    fl = FLConfig(num_clients=n, clients_per_round=4,
                  staleness_discount=1.0)
    pol = MifaPolicy(sim, fl)
    caches = core.init_caches({"w": np.zeros((2,), np.float32)}, n)
    stamp = np.full(n, -1, np.int32)
    stamp[2] = 1                       # memorized update from round 1
    caches = caches._replace(round_stamp=np.asarray(stamp))
    online = np.ones(n, bool)
    online[5] = False
    _, plan = pol.plan(None, RoundObservation(4, online, caches), None)
    sel = np.asarray(plan.selected)
    assert (sel == online).all()                 # no subsampling
    resume = np.asarray(plan.resume)
    assert resume[2] and resume.sum() == 1       # memorized state resumes
    w = np.asarray(plan.agg_weights)
    # staleness 4-1=3 ⇒ weight (1+3)^{+d} cancels the engine's (1+3)^{-d}
    assert w[2] == pytest.approx(4.0)
    assert (w[online & ~resume] == 1.0).all()
