"""Property-based tests (hypothesis) on FLUDE's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import core
from repro.configs.base import FLConfig
import importlib

D = importlib.import_module("repro.core.dependability")
DI = importlib.import_module("repro.core.distribution")
SE = importlib.import_module("repro.core.selection")

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.lists(st.tuples(st.integers(0, 50), st.integers(0, 50)),
                min_size=1, max_size=16))
def test_dependability_bounded_and_monotone(obs):
    """E[R] ∈ (0,1); adding a success never lowers it."""
    s = jnp.array([o[0] for o in obs], jnp.float32)
    f = jnp.array([o[1] for o in obs], jnp.float32)
    b = D.update_belief(D.init_belief(len(obs)), s, f)
    r = D.dependability(b)
    assert bool((r > 0).all()) and bool((r < 1).all())
    b2 = D.update_belief(b, jnp.ones_like(s), jnp.zeros_like(f))
    assert bool((D.dependability(b2) >= r - 1e-7).all())


@given(st.integers(4, 64), st.integers(1, 16),
       st.floats(0.0, 1.0), st.integers(0, 2 ** 31 - 1))
def test_selection_count_and_membership(n, x, eps, seed):
    x = min(x, n)
    rng = np.random.RandomState(seed)
    online = jnp.asarray(rng.rand(n) < 0.7)
    explored = jnp.asarray(rng.rand(n) < 0.5)
    b = D.init_belief(n)
    res = SE.select_participants(
        b, jnp.zeros((n,), jnp.int32), explored, online,
        jnp.float32(rng.rand() * 100), jnp.int32(x), jnp.float32(eps),
        0.5, jax.random.key(seed % 1000))
    sel = np.asarray(res.selected)
    assert sel.sum() == min(x, int(np.asarray(online).sum()))
    assert not (sel & ~np.asarray(online)).any()
    # exploit/explore partition the selection
    assert not (np.asarray(res.exploited)
                & np.asarray(res.explored_new)).any()
    assert (sel == (np.asarray(res.exploited)
                    | np.asarray(res.explored_new))).all()


@given(st.floats(0.01, 0.99), st.integers(0, 100), st.floats(0.1, 100.0),
       st.floats(0.0, 2.0))
def test_priority_penalty_only_above_threshold(dep, q, Q, sigma):
    n = 1000.0
    b = D.update_belief(D.init_belief(1, 0.0, 0.0),
                        jnp.array([dep * n]), jnp.array([(1 - dep) * n]))
    P = SE.priority(b, jnp.array([q]), jnp.float32(Q), sigma)
    R = float(D.dependability(b)[0])
    if q <= Q:
        np.testing.assert_allclose(float(P[0]), R, rtol=1e-5)
    else:
        assert float(P[0]) <= R + 1e-6


@given(st.lists(st.floats(0.0, 60.0), min_size=2, max_size=12),
       st.floats(1.0, 20.0))
def test_distribution_covers_all_selected(stales, w0):
    """Every selected device either receives the model or resumes."""
    n = len(stales)
    sel = jnp.ones((n,), bool)
    in_v = jnp.asarray([i % 2 == 0 for i in range(n)])
    cache = in_v
    plan = DI.plan_distribution(
        DI.DistributorState(jnp.float32(w0), jnp.float32(1.0),
                            jnp.float32(1.0)),
        sel, in_v, cache, jnp.asarray(stales, jnp.float32),
        lam=1.0, mu=0.5, w_min=1.0, w_max=50.0)
    covered = plan.distribute | plan.resume
    assert bool((covered == sel).all())
    assert not bool((plan.distribute & plan.resume).any())
    assert 1.0 <= float(plan.state.w_threshold) <= 50.0


@given(st.lists(st.floats(0.001, 10.0), min_size=1, max_size=8),
       st.integers(0, 2 ** 31 - 1))
def test_aggregation_convex_hull(ws, seed):
    """Weighted aggregate lies in the convex hull of client values."""
    n = len(ws)
    rng = np.random.RandomState(seed)
    vals = rng.randn(n, 3).astype(np.float32)
    g = {"w": jnp.zeros((3,))}
    out = core.fed_aggregate(g, {"w": jnp.asarray(vals)},
                             jnp.asarray(ws, jnp.float32))
    o = np.asarray(out["w"])
    assert (o >= vals.min(0) - 1e-4).all()
    assert (o <= vals.max(0) + 1e-4).all()


@given(st.integers(1, 6), st.integers(0, 2 ** 31 - 1))
def test_cache_roundtrip_identity(k, seed):
    """write → resume returns exactly the cached state for masked clients."""
    rng = np.random.RandomState(seed)
    n = 5
    tmpl = {"w": jnp.zeros((k, 2))}
    caches = core.init_caches(tmpl, n)
    stacked = {"w": jnp.asarray(rng.randn(n, k, 2), jnp.float32)}
    mask = jnp.asarray(rng.rand(n) < 0.5)
    caches = core.write_cache(caches, mask, stacked,
                              jnp.full((n,), 0.5), 2)
    g = {"w": jnp.asarray(rng.randn(k, 2), jnp.float32)}
    start = core.resume_params(caches, g, mask)
    for i in range(n):
        want = stacked["w"][i] if bool(mask[i]) else g["w"]
        np.testing.assert_allclose(start["w"][i], want)


@given(st.integers(8, 40), st.integers(1, 10), st.floats(1.0, 30.0))
def test_budget_respected(n, x, budget):
    cfg = FLConfig(num_clients=n, clients_per_round=min(x, n),
                   comm_budget=budget)
    stt = core.init_state(cfg)
    caches = core.init_caches({"w": jnp.zeros((1,))}, n)
    plan = core.plan_round(stt, caches, jnp.ones((n,), bool), cfg,
                           jax.random.key(0))
    assert float(plan.predicted_cost) <= budget + 1e-4 or \
        int(plan.selected.sum()) <= 1
