"""Robust-aggregation subsystem: rules, kernels, adversaries, threading.

Covers the ``agg_rule`` axis end to end:

* numpy-reference parity of the Weiszfeld geometric median and the
  coordinate-wise trimmed mean (xla and pallas_interpret impls);
* rule semantics on seeded sweeps — permutation invariance, C=1
  exactness, outlier robustness vs the weighted mean;
* bitwise History parity of ``agg_rule="mean"`` with the direct
  pre-rule aggregation path for every registered policy (the mean alias
  rule goes through the generic ``AggRule.reduce`` machinery; the
  default goes through the historical ``fed_aggregate_packed`` call);
* the trust rule's per-client state: carried on device across rounds,
  surfaced as ``hist.trust``, malicious clients down-weighted;
* zero new per-round host transfers with robust rules + adversaries;
* config validation naming the registered options;
* the adversary layer: deterministic exact-count malicious masks, label
  flipping, scenario presets.
"""
import dataclasses
import subprocess
import sys

import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core.agg_rules import (MeanRule, available_agg_rules,
                                  get_agg_rule, make_agg_rule,
                                  register_agg_rule)
from repro.data.synthetic import federated_classification
from repro.fl import FleetEngine, SimConfig
from repro.fl.api import available_policies
from repro.fleet import (apply_scenario, available_adversaries,
                         get_scenario, make_adversary)
from repro.kernels.robust_agg import ops as R
from repro.kernels.robust_agg.ref import (geometric_median_ref,
                                          trimmed_mean_ref)

N = 12


@pytest.fixture(scope="module")
def data():
    return federated_classification(N, seed=0, n_per_client=24, dim=8,
                                    num_classes=4)


SIM = SimConfig(num_clients=N, rounds=4, seed=0, local_steps=2)
FL = FLConfig(num_clients=N, clients_per_round=6, dynamics="bernoulli")


def _updates(c=7, d=33, seed=0, w_zero=2):
    rng = np.random.RandomState(seed)
    u = rng.randn(c, d).astype(np.float32)
    w = rng.rand(c).astype(np.float32) + 0.1
    w[rng.permutation(c)[:w_zero]] = 0.0
    return u, w


# ---------------------------------------------------------------------------
# Kernel / numpy-reference parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_geometric_median_matches_numpy_ref(impl):
    for seed in range(4):
        u, w = _updates(seed=seed)
        got = np.asarray(R.geometric_median(u, w, impl=impl,
                                            block_c=4, block_d=16))
        want = geometric_median_ref(u, w)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_residual_norms_matches_dense(impl):
    u, _ = _updates(c=9, d=50, seed=3)
    z = u.mean(0)
    got = np.asarray(R.residual_norms(u, z, impl=impl,
                                      block_c=4, block_d=16))
    want = np.linalg.norm(u - z[None], axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_residual_norms_unknown_impl():
    u, _ = _updates()
    with pytest.raises(ValueError, match="unknown robust_agg impl"):
        R.residual_norms(u, u[0], impl="cuda")


def test_trimmed_mean_matches_numpy_ref():
    for seed in range(4):
        u, w = _updates(seed=seed)
        got = np.asarray(R.trimmed_mean(u, w, trim=0.2))
        want = trimmed_mean_ref(u, w, trim=0.2)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_masked_median():
    x = np.array([5.0, 1.0, 9.0, 3.0, 7.0], np.float32)
    valid = np.array([True, True, False, True, True])
    # valid sorted: 1, 3, 5, 7 -> lower median 3
    assert float(R.masked_median(x, valid)) == 3.0
    assert float(R.masked_median(x, np.zeros(5, bool))) == 0.0


# ---------------------------------------------------------------------------
# Rule semantics (seeded sweeps)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule", ["geometric_median", "trimmed_mean",
                                  "mean"])
def test_rule_permutation_invariance(rule):
    r = make_agg_rule(rule)
    for seed in range(3):
        u, w = _updates(seed=seed)
        perm = np.random.RandomState(seed + 50).permutation(len(w))
        g = np.zeros(u.shape[1], np.float32)
        a = np.asarray(r.reduce(u, g, w))
        b = np.asarray(r.reduce(u[perm], g, w[perm]))
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("rule", ["geometric_median", "trimmed_mean",
                                  "mean"])
def test_rule_single_client_exactness(rule):
    """C=1 (one received client): every rule returns that update."""
    r = make_agg_rule(rule)
    u, _ = _updates(c=1, w_zero=0)
    w = np.ones(1, np.float32)
    got = np.asarray(r.reduce(u, np.zeros(u.shape[1], np.float32), w))
    np.testing.assert_allclose(got, u[0], rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("rule", ["geometric_median", "trimmed_mean"])
def test_rule_outlier_robustness_vs_mean(rule):
    """One wild client moves the mean much more than the robust rules."""
    r = make_agg_rule(rule)
    mean = MeanRule()
    for seed in range(3):
        rng = np.random.RandomState(seed)
        honest = rng.randn(9, 40).astype(np.float32) * 0.1 + 1.0
        u = np.concatenate([honest, np.full((1, 40), -80.0, np.float32)])
        w = np.ones(10, np.float32)
        g = np.zeros(40, np.float32)
        center = honest.mean(0)
        err_robust = np.linalg.norm(np.asarray(r.reduce(u, g, w)) - center)
        err_mean = np.linalg.norm(np.asarray(mean.reduce(u, g, w))
                                  - center)
        assert err_robust < 0.2 * err_mean, (rule, err_robust, err_mean)


def test_trimmed_mean_drops_extremes_exactly():
    """With unit weights the trimmed mean ignores the k most extreme
    values per coordinate on both sides."""
    u = np.array([[0.0], [1.0], [2.0], [3.0], [100.0]], np.float32)
    w = np.ones(5, np.float32)
    got = float(np.asarray(R.trimmed_mean(u, w, trim=0.2))[0])
    assert got == pytest.approx(2.0)    # keeps {1, 2, 3}


def test_geometric_median_zero_weight_rows_ignored():
    u, w = _updates(c=8, w_zero=0, seed=9)
    w[:3] = 0.0
    u2 = np.array(u)
    u2[:3] = 1e6                         # garbage in the dead rows
    a = np.asarray(R.geometric_median(u, w))
    b = np.asarray(R.geometric_median(u2, w))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Registry + config validation
# ---------------------------------------------------------------------------

def test_registry_contents():
    names = available_agg_rules()
    for expected in ("mean", "geometric_median", "trimmed_mean", "trust"):
        assert expected in names
    assert get_agg_rule("mean") is MeanRule
    with pytest.raises(KeyError, match="geometric_median"):
        get_agg_rule("krum")


def test_register_agg_rule_rejects_non_rule():
    with pytest.raises(TypeError, match="AggRule subclass"):
        register_agg_rule("bogus")(dict)


def test_stateless_rule_has_no_state_api():
    r = MeanRule()
    with pytest.raises(NotImplementedError, match="stateless"):
        r.init_state(4)


def test_flconfig_validates_agg_impl():
    with pytest.raises(ValueError, match="pallas_interpret"):
        FLConfig(num_clients=8, agg_impl="triton")


def test_flconfig_validates_agg_rule():
    with pytest.raises(ValueError, match="geometric_median"):
        FLConfig(num_clients=8, agg_rule="median_of_means")


def test_flconfig_validates_adversary():
    with pytest.raises(ValueError, match="sign_flip"):
        FLConfig(num_clients=8, adversary="backdoor")


# ---------------------------------------------------------------------------
# Mean stays bit-identical; the generic rule path reproduces it
# ---------------------------------------------------------------------------

def _hist_key(h):
    return (tuple(h.acc), tuple(h.comm_mb), tuple(h.wall_clock),
            tuple(h.received), tuple(h.selected))


# registered once: the mean rule forced through the generic
# ``AggRule.reduce`` machinery instead of the rule=None direct path
# (a subclass — the decorator stamps ``cls.name``, and MeanRule itself
# must keep its registered name)
if "mean_alias" not in available_agg_rules():
    @register_agg_rule("mean_alias")
    class _MeanAlias(MeanRule):
        pass


@pytest.mark.parametrize("policy", available_policies())
def test_mean_alias_bitwise_history_parity(data, policy):
    """For every registered policy, the generic rule path under
    ``agg_rule="mean_alias"`` reproduces the direct ``agg_rule="mean"``
    History bit for bit — the refactor moved the default aggregation
    without changing a single ULP."""
    hists = []
    for rule in ("mean", "mean_alias"):
        fl = dataclasses.replace(FL, agg_rule=rule)
        hists.append(FleetEngine(data, SIM, fl).run(
            policy, diagnostics=False))
    assert _hist_key(hists[0]) == _hist_key(hists[1]), policy


@pytest.mark.parametrize("variant", ["host", "cohort", "depth2"])
def test_mean_alias_parity_other_paths(data, variant):
    """The bitwise mean parity holds on the legacy host loop, the
    compact-cohort path and the pipelined loop too."""
    changes = {"host": dict(dynamics="bernoulli_host"),
               "cohort": dict(cohort_size=8),
               "depth2": dict(pipeline_depth=2)}[variant]
    hists = []
    for rule in ("mean", "mean_alias"):
        fl = dataclasses.replace(FL, agg_rule=rule, **changes)
        hists.append(FleetEngine(data, SIM, fl).run(
            "flude", diagnostics=False))
    assert _hist_key(hists[0]) == _hist_key(hists[1]), variant


# ---------------------------------------------------------------------------
# Engine integration: robust rules + adversaries
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule", ["geometric_median", "trimmed_mean",
                                  "trust"])
@pytest.mark.parametrize("dyn", ["bernoulli", "bernoulli_host"])
def test_robust_rules_run_under_attack(data, rule, dyn):
    fl = dataclasses.replace(
        FL, dynamics=dyn, agg_rule=rule, adversary="sign_flip",
        adversary_params=(("malicious_frac", 0.25),))
    h = FleetEngine(data, SIM, fl).run("flude", diagnostics=False)
    assert len(h.acc) == SIM.rounds
    assert np.isfinite(h.acc[-1])


def test_robust_rule_cohort_and_pipeline(data):
    """Robust rules ride the compact-cohort path at pipeline depth 2
    (the stateful trust rule threads its (N,) state through the gathered
    step and back)."""
    fl = dataclasses.replace(
        FL, agg_rule="trust", cohort_size=8, pipeline_depth=2,
        adversary="sign_flip", adversary_params=(("malicious_frac", 0.25),))
    h = FleetEngine(data, SIM, fl).run("flude", diagnostics=False)
    assert hasattr(h, "trust") and h.trust.shape == (N,)


def test_trust_downweights_malicious(data):
    """After sign-flip rounds, the trust rule's learned per-client scores
    are lower on the malicious slice than on the honest one.  The fleet
    is dependable here (trust only updates on *received* uploads — a
    malicious client that never uploads keeps its init score)."""
    sim = dataclasses.replace(SIM, rounds=10,
                              undep_means=(0.02, 0.02, 0.02))
    fl = dataclasses.replace(
        FL, clients_per_round=N, agg_rule="trust", adversary="sign_flip",
        adversary_params=(("malicious_frac", 0.25),))
    engine = FleetEngine(data, sim, fl)
    h = engine.run("random", diagnostics=False)
    mask = engine._malicious_np
    assert mask.sum() == 3               # exact count at 25% of 12
    assert h.trust[mask].mean() < h.trust[~mask].mean() - 0.1, h.trust


def test_trust_state_fresh_per_run(data):
    """Each ``run()`` starts from the rule's init state — back-to-back
    runs produce identical trust trajectories."""
    fl = dataclasses.replace(
        FL, agg_rule="trust", adversary="sign_flip",
        adversary_params=(("malicious_frac", 0.25),))
    engine = FleetEngine(data, SIM, fl)
    t1 = engine.run("random", diagnostics=False).trust
    t2 = engine.run("random", diagnostics=False).trust
    np.testing.assert_array_equal(t1, t2)


def test_label_flip_changes_training_labels(data):
    """Label-flip is data poisoning: the engine's training labels differ
    from the clean set exactly on the malicious rows."""
    fl = dataclasses.replace(
        FL, adversary="label_flip",
        adversary_params=(("malicious_frac", 0.25),))
    engine = FleetEngine(data, SIM, fl)
    mask = engine._malicious_np
    y0 = np.asarray(data.y)
    y1 = np.asarray(engine.data.y)
    assert (y1[mask] != y0[mask]).any()
    np.testing.assert_array_equal(y1[~mask], y0[~mask])
    np.testing.assert_array_equal(y1[mask],
                                  (data.num_classes - 1) - y0[mask])


def test_server_step_memory_with_robust_rule(data):
    fl = dataclasses.replace(
        FL, agg_rule="trust", adversary="sign_flip")
    m = FleetEngine(data, SIM, fl).server_step_memory()
    assert m["peak_live_bytes"] > 0


def test_robust_rules_add_no_per_round_transfers(data, monkeypatch):
    """Acceptance: the robust axis adds zero per-round host→device
    hand-offs — the ``place_per_client`` count stays round-count-
    independent with a stateful rule + adversary configured."""
    import repro.fl.engine as ENG
    import repro.fl.policies as POL
    import repro.fl.simulator as SIMM

    counts = {"n": 0}
    orig = SIMM.place_per_client

    def counting(arr, mesh=None):
        counts["n"] += 1
        return orig(arr, mesh)

    for mod in (ENG, POL, SIMM):
        monkeypatch.setattr(mod, "place_per_client", counting)

    fl = dataclasses.replace(
        FL, agg_rule="trust", adversary="sign_flip",
        adversary_params=(("malicious_frac", 0.25),))
    engine = FleetEngine(data, SIM, fl)
    engine.run("flude", rounds=1, diagnostics=False)   # compile + place
    per_run = []
    for rounds in (1, 4):
        counts["n"] = 0
        engine.run("flude", rounds=rounds, diagnostics=False)
        per_run.append(counts["n"])
    assert per_run[0] == per_run[1], per_run


# ---------------------------------------------------------------------------
# Adversary layer
# ---------------------------------------------------------------------------

def test_malicious_mask_exact_and_deterministic():
    adv = make_adversary("sign_flip", (("malicious_frac", 0.2),))
    m1 = adv.malicious_mask(50, seed=3)
    m2 = adv.malicious_mask(50, seed=3)
    assert m1.sum() == 10
    np.testing.assert_array_equal(m1, m2)
    assert adv.malicious_mask(50, seed=4).sum() == 10


def test_adversary_registry_and_validation():
    assert set(available_adversaries()) >= {"sign_flip", "grad_scale",
                                            "label_flip"}
    assert make_adversary("sign_flip").delta_scale == -4.0
    assert make_adversary("grad_scale").delta_scale == 10.0
    assert make_adversary("label_flip").flips_labels
    with pytest.raises(ValueError, match="malicious_frac"):
        make_adversary("sign_flip", (("malicious_frac", 1.5),))
    with pytest.raises(ValueError, match="scale"):
        make_adversary("sign_flip", (("scale", -1.0),))


@pytest.mark.parametrize("name", ["sign-flip-10", "sign-flip-20",
                                  "label-flip-20", "grad-scale-10"])
def test_attack_scenarios_apply(name):
    sc = get_scenario(name)
    fl = apply_scenario(FL, name)
    assert fl.adversary == sc.adversary
    assert fl.adversary_params == sc.adversary_params
    # benign scenarios leave the adversary untouched
    attacked = apply_scenario(fl, "churn")
    assert attacked.adversary == sc.adversary


# ---------------------------------------------------------------------------
# Sharded parity (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import numpy as np
from repro.configs.base import FLConfig
from repro.data.synthetic import federated_classification
from repro.fl import FleetEngine, SimConfig

n = 16
data = federated_classification(n, seed=0, n_per_client=24, dim=8,
                                num_classes=4)
sim = SimConfig(num_clients=n, rounds=3, seed=0, local_steps=2)
out = {}
for rule in ("geometric_median", "trust"):
    accs = {}
    for mesh in (None, (8,)):
        fl = FLConfig(num_clients=n, clients_per_round=8,
                      dynamics="bernoulli", mesh_shape=mesh,
                      agg_rule=rule, adversary="sign_flip",
                      adversary_params=(("malicious_frac", 0.25),))
        h = FleetEngine(data, sim, fl).run("flude", diagnostics=False)
        accs["single" if mesh is None else "mesh8"] = h.acc
    out[rule] = accs
print(json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_robust_rules_match_single_device():
    """geometric_median and the stateful trust rule agree between the
    single-device path and the 8-way client mesh (shard_map psum path) to
    float tolerance."""
    env = dict(__import__("os").environ)
    r = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-4000:]
    import json
    out = json.loads(r.stdout.strip().splitlines()[-1])
    for rule, accs in out.items():
        np.testing.assert_allclose(accs["single"], accs["mesh8"],
                                   rtol=0, atol=5e-2, err_msg=rule)
