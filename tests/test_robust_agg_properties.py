"""Hypothesis property tests for the robust aggregation rules.

The deterministic seeded sweeps in tests/test_robust_agg.py cover the
same invariants without the hypothesis dependency; this module widens
the search space (randomized client/parameter counts, weight sparsity,
adversarial outlier magnitudes) where hypothesis is available.

Properties:

* numpy-reference parity of the geometric median across random shapes
  and weight patterns (xla impl; the pallas_interpret parity on the same
  oracle lives in the seeded sweep);
* permutation invariance of every stateless rule;
* C=1 exactness: with one received client the rules return its update;
* outlier robustness: a bounded-fraction adversarial cluster moves the
  geometric median strictly less than it moves the weighted mean.
"""
import numpy as np
import pytest

from repro.core.agg_rules import make_agg_rule
from repro.kernels.robust_agg import ops as R
from repro.kernels.robust_agg.ref import geometric_median_ref

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

settings.register_profile("robust_agg", max_examples=40, deadline=None)
settings.load_profile("robust_agg")


def _problem(c, d, seed, zero_frac):
    rng = np.random.RandomState(seed)
    u = rng.randn(c, d).astype(np.float32)
    w = rng.rand(c).astype(np.float32) + 0.05
    nz = int(zero_frac * c)
    if nz >= c:
        nz = c - 1
    w[rng.permutation(c)[:nz]] = 0.0
    return u, w


@given(c=st.integers(2, 24), d=st.integers(1, 80),
       seed=st.integers(0, 2 ** 16), zero_frac=st.floats(0.0, 0.8))
def test_gm_matches_ref(c, d, seed, zero_frac):
    u, w = _problem(c, d, seed, zero_frac)
    got = np.asarray(R.geometric_median(u, w))
    want = geometric_median_ref(u, w)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-5)


@given(rule=st.sampled_from(["mean", "geometric_median", "trimmed_mean"]),
       c=st.integers(2, 16), d=st.integers(1, 40),
       seed=st.integers(0, 2 ** 16))
def test_rule_permutation_invariance(rule, c, d, seed):
    u, w = _problem(c, d, seed, 0.3)
    perm = np.random.RandomState(seed ^ 0xBEEF).permutation(c)
    r = make_agg_rule(rule)
    g = np.zeros(d, np.float32)
    a = np.asarray(r.reduce(u, g, w))
    b = np.asarray(r.reduce(u[perm], g, w[perm]))
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-6)


@given(rule=st.sampled_from(["mean", "geometric_median", "trimmed_mean"]),
       d=st.integers(1, 60), seed=st.integers(0, 2 ** 16))
def test_rule_single_client_exact(rule, d, seed):
    rng = np.random.RandomState(seed)
    u = rng.randn(1, d).astype(np.float32)
    w = np.ones(1, np.float32)
    r = make_agg_rule(rule)
    got = np.asarray(r.reduce(u, np.zeros(d, np.float32), w))
    np.testing.assert_allclose(got, u[0], rtol=1e-5, atol=1e-6)


@given(honest=st.integers(6, 20), bad=st.integers(1, 2),
       d=st.integers(2, 40), seed=st.integers(0, 2 ** 16),
       mag=st.floats(10.0, 1e4))
def test_gm_more_robust_than_mean(honest, bad, d, seed, mag):
    """An adversarial cluster (<~25% of the weight) at magnitude ``mag``
    displaces the geometric median strictly less than the mean."""
    rng = np.random.RandomState(seed)
    hu = rng.randn(honest, d).astype(np.float32) * 0.2 + 1.0
    bu = np.full((bad, d), -mag, np.float32)
    u = np.concatenate([hu, bu])
    w = np.ones(honest + bad, np.float32)
    center = hu.mean(0)
    g = np.zeros(d, np.float32)
    gm = np.asarray(make_agg_rule("geometric_median").reduce(u, g, w))
    mean = np.asarray(make_agg_rule("mean").reduce(u, g, w))
    err_gm = np.linalg.norm(gm - center)
    err_mean = np.linalg.norm(mean - center)
    assert err_gm < err_mean
