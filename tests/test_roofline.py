"""Roofline HLO-analyzer tests: exact flop counts + trip-count recovery."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.analysis import Roofline, build_roofline, model_flops
from repro.roofline.hlo import analyze_hlo_text, compiled_cost_analysis


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    c = _compile(lambda x, y: x @ y, a, b)
    cost = analyze_hlo_text(c.as_text())
    assert abs(cost.flops - 2 * 256 * 512 * 128) / (2 * 256 * 512 * 128) \
        < 0.01


def test_scan_trip_count_multiplied():
    def g(a, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, a, ws)
        return y

    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    a = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    cost = analyze_hlo_text(_compile(g, a, ws).as_text())
    expect = 10 * 2 * 32 * 128 * 128
    assert abs(cost.flops - expect) / expect < 0.05
    # XLA's own cost_analysis does NOT multiply (documents why we parse)
    xla = compiled_cost_analysis(_compile(g, a, ws))["flops"]
    assert xla < cost.flops / 5


def test_nested_scan():
    def h(a, ws):
        def outer(x, grp):
            def inner(x, w):
                return x @ w, None
            x, _ = jax.lax.scan(inner, x, grp)
            return x, None
        y, _ = jax.lax.scan(outer, a, ws)
        return y

    ws = jax.ShapeDtypeStruct((5, 4, 64, 64), jnp.float32)
    a = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    cost = analyze_hlo_text(_compile(h, a, ws).as_text())
    expect = 20 * 2 * 16 * 64 * 64
    assert abs(cost.flops - expect) / expect < 0.05


def test_grad_roughly_triples_flops():
    def f(w, x):
        return jnp.sum(jnp.tanh(x @ w) ** 2)

    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    fwd = analyze_hlo_text(_compile(f, w, x).as_text()).flops
    bwd = analyze_hlo_text(
        _compile(jax.grad(f), w, x).as_text()).flops
    assert 2.0 < bwd / fwd < 4.5


def test_roofline_terms_and_dominance():
    r = Roofline(
        arch="x", shape="train_4k", mesh="single",
        flops_per_device=197e12,          # exactly 1 s of compute
        bytes_per_device=819e9 * 2,       # 2 s of HBM
        collective_bytes_per_device=0.0,
        collective_wire_bytes=50e9 * 0.5,  # 0.5 s of ICI
        collective_breakdown={}, model_flops_total=197e12 * 128,
        n_devices=256, notes=[])
    assert abs(r.compute_s - 1.0) < 1e-6
    assert abs(r.memory_s - 2.0) < 1e-6
    assert abs(r.collective_s - 0.5) < 1e-6
    assert r.dominant == "memory"
    assert abs(r.useful_flops_fraction - 0.5) < 1e-6


def test_model_flops_reference():
    from repro.configs import INPUT_SHAPES, get_config
    cfg = get_config("qwen2-7b")
    shp = INPUT_SHAPES["train_4k"]
    f = model_flops(cfg, shp, 7.6e9, "train")
    assert abs(f - 6 * 7.6e9 * 256 * 4096) < 1e9
    d = model_flops(cfg, INPUT_SHAPES["decode_32k"], 7.6e9, "decode")
    assert abs(d - 2 * 7.6e9 * 128) < 1e6
