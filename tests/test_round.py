"""C5 unit tests: Algorithm 2 round process."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.configs.base import FLConfig


def _setup(n=32, budget=float("inf")):
    cfg = FLConfig(num_clients=n, clients_per_round=8, comm_budget=budget)
    st = core.init_state(cfg)
    caches = core.init_caches({"w": jnp.zeros((2,))}, n)
    return cfg, st, caches


def test_plan_selects_requested_count():
    cfg, st, caches = _setup()
    plan = core.plan_round(st, caches, jnp.ones((32,), bool), cfg,
                           jax.random.key(0))
    assert int(plan.selected.sum()) == 8
    assert int((plan.distribute | plan.resume).sum()) == 8


def test_budget_shrinks_participants():
    cfg, st, caches = _setup(budget=6.0)
    plan = core.plan_round(st, caches, jnp.ones((32,), bool), cfg,
                           jax.random.key(0))
    assert float(plan.predicted_cost) <= 6.0 + 1e-5
    assert int(plan.selected.sum()) < 8


def test_quorum_is_S_times_Rbar():
    cfg, st, caches = _setup()
    plan = core.plan_round(st, caches, jnp.ones((32,), bool), cfg,
                           jax.random.key(0))
    # fresh fleet: R̄ = 0.5 (Beta(2,2) prior) ⇒ quorum = ceil(8·0.5) = 4
    assert float(plan.quorum) == 4.0


def test_update_after_round_bookkeeping():
    cfg, st, caches = _setup()
    plan = core.plan_round(st, caches, jnp.ones((32,), bool), cfg,
                           jax.random.key(0))
    received = plan.selected & (jnp.arange(32) % 2 == 0)
    st2 = core.update_after_round(st, plan, received, cfg)
    assert int(st2.round) == 1
    assert float(st2.epsilon) < float(st.epsilon)
    assert float(st2.total_selected) == float(plan.selected.sum())
    # successes raised alpha, failures raised beta
    suc = plan.selected & received
    fail = plan.selected & ~received
    np.testing.assert_allclose(
        np.asarray(st2.belief.alpha - st.belief.alpha),
        np.asarray(suc, np.float32))
    np.testing.assert_allclose(
        np.asarray(st2.belief.beta - st.belief.beta),
        np.asarray(fail, np.float32))
    # V membership: selected-but-failed
    np.testing.assert_array_equal(np.asarray(st2.in_v), np.asarray(fail))


def test_dependable_devices_win_over_rounds():
    """Over rounds, FLUDE's selection mass shifts to dependable devices."""
    cfg = FLConfig(num_clients=20, clients_per_round=5,
                   epsilon_init=0.5, epsilon_decay=0.8)
    st = core.init_state(cfg)
    caches = core.init_caches({"w": jnp.zeros((1,))}, 20)
    rng = jax.random.key(0)
    dependable = jnp.arange(20) < 10     # first half always succeed
    picks = np.zeros(20)
    for r in range(40):
        rng, k1, k2 = jax.random.split(rng, 3)
        plan = core.plan_round(st, caches, jnp.ones((20,), bool), cfg, k1)
        rand = jax.random.uniform(k2, (20,))
        received = plan.selected & (dependable | (rand < 0.1))
        st = core.update_after_round(st, plan, received, cfg)
        picks += np.asarray(plan.selected, np.float32)
    assert picks[:10].sum() > 1.5 * picks[10:].sum()
