"""Device-resident round close + pipelined device loop.

Covers the tentpole invariants of the pipelined engine:
  * the jitted round cut (``core.make_round_cut``) matches the numpy
    reference (``core.host_round_cut``) bit-for-bit on float32 times —
    hypothesis property tests over inf-heavy times, quorum 0/1/N and the
    async (``waits_for_stragglers=False``) close-at-last-arrival path;
  * ``pipeline_depth`` changes scheduling only: trajectories are
    identical at depths 1/2/4 for every registered policy;
  * the ``time_budget`` stale-final-accuracy fix, the ``steps_override``
    over-charging fix, and the offline-download comm accounting fix.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.configs.base import FLConfig
from repro.data.synthetic import federated_classification
from repro.fl import (Fleet, FleetEngine, Policy, RoundObservation,
                      RoundPlan, SimConfig, available_policies,
                      make_trainer, register_policy)
from repro.fl import api as API

DEADLINE = 600.0


def _ref(times, quorum, waits, deadline=DEADLINE):
    return core.host_round_cut(times, quorum, deadline, waits)


def _check(times, quorum, waits, deadline=DEADLINE):
    """Jitted cut == numpy reference (cut, billed duration, receive
    mask), applying the ledger's billing rule for deadline-capped rounds
    (``deadline if capped else float(t_cut)`` — the float64 deadline may
    not be float32-representable)."""
    times = np.asarray(times, np.float32)
    success = np.isfinite(times)
    t_ref, d_ref = _ref(times, quorum, waits, deadline)
    cut = core.make_round_cut(times.shape[0], deadline, waits)
    t_dev, recv, capped = cut(jnp.asarray(times), quorum,
                              jnp.asarray(success))
    billed = deadline if bool(capped) else float(t_dev)
    assert billed == t_ref, (billed, t_ref)
    assert billed == d_ref
    # receive reference: float32 compare against the float32-nearest cast
    # of the host cut — the engine's receive semantics since PR 4 (the
    # old jitted received_fn weak-cast the f64 cut to f32)
    np.testing.assert_array_equal(
        np.asarray(recv), success & (times <= np.float32(t_ref)))


# ---------------------------------------------------------------------------
# Jitted cut vs numpy reference (seeded sweep; the hypothesis variants
# live in tests/test_round_close_properties.py)
# ---------------------------------------------------------------------------

def _times_case(n, inf_rate, seed):
    """(N,) float32 finish times with an ``inf_rate`` share of
    never-uploads (inf), like the engine's timing model produces."""
    rng = np.random.RandomState(seed)
    t = rng.uniform(1.0, 2.0 * DEADLINE, n).astype(np.float32)
    t[rng.rand(n) < inf_rate] = np.inf
    return t


@pytest.mark.parametrize("waits", [True, False])
def test_cut_matches_host_reference_sweep(waits):
    """Deterministic sweep over fleet sizes, inf densities and quorums —
    the jitted cut must reproduce the numpy reference exactly (the
    hypothesis property test widens this search space on CI)."""
    rng = np.random.RandomState(7)
    for case in range(60):
        n = int(rng.randint(1, 65))
        inf_rate = float(rng.rand())
        times = _times_case(n, inf_rate, case)
        finite = int(np.isfinite(times).sum())
        quorums = {0.0, 1.0, float(n), float(min(finite + 1, n)),
                   float(rng.randint(0, n + 1)),
                   float(np.float32(rng.rand() * n))}
        for q in quorums:
            _check(times, q, waits)


@pytest.mark.parametrize("waits", [True, False])
def test_cut_non_float32_deadline_bills_exact_config_value(waits):
    """round_deadline values float32 cannot represent (100.3) must bill
    exactly on capped rounds — the cut returns a cap *flag* (decided via
    the largest float32 ≤ deadline, so ``t > deadline`` is exact) and the
    ledger substitutes the float64 config value, while the receive
    compare keeps the engine's float32-nearest semantics."""
    for deadline in (100.3, 600.1, 599.9999999):
        assert float(np.float32(deadline)) != deadline   # the hard case
        for seed in range(6):
            times = _times_case(12, 0.5, seed)
            for q in (1.0, 6.0, 12.0, 13.0):
                _check(times, q, waits, deadline=deadline)
        # a device finishing at exactly float32-nearest(deadline), just
        # above the true deadline: billed duration stays the exact f64
        # deadline, and the receive mask matches the engine's f32 rule
        edge = np.asarray([1.0, float(np.float32(deadline)), np.inf],
                          np.float32)
        _check(edge, 2.0, waits, deadline=deadline)


def test_cut_async_closes_at_last_arrival():
    """waits_for_stragglers=False with an unmet quorum closes at the last
    finite arrival (deadline-capped) instead of idle-waiting."""
    for seed in range(8):
        times = _times_case(24, 0.6, seed)
        finite = np.sort(times[np.isfinite(times)])
        q = float(finite.size + 1)      # quorum never met
        _check(times, q, waits=False)
        if finite.size:
            cut = core.make_round_cut(24, DEADLINE, False)
            t_dev, _, capped = cut(jnp.asarray(times), q,
                                   jnp.asarray(np.isfinite(times)))
            billed = DEADLINE if bool(capped) else float(t_dev)
            assert billed == min(float(finite[-1]), DEADLINE)


def test_cut_all_inf_times_hits_deadline():
    times = np.full(7, np.inf, np.float32)
    for waits in (True, False):
        _check(times, 3.0, waits)
        cut = core.make_round_cut(7, DEADLINE, waits)
        t, recv, capped = cut(jnp.asarray(times), 3.0, jnp.zeros(7, bool))
        assert bool(capped) and float(t) == DEADLINE
        assert not np.asarray(recv).any()


def test_cut_respects_small_deadline():
    times = np.asarray([1.0, 2.0, 50.0, np.inf], np.float32)
    _check(times, 3.0, True, deadline=10.0)
    cut = core.make_round_cut(4, 10.0, True)
    t, recv, capped = cut(jnp.asarray(times), 3.0,
                          jnp.asarray(np.isfinite(times)))
    assert bool(capped) and float(t) == 10.0
    np.testing.assert_array_equal(np.asarray(recv),
                                  [True, True, False, False])


# ---------------------------------------------------------------------------
# Pipeline depth changes scheduling only
# ---------------------------------------------------------------------------

def _setup(n=16, rounds=3, **fl_kw):
    data = federated_classification(n, seed=0, n_per_client=32)
    sim = SimConfig(num_clients=n, rounds=rounds, seed=0, local_steps=2)
    fl = FLConfig(num_clients=n, clients_per_round=8, **fl_kw)
    return data, sim, fl


def _rows(h):
    return (h.acc, h.wall_clock, h.comm_mb, h.received, h.selected,
            h.eval_mask)


@pytest.mark.parametrize("policy", sorted(
    p for p in available_policies() if not p.startswith("_")))
def test_pipeline_depth_trajectory_parity(policy):
    """Depths 1/2/4 produce identical History rows for every registered
    policy on the device round path (depth > rounds exercises the
    flush-at-end path too)."""
    data, sim, fl = _setup(dynamics="bernoulli")
    ref = FleetEngine(data, sim, fl).run(policy, eval_every=2,
                                         diagnostics=False)
    for depth in (2, 4):
        fl_d = dataclasses.replace(fl, pipeline_depth=depth)
        h = FleetEngine(data, sim, fl_d).run(policy, eval_every=2,
                                             diagnostics=False)
        assert _rows(h) == _rows(ref), (policy, depth)


def test_pipeline_depth_parity_with_donation():
    """Buffer donation + rounds in flight is the riskiest aliasing combo:
    the server step recycles the previous global/caches while the ledger
    still holds round k's scalars — values must not change."""
    data, sim, fl = _setup(dynamics="bernoulli")
    ref = FleetEngine(data, sim, fl).run("flude", eval_every=2,
                                         diagnostics=False)
    fl_d = dataclasses.replace(fl, donate_buffers=True, pipeline_depth=3)
    engine = FleetEngine(data, sim, fl_d)
    h1 = engine.run("flude", eval_every=2, diagnostics=False)
    h2 = engine.run("flude", eval_every=2, diagnostics=False)
    assert _rows(h1) == _rows(ref) and _rows(h2) == _rows(ref)


def test_pipeline_depth_validated():
    data, sim, fl = _setup(pipeline_depth=0)
    with pytest.raises(ValueError, match="pipeline_depth"):
        FleetEngine(data, sim, fl)


def test_pipelined_progress_callback_sees_each_round():
    data, sim, fl = _setup(rounds=3, dynamics="bernoulli",
                           pipeline_depth=2)
    seen = []
    FleetEngine(data, sim, fl).run(
        "flude", diagnostics=False,
        progress=lambda rnd, acc, comm, time: seen.append(rnd))
    # rnd % 10 == 0 ticks plus the final round (regression: the last
    # round used to be dropped whenever (rounds-1) % 10 != 0)
    assert seen == [0, 2]


@pytest.mark.parametrize("dynamics", ["bernoulli_host", "bernoulli"])
def test_progress_callback_always_ticks_final_round(dynamics):
    """Both round loops report the final round to ``progress`` even when
    it falls off the every-10-rounds cadence, so a live ticker ends on
    the run's true final accuracy/cost row."""
    data, sim, fl = _setup(rounds=15, dynamics=dynamics)
    seen = []
    FleetEngine(data, sim, fl).run(
        "random", diagnostics=False,
        progress=lambda rnd, acc, comm, time: seen.append(rnd))
    assert seen == [0, 10, 14]


# ---------------------------------------------------------------------------
# Bugfix: time_budget break leaves a stale final accuracy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dynamics", ["bernoulli_host", "bernoulli"])
def test_time_budget_forces_final_eval(dynamics):
    """A budget break between eval boundaries used to leave hist.acc[-1]
    as a stale carried-forward value; the engine now forces an eval of
    the final global model on the last booked round."""
    data, sim, fl = _setup(rounds=30, dynamics=dynamics)
    engine = FleetEngine(data, sim, fl)
    # budget chosen to stop after a handful of rounds, off eval cadence
    h = engine.run("random", time_budget=3 * sim.round_deadline,
                   eval_every=100)
    assert 1 < len(h.acc) < 30          # the budget actually bit
    assert h.eval_mask[-1]
    from repro.fl.classifier import clf_accuracy
    fresh = float(jax.jit(clf_accuracy)(
        h.final_params, jnp.asarray(data.test_x),
        jnp.asarray(data.test_y)))
    assert h.acc[-1] == pytest.approx(fresh, abs=0)
    # the stale value it replaced came from the round-0 eval
    assert h.eval_mask[0] and not any(h.eval_mask[1:-1])


def test_round_cap_termination_needs_no_forced_eval():
    """Runs that exhaust n_rounds always evaluate the last round — the
    forced final eval must not fire (eval_mask semantics unchanged)."""
    data, sim, fl = _setup(rounds=5)
    h = FleetEngine(data, sim, fl).run("random", eval_every=2)
    assert h.eval_mask == [True, False, True, False, True]


# ---------------------------------------------------------------------------
# Bugfix: steps_override over-charging
# ---------------------------------------------------------------------------

def test_oversized_steps_override_rejected():
    """An override beyond the trainer's scan length is caught at plan
    validation instead of silently truncating training while the timing
    model charges the full request."""

    @register_policy("_test_oversized_steps")
    class Oversized(Policy):
        def plan(self, state, obs, rng):
            n = self.fl_cfg.num_clients
            sel = np.asarray(obs.online).copy()
            return state, RoundPlan(
                sel, sel, np.zeros(n, bool), float(max(sel.sum(), 1)),
                steps_override=np.full(n, 99, np.int32))
    try:
        data, sim, fl = _setup(rounds=1)
        with pytest.raises(ValueError, match="steps_override"):
            FleetEngine(data, sim, fl).run("_test_oversized_steps")
        with pytest.raises(ValueError, match="steps_override"):
            FleetEngine(data, sim, dataclasses.replace(
                fl, dynamics="bernoulli")).run("_test_oversized_steps")
    finally:
        API._REGISTRY.pop("_test_oversized_steps", None)


def test_roundplan_validate_steps_cap():
    sel = np.ones(4, bool)
    plan = RoundPlan.create(sel, sel, np.zeros(4, bool), 4.0,
                            steps_override=np.full(4, 8, np.int32))
    plan.validate(4)                     # no cap given: still fine
    with pytest.raises(ValueError, match="scans only 2"):
        plan.validate(4, local_steps=2)


def test_trainer_clamps_steps_and_loss_normalization():
    """Requesting more steps than the scan runs must behave exactly like
    requesting the scan length: same params, same cached steps, and a
    mean_loss divided by the steps actually executed (not the request)."""
    n = 8
    data = federated_classification(n, seed=3, n_per_client=16)
    sim = SimConfig(num_clients=n, local_steps=2, batch_size=8)
    trainer = make_trainer(sim, data)
    from repro.fl.classifier import init_classifier
    params = init_classifier(jax.random.key(0), dim=data.x.shape[-1],
                             num_classes=data.num_classes)
    caches = core.init_caches(params, n)
    stop = jnp.full((n,), 1 << 20, jnp.int32)
    ce = jnp.ones((n,), jnp.int32)
    resume = jnp.zeros((n,), bool)

    ref = trainer(params, caches, resume,
                  jnp.full((n,), 2, jnp.int32), stop, ce)
    over = trainer(params, caches, resume,
                   jnp.full((n,), 7, jnp.int32), stop, ce)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(over)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dynamics_trainer_charges_executed_steps_only():
    """On the device round path an oversized (device-array) override is
    clamped inside the fused trainer: workload, timing and losses match
    a local_steps request exactly."""

    def probe(times_box, steps):
        @register_policy("_test_steps_probe", allow_override=True)
        class Probe(Policy):
            waits_for_stragglers = True

            def plan(self, state, obs, rng):
                n = self.fl_cfg.num_clients
                sel = np.asarray(obs.online).copy()
                return state, RoundPlan.device(
                    obs.draw.online, obs.draw.online,
                    jnp.zeros(n, bool),
                    jnp.float32(max(int(sel.sum()), 1)),
                    steps_override=jnp.full(n, steps, jnp.int32))

            def observe(self, state, plan, report):
                times_box.append(np.asarray(report.durations))
                return state

    data, sim, fl = _setup(rounds=1, dynamics="bernoulli")
    out = {}
    for steps in (2, 9):
        box = []
        probe(box, steps)
        h = FleetEngine(data, sim, fl).run("_test_steps_probe",
                                           diagnostics=False)
        out[steps] = (box[0], h.wall_clock, h.comm_mb)
    API._REGISTRY.pop("_test_steps_probe", None)
    np.testing.assert_array_equal(out[2][0], out[9][0])
    assert out[2][1] == out[9][1] and out[2][2] == out[9][2]


# ---------------------------------------------------------------------------
# Bugfix: downloads to offline devices must not bill comm
# ---------------------------------------------------------------------------

class _PushToAll(Policy):
    """Selects online devices but marks *everyone* for distribution —
    the §4.4 server only reaches online devices, so offline 'downloads'
    must not be billed."""

    def init_state(self):
        return np.random.RandomState(self.sim_cfg.seed + 5)

    def plan(self, state, obs, rng):
        n = self.fl_cfg.num_clients
        online = np.asarray(obs.online)
        sel = np.zeros(n, bool)
        idx = np.flatnonzero(online)
        take = min(self.fl_cfg.clients_per_round, idx.size)
        sel[state.choice(idx, take, replace=False)] = True
        return state, RoundPlan.create(sel, np.ones(n, bool),
                                       np.zeros(n, bool), float(take))


def test_comm_counts_only_online_downloads_host_loop():
    API._REGISTRY["_test_push_all"] = _PushToAll
    try:
        data, sim, fl = _setup(rounds=1)
        h = FleetEngine(data, sim, fl).run("_test_push_all",
                                           diagnostics=False)
        online = Fleet(sim).online_mask()      # same seed ⇒ same draw
        expect = (int(online.sum()) + h.received[0]) * sim.model_mb
        assert h.comm_mb[0] == pytest.approx(expect, abs=0)
        assert h.comm_mb[0] < (len(online) + h.received[0]) * sim.model_mb
    finally:
        API._REGISTRY.pop("_test_push_all", None)


def test_comm_counts_only_online_downloads_device_loop():
    API._REGISTRY["_test_push_all"] = _PushToAll
    try:
        data, sim, fl = _setup(rounds=1, dynamics="bernoulli")
        engine = FleetEngine(data, sim, fl)
        h = engine.run("_test_push_all", diagnostics=False)
        online = np.asarray(engine._last_draw.online)
        expect = (int(online.sum()) + h.received[0]) * sim.model_mb
        assert h.comm_mb[0] == pytest.approx(expect, abs=0)
    finally:
        API._REGISTRY.pop("_test_push_all", None)


@pytest.mark.parametrize("dynamics", ["bernoulli_host", "bernoulli"])
def test_builtin_policies_never_distribute_offline(dynamics):
    """Every built-in's *raw* distribute mask is a subset of the round's
    online mask, so gating download accounting by online changes none of
    their (golden) comm trajectories — asserted against the un-gated
    plans instead of regenerating the goldens."""
    from repro.fl import make_policy
    data, sim, fl = _setup(rounds=3, dynamics=dynamics)
    fl = dataclasses.replace(fl, clients_per_round=16)  # push selection
    for name in sorted(p for p in available_policies()
                       if not p.startswith("_")):
        engine = FleetEngine(data, sim, fl)
        pol = make_policy(name, sim, fl, Fleet(sim))
        offline_downloads = []
        orig_plan = pol.plan

        def probing_plan(state, obs, rng, _orig=orig_plan):
            state, plan = _orig(state, obs, rng)
            offline_downloads.append(int(
                (np.asarray(plan.distribute)
                 & ~np.asarray(obs.online)).sum()))
            return state, plan

        pol.plan = probing_plan
        engine.run(pol, diagnostics=False)
        assert offline_downloads and not any(offline_downloads), name


# ---------------------------------------------------------------------------
# RoundPlan.device: structural checks without value sync
# ---------------------------------------------------------------------------

def test_roundplan_device_keeps_quorum_on_device():
    sel = jnp.ones(8, bool)
    p = RoundPlan.device(sel, sel, jnp.zeros(8, bool), jnp.float32(3.0))
    assert isinstance(p.quorum, jax.Array)
    assert getattr(p, "_validated", False)


def test_roundplan_device_rejects_structural_errors():
    sel = jnp.ones(8, bool)
    with pytest.raises(ValueError, match="must be bool"):
        RoundPlan.device(sel, sel, jnp.zeros(8, jnp.int32), 1.0)
    with pytest.raises(ValueError, match="quorum must be a scalar"):
        RoundPlan.device(sel, sel, jnp.zeros(8, bool),
                         jnp.ones(8, jnp.float32))
    with pytest.raises(ValueError, match="entries, expected"):
        RoundPlan.device(sel, sel[:4], jnp.zeros(8, bool), 1.0)
    with pytest.raises(ValueError, match="steps_override"):
        RoundPlan.device(sel, sel, jnp.zeros(8, bool), 1.0,
                         steps_override=jnp.ones(8, jnp.float32))
    with pytest.raises(ValueError, match="agg_weights"):
        RoundPlan.device(sel, sel, jnp.zeros(8, bool), 1.0,
                         agg_weights=jnp.ones(4, jnp.float32))
