"""Hypothesis property tests: the jitted device round cut
(``core.make_round_cut``) matches the numpy reference
(``core.host_round_cut``) bit-for-bit on float32 times.

The deterministic seeded sweep in tests/test_round_close.py covers the
same invariant without the hypothesis dependency; this module widens the
search space (randomized fleet sizes, inf-heavy times, fractional and
edge quorums, both straggler traits) where hypothesis is available.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

settings.register_profile("round_close", max_examples=60, deadline=None)
settings.load_profile("round_close")

DEADLINE = 600.0


def _times(n, inf_rate, seed, deadline=DEADLINE):
    rng = np.random.RandomState(seed)
    t = rng.uniform(1.0, 2.0 * deadline, n).astype(np.float32)
    t[rng.rand(n) < inf_rate] = np.inf
    return t


def _check(times, quorum, waits, deadline=DEADLINE):
    """Jitted cut == numpy reference under the ledger's billing rule
    (``deadline if capped else float(t_cut)``)."""
    times = np.asarray(times, np.float32)
    success = np.isfinite(times)
    t_ref, d_ref = core.host_round_cut(times, quorum, deadline, waits)
    cut = core.make_round_cut(times.shape[0], deadline, waits)
    t_dev, recv, capped = cut(jnp.asarray(times), quorum,
                              jnp.asarray(success))
    billed = deadline if bool(capped) else float(t_dev)
    assert billed == t_ref, (billed, t_ref)
    assert billed == d_ref
    # receive reference: float32 compare against the float32-nearest cast
    # of the host cut (the engine's receive semantics since PR 4)
    np.testing.assert_array_equal(
        np.asarray(recv), success & (times <= np.float32(t_ref)))


@given(st.integers(1, 64), st.floats(0.0, 1.0), st.data(),
       st.integers(0, 2 ** 31 - 1), st.booleans())
def test_cut_matches_host_reference(n, inf_rate, data, seed, waits):
    times = _times(n, inf_rate, seed)
    quorum = data.draw(st.one_of(
        st.integers(0, n).map(float),
        st.floats(0.0, float(n), allow_nan=False).map(
            lambda q: float(np.float32(q)))))
    _check(times, quorum, waits)


@given(st.integers(1, 48), st.integers(0, 2 ** 31 - 1), st.booleans())
def test_cut_quorum_edges_0_1_N(n, seed, waits):
    """The quorum corner cases: 0 (idle round), 1, exactly N, and one
    more than the finite count (unmet quorum)."""
    for inf_rate in (0.0, 0.5, 1.0):
        times = _times(n, inf_rate, seed)
        finite = int(np.isfinite(times).sum())
        for q in (0.0, 1.0, float(n), float(min(finite + 1, n))):
            _check(times, q, waits)


@given(st.integers(1, 48), st.floats(0.3, 1.0),
       st.integers(0, 2 ** 31 - 1))
def test_cut_async_last_arrival(n, inf_rate, seed):
    """Async designs close at the last arrival when the quorum is not
    met (and never receive anything past the deadline)."""
    times = _times(n, inf_rate, seed)
    finite = np.sort(times[np.isfinite(times)])
    _check(times, float(finite.size + 1), waits=False)


@given(st.integers(1, 32), st.integers(0, 2 ** 31 - 1), st.booleans(),
       st.sampled_from([5.0, 50.0, 600.0, 100.3, 600.1, 3599.9997]))
def test_cut_deadline_cap(n, seed, waits, deadline):
    """Deadline caps bill the exact float64 config value even when it is
    not float32-representable (100.3, 600.1, ...)."""
    times = _times(n, 0.3, seed, deadline=deadline)
    for q in (1.0, float(n)):
        _check(times, q, waits, deadline=deadline)
