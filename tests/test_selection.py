"""C2 unit tests: Algorithm 1 adaptive selection (Eqs. 2–3)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (freq_threshold, init_belief, priority,
                        select_participants, update_belief, decay_epsilon)


def _belief(dep):
    """Belief whose posterior mean is exactly ``dep`` (scaled counts)."""
    dep = jnp.asarray(dep, jnp.float32)
    n = 1000.0
    b = init_belief(dep.shape[0], 0.0, 0.0)
    return update_belief(b, dep * n, (1 - dep) * n)


def test_eq2_priority_penalty():
    """P = R·(Q/q)^(1(Q<q)·σ): only above-threshold devices penalized."""
    b = _belief([0.8, 0.8, 0.8])
    q = jnp.array([0, 5, 20])          # Q will be 10
    P = priority(b, q, 10.0, sigma=0.5)
    np.testing.assert_allclose(P[0], 0.8, atol=1e-3)   # q=0: no penalty
    np.testing.assert_allclose(P[1], 0.8, atol=1e-3)   # q<Q: no penalty
    np.testing.assert_allclose(P[2], 0.8 * (10 / 20) ** 0.5, atol=1e-3)


def test_eq3_threshold():
    assert float(freq_threshold(jnp.float32(320.0), 64)) == 5.0


def test_exploit_prefers_dependable():
    N = 32
    dep = jnp.linspace(0.05, 0.95, N)
    b = _belief(dep)
    res = select_participants(
        b, jnp.zeros(N, jnp.int32), jnp.ones(N, bool), jnp.ones(N, bool),
        jnp.float32(0.0), jnp.int32(8), jnp.float32(0.0), 0.5,
        jax.random.key(0))
    assert int(res.selected.sum()) == 8
    # with epsilon=0 and all explored: the top-8 dependable are chosen
    assert bool(res.selected[-8:].all())


def test_exploration_fraction():
    N = 40
    b = _belief(jnp.full((N,), 0.5))
    explored = jnp.arange(N) < 20
    res = select_participants(
        b, jnp.zeros(N, jnp.int32), explored, jnp.ones(N, bool),
        jnp.float32(100.0), jnp.int32(10), jnp.float32(0.5), 0.5,
        jax.random.key(1))
    assert int(res.selected.sum()) == 10
    assert int(res.explored_new.sum()) == 5          # ε·X = 5 new devices
    assert not bool((res.explored_new & explored).any())


def test_respects_online_mask():
    N = 16
    b = _belief(jnp.full((N,), 0.9))
    online = jnp.arange(N) % 2 == 0
    res = select_participants(
        b, jnp.zeros(N, jnp.int32), jnp.ones(N, bool), online,
        jnp.float32(0.0), jnp.int32(12), jnp.float32(0.0), 0.5,
        jax.random.key(2))
    assert not bool((res.selected & ~online).any())
    assert int(res.selected.sum()) == 8              # only 8 online


def test_frequency_balancing_rotates_selection():
    """Devices over the frequency threshold lose priority (paper's bias
    mitigation): a high-count dependable device ranks below a fresh one."""
    b = _belief(jnp.array([0.9, 0.85]))
    q = jnp.array([50, 1])
    P = priority(b, q, 5.0, sigma=0.5)
    assert float(P[1]) > float(P[0])


def test_epsilon_decay_floor():
    e = jnp.float32(0.9)
    for _ in range(200):
        e = decay_epsilon(e, 0.98, 0.2)
    np.testing.assert_allclose(float(e), 0.2, atol=1e-6)
