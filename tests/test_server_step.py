"""Packed aggregation + fused server round step: parity vs the leaf-wise
path (fed_aggregate / write_cache / clear_cache sequence the runner used
before the fusion)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.configs.base import FLConfig
from repro.kernels.fed_agg.ops import fed_agg_packed
from repro.kernels.fed_agg.ref import fed_agg_ref


def _tree(key, C=None, dtypes=(jnp.float32, jnp.float32, jnp.float32)):
    """Ragged-leaf pytree; stacked (C, ...) when C is given."""
    shapes = [(7,), (3, 5), (2, 2, 2)]
    ks = jax.random.split(key, len(shapes))
    lead = () if C is None else (C,)
    return {
        f"l{i}": jax.random.normal(k, lead + s).astype(dt)
        for i, (k, s, dt) in enumerate(zip(ks, shapes, dtypes))
    }


def test_pack_unpack_roundtrip_mixed_dtypes():
    t = _tree(jax.random.key(0),
              dtypes=(jnp.float32, jnp.bfloat16, jnp.float32))
    layout = core.pack_layout(t)
    assert layout.dim == 7 + 15 + 8
    vec = core.pack(t, layout)
    assert vec.shape == (30,) and vec.dtype == jnp.float32
    back = core.unpack(vec, layout)
    for k in t:
        assert back[k].dtype == t[k].dtype
        np.testing.assert_allclose(np.asarray(back[k], np.float32),
                                   np.asarray(t[k], np.float32))


def test_pack_stacked_matches_per_leaf_ravel():
    C = 5
    t = _tree(jax.random.key(1), C=C)
    layout = core.pack_layout(_tree(jax.random.key(1)))
    buf = core.pack_stacked(t, layout)
    assert buf.shape == (C, layout.dim)
    for i, k in enumerate(sorted(t)):
        off, n = layout.offsets[i], layout.sizes[i]
        np.testing.assert_array_equal(np.asarray(buf[:, off:off + n]),
                                      np.asarray(t[k]).reshape(C, -1))


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_packed_matches_leafwise(impl, dtype):
    """Packed whole-model aggregation == leaf-wise fed_aggregate, for
    ragged leaves and C/D not multiples of the kernel block sizes."""
    C = 5                                    # not a multiple of block_c
    g = _tree(jax.random.key(2), dtypes=(dtype,) * 3)
    c = _tree(jax.random.key(3), C=C, dtypes=(dtype,) * 3)
    w = jnp.array([0.5, 0.0, 2.0, 1.0, 0.25])
    want = core.fed_aggregate(g, c, w)
    got = core.fed_aggregate_packed(g, c, w, impl=impl,
                                    block_c=4, block_d=16)
    tol = 1e-6 if dtype == jnp.float32 else 1e-2
    for k in want:
        assert got[k].dtype == want[k].dtype
        np.testing.assert_allclose(np.asarray(got[k], np.float32),
                                   np.asarray(want[k], np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_packed_zero_weights_keeps_global(impl):
    g = _tree(jax.random.key(4))
    c = _tree(jax.random.key(5), C=3)
    out = core.fed_aggregate_packed(g, c, jnp.zeros((3,)), impl=impl)
    for k in g:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(g[k]))


def test_fed_agg_packed_impl_parity():
    C, D = 6, 50                             # both off the block grid
    u = jax.random.normal(jax.random.key(6), (C, D))
    w = jax.random.uniform(jax.random.key(7), (C,))
    want = fed_agg_ref(u, w)
    for impl in ("xla", "pallas_interpret"):
        got = fed_agg_packed(u, w, impl=impl, block_c=4, block_d=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


def _old_leafwise_round(global_params, caches, final, cache_p, cached_steps,
                        selected, fail, received, resume, n_samples, rnd,
                        local_steps):
    """The pre-fusion server path, verbatim: host-driven leaf-wise ops."""
    stamp0 = np.asarray(caches.round_stamp)
    base_stale = np.where(resume & (stamp0 >= 0),
                          np.maximum(rnd - stamp0, 0), 0)
    w = core.aggregation_weights(jnp.asarray(received), n_samples=n_samples,
                                 staleness=jnp.asarray(base_stale,
                                                       jnp.float32),
                                 staleness_discount=1.0)
    global_params = core.fed_aggregate(global_params, final, w)
    prior_steps = np.round(np.asarray(caches.progress)
                           * local_steps).astype(np.int32)
    total_cached = np.where(resume, prior_steps, 0) + np.asarray(cached_steps)
    write = selected & fail & (total_cached > 0)
    base_round = np.where(resume & (stamp0 >= 0), stamp0, rnd)
    caches = core.write_cache(
        caches, jnp.asarray(write), cache_p,
        jnp.asarray(total_cached / max(local_steps, 1)).astype(jnp.float32),
        jnp.asarray(base_round, jnp.int32))
    caches = core.clear_cache(caches, jnp.asarray(received))
    return global_params, caches


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_server_round_step_matches_leafwise_3round_smoke(impl):
    """Acceptance: the fused jitted step reproduces the old leaf-wise
    sequence (weights -> aggregate -> cache write/clear) within 1e-5 over
    a 3-round run with failures, resumes and empty rounds."""
    N, local_steps = 8, 4
    rng = np.random.RandomState(0)
    template = _tree(jax.random.key(8))
    step = core.make_server_round_step(template, local_steps=local_steps,
                                       agg_impl=impl, block_c=4, block_d=16)
    g_new = g_old = template
    caches_new = caches_old = core.init_caches(template, N)
    n_samples = jnp.full((N,), 32.0)
    for rnd in range(3):
        key = jax.random.key(100 + rnd)
        final = _tree(key, C=N)
        cache_p = jax.tree.map(lambda a: a * 0.5, final)
        cached_steps = rng.randint(0, local_steps + 1, N).astype(np.int32)
        selected = rng.rand(N) < 0.8
        fail = selected & (rng.rand(N) < 0.4)
        received = selected & ~fail
        if rnd == 1:
            received[:] = False                    # empty round
        resume = selected & (rng.rand(N) < 0.5)
        g_new, caches_new = step(
            g_new, caches_new, final, cache_p, jnp.asarray(cached_steps),
            jnp.asarray(selected), jnp.asarray(fail), jnp.asarray(received),
            jnp.asarray(resume), n_samples, jnp.ones((N,), jnp.float32),
            rnd)
        g_old, caches_old = _old_leafwise_round(
            g_old, caches_old, final, cache_p, cached_steps, selected,
            fail, received, resume, n_samples, rnd, local_steps)
    for a, b in zip(jax.tree.leaves(g_new), jax.tree.leaves(g_old)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    for a, b in zip(jax.tree.leaves(caches_new), jax.tree.leaves(caches_old)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_run_fl_agg_impl_parity_smoke():
    """End-to-end: 3 FLUDE rounds with the Pallas interpret kernel match
    the XLA packed path."""
    from repro.data.synthetic import federated_classification
    from repro.fl import SimConfig, run_fl

    data = federated_classification(16, seed=0, n_per_client=32)
    sim = SimConfig(num_clients=16, rounds=3, local_steps=4)
    fl = FLConfig(num_clients=16, clients_per_round=8)
    h_x = run_fl("flude", data, sim, dataclasses.replace(fl,
                                                         agg_impl="xla"))
    h_p = run_fl("flude", data, sim,
                 dataclasses.replace(fl, agg_impl="pallas_interpret"))
    np.testing.assert_allclose(h_x.acc, h_p.acc, atol=1e-5)
    for a, b in zip(jax.tree.leaves(h_x.final_params),
                    jax.tree.leaves(h_p.final_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
